"""Paper Table 1 proxy — score + training-throughput for the paper's two
network sizes.

Real ALE scores are not reproducible in this container (no emulator); the
Table-1 claims we CAN check are (a) the system trains stably with the
paper's §5.1 hyperparameters (n_e=32, t_max=5, RMSProp ε=0.1 decay=0.99,
clip 40, lr 0.0224), and (b) the relative cost of arch_nips vs arch_nature —
the paper reports a ~22% timesteps/s drop on GPU moving to the bigger net.
We report both nets' steps/s on the 84×84×4 pixel pipeline and the
projected hours to the paper's N_max = 1.15e8 timesteps.
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.configs import PipelineConfig, get_config
from repro.core import ParallelRL
from repro.core.agents import PAACAgent, PAACConfig
from repro.envs import AtariLike, FrameStack
from repro.optim import constant
from repro.pipeline import PipelinedRL

PAPER_NMAX = 1.15e8


def run(n_e: int = 32, iters: int = 8, pipelined: bool = True,
        pipelined_actors: int = 4):
    """Per-arch steps/s for the synchronous backend and (optionally) the
    asynchronous pipeline on the same JAX-native env — one actor, and
    ``pipelined_actors`` replicas with the env axis split between them
    (the actor-count scaling column). On a single shared device the
    pipelined columns mainly measure overlap overhead (both halves are
    compute-bound); the host-env win is measured by
    ``fig2_time_split.run_pipelined_host`` / ``run_multi_actor_host``."""
    results = {}
    for arch in ("paac_nips", "paac_nature"):
        env = FrameStack(AtariLike(n_e), n=4)
        cfg = get_config(arch).replace(
            obs_shape=env.obs_shape, num_actions=env.num_actions
        )
        # paper §5.1 hyperparameters
        agent = PAACAgent(cfg, PAACConfig(gamma=0.99, entropy_beta=0.01, t_max=5))
        rl = ParallelRL(env, agent, optimizer="rmsprop",
                        lr_schedule=constant(0.0224))
        rl.run(2)  # compile + warmup
        res = rl.run(iters)
        tps = res.timesteps_per_sec
        hours = PAPER_NMAX / max(tps, 1e-9) / 3600
        results[arch] = tps
        derived = (
            f"steps_per_s={tps:.0f};proj_hours_to_115M={hours:.1f};"
            f"loss={res.mean_metrics['loss']:.4f}"
        )
        if pipelined:
            env_p = FrameStack(AtariLike(n_e), n=4)
            prl = PipelinedRL(env_p, agent, optimizer="rmsprop",
                              lr_schedule=constant(0.0224),
                              pipeline=PipelineConfig(queue_depth=2))
            prl.run(2)
            pres = prl.run(iters)
            results[arch + "_pipelined"] = pres.timesteps_per_sec
            derived += (
                f";steps_per_s_pipelined={pres.timesteps_per_sec:.0f}"
                f";pipelined_ratio={pres.timesteps_per_sec / max(tps, 1e-9):.2f}"
            )
            # actor-count scaling column: env axis split across replicas
            env_m = FrameStack(AtariLike(n_e), n=4)
            mrl = PipelinedRL(
                env_m, agent, optimizer="rmsprop",
                lr_schedule=constant(0.0224),
                pipeline=PipelineConfig(queue_depth=pipelined_actors,
                                        num_actors=pipelined_actors),
            )
            mrl.run(pipelined_actors)
            mres = mrl.run(iters * pipelined_actors)  # same total timesteps
            results[f"{arch}_pipelined{pipelined_actors}"] = \
                mres.timesteps_per_sec
            derived += (
                f";steps_per_s_actors{pipelined_actors}="
                f"{mres.timesteps_per_sec:.0f}"
                f";actors{pipelined_actors}_ratio="
                f"{mres.timesteps_per_sec / max(tps, 1e-9):.2f}"
            )
        emit(
            f"table1_throughput/{arch}/ne={n_e}",
            1e6 * n_e * 5 / max(tps, 1e-9),
            derived,
        )
    drop = 100 * (1 - results["paac_nature"] / results["paac_nips"])
    emit("table1_throughput/nature_vs_nips_drop", 0.0,
         f"steps_per_s_drop_pct={drop:.0f} (paper GPU: ~22%)")
    return results


if __name__ == "__main__":
    run()
