"""Serving-plane benchmark — continuous batching vs lockstep waves.

The claim (the serving twin of the paper's Fig. 2 utilization argument):
with mixed generation lengths, lockstep generate-then-drain idles every
finished row until the *longest* request in the wave completes, while
continuous batching backfills freed slots immediately. Both modes run
the SAME fixed-width jitted decode step and pay the SAME exact-length
batch-1 prefills, so the aggregate-tokens/s ratio isolates pure
occupancy — nothing else differs.

Per reduced-zoo arch (dense GQA, MoE, SSM) the job reports aggregate
tokens/s, request-latency p50/p99, and decode-step counts for both
modes, plus the continuous/lockstep speedup. ``fig2_serve`` (see
``benchmarks/run.py``) writes the grid to ``BENCH_serve.json``.

The workload is a burst (all requests queued up front): open-loop
arrival pacing only adds idle time to both modes equally; a burst
measures capacity, which is what the speedup claim is about.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit

DEFAULT_ARCHS = ("qwen2-7b", "dbrx-132b", "mamba2-370m")


def _run_mode(engine, requests, *, continuous: bool):
    """Feed ``requests`` as a burst through a fresh scheduler; returns
    (wall_s, tokens, p50_ms, p99_ms, steps)."""
    from repro.pipeline.queue import TrajectoryQueue
    from repro.serving import Scheduler

    queue = TrajectoryQueue(depth=len(requests) + 2)
    sched = Scheduler(engine, queue, continuous=continuous)
    t0 = time.perf_counter()
    for r in requests:
        r.t_submit = time.perf_counter()
        queue.put(r)
    queue.producer_done()
    done = sched.run()
    wall = time.perf_counter() - t0
    bad = [r for r in done if r.status != "done"]
    if bad:
        raise RuntimeError(
            f"{len(bad)} requests failed: {bad[0].rid}: {bad[0].error}")
    lat = np.asarray([r.latency_s for r in done], np.float64) * 1e3
    tokens = int(sum(r.n_generated for r in done))
    return wall, tokens, float(np.percentile(lat, 50)), \
        float(np.percentile(lat, 99)), sched.steps


def run(archs=DEFAULT_ARCHS, n_requests: int = 48, slots: int = 6,
        prompt_lens=(4, 8), gen_range=(1, 96), seed: int = 0,
        repeats: int = 3):
    """Continuous vs lockstep over an identical burst workload per arch.

    Trials are **paired**: each repeat runs one continuous trial and one
    lockstep trial back to back over the same workload, and the speedup
    is the median of the per-pair ratios. Host speed on a small shared
    VM drifts on a seconds timescale, so comparing modes measured in
    separate time windows confounds drift with the occupancy effect the
    bench exists to isolate; adjacent trials share the drift and the
    ratio cancels it. Per-mode stats (tok/s, p50/p99) come from each
    mode's best trial."""
    import jax

    from repro.configs import get_config
    from repro.models import init_policy
    from repro.serving import DecodeEngine, make_requests

    max_len = max(prompt_lens) + gen_range[1]
    results = {}
    for arch in archs:
        cfg = get_config(arch).reduced()
        params = init_policy(jax.random.PRNGKey(seed), cfg)
        engines = {}
        for mode in ("continuous", "lockstep"):
            engine = DecodeEngine(cfg, params, max_slots=slots,
                                  max_len=max_len)
            # warmup pass at full workload size compiles every prefill
            # length + the step; the engine is reusable after a run (all
            # slots released at drain)
            _run_mode(engine, make_requests(
                n_requests, seed=seed + 1, vocab=cfg.vocab_size,
                prompt_lens=prompt_lens, gen_range=gen_range),
                continuous=(mode == "continuous"))
            engines[mode] = engine
        best = {}
        ratios = []
        for _ in range(max(1, repeats)):
            pair = {}
            for mode in ("continuous", "lockstep"):
                reqs = make_requests(n_requests, seed=seed + 1,
                                     prompt_lens=prompt_lens,
                                     gen_range=gen_range,
                                     vocab=cfg.vocab_size)
                trial = _run_mode(engines[mode], reqs,
                                  continuous=(mode == "continuous"))
                pair[mode] = trial
                prev = best.get(mode)
                if prev is None or trial[1] / trial[0] > prev[1] / prev[0]:
                    best[mode] = trial
            ratios.append((pair["continuous"][1] / pair["continuous"][0])
                          / (pair["lockstep"][1] / pair["lockstep"][0]))
        engines.clear()
        grid = {}
        for mode in ("continuous", "lockstep"):
            wall, tokens, p50, p99, steps = best[mode]
            grid[mode] = {
                "tok_per_s": round(tokens / wall, 2),
                "p50_ms": round(p50, 2),
                "p99_ms": round(p99, 2),
                "decode_steps": steps,
                "tokens": tokens,
                "wall_s": round(wall, 4),
            }
        grid["speedup"] = round(float(np.median(ratios)), 3)
        grid["n_requests"] = n_requests
        grid["slots"] = slots
        results[arch] = grid
        for mode in ("continuous", "lockstep"):
            g = grid[mode]
            emit(f"serve/{arch}/{mode}", 1e6 / max(g["tok_per_s"], 1e-9),
                 f"tok_per_s={g['tok_per_s']};p50_ms={g['p50_ms']};"
                 f"p99_ms={g['p99_ms']};steps={g['decode_steps']}")
        emit(f"serve/{arch}/speedup", 0.0,
             f"continuous_over_lockstep={grid['speedup']:.3f}")
    return results
