"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Three profiles:

* ``quick`` (default) — CPU-scale versions of every job.
* ``full`` (or ``--full``) — the longer sweeps.
* ``ci`` — tiny shapes for the CI bench-smoke: every job must *run*, not
  produce meaningful timings. In this profile failures are fatal (no
  ERROR-row swallowing) so a broken benchmark or a silently-rotted
  ``BENCH_pipeline.json`` emission fails the build.

Each job is declared exactly once in ``PARAMS`` with its kwargs per
profile, so a new benchmark cannot land in ``quick``/``full`` while
silently missing from the CI smoke: any job without a ``ci`` column must be
listed in ``CI_EXCLUDED`` (with a reason), or the harness refuses to start.

The ``fig2_ring``, ``fig2_procs``, ``fig2_mesh``, ``fig2_telemetry`` and
``fig2_replay`` jobs additionally write ``BENCH_pipeline.json`` (path via
``--out-json``): the machine-readable steps/s grids for sync vs host-queue
vs device-ring (``steps_per_s``), thread vs process actor backends on a
GIL-holding env (``process_actors``), the mesh plane at 1/2/4 devices
(``mesh_ring``), span capture on vs off (``telemetry_overhead`` — the
proof the always-on instrumentation stays within its 2% budget), and the
replay plane's pipelined replay-DQN vs sync scan-DQN grid
(``replay_ring``) — the perf trajectory future PRs diff against.

``fig2_serve`` writes its own file, ``BENCH_serve.json`` (path via
``--out-serve-json``): per reduced-zoo arch, aggregate tokens/s and
request-latency p50/p99 for continuous batching vs lockstep waves over
an identical mixed-length burst, plus the continuous/lockstep speedup.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

# job -> profile -> kwargs. One row per benchmark; a missing profile key
# means the job doesn't run under that profile (CI absences must be
# justified in CI_EXCLUDED below).
PARAMS = {
    "kernels": {"quick": {}, "full": {}, "ci": {}},
    "table1": {
        "quick": {"iters": 8}, "full": {"iters": 40}, "ci": {"iters": 2},
    },
    "fig2": {
        "quick": {"n_envs_list": (16, 32, 64)},
        "full": {"n_envs_list": (16, 32, 64, 128)},
        "ci": {"n_envs_list": (8,), "iters": 2},
    },
    "fig2_pipelined": {
        "quick": {"iters": 12}, "full": {"iters": 40},
        "ci": {"n_e": 4, "n_w": 2, "obs_dim": 32, "width": 64, "iters": 3,
               "warmup": 1},
    },
    "fig2_actors": {
        "quick": {"iters": 16}, "full": {"iters": 48},
        "ci": {"n_e": 4, "n_w": 4, "obs_dim": 32, "width": 64, "iters": 4,
               "actor_counts": (1, 2), "warmup": 1},
    },
    "fig2_ring": {
        "quick": {}, "full": {"iters": 160, "repeats": 3},
        "ci": {"n_e": 8, "obs_dim": 256, "width": 16, "t_max": 2, "iters": 4,
               "warmup": 1, "repeats": 1, "actor_counts": (1, 2)},
    },
    "fig2_procs": {
        "quick": {"iters": 12}, "full": {"iters": 40},
        # tiny but end-to-end: the process backend really spawns workers,
        # ships specs, and round-trips shm payloads under the ci profile
        "ci": {"n_e": 2, "n_w": 2, "obs_dim": 16, "width": 32, "t_max": 2,
               "iters": 3, "actor_counts": (1, 2), "spin": 300, "warmup": 1},
    },
    "fig2_replay": {
        "quick": {}, "full": {"iters": 120, "repeats": 3},
        # tiny but end-to-end: the replay-plane DQN really runs actor
        # threads against a ReplayRing, and the sync scan-DQN baseline
        # really carries its transition buffer through the scan
        "ci": {"n_e": 4, "obs_dim": 128, "width": 16, "t_max": 2, "iters": 4,
               "warmup": 1, "repeats": 1, "actor_counts": (1, 2),
               "replay_capacity": 4, "sync_capacity": 64},
    },
    "fig2_mesh": {
        "quick": {}, "full": {"iters": 120, "repeats": 3},
        # the ci profile runs whatever mesh counts the visible devices
        # allow: mesh=1 on a plain runner, the full 1/2/4 grid under the
        # mesh-smoke job's forced 4 host devices
        "ci": {"n_e": 2, "obs_dim": 32, "width": 16, "t_max": 8, "iters": 4,
               "warmup": 1, "repeats": 1},
    },
    "fig2_telemetry": {
        "quick": {}, "full": {"iters": 60, "repeats": 5},
        # tiny but end-to-end: both planes really run with capture on and
        # off, and the trace cross-check reads a real exported span ring
        "ci": {"n_e": 4, "obs_dim": 64, "width": 16, "t_max": 2, "iters": 3,
               "warmup": 1, "repeats": 1, "pair_n": 2_000},
    },
    "fig2_serve": {
        "quick": {}, "full": {"n_requests": 96, "slots": 8},
        # tiny but end-to-end: both scheduling modes really lease slots,
        # prefill exact lengths, and decode on the fixed-width jitted step
        "ci": {"archs": ("qwen2-7b",), "n_requests": 4, "slots": 2,
               "prompt_lens": (4,), "gen_range": (2, 6)},
    },
    "fig34": {
        "quick": {"n_envs_list": (16, 32, 64), "total_steps": 30_000},
        "full": {"n_envs_list": (16, 32, 64, 128, 256),
                 "total_steps": 120_000},
        "ci": {"n_envs_list": (8,), "total_steps": 2_000},
    },
    "baselines": {
        "quick": {"iters": 150}, "full": {"iters": 400}, "ci": {"iters": 10},
    },
    "roofline": {"quick": {}, "full": {}},
}

# jobs deliberately absent from the ci profile, with the reason on record
CI_EXCLUDED = {
    "roofline": "analyses dry-run artifacts CI doesn't generate",
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="",
                    help="run only these jobs (comma-separated names)")
    ap.add_argument("--profile", choices=("quick", "full", "ci"), default="")
    ap.add_argument("--out-json", default="BENCH_pipeline.json",
                    help="where fig2_ring writes the pipeline steps/s grid")
    ap.add_argument("--out-serve-json", default="BENCH_serve.json",
                    help="where fig2_serve writes the serving grid")
    args, _ = ap.parse_known_args()
    profile = args.profile or ("full" if args.full else "quick")
    strict = profile == "ci"

    missing = [n for n, p in PARAMS.items()
               if "ci" not in p and n not in CI_EXCLUDED]
    if missing:
        raise SystemExit(
            f"benchmarks {missing} have no ci profile and no CI_EXCLUDED "
            "entry — give them tiny ci kwargs or justify the exclusion"
        )

    from benchmarks import (
        baselines,
        fig2_time_split,
        fig34_ne_scaling,
        kernels_bench,
        roofline,
        table1_throughput,
    )

    ring_result = {}
    procs_result = {}
    mesh_result = {}
    telemetry_result = {}
    replay_result = {}
    serve_result = {}

    def fig2_ring_job(**kw):
        ring_result.update(fig2_time_split.run_device_ring(**kw))

    def fig2_procs_job(**kw):
        procs_result.update(fig2_time_split.run_process_actors(**kw))

    def fig2_mesh_job(**kw):
        mesh_result.update(fig2_time_split.run_mesh_ring(**kw))

    def fig2_telemetry_job(**kw):
        telemetry_result.update(fig2_time_split.run_telemetry_overhead(**kw))

    def fig2_replay_job(**kw):
        replay_result.update(fig2_time_split.run_replay_ring(**kw))

    def fig2_serve_job(**kw):
        from benchmarks import serve_bench
        serve_result.update(serve_bench.run(**kw))

    runners = {
        "kernels": kernels_bench.run,
        "table1": table1_throughput.run,
        "fig2": fig2_time_split.run,
        "fig2_pipelined": fig2_time_split.run_pipelined_host,
        "fig2_actors": fig2_time_split.run_multi_actor_host,
        "fig2_ring": fig2_ring_job,
        "fig2_procs": fig2_procs_job,
        "fig2_mesh": fig2_mesh_job,
        "fig2_telemetry": fig2_telemetry_job,
        "fig2_replay": fig2_replay_job,
        "fig2_serve": fig2_serve_job,
        "fig34": fig34_ne_scaling.run,
        "baselines": baselines.run,
        "roofline": roofline.run,
    }

    print("name,us_per_call,derived")
    only = [n for n in args.only.split(",") if n] if args.only else None
    for name, per_profile in PARAMS.items():
        if only is not None and name not in only:
            continue
        if profile not in per_profile:
            continue
        try:
            runners[name](**per_profile[profile])
        except Exception as e:
            if strict:
                raise  # ci profile: a broken benchmark fails the build
            # keep the harness going; record the failure
            print(f"{name},0.0,ERROR={type(e).__name__}:{e}", file=sys.stdout)

    if (ring_result or procs_result or mesh_result or telemetry_result
            or replay_result):
        # merge-on-write: a partial run (e.g. the mesh-smoke job's
        # `--only fig2_mesh` under forced host devices) refreshes only its
        # own grid and leaves the other committed rows intact. Each grid
        # carries its own profile/unix_time stamp so a partial refresh
        # cannot misattribute the grids it did NOT regenerate; the
        # file-level stamp belongs to the fig2_ring grid (whose rows live
        # at the top level for backward compatibility).
        stamp = {"profile": profile, "unix_time": time.time()}
        payload = {}
        try:
            with open(args.out_json) as f:
                payload = json.load(f)
        except (OSError, ValueError):
            pass
        payload["bench"] = "pipeline_planes"
        if ring_result:
            payload.update({**stamp, **ring_result})
        if procs_result:
            # the actor-backend grid (run_process_actors): thread vs
            # process steps/s over a GIL-holding Python env
            payload["process_actors"] = {**procs_result, **stamp}
        if mesh_result:
            # the mesh-plane grid (run_mesh_ring): steps/s at 1/2/4 devices
            payload["mesh_ring"] = {**mesh_result, **stamp}
        if telemetry_result:
            # span capture on/off steps/s + trace/accounting cross-check
            # (run_telemetry_overhead): proof the always-on instrumentation
            # stays within the 2% budget
            payload["telemetry_overhead"] = {**telemetry_result, **stamp}
        if replay_result:
            # the replay-plane grid (run_replay_ring): pipelined replay-DQN
            # vs the synchronous scan-based DQN at 1/2/4 actors
            payload["replay_ring"] = {**replay_result, **stamp}
        with open(args.out_json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"fig2_ring/json,0.0,wrote={args.out_json}")

    if serve_result:
        # the serving grid gets its own file: its rows are per-arch
        # continuous/lockstep dicts, a different shape from the pipeline
        # steps/s grids, and the serve-smoke CI job asserts on it alone
        payload = {"bench": "serving_plane", "profile": profile,
                   "unix_time": time.time(), "serve": serve_result}
        with open(args.out_serve_json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"fig2_serve/json,0.0,wrote={args.out_serve_json}")


if __name__ == "__main__":
    main()
