"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Quick CPU-scale versions; pass
--full for the longer sweeps.
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="")
    args, _ = ap.parse_known_args()

    from benchmarks import (
        baselines,
        fig2_time_split,
        fig34_ne_scaling,
        kernels_bench,
        roofline,
        table1_throughput,
    )

    print("name,us_per_call,derived")
    jobs = {
        "kernels": lambda: kernels_bench.run(),
        "table1": lambda: table1_throughput.run(iters=8 if not args.full else 40),
        "fig2": lambda: fig2_time_split.run(
            n_envs_list=(16, 32, 64) if not args.full else (16, 32, 64, 128)
        ),
        "fig2_pipelined": lambda: fig2_time_split.run_pipelined_host(
            iters=12 if not args.full else 40
        ),
        "fig2_actors": lambda: fig2_time_split.run_multi_actor_host(
            iters=16 if not args.full else 48
        ),
        "fig34": lambda: fig34_ne_scaling.run(
            n_envs_list=(16, 32, 64) if not args.full else (16, 32, 64, 128, 256),
            total_steps=30_000 if not args.full else 120_000,
        ),
        "baselines": lambda: baselines.run(iters=150 if not args.full else 400),
        "roofline": lambda: roofline.run(),
    }
    for name, job in jobs.items():
        if args.only and args.only != name:
            continue
        try:
            job()
        except Exception as e:  # keep the harness going; record the failure
            print(f"{name},0.0,ERROR={type(e).__name__}:{e}", file=sys.stdout)


if __name__ == "__main__":
    main()
