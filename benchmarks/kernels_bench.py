"""Kernel-layer benchmark: XLA chunked attention vs naive materialization,
and the batched n-step return path vs a per-env host loop.

(Pallas kernels themselves run in interpret mode on CPU, so wall-times are
not meaningful for them here — their win is validated structurally in the
roofline analysis. These benches quantify the algorithmic choices that ARE
measurable on CPU.)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_call
from repro.core.returns import n_step_returns, vtrace_returns
from repro.models.attention import chunked_attention, naive_attention


def run():
    key = jax.random.PRNGKey(0)
    B, S, H, D = 2, 1024, 8, 64
    q = jax.random.normal(key, (B, S, H, D), jnp.float32)
    k = jax.random.normal(key, (B, S, H, D), jnp.float32)
    v = jax.random.normal(key, (B, S, H, D), jnp.float32)
    f_chunk = jax.jit(lambda q, k, v: chunked_attention(q, k, v, block_k=256))
    f_naive = jax.jit(lambda q, k, v: naive_attention(q, k, v))
    t_c = time_call(f_chunk, q, k, v, iters=5)
    t_n = time_call(f_naive, q, k, v, iters=5)
    emit("kernels/chunked_attention_1k", t_c, f"naive_us={t_n:.0f};ratio={t_n/t_c:.2f}")

    E, T = 256, 128
    r = jax.random.normal(key, (E, T))
    d = jax.random.bernoulli(key, 0.1, (E, T))
    b = jax.random.normal(key, (E,))
    f_batched = jax.jit(lambda r, d, b: n_step_returns(r, d, b, 0.99))
    t_b = time_call(f_batched, r, d, b, iters=10)
    emit("kernels/nstep_returns_batched", t_b,
         f"actors={E};t_max={T};throughput={E*T/(t_b/1e6):.2e}_returns_per_s")

    # full V-trace (the pipelined learner's targets) vs the plain recursion:
    # the clipped-importance corrections cost ~2 extra elementwise passes
    vals = jax.random.normal(key, (E, T))
    rho = jnp.exp(0.3 * jax.random.normal(key, (E, T)))
    f_vtrace = jax.jit(
        lambda r, d, v, b, w: vtrace_returns(r, d, v, b, w, 0.99, 1.0, 1.0)
    )
    t_v = time_call(f_vtrace, r, d, vals, b, rho, iters=10)
    emit("kernels/vtrace_returns_batched", t_v,
         f"actors={E};t_max={T};nstep_us={t_b:.0f};overhead={t_v/t_b:.2f}x")


if __name__ == "__main__":
    run()
