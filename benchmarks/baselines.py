"""Baseline comparison — the paper's §1/§3 stability argument.

PAAC vs the two failure modes it eliminates:
* A3C-sim  (stale gradients, delay=8)
* GA3C-sim (policy lag, delay=8)
and DQN (the off-policy member of the framework family).

Metric: reward per iteration after a fixed training budget on Catch.
Expected qualitative result (the paper's claim): PAAC >= lagged variants;
large staleness hurts convergence.
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.configs import get_config
from repro.core import ParallelRL
from repro.core.agents import (
    DQNAgent,
    DQNConfig,
    LaggedConfig,
    LaggedPAACAgent,
    PAACAgent,
    PAACConfig,
)
from repro.envs import Catch
from repro.optim import constant


def run(iters: int = 300, n_e: int = 32, delay: int = 8):
    env = Catch(n_e, rows=6, cols=5)
    cfg = get_config("paac_vector").replace(
        obs_shape=env.obs_shape, num_actions=env.num_actions
    )
    agents = {
        "paac": (PAACAgent(cfg, PAACConfig(t_max=5)), "rmsprop", 0.01),
        "a3c_sim_stale_grad": (
            LaggedPAACAgent(cfg, LaggedConfig(t_max=5, delay=delay), "grad"),
            "rmsprop", 0.01,
        ),
        "ga3c_sim_policy_lag": (
            LaggedPAACAgent(cfg, LaggedConfig(t_max=5, delay=delay), "act"),
            "rmsprop", 0.01,
        ),
        "dqn": (
            DQNAgent(cfg, DQNConfig(t_max=5, batch_size=64, eps_steps=500)),
            "adam", 1e-3,
        ),
    }
    scores = {}
    for name, (agent, opt, lr) in agents.items():
        rl = ParallelRL(env, agent, optimizer=opt, lr_schedule=constant(lr), seed=0)
        rl.run(iters)
        final = rl.run(40).mean_metrics["reward_sum"]
        scores[name] = final
        emit(f"baselines/{name}", 0.0, f"final_reward_per_iter={final:.3f}")
    emit(
        "baselines/paac_vs_stale",
        0.0,
        f"paac={scores['paac']:.3f};stale={scores['a3c_sim_stale_grad']:.3f};"
        f"lag={scores['ga3c_sim_policy_lag']:.3f}",
    )
    return scores


if __name__ == "__main__":
    run()
