"""Regenerate the generated sections of EXPERIMENTS.md from dry-run JSONs.

Keeps hand-written prose; replaces the blocks between
``<!-- BEGIN GENERATED: <name> -->`` / ``<!-- END GENERATED: <name> -->``.
"""
from __future__ import annotations

import glob
import json
import os
import re
import sys
from typing import Dict, List


def load(path="experiments/dryrun") -> List[Dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(path, "*.json"))):
        with open(f) as fh:
            r = json.load(fh)
        r["_file"] = os.path.basename(f)
        out.append(r)
    return out


def fmt_bytes(x) -> str:
    if x is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(x) < 1024:
            return f"{x:.1f}{unit}"
        x /= 1024
    return f"{x:.1f}PB"


def dryrun_table(reports: List[Dict]) -> str:
    lines = [
        "| arch | shape | mesh | compile s | flops/chip | bytes/chip | "
        "wire bytes/chip | collectives (ag/ar/rs/a2a/cp) | status |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in reports:
        if r.get("mla_absorb") or r.get("sharding_mode", "fsdp_tp") != "fsdp_tp":
            continue  # perf variants listed in §Perf, not the baseline table
        if "skipped" in r:
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | — | — | — | "
                f"SKIP: {r['skipped']} |"
            )
            continue
        if "error" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | ? | — | — | — | — | — | FAIL |")
            continue
        c = r["collective_counts"]
        cc = "/".join(
            str(c.get(k, 0)) for k in
            ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")
        )
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['compile_s']} | "
            f"{r['flops_per_chip']:.3e} | {fmt_bytes(r['bytes_per_chip'])} | "
            f"{fmt_bytes(r['collectives']['total_wire_bytes'])} | {cc} | OK |"
        )
    return "\n".join(lines)


def roofline_table(reports: List[Dict]) -> str:
    lines = [
        "| arch | shape | compute s | memory s | collective s | bottleneck | "
        "MODEL_FLOPS/HLO_FLOPs | to move the dominant term |",
        "|---|---|---|---|---|---|---|---|",
    ]
    from benchmarks.roofline import _hint

    for r in reports:
        if "roofline" not in r or r["mesh"] != "16x16":
            continue
        if r.get("mla_absorb") or r.get("sharding_mode", "fsdp_tp") != "fsdp_tp":
            continue
        t = r["roofline"]
        u = r.get("useful_flops_ratio")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.3f} | "
            f"{t['memory_s']:.3f} | {t['collective_s']:.3f} | "
            f"**{t['bottleneck']}** | {u if u is None else f'{u:.2f}'} | "
            f"{_hint(r)} |"
        )
    return "\n".join(lines)


def replace_section(text: str, name: str, content: str) -> str:
    begin = f"<!-- BEGIN GENERATED: {name} -->"
    end = f"<!-- END GENERATED: {name} -->"
    pattern = re.compile(re.escape(begin) + r".*?" + re.escape(end), re.S)
    block = begin + "\n" + content + "\n" + end
    if pattern.search(text):
        return pattern.sub(block, text)
    return text + "\n" + block + "\n"


def main():
    reports = load()
    path = "EXPERIMENTS.md"
    text = open(path).read() if os.path.exists(path) else "# EXPERIMENTS\n"
    text = replace_section(text, "dryrun-table", dryrun_table(reports))
    text = replace_section(text, "roofline-table", roofline_table(reports))
    open(path, "w").write(text)
    print(f"updated {path} from {len(reports)} reports")


if __name__ == "__main__":
    main()
