"""Paper Fig. 3/4 — the n_e sweep with the α = 0.0007·n_e rule.

Fig. 3's claim: most n_e reach similar score *per timestep*. Fig. 4's claim:
large n_e reaches those timesteps much faster (wall-clock). The paper also
observes divergence at n_e = 256 (the lr-scaling limit). We reproduce the
sweep on GridWorld at CPU scale and report per-n_e: reward per timestep,
timesteps/s, and a divergence flag (non-finite loss or collapsed entropy).
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import emit
from repro.configs import get_config
from repro.core import ParallelRL
from repro.core.agents import PAACAgent, PAACConfig
from repro.envs import GridWorld
from repro.optim import constant


def run(n_envs_list=(16, 32, 64, 128, 256), total_steps: int = 60_000,
        lr_base: float = 0.002):
    rows = []
    for n_e in n_envs_list:
        env = GridWorld(n_e, size=4, max_steps=30)
        cfg = get_config("paac_vector").replace(
            obs_shape=env.obs_shape, num_actions=env.num_actions
        )
        agent = PAACAgent(cfg, PAACConfig(t_max=5))
        # the paper's rule: lr scales linearly with n_e
        rl = ParallelRL(env, agent, lr_schedule=constant(lr_base * n_e), seed=0)
        iters = max(total_steps // (n_e * 5), 1)
        res = rl.run(iters)
        reward_per_step = (
            res.mean_metrics["reward_sum"] / (n_e * 5)
        )
        diverged = not bool(jnp.isfinite(jnp.asarray(res.mean_metrics["loss"])))
        emit(
            f"fig34_ne_scaling/ne={n_e}",
            1e6 * iters / max(res.timesteps_per_sec / (n_e * 5), 1e-9) / max(iters, 1),
            f"reward_per_step={reward_per_step:.4f};tps={res.timesteps_per_sec:.0f};"
            f"lr={lr_base*n_e:.4f};diverged={diverged}",
        )
        rows.append((n_e, reward_per_step, res.timesteps_per_sec, diverged))
    return rows


if __name__ == "__main__":
    run()
