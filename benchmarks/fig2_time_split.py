"""Paper Fig. 2 — time-usage split for different n_e.

The paper instruments where wall-time goes at each n_e: environment
interaction vs. action selection (policy forward) vs. learning (backward +
update). We reproduce the measurement on the JAX-native system by timing
three jitted programs per n_e:

  * env-only: the vmapped worker step (paper: "interacting with the env")
  * act-only: batched policy forward + sampling (the master)
  * full:     the complete Algorithm-1 iteration

learning_time ≈ full − env − act. The paper's observation to reproduce:
as the model grows (arch_nips → arch_nature), timesteps/s drops far less
than the model cost grows, because env time dominates (~50% at n_e=32).

``run_pipelined_host`` extends the measurement to the regime the paper can
only mitigate, not remove: *external* (host-bound) environments driven via
``HostEnvPool``, where env latency sits on the critical path of every
synchronous iteration. It reports the sync rollout/update split, the
pipelined backend's actor-idle vs learner-idle time, and the end-to-end
timesteps/s speedup from overlapping the two (repro.pipeline).

``run_multi_actor_host`` is the GA3C-style n_actors sweep on top of that:
N actor replicas, each with its own pool of external envs, feed the single
learner. Env latency is auto-calibrated so that one actor leaves the
learner mostly idle (the deep-env-latency regime); adding replicas hides
more latency until the learner saturates. This is the paper-adjacent claim
the multi-actor pipeline exists for: throughput scales with n_actors, not
with one actor's critical path.

``run_process_actors`` closes the loop on the env class the thread sweeps
can't touch: *GIL-holding* Python emulators (``repro.envs.PyBoundEnv``),
where every thread-backed replica serializes on the interpreter lock and
only the multi-process actor plane (``actor_backend="process"``) scales.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.configs import PipelineConfig, get_config
from repro.core import ParallelRL
from repro.core.agents import DQNAgent, DQNConfig, PAACAgent, PAACConfig
from repro.envs import AtariLike, FrameStack, HostEnvPool, PyBoundEnv, py_bound_spec
from repro.envs.base import VectorEnv
from repro.optim import constant
from repro.pipeline import PipelinedRL
from repro.pipeline.actor import collect_host
from repro.telemetry import (
    LEASE,
    QUEUE_GET_WAIT,
    QUEUE_PUT_WAIT,
    SpanEmitter,
    set_capture,
)


def run(n_envs_list=(16, 32, 64), arch: str = "paac_nips", t_max: int = 5,
        iters: int = 5):
    rows = []
    for n_e in n_envs_list:
        env = FrameStack(AtariLike(n_e), n=4)
        cfg = get_config(arch).replace(
            obs_shape=env.obs_shape, num_actions=env.num_actions
        )
        agent = PAACAgent(cfg, PAACConfig(t_max=t_max))
        rl = ParallelRL(env, agent, lr_schedule=constant(0.0007 * n_e))

        # env-only program (the n_w workers)
        def env_only(state, key):
            def body(c, _):
                st, k = c
                k, k2 = jax.random.split(k)
                st, obs, r, d = env.step(st, jnp.zeros((n_e,), jnp.int32), k2)
                return (st, k), None

            (state, key), _ = jax.lax.scan(body, (state, key), None, length=t_max)
            return state

        env_only = jax.jit(env_only)

        act = agent.act_fn()

        def act_only(params, obs, key):
            def body(c, _):
                o, k = c
                k, k2 = jax.random.split(k)
                logits, v = act(params, o)
                a = jax.random.categorical(k2, logits)
                return (o, k), a

            _, actions = jax.lax.scan(body, (obs, key), None, length=t_max)
            return actions

        act_only = jax.jit(act_only)

        key = jax.random.PRNGKey(0)
        t_env = time_call(env_only, rl.env_state, key, iters=iters)
        t_act = time_call(act_only, rl.params, rl.obs, key, iters=iters)
        t_full = time_call(
            lambda: rl._train_step(rl.params, rl.opt_state, rl.env_state,
                                   rl.obs, rl.key, jnp.int32(0)),
            iters=iters,
        )
        t_learn = max(t_full - t_env - t_act, 0.0)
        steps = n_e * t_max
        emit(
            f"fig2_time_split/ne={n_e}/{arch}",
            t_full,
            f"env%={100*t_env/t_full:.0f};act%={100*t_act/t_full:.0f};"
            f"learn%={100*t_learn/t_full:.0f};steps_per_s={steps/(t_full/1e6):.0f}",
        )
        rows.append((n_e, t_env, t_act, t_learn, t_full))
    return rows


# ---------------------------------------------------------------------------
# Pipelined host-env split — sync vs repro.pipeline on external envs
# ---------------------------------------------------------------------------


class SleepyExternalEnv:
    """Gym-style stand-in for an external emulator/simulator: each step costs
    ``delay`` seconds of host latency (sleeping, i.e. GIL-free — an ALE step,
    a network round-trip). Reward: +1 for action == state mod 3."""

    def __init__(self, seed: int, obs_dim: int, delay: float):
        self.rng = np.random.RandomState(seed)
        self.obs_dim = obs_dim
        self.delay = delay
        self.state = 0

    def _obs(self):
        return np.full((self.obs_dim,), self.state % 7, np.float32)

    def reset(self):
        self.state = int(self.rng.randint(0, 100))
        return self._obs()

    def step(self, action):
        if self.delay:
            time.sleep(self.delay)
        reward = 1.0 if action == self.state % 3 else 0.0
        self.state += 1
        return self._obs(), reward, self.state % 10 == 0, {}


def run_pipelined_host(n_e: int = 16, n_w: int = 8, obs_dim: int = 512,
                       width: int = 16384, t_max: int = 1, iters: int = 12,
                       delay: float = 0.0, warmup: int = 3):
    """Sync vs pipelined throughput on a HostEnvPool of slow external envs.

    With ``delay=0`` the env latency is auto-calibrated to the measured
    update time (the paper's ~50% env-time regime): the external env is as
    slow as one learner update, so a perfect pipeline hides the update
    entirely and sync pays both serially.
    """
    cfg = get_config("paac_vector").replace(
        obs_shape=(obs_dim,), num_actions=3, cnn_dense=width, d_model=width
    )
    agent = PAACAgent(cfg, PAACConfig(t_max=t_max))
    envs_per_worker = -(-n_e // n_w)

    def make_pool(d):
        return HostEnvPool(
            [lambda s=i: SleepyExternalEnv(s, obs_dim, d) for i in range(n_e)],
            n_workers=n_w, obs_shape=(obs_dim,),
        )

    # -- calibrate: measure rollout (act+env, zero delay) and update time ----
    with make_pool(0.0) as pool:
        rl = ParallelRL(pool, agent, lr_schedule=constant(0.003), seed=0)
        rl.run(warmup)
        t0 = time.perf_counter()
        for _ in range(5):
            obs, key, traj, last_obs = collect_host(
                rl._act, pool, rl.params, rl.obs, rl.key, t_max
            )
        t_roll0 = (time.perf_counter() - t0) / 5
        params, opt_state = rl.params, rl.opt_state
        t0 = time.perf_counter()
        for _ in range(5):
            params, opt_state, m = rl._update_step(
                params, opt_state, traj, last_obs, jnp.int32(0)
            )
            jax.block_until_ready(jax.tree_util.tree_leaves(params)[0])
        t_upd = (time.perf_counter() - t0) / 5
    if delay <= 0.0:
        # env window ≈ update + copy slack: the 50%-env regime, and wide
        # enough that the update hides inside one env step's latency.
        delay = min(max((t_upd + 0.02) / envs_per_worker, 0.002), 0.25)
    t_env = delay * t_max * envs_per_worker

    steps = n_e * t_max
    with make_pool(delay) as pool:
        rl = ParallelRL(pool, agent, lr_schedule=constant(0.003), seed=0)
        rl.run(warmup)
        sync = rl.run(iters)
    with make_pool(delay) as pool:
        prl = PipelinedRL(pool, agent, lr_schedule=constant(0.003), seed=0,
                          pipeline=PipelineConfig(queue_depth=2, rho_bar=1.0))
        prl.run(warmup)
        pipe = prl.run(iters)

    t_sync_iter = 1e6 * steps / max(sync.timesteps_per_sec, 1e-9)
    t_pipe_iter = 1e6 * steps / max(pipe.timesteps_per_sec, 1e-9)
    wall_pipe = iters * t_pipe_iter / 1e6
    speedup = pipe.timesteps_per_sec / max(sync.timesteps_per_sec, 1e-9)
    emit(
        f"fig2_time_split/host_sync/ne={n_e}",
        t_sync_iter,
        f"steps_per_s={sync.timesteps_per_sec:.0f};"
        f"env_ms={1e3*t_env:.0f};rollout0_ms={1e3*t_roll0:.0f};"
        f"update_ms={1e3*t_upd:.0f}",
    )
    emit(
        f"fig2_time_split/host_pipelined/ne={n_e}",
        t_pipe_iter,
        f"steps_per_s={pipe.timesteps_per_sec:.0f};"
        f"actor_idle%={100*pipe.actor_idle_s/max(wall_pipe,1e-9):.0f};"
        f"learner_idle%={100*pipe.learner_idle_s/max(wall_pipe,1e-9):.0f};"
        f"staleness={pipe.mean_metrics.get('staleness', 0.0):.1f}",
    )
    emit(
        "fig2_time_split/host_pipelined_speedup",
        0.0,
        f"speedup_vs_sync={speedup:.2f}x (target >=1.3x)",
    )
    return speedup


# ---------------------------------------------------------------------------
# Queue planes — sync vs host TrajectoryQueue vs DeviceTrajectoryRing on a
# JAX-native env (the GA3C staging-leak measurement)
# ---------------------------------------------------------------------------


class WideObsJaxEnv(VectorEnv):
    """JAX-native stand-in with a tunably wide observation: the same counter
    dynamics as ``SleepyExternalEnv`` (reward for action == state mod 3) but
    expressed as a pure-JAX ``VectorEnv``, so rollouts are born on the
    device. ``obs_dim`` scales the trajectory payload — the thing the host
    queue plane has to round-trip and the device ring does not."""

    num_actions = 3

    def __init__(self, n_envs: int, obs_dim: int, horizon: int = 10):
        super().__init__(n_envs)
        self.obs_dim = obs_dim
        self.obs_shape = (obs_dim,)
        self.horizon = horizon

    def _reset_one(self, key):
        return {"state": jax.random.randint(key, (), 0, 100)}

    def _observe_one(self, state):
        ramp = jnp.arange(self.obs_dim, dtype=jnp.float32) / self.obs_dim
        return (state["state"] % 7).astype(jnp.float32) * 0.1 + ramp

    def _step_one(self, state, action, key):
        s = state["state"]
        reward = (action == s % 3).astype(jnp.float32)
        new = {"state": s + 1}
        done = (new["state"] % self.horizon) == 0
        return new, reward, done


def _best_of(make_rl, iters: int, warmup: int, repeats: int):
    """Best steps/s over ``repeats`` fresh runs (plus that run's learner
    idle time and mean staleness): the multi-thread sweeps' scheduler-noise
    filter, mirroring ``time_call``'s median for single-program benches —
    on a small shared CPU the actor/learner threads and XLA's pool
    oversubscribe the cores, and best-of drops the transients."""
    best, idle, stale = 0.0, 0.0, 0.0
    for _ in range(repeats):
        rl = make_rl()
        rl.run(max(warmup, 2))  # compile + fill the pipeline
        res = rl.run(iters)
        if res.timesteps_per_sec > best:
            best = res.timesteps_per_sec
            idle = res.learner_idle_s
            stale = res.mean_metrics.get("staleness", 0.0)
    return best, idle, stale


def run_device_ring(n_e: int = 16, obs_dim: int = 32768, width: int = 16,
                    t_max: int = 6, iters: int = 40,
                    actor_counts=(1, 2, 4), warmup: int = 4,
                    repeats: int = 3, target: float = 1.2):
    """Steps/s for sync vs host-queue vs device-ring on a JAX-native env.

    The host ``TrajectoryQueue`` plane forces the GA3C shape on a JAX env:
    every rollout is pulled D2H into staging buffers and re-uploaded when
    the learner dispatches — the staging leak Babaeizadeh et al. (2017)
    measured — and its consume-completion release protocol pins the learner
    loop to one blocking sync per update. The ``DeviceTrajectoryRing``
    plane keeps the payload on the accelerator, fuses update+publish into
    one donated dispatch, and (having no release protocol) never syncs the
    learner loop at all. The acceptance figure is the device/host ratio at
    ``num_actors=2`` (target ≥ ``target``); the sweep also records actor
    counts 1/2/4 for both planes plus the fused synchronous baseline, and
    returns the whole grid for ``BENCH_pipeline.json``.

    Following ``run_multi_actor_host`` (GA3C's sweep), each actor replica
    owns its *own* ``n_e``-env pool, so the learner's batch — and the
    payload the host plane must round-trip — keeps its full width at every
    actor count. The default shape (wide obs, thin trunk) is the
    payload-bound regime where the staging leak is visible at all: per
    iteration the host plane moves ``2 · t_max · n_e · obs_dim`` floats
    across the host boundary while the update itself is a thin matmul.
    Compute-bound shapes bury the copies under arithmetic on any backend.
    Each cell reports the best of ``repeats`` runs — on a small shared CPU
    the actor/learner threads and XLA's pool oversubscribe the cores, and
    best-of filters the scheduler transients exactly like ``time_call``'s
    median does for single-program benches.
    """
    cfg = get_config("paac_vector").replace(
        obs_shape=(obs_dim,), num_actions=3, cnn_dense=width, d_model=width
    )
    agent = PAACAgent(cfg, PAACConfig(t_max=t_max))

    def make_env():
        return WideObsJaxEnv(n_e, obs_dim)

    def best_of(make_rl):
        return _best_of(make_rl, iters, warmup, repeats)

    results = {"sync": {}, "host": {}, "device": {}}
    tps, _, _ = best_of(lambda: ParallelRL(
        make_env(), agent, lr_schedule=constant(0.003), seed=0))
    results["sync"][1] = tps
    emit(
        f"fig2_time_split/plane_sync/ne={n_e}",
        1e6 * n_e * t_max / max(tps, 1e-9),
        f"steps_per_s={tps:.0f}",
    )
    shard_steps = n_e * t_max  # per-actor pools: full width at every count
    for plane in ("host", "device"):
        for n_actors in actor_counts:
            tps, idle_s, stale = best_of(lambda: PipelinedRL(
                [make_env() for _ in range(n_actors)], agent,
                lr_schedule=constant(0.003), seed=0,
                pipeline=PipelineConfig(
                    queue_depth=max(2, n_actors), num_actors=n_actors,
                    rollout_plane=plane,
                ),
            ))
            results[plane][n_actors] = tps
            wall = iters * shard_steps / max(tps, 1e-9)
            emit(
                f"fig2_time_split/plane_{plane}/na={n_actors}",
                1e6 * shard_steps / max(tps, 1e-9),
                f"steps_per_s={tps:.0f};"
                f"learner_idle%={100 * idle_s / max(wall, 1e-9):.0f};"
                f"staleness={stale:.1f}",
            )
    pivot = 2 if 2 in results["device"] else max(results["device"])
    speedup = results["device"][pivot] / max(results["host"][pivot], 1e-9)
    emit(
        "fig2_time_split/device_ring_speedup",
        0.0,
        f"device_vs_host_na{pivot}={speedup:.2f}x (target >={target}x)",
    )
    return {
        "config": {
            "n_e": n_e, "obs_dim": obs_dim, "width": width, "t_max": t_max,
            "iters": iters, "repeats": repeats,
            "actor_counts": list(actor_counts),
        },
        "steps_per_s": results,
        "device_vs_host_speedup": {"num_actors": pivot, "speedup": speedup,
                                   "target": target},
    }


# ---------------------------------------------------------------------------
# Replay plane — pipelined replay-DQN vs the synchronous scan-based DQN
# ---------------------------------------------------------------------------


def run_replay_ring(n_e: int = 16, obs_dim: int = 16384, width: int = 16,
                    t_max: int = 6, iters: int = 40,
                    actor_counts=(1, 2, 4), warmup: int = 4,
                    repeats: int = 3, replay_capacity: int = 16,
                    replay_batch: int = 1, sync_capacity: int = 512,
                    target: float = 1.2):
    """Steps/s for the replay-plane DQN vs the synchronous scan-based DQN.

    The off-policy rung of the plane ladder: ``ParallelRL``'s scan-based
    DQN is one fused jitted program per iteration — ε-greedy acting *and*
    per-transition replay scatter *and* a sampled TD update, all serial on
    the critical path, with the transition-level replay buffer
    (``sync_capacity × obs_dim``, obs + next_obs) carried through the scan.
    The replay-plane ``PipelinedRL`` splits that program: actor threads run
    the detached ε-greedy collector and ``put`` whole rollouts into the
    device-resident ``ReplayRing`` (never blocking — FIFO eviction absorbs
    a slow learner), while the learner thread samples resident rollouts and
    updates concurrently. Because Q-learning's target is defined
    off-policy, the sampled-stale rollouts need no correction — this is the
    plane where acting and learning genuinely decouple.

    Fairness: the sync baseline's ``batch_size`` is pinned to
    ``n_e · t_max`` — exactly the transitions one sampled rollout feeds the
    pipelined learner per update at ``replay_batch=1`` — so both sides do
    the same per-update TD work and the measured gap is scheduling plus the
    scatter/gather the scan pays and the ring does not. Same wide-obs
    thin-trunk payload-bound shape and per-actor env pools as
    ``run_device_ring``; each cell is best-of-``repeats``. The acceptance
    figure is pipelined-replay steps/s at ``num_actors=2`` over the sync
    scan baseline (target ≥ ``target``); the grid lands in
    ``BENCH_pipeline.json`` under ``replay_ring``.
    """
    cfg = get_config("paac_vector").replace(
        obs_shape=(obs_dim,), num_actions=3, cnn_dense=width, d_model=width
    )
    # throughput bench: the ε/target cadences just need to be well-defined
    agent = DQNAgent(cfg, DQNConfig(t_max=t_max, batch_size=n_e * t_max,
                                    eps_steps=1_000, target_sync=100))

    def make_env():
        return WideObsJaxEnv(n_e, obs_dim)

    results = {"sync": {}, "replay": {}}
    tps, _, _ = _best_of(
        lambda: ParallelRL(make_env(), agent, lr_schedule=constant(1e-3),
                           seed=0, replay_capacity=sync_capacity),
        iters, warmup, repeats,
    )
    results["sync"][1] = tps
    emit(
        f"fig2_time_split/replay_sync/ne={n_e}",
        1e6 * n_e * t_max / max(tps, 1e-9),
        f"steps_per_s={tps:.0f};batch={n_e * t_max};capacity={sync_capacity}",
    )
    shard_steps = n_e * t_max  # per-actor pools: full width at every count
    for n_actors in actor_counts:
        tps, idle_s, stale = _best_of(
            lambda: PipelinedRL(
                [make_env() for _ in range(n_actors)], agent,
                lr_schedule=constant(1e-3), seed=0,
                pipeline=PipelineConfig(
                    queue_depth=max(2, n_actors), num_actors=n_actors,
                    rollout_plane="device", replay_plane=True,
                    replay_capacity=replay_capacity,
                    replay_batch=replay_batch,
                ),
            ),
            iters, warmup, repeats,
        )
        results["replay"][n_actors] = tps
        wall = iters * shard_steps / max(tps, 1e-9)
        emit(
            f"fig2_time_split/replay_ring/na={n_actors}",
            1e6 * shard_steps / max(tps, 1e-9),
            f"steps_per_s={tps:.0f};"
            f"learner_idle%={100 * idle_s / max(wall, 1e-9):.0f};"
            f"staleness={stale:.1f}",
        )
    pivot = 2 if 2 in results["replay"] else max(results["replay"])
    speedup = results["replay"][pivot] / max(results["sync"][1], 1e-9)
    emit(
        "fig2_time_split/replay_ring_speedup",
        0.0,
        f"replay_vs_sync_na{pivot}={speedup:.2f}x (target >={target}x)",
    )
    return {
        "config": {
            "n_e": n_e, "obs_dim": obs_dim, "width": width, "t_max": t_max,
            "iters": iters, "repeats": repeats,
            "actor_counts": list(actor_counts),
            "replay_capacity": replay_capacity, "replay_batch": replay_batch,
            "sync_capacity": sync_capacity, "sync_batch": n_e * t_max,
        },
        "steps_per_s": results,
        "replay_vs_sync_speedup": {"num_actors": pivot, "speedup": speedup,
                                   "target": target},
    }


# ---------------------------------------------------------------------------
# Mesh plane — device-ring scaling across a ("data",) device mesh
# ---------------------------------------------------------------------------


def run_mesh_ring(n_e: int = 4, obs_dim: int = 128, width: int = 16,
                  t_max: int = 64, iters: int = 40, mesh_counts=(1, 2, 4),
                  warmup: int = 3, repeats: int = 3, target: float = 1.3):
    """Steps/s of the mesh rollout plane at 1/2/4 devices (weak scaling).

    The follow-on rung to ``run_device_ring``: the same device-resident
    pipeline sharded across a 1-axis ``("data",)`` mesh. Following this
    file's established sweep shape (per-actor pools — GA3C's "actors scale
    emulators"), each mesh lane owns its *own* ``n_e``-env pool, so
    ``mesh=D`` trains on ``D·n_e`` envs per update: the env axis grows with
    the mesh, which is precisely the scaling a data-parallel mesh buys
    (Stooke & Abbeel 2018's synchronous multi-GPU regime — more emulators
    *and* an all-reduced optimizer step, not a faster single stream).

    Run in the *synchronous lockstep* configuration (depth 1, every lane
    contributes one sub-rollout to every update, zero staleness): that is
    the regime whose math is invariant in ``D`` — and, on CPU hosts with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``, the one where
    the scaling is honestly measurable. The run-ahead variant overlaps each
    lane's next collect with the sharded update, but XLA's CPU collectives
    rendezvous across *all* device threads, so on an oversubscribed host
    the all-reduce convoys behind whichever lane is mid-collect (measured
    ~6x update-latency inflation); lockstep alternates the phases instead.
    On real accelerator meshes (one core-complex per device) the overlap is
    free and the run-ahead mesh is the right configuration.

    The latency-bound default shape (thin trunk, deep ``t_max``: a long
    scan of small per-step programs) is where a CPU host shows the mesh
    win at all — one device executes its scan serially on one core, so
    parallel lanes genuinely overlap; compute-bound shapes saturate the
    host's cores on a single device and bury the scaling. The acceptance
    figure is steps/s at the largest available mesh vs ``mesh=1`` (target
    ≥ ``target``); each cell is best-of-``repeats`` (same scheduler-noise
    filter as the rest of this file). Mesh counts beyond the visible device
    count are skipped with a note row, so the sweep degrades gracefully on
    a 1-device host (CI's default) and covers the full grid under the
    mesh-smoke job's 4 forced host devices.
    """
    cfg = get_config("paac_vector").replace(
        obs_shape=(obs_dim,), num_actions=3, cnn_dense=width, d_model=width
    )
    agent = PAACAgent(cfg, PAACConfig(t_max=t_max))
    n_dev = len(jax.devices())
    counts = [d for d in mesh_counts if d <= n_dev]
    skipped = [d for d in mesh_counts if d > n_dev]
    if skipped:
        emit(
            "fig2_time_split/mesh_ring/skipped",
            0.0,
            f"mesh_counts={skipped} need more devices (visible={n_dev}); "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=4",
        )

    results = {}
    for D in counts:
        best, idle, _ = _best_of(
            lambda D=D: PipelinedRL(
                [WideObsJaxEnv(n_e, obs_dim) for _ in range(D)], agent,
                lr_schedule=constant(0.003), seed=0,
                pipeline=PipelineConfig(queue_depth=1, lockstep=True,
                                        num_actors=D, mesh_shape=D,
                                        rollout_plane="mesh"),
            ),
            iters, warmup, repeats,
        )
        results[D] = best
        steps = D * n_e * t_max  # per-lane pools: the batch grows with D
        wall = iters * steps / max(best, 1e-9)
        emit(
            f"fig2_time_split/mesh_ring/mesh={D}",
            1e6 * steps / max(best, 1e-9),
            f"steps_per_s={best:.0f};envs={D * n_e};"
            f"learner_idle%={100 * idle / max(wall, 1e-9):.0f}",
        )
    pivot, base = max(results), min(results)
    speedup = results[pivot] / max(results[base], 1e-9)
    emit(
        "fig2_time_split/mesh_ring_speedup",
        0.0,
        f"mesh{pivot}_vs_mesh{base}={speedup:.2f}x (target >={target}x)",
    )
    return {
        "config": {
            "n_e_per_lane": n_e, "obs_dim": obs_dim, "width": width,
            "t_max": t_max, "iters": iters, "repeats": repeats,
            "mesh_counts": counts, "lockstep": True, "queue_depth": 1,
        },
        "steps_per_s": results,
        "mesh_vs_mesh1_speedup": {"mesh": pivot, "baseline_mesh": base,
                                  "speedup": speedup, "target": target},
    }


# ---------------------------------------------------------------------------
# Actor backends — thread vs process replicas on a GIL-holding Python env
# ---------------------------------------------------------------------------


def run_process_actors(n_e: int = 4, n_w: int = 2, obs_dim: int = 32,
                       width: int = 256, t_max: int = 4, iters: int = 12,
                       actor_counts=(1, 2, 4), spin: int = 0,
                       warmup: int = 2, target: float = 1.1):
    """Thread vs process actor backend on a *GIL-holding* Python env.

    ``SleepyExternalEnv`` (above) models emulators that release the GIL —
    the regime the thread plane scales. ``repro.envs.PyBoundEnv`` models
    the ones that don't: each step executes ``spin`` iterations of Python
    bytecode, so every thread-backed replica (and every worker thread
    inside each pool) serializes on the interpreter lock, and
    ``run_multi_actor_host``'s scaling collapses exactly where the paper's
    Fig. 2 "50% env time" regime begins. The process backend moves each
    replica into its own interpreter (own GIL), which is the A3C /
    Accelerated-Methods answer; this sweep measures both backends over
    GA3C-style per-actor pools at each actor count.

    With ``spin=0`` the per-step Python work is auto-calibrated so one
    actor's rollout costs ≈ ``max(actor_counts)`` learner updates — the
    same deep-env-latency regime ``run_multi_actor_host`` targets, except
    the latency is GIL-bound, not sleepable. The acceptance figure is
    process/thread steps/s at the 2-actor pivot (target ≥ ``target``);
    the grid is returned for ``BENCH_pipeline.json``.
    """
    cfg = get_config("paac_vector").replace(
        obs_shape=(obs_dim,), num_actions=3, cnn_dense=width, d_model=width
    )
    agent = PAACAgent(cfg, PAACConfig(t_max=t_max))
    a_max = max(actor_counts)

    # -- calibrate: Python work per step vs one learner update ---------------
    # (explicit ``spin`` — the ci profile — skips the compile-heavy update
    # probe entirely; the derived fields then report nan for update_ms)
    probe_spin = 20_000
    env = PyBoundEnv(0, obs_dim, spin=probe_spin)
    env.reset()
    t0 = time.perf_counter()
    for _ in range(5):
        env.step(0)
    t_unit = (time.perf_counter() - t0) / (5 * probe_spin)  # s per spin iter
    t_upd = float("nan")
    if spin <= 0:
        with py_bound_spec(n_e, obs_dim, 0, n_w).build() as pool:
            rl = ParallelRL(pool, agent, lr_schedule=constant(0.003), seed=0)
            rl.run(warmup)
            obs, key, traj, last_obs = collect_host(
                rl._act, pool, rl.params, rl.obs, rl.key, t_max
            )
            params, opt_state = rl.params, rl.opt_state
            t0 = time.perf_counter()
            for _ in range(5):
                params, opt_state, m = rl._update_step(
                    params, opt_state, traj, last_obs, jnp.int32(0)
                )
                jax.block_until_ready(jax.tree_util.tree_leaves(params)[0])
            t_upd = (time.perf_counter() - t0) / 5
        # GIL-bound env work serializes across *all* threads, so one actor's
        # rollout costs t_max·n_e·spin·t_unit of interpreter time no matter
        # how many pool workers run. Aim that at a_max updates.
        spin = int(min(
            max(a_max * (t_upd + 0.01) / (t_max * n_e * t_unit), 200),
            2_000_000,
        ))
    t_env = spin * t_unit * t_max * n_e  # one rollout's GIL-bound env time

    results = {"thread": {}, "process": {}}
    steps = n_e * t_max  # per-actor pools: full width at every count
    for backend in ("thread", "process"):
        for n_actors in actor_counts:
            specs = [py_bound_spec(n_e, obs_dim, spin, n_w, base_seed=100 * a)
                     for a in range(n_actors)]
            prl = PipelinedRL(
                specs if n_actors > 1 else specs[0], agent,
                lr_schedule=constant(0.003), seed=0,
                pipeline=PipelineConfig(
                    queue_depth=max(2, n_actors), num_actors=n_actors,
                    actor_backend=backend,
                ),
            )
            try:
                prl.run(max(warmup, 2))  # compile (workers too) + fill
                res = prl.run(iters)
            finally:
                prl.close()
            results[backend][n_actors] = res.timesteps_per_sec
            wall = iters * steps / max(res.timesteps_per_sec, 1e-9)
            emit(
                f"fig2_time_split/actors_{backend}/na={n_actors}",
                1e6 * steps / max(res.timesteps_per_sec, 1e-9),
                f"steps_per_s={res.timesteps_per_sec:.0f};"
                f"spin={spin};env_ms={1e3 * t_env:.0f};"
                f"update_ms={1e3 * t_upd:.0f};"
                f"learner_idle%={100 * res.learner_idle_s / max(wall, 1e-9):.0f};"
                f"staleness={res.mean_metrics.get('staleness', 0.0):.1f}",
            )
    pivot = 2 if 2 in results["process"] else max(results["process"])
    speedup = results["process"][pivot] / max(results["thread"][pivot], 1e-9)
    emit(
        "fig2_time_split/process_backend_speedup",
        0.0,
        f"process_vs_thread_na{pivot}={speedup:.2f}x (target >={target}x)",
    )
    return {
        "config": {
            "n_e": n_e, "n_w": n_w, "obs_dim": obs_dim, "width": width,
            "t_max": t_max, "iters": iters, "spin": spin,
            "actor_counts": list(actor_counts),
        },
        "steps_per_s": results,
        "process_vs_thread_speedup": {"num_actors": pivot,
                                      "speedup": speedup, "target": target},
    }


# ---------------------------------------------------------------------------
# Telemetry overhead — span capture on vs off on the host and device grids
# ---------------------------------------------------------------------------


def run_telemetry_overhead(n_e: int = 8, obs_dim: int = 8192, width: int = 16,
                           t_max: int = 4, iters: int = 24, warmup: int = 3,
                           repeats: int = 3, host_n_w: int = 4,
                           host_delay: float = 0.002, pair_n: int = 100_000,
                           budget: float = 0.98):
    """Cost of always-on telemetry, plus the trace/accounting cross-check.

    Every hot-path wait in the pipeline is now a recorded span
    (``repro.telemetry``) and ``RunResult``'s idle fields are derived from
    the span totals, so the instrumentation runs whether or not anyone
    exports a trace. This bench prices that:

    * a microbench of one ``begin``/``end`` pair (capture on vs
      ``set_capture(False)`` — the totals-only cost model),
    * pipelined steps/s with capture on vs off on both fig2 grids — the
      host ``TrajectoryQueue`` plane over ``SleepyExternalEnv`` pools and
      the device-ring plane over ``WideObsJaxEnv`` — best-of ``repeats``
      each (acceptance: on/off ratio ≥ ``budget``, i.e. within 2%),
    * a trace-derived time-split cross-check: one captured device run,
      learner/actor idle recomputed from the exported span rings and
      compared against the ``RunResult`` fields they are supposed to
      derive from (ratio ≈ 1.0 by construction — same spans, same sums —
      so drift here means the accounting and the trace diverged).

    Returns the grid for ``BENCH_pipeline.json``'s ``telemetry_overhead``
    entry.
    """
    # -- microbench: one begin/end pair on a private single-writer emitter ---
    bench_em = SpanEmitter("bench", capacity=pair_n)
    t0 = time.perf_counter()
    for _ in range(pair_n):
        bench_em.begin(0)
        bench_em.end()
    pair_on_us = 1e6 * (time.perf_counter() - t0) / pair_n
    bench_em.reset()
    set_capture(False)
    try:
        t0 = time.perf_counter()
        for _ in range(pair_n):
            bench_em.begin(0)
            bench_em.end()
        pair_off_us = 1e6 * (time.perf_counter() - t0) / pair_n
    finally:
        set_capture(True)
    emit(
        "fig2_time_split/telemetry_span_pair",
        pair_on_us,
        f"capture_off_us={pair_off_us:.3f};drops={bench_em.drops}",
    )

    cfg = get_config("paac_vector").replace(
        obs_shape=(obs_dim,), num_actions=3, cnn_dense=width, d_model=width
    )
    agent = PAACAgent(cfg, PAACConfig(t_max=t_max))

    def make_pool():
        return HostEnvPool(
            [lambda s=i: SleepyExternalEnv(s, obs_dim, host_delay)
             for i in range(n_e)],
            n_workers=host_n_w, obs_shape=(obs_dim,),
        )

    def make_device_rl():
        return PipelinedRL(
            [WideObsJaxEnv(n_e, obs_dim) for _ in range(2)], agent,
            lr_schedule=constant(0.003), seed=0,
            pipeline=PipelineConfig(queue_depth=2, num_actors=2,
                                    rollout_plane="device"),
        )

    def best_tps(plane: str) -> float:
        best = 0.0
        for _ in range(repeats):
            if plane == "device":
                rl = make_device_rl()
                rl.run(max(warmup, 2))
                res = rl.run(iters)
            else:
                with make_pool() as pool:
                    rl = PipelinedRL(
                        pool, agent, lr_schedule=constant(0.003), seed=0,
                        pipeline=PipelineConfig(queue_depth=2),
                    )
                    rl.run(max(warmup, 2))
                    res = rl.run(iters)
            best = max(best, res.timesteps_per_sec)
        return best

    grids = {}
    for plane in ("host", "device"):
        on = best_tps(plane)
        set_capture(False)
        try:
            off = best_tps(plane)
        finally:
            set_capture(True)
        ratio = on / max(off, 1e-9)
        grids[plane] = {"capture_on": on, "capture_off": off, "ratio": ratio}
        emit(
            f"fig2_time_split/telemetry_overhead/{plane}",
            0.0,
            f"on_steps_per_s={on:.0f};off_steps_per_s={off:.0f};"
            f"ratio={ratio:.3f} (target >={budget})",
        )

    # -- trace-derived time-split cross-check (one captured device run) ------
    prl = make_device_rl()
    prl.run(max(warmup, 2))
    res = prl.run(iters)
    by_name = {em.name: em for _, _, em in prl.telemetry.tracks()}
    trace_learner_idle = sum(
        t1 - t0 for c, t0, t1 in by_name["ring"].snapshot()
        if c == QUEUE_GET_WAIT
    )
    trace_actor_idle = sum(
        t1 - t0
        for name in ("actor0", "actor1")
        for c, t0, t1 in by_name[name].snapshot()
        if c in (QUEUE_PUT_WAIT, LEASE)
    )
    learner_ratio = trace_learner_idle / max(res.learner_idle_s, 1e-9)
    actor_ratio = trace_actor_idle / max(res.actor_idle_s, 1e-9)
    emit(
        "fig2_time_split/telemetry_trace_crosscheck",
        0.0,
        f"learner_idle_trace_s={trace_learner_idle:.4f};"
        f"learner_idle_result_s={res.learner_idle_s:.4f};"
        f"learner_ratio={learner_ratio:.4f};actor_ratio={actor_ratio:.4f}"
        " (target 1.00 each — trace and accounting share the spans)",
    )
    return {
        "config": {
            "n_e": n_e, "obs_dim": obs_dim, "width": width, "t_max": t_max,
            "iters": iters, "repeats": repeats, "host_n_w": host_n_w,
            "host_delay": host_delay, "pair_n": pair_n,
        },
        "span_pair_us": {"capture_on": pair_on_us, "capture_off": pair_off_us},
        "steps_per_s": grids,
        "budget_ratio": budget,
        "trace_crosscheck": {"learner_idle_ratio": learner_ratio,
                             "actor_idle_ratio": actor_ratio},
    }


# ---------------------------------------------------------------------------
# Multi-actor scaling — GA3C-style n_actors sweep on external envs
# ---------------------------------------------------------------------------


def run_multi_actor_host(n_e: int = 8, n_w: int = 8, obs_dim: int = 256,
                         width: int = 4096, t_max: int = 2, iters: int = 16,
                         actor_counts=(1, 2, 4), delay: float = 0.0,
                         warmup: int = 2, target: float = 1.5):
    """Pipelined throughput vs ``--num-actors`` on per-actor HostEnvPools.

    Each actor replica owns its own pool of ``n_e`` external envs (GA3C's
    sweep: actors scale emulators). With ``delay=0`` the env latency is
    auto-calibrated so one actor's rollout takes ≈ ``max(actor_counts)``
    learner updates — the deep-latency regime where a single actor leaves
    the learner idle most of the time and each extra replica hides another
    update's worth of latency. Returns the speedup of the largest actor
    count over one actor (acceptance target ≥ ``target``).
    """
    cfg = get_config("paac_vector").replace(
        obs_shape=(obs_dim,), num_actions=3, cnn_dense=width, d_model=width
    )
    agent = PAACAgent(cfg, PAACConfig(t_max=t_max))
    envs_per_worker = -(-n_e // n_w)
    a_max = max(actor_counts)

    def make_pool(d, base_seed=0):
        return HostEnvPool(
            [lambda s=base_seed + i: SleepyExternalEnv(s, obs_dim, d)
             for i in range(n_e)],
            n_workers=n_w, obs_shape=(obs_dim,),
        )

    # -- calibrate: measure one learner update on an n_e-wide rollout --------
    with make_pool(0.0) as pool:
        rl = ParallelRL(pool, agent, lr_schedule=constant(0.003), seed=0)
        rl.run(warmup)
        obs, key, traj, last_obs = collect_host(
            rl._act, pool, rl.params, rl.obs, rl.key, t_max
        )
        params, opt_state = rl.params, rl.opt_state
        t0 = time.perf_counter()
        for _ in range(5):
            params, opt_state, m = rl._update_step(
                params, opt_state, traj, last_obs, jnp.int32(0)
            )
            jax.block_until_ready(jax.tree_util.tree_leaves(params)[0])
        t_upd = (time.perf_counter() - t0) / 5
    if delay <= 0.0:
        # one actor's rollout window ≈ a_max updates (+ dispatch slack): the
        # learner idles (a_max-1)/a_max of the time under a single replica
        delay = min(
            max(a_max * (t_upd + 0.01) / (t_max * envs_per_worker), 0.002),
            0.25,
        )
    t_env = delay * t_max * envs_per_worker

    results = {}
    for n_actors in actor_counts:
        pools = [make_pool(delay, base_seed=100 * a) for a in range(n_actors)]
        try:
            prl = PipelinedRL(
                pools, agent, lr_schedule=constant(0.003), seed=0,
                pipeline=PipelineConfig(queue_depth=max(2, n_actors),
                                        num_actors=n_actors),
            )
            prl.run(max(warmup, n_actors))  # compile + fill the pipeline
            res = prl.run(iters)
        finally:
            for p in pools:
                p.close()
        results[n_actors] = res.timesteps_per_sec
        steps = n_e * t_max
        wall = iters * steps / max(res.timesteps_per_sec, 1e-9)
        emit(
            f"fig2_time_split/multi_actor/na={n_actors}",
            1e6 * steps / max(res.timesteps_per_sec, 1e-9),
            f"steps_per_s={res.timesteps_per_sec:.0f};"
            f"env_ms={1e3 * t_env:.0f};update_ms={1e3 * t_upd:.0f};"
            f"learner_idle%={100 * res.learner_idle_s / max(wall, 1e-9):.0f};"
            f"staleness={res.mean_metrics.get('staleness', 0.0):.1f}",
        )
    a_min = min(actor_counts)
    speedup = results[a_max] / max(results[a_min], 1e-9)
    emit(
        "fig2_time_split/multi_actor_speedup",
        0.0,
        f"speedup_{a_max}x_vs_{a_min}x={speedup:.2f}x (target >={target}x)",
    )
    return speedup


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--only",
                    choices=("fig2", "pipelined", "multi", "procs", "mesh",
                             "telemetry", "replay"),
                    default="")
    ap.add_argument("--num-actors", type=int, nargs="+", default=(1, 2, 4),
                    help="actor counts for the multi-actor sweep")
    ap.add_argument("--iters", type=int, default=0,
                    help="measurement iterations (0 = each benchmark's default)")
    args = ap.parse_args()
    if args.only in ("", "fig2"):
        run(**({"iters": args.iters} if args.iters else {}))
    if args.only in ("", "pipelined"):
        run_pipelined_host(**({"iters": args.iters} if args.iters else {}))
    if args.only in ("", "multi"):
        run_multi_actor_host(actor_counts=tuple(args.num_actors),
                             **({"iters": args.iters} if args.iters else {}))
    if args.only in ("", "procs"):
        run_process_actors(actor_counts=tuple(args.num_actors),
                           **({"iters": args.iters} if args.iters else {}))
    if args.only in ("", "mesh"):
        run_mesh_ring(**({"iters": args.iters} if args.iters else {}))
    if args.only in ("", "replay"):
        run_replay_ring(actor_counts=tuple(args.num_actors),
                        **({"iters": args.iters} if args.iters else {}))
    if args.only in ("", "telemetry"):
        run_telemetry_overhead(**({"iters": args.iters} if args.iters else {}))
