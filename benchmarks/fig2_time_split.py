"""Paper Fig. 2 — time-usage split for different n_e.

The paper instruments where wall-time goes at each n_e: environment
interaction vs. action selection (policy forward) vs. learning (backward +
update). We reproduce the measurement on the JAX-native system by timing
three jitted programs per n_e:

  * env-only: the vmapped worker step (paper: "interacting with the env")
  * act-only: batched policy forward + sampling (the master)
  * full:     the complete Algorithm-1 iteration

learning_time ≈ full − env − act. The paper's observation to reproduce:
as the model grows (arch_nips → arch_nature), timesteps/s drops far less
than the model cost grows, because env time dominates (~50% at n_e=32).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_call
from repro.configs import get_config
from repro.core import ParallelRL
from repro.core.agents import PAACAgent, PAACConfig
from repro.envs import AtariLike, FrameStack
from repro.optim import constant


def run(n_envs_list=(16, 32, 64), arch: str = "paac_nips", t_max: int = 5,
        iters: int = 5):
    rows = []
    for n_e in n_envs_list:
        env = FrameStack(AtariLike(n_e), n=4)
        cfg = get_config(arch).replace(
            obs_shape=env.obs_shape, num_actions=env.num_actions
        )
        agent = PAACAgent(cfg, PAACConfig(t_max=t_max))
        rl = ParallelRL(env, agent, lr_schedule=constant(0.0007 * n_e))

        # env-only program (the n_w workers)
        def env_only(state, key):
            def body(c, _):
                st, k = c
                k, k2 = jax.random.split(k)
                st, obs, r, d = env.step(st, jnp.zeros((n_e,), jnp.int32), k2)
                return (st, k), None

            (state, key), _ = jax.lax.scan(body, (state, key), None, length=t_max)
            return state

        env_only = jax.jit(env_only)

        act = agent.act_fn()

        def act_only(params, obs, key):
            def body(c, _):
                o, k = c
                k, k2 = jax.random.split(k)
                logits, v = act(params, o)
                a = jax.random.categorical(k2, logits)
                return (o, k), a

            _, actions = jax.lax.scan(body, (obs, key), None, length=t_max)
            return actions

        act_only = jax.jit(act_only)

        key = jax.random.PRNGKey(0)
        t_env = time_call(env_only, rl.env_state, key, iters=iters)
        t_act = time_call(act_only, rl.params, rl.obs, key, iters=iters)
        t_full = time_call(
            lambda: rl._train_step(rl.params, rl.opt_state, rl.env_state,
                                   rl.obs, rl.key, jnp.int32(0)),
            iters=iters,
        )
        t_learn = max(t_full - t_env - t_act, 0.0)
        steps = n_e * t_max
        emit(
            f"fig2_time_split/ne={n_e}/{arch}",
            t_full,
            f"env%={100*t_env/t_full:.0f};act%={100*t_act/t_full:.0f};"
            f"learn%={100*t_learn/t_full:.0f};steps_per_s={steps/(t_full/1e6):.0f}",
        )
        rows.append((n_e, t_env, t_act, t_learn, t_full))
    return rows


if __name__ == "__main__":
    run()
