"""Roofline table builder — reads experiments/dryrun/*.json and emits the
per-(arch × shape × mesh) table for EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

from benchmarks.common import emit


def load_reports(path: str = "experiments/dryrun") -> List[Dict]:
    reports = []
    for f in sorted(glob.glob(os.path.join(path, "*.json"))):
        with open(f) as fh:
            reports.append(json.load(fh))
    return reports


def table_markdown(reports: List[Dict]) -> str:
    lines = [
        "| arch | shape | mesh | compute_s | memory_s | collective_s | "
        "bottleneck | MODEL_FLOPS/HLO | what would move the dominant term |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in reports:
        if "skipped" in r or "error" in r:
            continue
        t = r["roofline"]
        ratio = r.get("useful_flops_ratio")
        hint = _hint(r)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']}"
            f"{' absorb' if r.get('mla_absorb') else ''} | "
            f"{t['compute_s']:.3f} | {t['memory_s']:.3f} | "
            f"{t['collective_s']:.3f} | {t['bottleneck']} | "
            f"{ratio:.2f} | {hint} |"
            if ratio is not None else
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{t['compute_s']:.3f} | {t['memory_s']:.3f} | "
            f"{t['collective_s']:.3f} | {t['bottleneck']} | n/a | {hint} |"
        )
    return "\n".join(lines)


def _hint(r: Dict) -> str:
    b = r["roofline"]["bottleneck"]
    kind = r.get("kind", "")
    if b == "collective":
        if kind in ("decode",):
            return "drop FSDP weight gathers for inference (tp mode)"
        return "reduce per-layer weight (re)gathers: cast gathers to bf16 / larger data shards"
    if b == "memory":
        if kind == "train":
            return "bf16 intermediates + fused attention kernel (fewer HBM round trips)"
        return "fuse decode attention (Pallas) and keep cache bf16"
    return "already MXU-bound: increase per-chip batch or reduce remat"


def run(path: str = "experiments/dryrun"):
    reports = load_reports(path)
    ok = [r for r in reports if "roofline" in r]
    for r in ok:
        t = r["roofline"]
        emit(
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
            t[t["bottleneck"] + "_s"] * 1e6,
            f"bottleneck={t['bottleneck']};compute={t['compute_s']:.3f};"
            f"memory={t['memory_s']:.3f};collective={t['collective_s']:.3f}",
        )
    if not ok:
        emit("roofline/none", 0.0, "no dry-run reports found — run repro.launch.dryrun")
    return ok


if __name__ == "__main__":
    print(table_markdown(load_reports()))
