"""Batched serving example: the paper's master (batched action selection)
as modern LLM inference — prefill a batch of prompts, then decode.

    PYTHONPATH=src python examples/serve_batch.py --arch mamba2-370m
    PYTHONPATH=src python examples/serve_batch.py --arch qwen2-7b --gen 64
    PYTHONPATH=src python examples/serve_batch.py --continuous --requests 8
"""
import sys

from repro.launch.serve import main


def run(argv=None):
    """Forward to the serve launcher with ``--reduced`` defaulted on,
    without mutating ``sys.argv`` (importable and testable)."""
    argv = list(sys.argv[1:] if argv is None else argv)
    if "--reduced" not in argv:
        argv.append("--reduced")
    main(argv)


if __name__ == "__main__":
    run()
