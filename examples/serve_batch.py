"""Batched serving example: the paper's master (batched action selection)
as modern LLM inference — prefill a batch of prompts, then decode.

    PYTHONPATH=src python examples/serve_batch.py --arch mamba2-370m
    PYTHONPATH=src python examples/serve_batch.py --arch qwen2-7b --gen 64
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    if "--reduced" not in sys.argv:
        sys.argv.append("--reduced")
    main()
