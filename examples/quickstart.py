"""Quickstart: PAAC (paper Algorithm 1) on GridWorld, then the plane matrix.

Part 1 trains synchronously in ~20 lines. Part 2 runs the *same* training
through every rollout plane of the asynchronous pipeline — host staging
queue, device-resident ring, mesh sub-rings — in lockstep settings (depth
1, single stream, infinite V-trace clips) and asserts they all reproduce
the synchronous metrics exactly: the planes differ in overlap and
placement, never in math.

    PYTHONPATH=src python examples/quickstart.py

To watch the mesh plane actually span devices on a CPU-only machine,
expose fake host devices first (must be set before jax starts):

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs import PipelineConfig, get_config
from repro.core import ParallelRL
from repro.core.agents import PAACAgent, PAACConfig
from repro.envs import GridWorld
from repro.optim import constant
from repro.pipeline import PipelinedRL

# -- part 1: the paper's synchronous framework ------------------------------

# n_e parallel environments — one vectorized JAX program (paper §3)
env = GridWorld(n_envs=32, size=5)
cfg = get_config("paac_vector").replace(
    obs_shape=env.obs_shape, num_actions=env.num_actions
)
agent = PAACAgent(cfg, PAACConfig(t_max=5, gamma=0.99, entropy_beta=0.01))
rl = ParallelRL(env, agent, optimizer="rmsprop", lr_schedule=constant(0.01))

for epoch in range(8):
    res = rl.run(50)
    print(
        f"epoch {epoch}: steps={res.steps:6d} "
        f"reward/iter={res.mean_metrics['reward_sum']:+.3f} "
        f"episodes={res.episodes:.0f} steps/s={res.timesteps_per_sec:,.0f}"
    )

# -- part 2: the plane matrix, pinned to the synchronous run ----------------
# Lockstep settings: queue depth 1, the actor waits for fresh params, and
# rho_bar = c_bar = inf compiles the V-trace correction out — every plane
# must then reproduce the synchronous trajectory stream exactly.

ITERS, SEED, INF = 20, 7, float("inf")


def fresh_agent():
    return PAACAgent(cfg, PAACConfig(t_max=5, gamma=0.99, entropy_beta=0.01))


def run_plane(plane, mesh_shape=1):
    prl = PipelinedRL(
        GridWorld(n_envs=32, size=5), fresh_agent(),
        optimizer="rmsprop", lr_schedule=constant(0.01), seed=SEED,
        pipeline=PipelineConfig(queue_depth=1, lockstep=True, rho_bar=INF,
                                c_bar=INF, rollout_plane=plane,
                                mesh_shape=mesh_shape),
    )
    res = prl.run(ITERS)
    print(
        f"{plane + (f'[{mesh_shape}]' if plane == 'mesh' else ''):>10}: "
        f"reward/iter={res.mean_metrics['reward_sum']:+.3f} "
        f"loss={res.mean_metrics['loss']:+.5f} "
        f"steps/s={res.timesteps_per_sec:,.0f}"
    )
    return res


sync_rl = ParallelRL(GridWorld(n_envs=32, size=5), fresh_agent(),
                     optimizer="rmsprop", lr_schedule=constant(0.01),
                     seed=SEED)
sync = sync_rl.run(ITERS)
print(
    f"{'sync':>10}: reward/iter={sync.mean_metrics['reward_sum']:+.3f} "
    f"loss={sync.mean_metrics['loss']:+.5f} "
    f"steps/s={sync.timesteps_per_sec:,.0f}"
)

# host TrajectoryQueue (GA3C-style staging baseline), flat device ring,
# and the mesh machinery on one device — all bit-identical to sync
for plane in ("host", "device", "mesh"):
    res = run_plane(plane)
    for k in ("loss", "reward_sum", "policy_loss", "value_loss", "entropy"):
        assert res.mean_metrics[k] == sync.mean_metrics[k], (
            plane, k, res.mean_metrics[k], sync.mean_metrics[k])
print("all planes reproduce the synchronous metrics bit-for-bit")

# with more than one device visible, span the mesh for real: the env axis
# shards over the devices and the learner's gradients all-reduce (a bigger
# effective batch per update — same machinery, scaled, so the metrics are
# its own stream, not the single-stream pin above)
if len(jax.devices()) > 1:
    D = min(len(jax.devices()), 4)
    run_plane("mesh", mesh_shape=D)
    print(f"mesh[{D}]: env axis sharded over {D} devices, "
          "gradients all-reduced over the 'data' axis")
