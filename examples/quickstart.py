"""Quickstart: PAAC (paper Algorithm 1) on GridWorld in ~20 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.configs import get_config
from repro.core import ParallelRL
from repro.core.agents import PAACAgent, PAACConfig
from repro.envs import GridWorld
from repro.optim import constant

# n_e parallel environments — one vectorized JAX program (paper §3)
env = GridWorld(n_envs=32, size=5)
cfg = get_config("paac_vector").replace(
    obs_shape=env.obs_shape, num_actions=env.num_actions
)
agent = PAACAgent(cfg, PAACConfig(t_max=5, gamma=0.99, entropy_beta=0.01))
rl = ParallelRL(env, agent, optimizer="rmsprop", lr_schedule=constant(0.01))

for epoch in range(8):
    res = rl.run(50)
    print(
        f"epoch {epoch}: steps={res.steps:6d} "
        f"reward/iter={res.mean_metrics['reward_sum']:+.3f} "
        f"episodes={res.episodes:.0f} steps/s={res.timesteps_per_sec:,.0f}"
    )
