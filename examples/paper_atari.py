"""The paper's exact setting at CPU scale: arch_nips CNN on an 84×84×4 pixel
environment with the §5.1 pipeline (frame stack, action repeat, no-op starts)
and §5.1 hyperparameters (n_e=32, t_max=5, RMSProp decay .99 eps .1,
clip 40, lr 0.0007·n_e).

    PYTHONPATH=src python examples/paper_atari.py [--iters 150]
"""
import argparse

from repro.configs import get_config
from repro.core import ParallelRL
from repro.core.agents import PAACAgent, PAACConfig
from repro.envs import AtariLike, FrameStack
from repro.optim import constant

ap = argparse.ArgumentParser()
ap.add_argument("--iters", type=int, default=150)
ap.add_argument("--n-envs", type=int, default=32)
ap.add_argument("--arch", default="paac_nips", choices=("paac_nips", "paac_nature"))
args = ap.parse_args()

env = FrameStack(AtariLike(args.n_envs), n=4)
cfg = get_config(args.arch).replace(
    obs_shape=env.obs_shape, num_actions=env.num_actions
)
agent = PAACAgent(cfg, PAACConfig(gamma=0.99, entropy_beta=0.01, t_max=5))
rl = ParallelRL(
    env, agent, optimizer="rmsprop", lr_schedule=constant(0.0007 * args.n_envs)
)

for epoch in range(max(args.iters // 25, 1)):
    res = rl.run(25)
    print(
        f"epoch {epoch}: steps={res.steps:7d} "
        f"reward/iter={res.mean_metrics['reward_sum']:+.2f} "
        f"entropy={res.mean_metrics['entropy']:.3f} "
        f"steps/s={res.timesteps_per_sec:,.0f}"
    )
