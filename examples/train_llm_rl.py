"""End-to-end driver: train a ~100M-parameter transformer policy with PAAC
on the token environment for a few hundred steps (deliverable b).

The policy is a qwen2-family backbone scaled to ~100M params; the
environment is the k-back echo game (repro.envs.TokenEnv) — the action
space is the vocabulary, so the rollout is batched autoregressive acting,
exactly the paper's master/worker schedule applied to an LLM.

    PYTHONPATH=src python examples/train_llm_rl.py --iters 300
    PYTHONPATH=src python examples/train_llm_rl.py --smoke   # tiny, fast
"""
import argparse
import time

import jax

from repro.configs import get_config
from repro.core import ParallelRL
from repro.core.agents import PAACAgent, PAACConfig
from repro.envs import TokenEnv
from repro.optim import constant
from repro.utils.tree import tree_size

ap = argparse.ArgumentParser()
ap.add_argument("--iters", type=int, default=300)
ap.add_argument("--n-envs", type=int, default=8)
ap.add_argument("--smoke", action="store_true")
args = ap.parse_args()

VOCAB = 64
if args.smoke:
    cfg = get_config("qwen2-7b").reduced().replace(
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=VOCAB, num_actions=VOCAB,
    )
else:
    # ~100M params: 12L, d_model 768, d_ff 2048
    cfg = get_config("qwen2-7b").replace(
        num_layers=12, d_model=768, num_heads=12, num_kv_heads=4, head_dim=64,
        d_ff=2048, vocab_size=VOCAB, num_actions=VOCAB,
        param_dtype="float32", compute_dtype="float32", remat="none",
    )

env = TokenEnv(args.n_envs, vocab=VOCAB, ctx=16, k=2, horizon=32)
agent = PAACAgent(cfg, PAACConfig(t_max=4, entropy_beta=0.005))
rl = ParallelRL(env, agent, optimizer="adam", lr_schedule=constant(1e-3))
n_params = tree_size(rl.params)
print(f"policy params: {n_params/1e6:.1f}M ({cfg.num_layers}L d={cfg.d_model})")

steps_per_iter = args.n_envs * 4
chunk = 25
for epoch in range((args.iters + chunk - 1) // chunk):
    t0 = time.time()
    res = rl.run(chunk)
    r = res.mean_metrics["reward_sum"] / steps_per_iter
    print(
        f"iter {(epoch+1)*chunk:4d}: reward/step={r:.3f} "
        f"(random={1/VOCAB:.3f}, optimal=1.0) "
        f"loss={res.mean_metrics['loss']:.4f} "
        f"steps/s={res.timesteps_per_sec:,.0f} [{time.time()-t0:.1f}s]"
    )
