"""Reproduce the paper's stability argument: PAAC vs A3C-sim (stale grads)
vs GA3C-sim (policy lag) vs DQN on Catch.

    PYTHONPATH=src python examples/compare_baselines.py
"""
from benchmarks.baselines import run

if __name__ == "__main__":
    scores = run(iters=300)
    print()
    print("final reward/iteration (higher is better):")
    for name, score in sorted(scores.items(), key=lambda kv: -kv[1]):
        print(f"  {name:24s} {score:+.3f}")
