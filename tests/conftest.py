"""Test fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must see
1 device; only launch/dryrun.py forces the 512-device placeholder count."""
import jax
import pytest


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
