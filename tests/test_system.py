"""End-to-end behaviour of the paper's system.

* Algorithm 1 runs as one compiled program and improves the policy,
* the same framework trains a transformer policy on a token environment
  (the LLM instantiation used by the assigned architectures),
* the serving path (prefill + batched decode) emits coherent actions,
* PAAC's synchronous semantics: one parameter copy, deterministic across
  runs with the same seed.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import ParallelRL
from repro.core.agents import PAACAgent, PAACConfig
from repro.envs import GridWorld, TokenEnv
from repro.optim import constant


def _vector_cfg(env):
    return get_config("paac_vector").replace(
        obs_shape=env.obs_shape, num_actions=env.num_actions
    )


def test_algorithm1_end_to_end_improves():
    env = GridWorld(32, size=4, max_steps=30)
    agent = PAACAgent(_vector_cfg(env), PAACConfig(t_max=5))
    rl = ParallelRL(env, agent, lr_schedule=constant(0.01), seed=0)
    before = rl.run(20).mean_metrics["reward_sum"]
    rl.run(300)
    after = rl.run(20).mean_metrics["reward_sum"]
    assert after > before


def test_deterministic_same_seed():
    env = GridWorld(8, size=3, max_steps=10)
    cfg = _vector_cfg(env)

    def run():
        agent = PAACAgent(cfg, PAACConfig(t_max=3))
        rl = ParallelRL(env, agent, lr_schedule=constant(0.01), seed=123)
        rl.run(15)
        return rl.params

    p1, p2 = run(), run()
    for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_llm_policy_learns_token_env():
    """A tiny transformer (qwen2 family, reduced) learns the k-back echo game
    through the PAAC loop — the LLM instantiation of the framework."""
    env = TokenEnv(16, vocab=12, ctx=8, k=1, horizon=16)
    cfg = (
        get_config("qwen2-7b")
        .reduced()
        .replace(num_layers=1, d_model=64, head_dim=16, num_heads=4,
                 num_kv_heads=2, d_ff=128, vocab_size=12, num_actions=12)
    )
    agent = PAACAgent(cfg, PAACConfig(t_max=4, entropy_beta=0.005))
    rl = ParallelRL(env, agent, optimizer="adam", lr_schedule=constant(3e-3), seed=5)
    before = rl.run(15).mean_metrics["reward_sum"]
    rl.run(150)
    after = rl.run(15).mean_metrics["reward_sum"]
    # random = 1/12 per step; learned echo should be well above
    assert after > before + 5.0, (before, after)


def test_serve_path_batched_actions(key):
    from repro.launch.steps import build_serve_step
    from repro.models import init_policy, init_policy_cache

    cfg = get_config("mamba2-370m").reduced()
    params = init_policy(key, cfg)
    serve = jax.jit(build_serve_step(cfg))
    B, S = 4, 16
    cache = init_policy_cache(cfg, B, S)
    token = jnp.zeros((B, 1), jnp.int32)
    for t in range(5):
        key, sub = jax.random.split(key)
        token, value, cache = serve(params, cache, token,
                                    jnp.asarray(t, jnp.int32),
                                    jax.random.key_data(sub))
    assert token.shape == (B, 1)
    assert int(token.min()) >= 0 and int(token.max()) < cfg.vocab_size
    assert value.shape == (B,)


def test_single_parameter_copy_invariant():
    """The framework holds exactly one params tree and one optimizer state —
    the paper's synchronous-update invariant (no per-worker copies)."""
    env = GridWorld(4, size=3)
    agent = PAACAgent(_vector_cfg(env), PAACConfig(t_max=2))
    rl = ParallelRL(env, agent, lr_schedule=constant(0.01))
    assert isinstance(rl.params, dict)
    assert rl.agent_state is None  # PAAC keeps no lagged/duplicate params
