"""MoE routing invariants (hypothesis property tests + unit checks).

``hypothesis`` is a dev-extra (see requirements-dev.txt) — skip the module
cleanly when it isn't installed instead of erroring the whole collection.
"""
import pytest

pytest.importorskip("hypothesis")

import hypothesis.strategies as st  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from hypothesis import given, settings  # noqa: E402

from repro.configs import ArchConfig
from repro.models.moe import _route_group, init_moe, moe_forward


def _moe_cfg(E=4, k=2, d=32, ff=64, shared=0, cf=1.25):
    return ArchConfig(
        name="t", family="moe", d_model=d, num_experts=E, num_experts_per_tok=k,
        moe_d_ff=ff, num_shared_experts=shared, param_dtype="float32",
        compute_dtype="float32", moe_capacity_factor=cf, moe_group_size=4096,
    )


@settings(deadline=None, max_examples=20)
@given(
    seed=st.integers(0, 1000),
    T=st.integers(4, 64),
    E=st.sampled_from([2, 4, 8]),
    k=st.sampled_from([1, 2]),
)
def test_route_group_invariants(seed, T, E, k):
    key = jax.random.PRNGKey(seed)
    d = 16
    capacity = max(int(np.ceil(T * k * 1.25 / E)), 1)
    tokens = jax.random.normal(key, (T, d))
    logits = jax.random.normal(key, (T, E))
    buf, slot, top_w, aux, inv_tok, w_slot = _route_group(
        tokens, logits, k=k, capacity=capacity, E=E
    )
    # combine weights: non-negative, sum to 1 per token
    w = np.asarray(top_w)
    assert (w >= -1e-6).all()
    np.testing.assert_allclose(w.sum(-1), 1.0, rtol=1e-5)
    # every non-overflow slot holds the right token
    slot = np.asarray(slot)
    buf = np.asarray(buf).reshape(E * capacity, d)
    tok = np.asarray(tokens)
    for t in range(T):
        for j in range(k):
            s = slot[t, j]
            if s < E * capacity:
                np.testing.assert_allclose(buf[s], tok[t], rtol=1e-6)
    # no slot assigned twice
    used = slot[slot < E * capacity]
    assert len(np.unique(used)) == len(used)
    # aux loss ~ E * sum f_e P_e — near 1 at uniformity, positive always
    assert float(aux) > 0.5


def test_moe_forward_matches_dense_when_single_expert(key):
    """E=1, k=1 MoE == plain per-token expert matmul (no routing effects)."""
    cfg = _moe_cfg(E=1, k=1, cf=2.0)
    p = init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(key, (2, 8, cfg.d_model))
    y, aux = moe_forward(p, cfg, x)
    h = jax.nn.silu(x @ p["wi"][0]) * (x @ p["wg"][0])
    expect = h @ p["wo"][0]
    np.testing.assert_allclose(y, expect, rtol=2e-4, atol=2e-4)


def test_moe_capacity_drops_tokens(key):
    """With capacity factor << 1 some tokens must be dropped (output zeros)."""
    cfg = _moe_cfg(E=4, k=2, cf=0.1)
    p = init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(key, (1, 64, cfg.d_model))
    y_small, _ = moe_forward(p, cfg, x)
    y_big, _ = moe_forward(p, cfg.replace(moe_capacity_factor=8.0), x)
    # some tokens differ (dropped with small capacity)
    assert float(jnp.abs(y_small - y_big).max()) > 1e-3


def test_shared_experts_added(key):
    cfg = _moe_cfg(E=2, k=1, shared=1, cf=8.0)
    p = init_moe(key, cfg, jnp.float32)
    assert "shared" in p
    x = jax.random.normal(key, (1, 8, cfg.d_model))
    y, _ = moe_forward(p, cfg, x)
    from repro.models.mlp import mlp_forward

    y_no_shared, _ = moe_forward({k_: v for k_, v in p.items() if k_ != "shared"}, cfg, x)
    np.testing.assert_allclose(
        np.asarray(y - y_no_shared), np.asarray(mlp_forward(p["shared"], x)),
        rtol=2e-4, atol=2e-4,
    )
