"""HostEnvPool: the paper's n_w-worker path for external environments."""
import numpy as np

from repro.envs import HostEnvPool


class _ToyEnv:
    """Gym-style counter env: reward 1 when action == state % 3."""

    def __init__(self, seed):
        self.rng = np.random.RandomState(seed)
        self.state = 0

    def reset(self):
        self.state = int(self.rng.randint(0, 100))
        return np.array([self.state], np.float32)

    def step(self, action):
        reward = 1.0 if action == self.state % 3 else 0.0
        self.state += 1
        done = self.state % 10 == 0
        return np.array([self.state], np.float32), reward, done, {}


def test_host_env_pool_steps_in_parallel():
    n = 12
    pool = HostEnvPool([lambda s=i: _ToyEnv(s) for i in range(n)],
                       n_workers=4, obs_shape=(1,))
    obs = pool.reset()
    assert obs.shape == (n, 1)
    states = np.asarray(obs)[:, 0].astype(int)
    actions = states % 3  # always-correct actions
    obs2, rewards, dones = pool.step(actions)
    assert rewards.shape == (n,)
    assert float(np.asarray(rewards).min()) == 1.0  # every env rewarded
    # auto-reset happened for any env that hit done
    pool.close()


def test_host_env_pool_parallel_reset_covers_all_envs():
    n = 10
    pool = HostEnvPool([lambda s=i: _ToyEnv(s) for i in range(n)],
                       n_workers=3, obs_shape=(1,))
    obs = np.asarray(pool.reset())
    # every env was reset (each _ToyEnv seeds a deterministic first state)
    expect = np.array([[_ToyEnv(i).reset()[0]] for i in range(n)])
    np.testing.assert_array_equal(obs, expect)
    pool.close()


def test_host_env_pool_step_host_returns_shared_buffers():
    n = 4
    pool = HostEnvPool([lambda s=i: _ToyEnv(s) for i in range(n)],
                       n_workers=2, obs_shape=(1,))
    pool.reset()
    obs, rewards, dones = pool.step_host(np.zeros((n,), np.int64))
    assert isinstance(obs, np.ndarray) and obs.shape == (n, 1)
    assert rewards.dtype == np.float32 and dones.dtype == bool
    pool.close()


def test_host_env_pool_context_manager_and_idempotent_close():
    closed = []

    class ClosableEnv(_ToyEnv):
        def close(self):
            closed.append(id(self))

    with HostEnvPool([lambda s=i: ClosableEnv(s) for i in range(4)],
                     n_workers=2, obs_shape=(1,)) as pool:
        pool.reset()
    assert len(closed) == 4
    pool.close()  # second close is a no-op
    assert len(closed) == 4
