"""HostEnvPool: the paper's n_w-worker path for external environments."""
import numpy as np

from repro.envs import HostEnvPool


class _ToyEnv:
    """Gym-style counter env: reward 1 when action == state % 3."""

    def __init__(self, seed):
        self.rng = np.random.RandomState(seed)
        self.state = 0

    def reset(self):
        self.state = int(self.rng.randint(0, 100))
        return np.array([self.state], np.float32)

    def step(self, action):
        reward = 1.0 if action == self.state % 3 else 0.0
        self.state += 1
        done = self.state % 10 == 0
        return np.array([self.state], np.float32), reward, done, {}


def test_host_env_pool_steps_in_parallel():
    n = 12
    pool = HostEnvPool([lambda s=i: _ToyEnv(s) for i in range(n)],
                       n_workers=4, obs_shape=(1,))
    obs = pool.reset()
    assert obs.shape == (n, 1)
    states = np.asarray(obs)[:, 0].astype(int)
    actions = states % 3  # always-correct actions
    obs2, rewards, dones = pool.step(actions)
    assert rewards.shape == (n,)
    assert float(np.asarray(rewards).min()) == 1.0  # every env rewarded
    # auto-reset happened for any env that hit done
    pool.close()
