"""HostEnvPool: the paper's n_w-worker path for external environments."""
import pickle

import numpy as np
import pytest

from repro.envs import HostEnvPool, HostEnvSpec
from repro.envs.pyemu import make_py_bound_env


class _ToyEnv:
    """Gym-style counter env: reward 1 when action == state % 3."""

    def __init__(self, seed):
        self.rng = np.random.RandomState(seed)
        self.state = 0

    def reset(self):
        self.state = int(self.rng.randint(0, 100))
        return np.array([self.state], np.float32)

    def step(self, action):
        reward = 1.0 if action == self.state % 3 else 0.0
        self.state += 1
        done = self.state % 10 == 0
        return np.array([self.state], np.float32), reward, done, {}


def test_host_env_pool_steps_in_parallel():
    n = 12
    pool = HostEnvPool([lambda s=i: _ToyEnv(s) for i in range(n)],
                       n_workers=4, obs_shape=(1,))
    obs = pool.reset()
    assert obs.shape == (n, 1)
    states = np.asarray(obs)[:, 0].astype(int)
    actions = states % 3  # always-correct actions
    obs2, rewards, dones = pool.step(actions)
    assert rewards.shape == (n,)
    assert float(np.asarray(rewards).min()) == 1.0  # every env rewarded
    # auto-reset happened for any env that hit done
    pool.close()


def test_host_env_pool_parallel_reset_covers_all_envs():
    n = 10
    pool = HostEnvPool([lambda s=i: _ToyEnv(s) for i in range(n)],
                       n_workers=3, obs_shape=(1,))
    obs = np.asarray(pool.reset())
    # every env was reset (each _ToyEnv seeds a deterministic first state)
    expect = np.array([[_ToyEnv(i).reset()[0]] for i in range(n)])
    np.testing.assert_array_equal(obs, expect)
    pool.close()


def test_host_env_pool_step_host_returns_shared_buffers():
    n = 4
    pool = HostEnvPool([lambda s=i: _ToyEnv(s) for i in range(n)],
                       n_workers=2, obs_shape=(1,))
    pool.reset()
    obs, rewards, dones = pool.step_host(np.zeros((n,), np.int64))
    assert isinstance(obs, np.ndarray) and obs.shape == (n, 1)
    assert rewards.dtype == np.float32 and dones.dtype == bool
    pool.close()


def test_host_env_device_outputs_never_alias_shared_buffers():
    """Regression: ``reset``/``step`` must snapshot the shared host buffers.
    jnp.asarray can zero-copy an aligned numpy array on CPU, in which case
    the workers' in-place writes on later steps silently mutate an
    already-returned observation (flaky, alignment-dependent)."""
    n = 6
    with HostEnvPool([lambda s=i: _ToyEnv(s) for i in range(n)],
                     n_workers=2, obs_shape=(1,)) as pool:
        obs0 = pool.reset()
        snap0 = np.asarray(obs0).copy()
        obs1, _, _ = pool.step(np.zeros((n,), np.int64))
        snap1 = np.asarray(obs1).copy()
        pool.step(np.ones((n,), np.int64))
        np.testing.assert_array_equal(np.asarray(obs0), snap0)
        np.testing.assert_array_equal(np.asarray(obs1), snap1)
    # shards snapshot too
    with HostEnvPool([lambda s=i: _ToyEnv(s) for i in range(n)],
                     n_workers=2, obs_shape=(1,)) as pool:
        shard = pool.shard(2)[0]
        obs0 = shard.reset()
        snap0 = np.asarray(obs0).copy()
        shard.step(np.zeros((shard.n_envs,), np.int64))
        np.testing.assert_array_equal(np.asarray(obs0), snap0)


def test_host_env_pool_shard_partitions_env_axis():
    """Shards cover disjoint contiguous slices and step independently."""
    n = 8
    with HostEnvPool([lambda s=i: _ToyEnv(s) for i in range(n)],
                     n_workers=4, obs_shape=(1,)) as pool:
        shards = pool.shard(4)
        assert [s.n_envs for s in shards] == [2, 2, 2, 2]
        obs = np.concatenate([np.asarray(s.reset()) for s in shards])
        expect = np.array([[_ToyEnv(i).reset()[0]] for i in range(n)])
        np.testing.assert_array_equal(obs, expect)
        # stepping shard 1 leaves shard 0's envs untouched
        before = [e.state for e in shards[0].envs]
        shards[1].step_host(np.zeros((2,), np.int64))
        assert [e.state for e in shards[0].envs] == before
        with pytest.raises(ValueError):
            pool.shard(3)  # 8 envs don't split into 3 equal shards


def test_host_env_obs_dtype_property():
    """Pool and shard expose the observation dtype the pipeline's staging
    rings preallocate against."""
    n = 4
    with HostEnvPool([lambda s=i: _ToyEnv(s) for i in range(n)],
                     n_workers=2, obs_shape=(1,)) as pool:
        assert pool.obs_dtype == np.float32
        assert pool.shard(2)[0].obs_dtype == np.float32


def test_stepping_closed_pool_raises_diagnosable_error():
    """Regression: step/reset on a closed pool used to die inside the
    executor with an opaque 'cannot schedule new futures after shutdown' —
    indistinguishable from an env crash during multi-process teardown."""
    n = 4
    pool = HostEnvPool([lambda s=i: _ToyEnv(s) for i in range(n)],
                       n_workers=2, obs_shape=(1,))
    pool.reset()
    shard = pool.shard(2)[0]
    shard.reset()
    pool.close()
    with pytest.raises(RuntimeError, match="closed env pool"):
        pool.step_host(np.zeros((n,), np.int64))
    with pytest.raises(RuntimeError, match="closed env pool"):
        pool.reset()
    # shards inherit the parent's closed state (parent owns envs + executor)
    with pytest.raises(RuntimeError, match="closed env pool"):
        shard.step_host(np.zeros((shard.n_envs,), np.int64))
    with pytest.raises(RuntimeError, match="closed env pool"):
        shard.reset()
    with pytest.raises(RuntimeError, match="closed"):
        pool.shard(2)


# ---------------------------------------------------------------------------
# HostEnvSpec — the picklable pool recipe (process actor plane contract)
# ---------------------------------------------------------------------------


def test_host_env_spec_builds_equivalent_pool():
    spec = HostEnvSpec(
        env_fn=make_py_bound_env,
        env_args=tuple((i, 3, 0) for i in range(6)),
        n_workers=2, obs_shape=(3,), obs_dtype=np.float32,
    )
    assert spec.n_envs == 6
    with spec.build() as pool:
        obs = np.asarray(pool.reset())
        assert obs.shape == (6, 3)
        expect = np.array([make_py_bound_env(i, 3, 0).reset()
                           for i in range(6)])
        np.testing.assert_array_equal(obs, expect)


def test_host_env_spec_shard_partitions_args_and_workers():
    spec = HostEnvSpec(
        env_fn=make_py_bound_env,
        env_args=tuple((i, 2, 0) for i in range(8)),
        n_workers=4, obs_shape=(2,),
    )
    shards = spec.shard(2)
    assert [s.n_envs for s in shards] == [4, 4]
    assert shards[0].env_args == spec.env_args[:4]
    assert shards[1].env_args == spec.env_args[4:]
    assert all(s.n_workers == 2 for s in shards)  # concurrency budget split
    with pytest.raises(ValueError):
        spec.shard(3)  # 8 envs don't split into 3 equal shards


def test_host_env_spec_pickles_and_rejects_closures():
    good = HostEnvSpec(env_fn=make_py_bound_env,
                       env_args=((0, 2, 0),), obs_shape=(2,))
    good.validate_picklable()
    rebuilt = pickle.loads(pickle.dumps(good))
    assert rebuilt.env_args == good.env_args
    bad = HostEnvSpec(env_fn=lambda s: _ToyEnv(s), env_args=((0,),),
                      obs_shape=(1,))
    with pytest.raises(ValueError, match="module-level"):
        bad.validate_picklable()


def test_host_env_pool_context_manager_and_idempotent_close():
    closed = []

    class ClosableEnv(_ToyEnv):
        def close(self):
            closed.append(id(self))

    with HostEnvPool([lambda s=i: ClosableEnv(s) for i in range(4)],
                     n_workers=2, obs_shape=(1,)) as pool:
        pool.reset()
    assert len(closed) == 4
    pool.close()  # second close is a no-op
    assert len(closed) == 4
