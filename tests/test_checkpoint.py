"""Checkpointer round-trips + pipeline full-state checkpoint/resume.

Pins the checkpoint plane's contracts:

* pytree round-trips are dtype- and residency-faithful: bf16 leaves come
  back bf16 **bitwise** (saved as lossless f32 — numpy has no bf16),
  numpy leaves stay numpy, python scalars survive, and the json manifest
  records each leaf's logical dtype,
* ``latest_step`` is anchored — prefix look-alikes never shadow the real
  series,
* the tentpole: kill a pipelined run mid-flight and resume from its last
  checkpoint — under depth-1 lockstep with infinite clips the resumed
  run's params are **bitwise identical** to the uninterrupted run's
  (params, opt state, learner step counter, per-actor RNG/env/obs state
  and seq numbering all restore exactly; in-flight rollouts re-collect),
* the host plane resumes warm (params/counters exact, envs re-reset) and
  keeps running.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import (
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.configs import PipelineConfig, get_config
from repro.core.agents import PAACAgent, PAACConfig
from repro.envs import GridWorld, HostEnvPool
from repro.pipeline import FaultPlan, PipelinedRL


def _leaves(tree):
    return jax.tree_util.tree_leaves(tree)


# ---------------------------------------------------------------------------
# checkpointer round-trips
# ---------------------------------------------------------------------------


def test_bf16_roundtrip_is_bitwise(tmp_path):
    key = jax.random.PRNGKey(0)
    tree = {
        "w": jax.random.normal(key, (16, 8), jnp.bfloat16),
        "b": jnp.arange(8, dtype=jnp.bfloat16) / 3,
        "f32": jax.random.normal(key, (4,), jnp.float32),
    }
    save_checkpoint(str(tmp_path), 1, tree)
    back = restore_checkpoint(str(tmp_path), 1, tree)
    for k in tree:
        assert back[k].dtype == tree[k].dtype, k
        # bitwise: compare the raw bit patterns, not approximate values
        np.testing.assert_array_equal(
            np.asarray(back[k]).view(np.uint8),
            np.asarray(tree[k]).view(np.uint8), err_msg=k)


def test_scalar_and_numpy_leaves_roundtrip(tmp_path):
    tree = {
        "step": 42,
        "lr": 0.125,
        "host_obs": np.arange(6, dtype=np.float32).reshape(2, 3),
        "counters": np.asarray([3, 5], np.int64),
        "key": jax.random.PRNGKey(7),
    }
    save_checkpoint(str(tmp_path), 3, tree)
    back = restore_checkpoint(str(tmp_path), 3, tree)
    assert back["step"] == 42 and isinstance(back["step"], int)
    assert back["lr"] == 0.125
    # numpy stays numpy: a host-plane resume must not promote to device
    assert type(back["host_obs"]) is np.ndarray
    np.testing.assert_array_equal(back["host_obs"], tree["host_obs"])
    np.testing.assert_array_equal(back["counters"], tree["counters"])
    np.testing.assert_array_equal(np.asarray(back["key"]),
                                  np.asarray(tree["key"]))


def test_manifest_records_logical_dtypes(tmp_path):
    tree = {"w": jnp.zeros((2,), jnp.bfloat16), "n": 7}
    save_checkpoint(str(tmp_path), 2, tree, prefix="pipe")
    with open(os.path.join(str(tmp_path), "pipe_0000000002.json")) as f:
        manifest = json.load(f)
    assert manifest["step"] == 2
    assert manifest["dtypes"]["w"] == "bfloat16"


def test_latest_step_is_anchored(tmp_path):
    for name in ("pipe_0000000003.npz", "pipe_0000000001.npz",
                 "pipe_extra_0000000009.npz", "xpipe_0000000008.npz"):
        (tmp_path / name).write_bytes(b"")
    assert latest_step(str(tmp_path), prefix="pipe") == 3
    assert latest_step(str(tmp_path), prefix="nope") is None
    assert latest_step(str(tmp_path / "missing")) is None


def test_restore_rejects_shape_mismatch(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"w": np.zeros((4,), np.float32)})
    with pytest.raises(AssertionError):
        restore_checkpoint(str(tmp_path), 1,
                           {"w": np.zeros((5,), np.float32)})


# ---------------------------------------------------------------------------
# pipeline checkpoint/resume
# ---------------------------------------------------------------------------


def _grid_rl(tmp_dir="", every=0, fault_plan=None, seed=1):
    env = GridWorld(8, size=4, max_steps=20)
    cfg = get_config("paac_vector").replace(
        obs_shape=env.obs_shape, num_actions=env.num_actions)
    agent = PAACAgent(cfg, PAACConfig(t_max=5))
    inf = float("inf")
    return PipelinedRL(
        env, agent, seed=seed,
        pipeline=PipelineConfig(
            queue_depth=1, rho_bar=inf, c_bar=inf, lockstep=True,
            checkpoint_dir=str(tmp_dir), checkpoint_every=every,
            fault_plan=fault_plan),
    )


def test_kill_and_resume_is_bitwise_vs_uninterrupted(tmp_path):
    """The acceptance pin: run A uninterrupted; run B checkpoints every 3
    updates and is killed mid-run by an injected fault; run C restores B's
    newest checkpoint and runs the remainder. Under depth-1 lockstep with
    infinite clips C's params equal A's bit for bit."""
    total = 8
    rl_a = _grid_rl()
    rl_a.run(total)

    rl_b = _grid_rl(tmp_dir=tmp_path, every=3,
                    fault_plan=FaultPlan(kills=((0, 5, "error"),)))
    with pytest.raises(RuntimeError):
        rl_b.run(total)
    assert latest_step(str(tmp_path), prefix="pipe") == 3

    rl_c = _grid_rl(tmp_dir=tmp_path)
    done = rl_c.restore()
    assert done == 3
    assert rl_c.total_steps == rl_b._steps_per_iter * 3
    res = rl_c.run(total - done)
    assert np.isfinite(res.mean_metrics["loss"])
    # params AND opt state bitwise equal the uninterrupted run's
    for a, c in zip(_leaves(rl_a.params), _leaves(rl_c.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))
    for a, c in zip(_leaves(rl_a.opt_state), _leaves(rl_c.opt_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))
    assert rl_c.total_steps == rl_a.total_steps
    # seq numbering continued where the consumed stream left off
    assert [s for _, s in rl_c.learned_ids] == list(range(3, total))


def test_resume_with_empty_dir_is_noop(tmp_path):
    rl = _grid_rl(tmp_dir=tmp_path)
    assert rl.restore() == 0
    with pytest.raises(ValueError, match="checkpoint dir"):
        _grid_rl().restore()


def test_periodic_checkpoints_accumulate(tmp_path):
    rl = _grid_rl(tmp_dir=tmp_path, every=2)
    rl.run(5)
    # checkpoints at updates 2 and 4; latest wins
    assert latest_step(str(tmp_path), prefix="pipe") == 4
    names = sorted(n for n in os.listdir(tmp_path) if n.endswith(".npz"))
    assert names == ["pipe_0000000002.npz", "pipe_0000000004.npz"]


class _ToyGymEnv:
    def __init__(self, seed):
        self.rng = np.random.RandomState(seed)
        self.state = 0

    def reset(self):
        self.state = int(self.rng.randint(0, 100))
        return np.array([self.state % 7], np.float32)

    def step(self, action):
        reward = 1.0 if action == self.state % 3 else 0.0
        self.state += 1
        return np.array([self.state % 7], np.float32), reward, \
            self.state % 10 == 0, {}


def test_host_plane_checkpoint_and_warm_resume(tmp_path):
    """Host pool: env state lives inside the pool workers, so a resume is
    warm — params/opt/counters restore exactly, the policy-input obs
    restores from its copied snapshot, and the run keeps going."""
    cfg = get_config("paac_vector").replace(obs_shape=(1,), num_actions=3)
    agent = PAACAgent(cfg, PAACConfig(t_max=3))

    def pool():
        return HostEnvPool([lambda s=i: _ToyGymEnv(s) for i in range(4)],
                           n_workers=2, obs_shape=(1,))

    with pool() as p:
        rl = PipelinedRL(
            p, agent, seed=0,
            pipeline=PipelineConfig(queue_depth=2,
                                    checkpoint_dir=str(tmp_path),
                                    checkpoint_every=2))
        rl.run(4)
        saved_params = jax.tree_util.tree_map(np.asarray, rl.params)
    with pool() as p:
        rl2 = PipelinedRL(
            p, agent, seed=0,
            pipeline=PipelineConfig(queue_depth=2,
                                    checkpoint_dir=str(tmp_path)))
        done = rl2.restore()
        assert done == 4
        for a, b in zip(_leaves(saved_params), _leaves(rl2.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        res = rl2.run(2)
    assert np.isfinite(res.mean_metrics["loss"])
    assert rl2.total_steps == 6 * 4 * 3
