"""Environment invariants: shapes, auto-reset, reward ranges, determinism."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.envs import AtariLike, CartPole, Catch, FrameStack, GridWorld, TokenEnv

ENVS = [
    lambda n: GridWorld(n, size=4, max_steps=12),
    lambda n: Catch(n, rows=6, cols=5),
    lambda n: CartPole(n, max_steps=20),
    lambda n: TokenEnv(n, vocab=16, ctx=8, k=2, horizon=10),
    lambda n: FrameStack(AtariLike(n, lives=1), n=4),
]


@pytest.mark.parametrize("make_env", ENVS)
def test_env_contract(make_env, key):
    n = 5
    env = make_env(n)
    state = env.reset(key)
    obs = env.observe(state)
    assert obs.shape == (n,) + tuple(env.obs_shape)
    for i in range(30):
        key, k_act, k_step = jax.random.split(key, 3)
        actions = jax.random.randint(k_act, (n,), 0, env.num_actions)
        state, obs, reward, done = env.step(state, actions, k_step)
        assert obs.shape == (n,) + tuple(env.obs_shape)
        assert reward.shape == (n,) and reward.dtype == jnp.float32
        assert done.shape == (n,) and done.dtype == bool
        assert not bool(jnp.isnan(obs).any()) if jnp.issubdtype(obs.dtype, jnp.floating) else True


def test_env_step_is_jittable(key):
    env = GridWorld(8, size=4)
    state = env.reset(key)
    step = jax.jit(env.step)
    actions = jnp.zeros((8,), jnp.int32)
    state, obs, r, d = step(state, actions, key)
    assert obs.shape == (8, 16)


def test_gridworld_goal_reward(key):
    env = GridWorld(1, size=3, max_steps=50)
    state = env.reset(key)
    # place agent next to goal deterministically
    state = {
        "pos": jnp.array([[0, 0]]),
        "goal": jnp.array([[0, 1]]),
        "t": jnp.zeros((1,), jnp.int32),
    }
    state2, obs, reward, done = env.step(state, jnp.array([0]), key)  # move +y
    assert float(reward[0]) == 1.0
    assert bool(done[0])


def test_token_env_echo_reward(key):
    env = TokenEnv(1, vocab=8, ctx=6, k=2, horizon=10)
    state = env.reset(key)
    target = state["hist"][:, -2]  # correct action: token from k=2 back
    _, _, reward, _ = env.step(state, target, key)
    assert float(reward[0]) == 1.0
    state = env.reset(key)
    wrong = (state["hist"][:, -2] + 1) % 8
    _, _, reward, _ = env.step(state, wrong, key)
    assert float(reward[0]) == 0.0


def test_auto_reset(key):
    env = Catch(4, rows=4, cols=3)
    state = env.reset(key)
    done_seen = False
    for _ in range(10):
        key, k = jax.random.split(key)
        state, obs, r, done = env.step(state, jnp.ones((4,), jnp.int32), k)
        if bool(done.any()):
            done_seen = True
            # after auto-reset, the ball is back near the top for done envs
            assert int(state["ball"][jnp.argmax(done), 0]) <= 1
    assert done_seen


def test_framestack_shapes(key):
    env = FrameStack(AtariLike(3, lives=1), n=4)
    state = env.reset(key)
    obs = env.observe(state)
    assert obs.shape == (3, 84, 84, 4)
    state, obs2, r, d = env.step(state, jnp.zeros((3,), jnp.int32), key)
    # newest frame at the end; stack shifted
    np.testing.assert_allclose(obs[..., 1:][0], np.asarray(obs2[..., :-1])[0])
