"""Serving plane: contract, property, and bitwise-equivalence pins.

Four layers, mirroring the plane's own (``docs/serving.md``):

* **slot-cache contract** — ``KVSlotCache``'s lease discipline is the
  ring's ownership-transfer contract on cache rows: FIFO slot reuse,
  loud double-free / wrong-owner / use-after-free, evict-as-forced-
  reclaim, close-stops-leases-but-drains. Same suite shape as
  ``tests/test_pipeline.py``'s ring tests.
* **scheduler properties** (hypothesis, when installed) — driven by a
  ``MockEngine`` so the properties are about the scheduler alone: FIFO
  admission fairness, request conservation (every admitted request
  completes or errors exactly once — nothing lost, nothing duplicated,
  no starvation under random join/leave), and the slot bound (resident
  requests never exceed capacity).
* **bitwise equivalence** — the headline pin: a request's sampled tokens
  under continuous batching with random co-resident traffic are bitwise
  identical to a solo lockstep run of the same ``(prompt, seed)`` on the
  same-width engine. Pinned across an attention (qwen2-7b) and an SSM
  (mamba2-370m) backbone, per ``ROADMAP.md``'s bitwise-parity bar.
* **launcher + telemetry** — serving spans land in the Chrome trace with
  the serving category table, heartbeat lines carry the
  ``serve_queue_depth``/``serve_active_slots`` gauges, and the demo's
  PRNG streams are split, not reused (the key-reuse regression).
"""
import json
import os
import sys
import time

import numpy as np
import pytest

from repro.configs import get_config
from repro.pipeline.queue import TrajectoryQueue
from repro.serving import (
    DONE,
    ERRORED,
    DecodeEngine,
    KVSlotCache,
    OpenLoopTraffic,
    Request,
    Scheduler,
    SlotCacheClosed,
    SlotError,
    SlotsExhausted,
    make_requests,
)
from repro.telemetry import Telemetry

try:  # hypothesis is a dev-extra; the contract tests below run without it
    import hypothesis
    import hypothesis.strategies as st
    from hypothesis import given

    hypothesis.settings.register_profile("ci", deadline=None, max_examples=25)
    hypothesis.settings.register_profile("dev", deadline=None,
                                         max_examples=100)
    hypothesis.settings.load_profile(
        os.environ.get("HYPOTHESIS_PROFILE", "ci"))
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised by the tier-1 CI job
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# slot-cache contract (the ring's ownership discipline on cache rows)
# ---------------------------------------------------------------------------


def test_allocate_is_fifo_and_free_recycles_in_order():
    c = KVSlotCache(3)
    assert [c.allocate(f"r{i}") for i in range(3)] == [0, 1, 2]
    c.free(1, "r1")
    c.free(0, "r0")
    # oldest-freed first, like the ring's ticket order
    assert c.allocate("r3") == 1
    assert c.allocate("r4") == 0
    assert c.active_count == 3 and c.free_count == 0
    assert c.leases_issued == 5


def test_exhaustion_raises_instead_of_blocking():
    c = KVSlotCache(1)
    c.allocate("a")
    with pytest.raises(SlotsExhausted):
        c.allocate("b")
    c.free(0, "a")
    assert c.allocate("b") == 0


def test_double_free_and_wrong_owner_are_loud():
    c = KVSlotCache(2)
    s = c.allocate("owner")
    with pytest.raises(SlotError, match="wrong-owner"):
        c.free(s, "intruder")
    c.free(s, "owner")
    with pytest.raises(SlotError, match="double-free"):
        c.free(s, "owner")


def test_use_after_free_is_loud_on_the_read_side():
    c = KVSlotCache(2)
    s = c.allocate("a")
    c.allocate("x")  # occupy the other slot so s is the next reuse
    c.assert_owner(s, "a")
    c.free(s, "a")
    with pytest.raises(SlotError, match="use-after-free"):
        c.owner_of(s)
    # slot reused by someone else: the stale handle's check still fails
    assert c.allocate("b") == s
    with pytest.raises(SlotError, match="use-after-free"):
        c.assert_owner(s, "a")


def test_evict_reclaims_and_reports_the_owner():
    c = KVSlotCache(2)
    s = c.allocate("victim")
    c.allocate("bystander")  # occupy the other slot
    assert c.evict(s) == "victim"
    assert c.evictions == 1
    with pytest.raises(SlotError):
        c.evict(s)  # already free
    assert c.allocate("next") == s  # slot is back in the pool


def test_close_stops_leases_but_drains_active_ones():
    c = KVSlotCache(2)
    s = c.allocate("a")
    c.close()
    assert c.closed
    with pytest.raises(SlotCacheClosed):
        c.allocate("b")
    c.free(s, "a")  # draining still works
    assert c.free_count == 2


def test_slot_range_and_capacity_validation():
    with pytest.raises(ValueError):
        KVSlotCache(0)
    c = KVSlotCache(2)
    with pytest.raises(SlotError, match="out of range"):
        c.free(7, "x")
    with pytest.raises(ValueError):
        c.allocate(None)


# ---------------------------------------------------------------------------
# request validation
# ---------------------------------------------------------------------------


def test_request_validates_prompt_and_budget():
    with pytest.raises(ValueError, match="non-empty 1-D"):
        Request(rid=0, prompt=np.zeros((2, 2), np.int32),
                max_new_tokens=4, seed=0)
    with pytest.raises(ValueError, match="max_new_tokens"):
        Request(rid=0, prompt=np.arange(4), max_new_tokens=0, seed=0)


# ---------------------------------------------------------------------------
# scheduler over a MockEngine (pure host logic, no jax)
# ---------------------------------------------------------------------------


class MockEngine:
    """Deterministic stand-in for DecodeEngine: token ``t`` of every
    request is the global step index; records an event log so tests can
    assert scheduling shape (waves vs mid-flight joins)."""

    def __init__(self, max_slots, max_len=10**6, overflow_at=None):
        self.max_slots = max_slots
        self.max_len = max_len
        self._overflow_at = overflow_at  # pos ceiling remaining() honors
        self._pos = [0] * max_slots
        self._resident = [False] * max_slots
        self._toks = [[] for _ in range(max_slots)]  # per-slot token log
        self._t = 0
        self.events = []  # ("admit"|"step"|"release", detail)
        self.max_resident = 0

    def admit(self, slot, prompt, seed):
        self._pos[slot] = len(prompt)
        self._resident[slot] = True
        self._toks[slot] = [1000 + seed]  # the prefill-sampled token (t=0)
        self.max_resident = max(self.max_resident, sum(self._resident))
        self.events.append(("admit", slot))

    def step(self):
        self._t += 1
        self._pos = [p + 1 for p in self._pos]
        for s in range(self.max_slots):
            if self._resident[s]:
                self._toks[s].append(self._t)
        self.events.append(("step", self._t))

    def harvest(self, slot, n):
        return np.asarray(self._toks[slot][:n], np.int32)

    def remaining(self, slot):
        cap = self._overflow_at if self._overflow_at else self.max_len
        return cap - self._pos[slot]

    def release(self, slot):
        self._pos[slot] = 0
        self._resident[slot] = False
        self.events.append(("release", slot))


def _feed(reqs, depth=None):
    q = TrajectoryQueue(depth=depth or (len(reqs) + 2))
    for r in reqs:
        q.put(r)
    q.producer_done()
    return q


def _mock_reqs(gens, prompt_len=4):
    return [Request(rid=i, prompt=np.arange(1, prompt_len + 1),
                    max_new_tokens=g, seed=i) for i, g in enumerate(gens)]


def test_continuous_completes_all_and_admits_fifo():
    eng = MockEngine(2)
    reqs = _mock_reqs([3, 1, 2, 5, 1])
    sched = Scheduler(eng, _feed(reqs), continuous=True)
    done = sched.run()
    assert sorted(r.rid for r in done) == [0, 1, 2, 3, 4]
    assert all(r.status == DONE for r in done)
    assert sched.admit_order == [0, 1, 2, 3, 4]  # FIFO admission
    assert eng.max_resident <= 2
    for r in done:
        assert r.tokens is not None and len(r.tokens) == r.max_new_tokens
        assert r._free is None and r.n_generated == r.max_new_tokens
    assert sched.slots.closed  # run() closes the pool on drain


def test_continuous_joins_mid_flight_lockstep_waits_for_wave():
    """With slots=2 and gens [4, 1, 1, 1]: continuous backfills the short
    requests while the long one decodes; lockstep drains each wave."""
    gens = [4, 1, 1, 1]
    cont = MockEngine(2)
    Scheduler(cont, _feed(_mock_reqs(gens)), continuous=True).run()
    lock = MockEngine(2)
    Scheduler(lock, _feed(_mock_reqs(gens)), continuous=False).run()
    # continuous: a new request joins while another is resident
    assert any(e[0] == "admit" and sum(cont._resident) >= 0
               for e in cont.events)
    joined_mid = False
    resident = 0
    for kind, _ in cont.events:
        if kind == "admit":
            joined_mid = joined_mid or resident > 0
            resident += 1
        elif kind == "release":
            resident -= 1
        elif kind == "step" and resident == 2:
            pass
    assert joined_mid
    # lockstep: every admit happens with an empty batch or during the
    # same wave-fill (never after a step with residents still active)
    resident = 0
    stepped_since_fill = False
    for kind, _ in lock.events:
        if kind == "admit":
            assert resident == 0 or not stepped_since_fill
            resident += 1
        elif kind == "step":
            stepped_since_fill = True
        elif kind == "release":
            resident -= 1
            if resident == 0:
                stepped_since_fill = False
    # lockstep idles finished rows: it needs at least as many steps
    assert lock._t >= cont._t


def test_oversized_request_errors_without_holding_a_slot():
    eng = MockEngine(2, max_len=8)
    good = Request(rid=0, prompt=np.arange(4), max_new_tokens=4, seed=0)
    bad = Request(rid=1, prompt=np.arange(4), max_new_tokens=40, seed=1)
    sched = Scheduler(eng, _feed([good, bad]), continuous=True)
    done = sched.run()
    by = {r.rid: r for r in done}
    assert by[0].status == DONE
    assert by[1].status == ERRORED and "max_len" in by[1].error
    assert by[1].slot is None and sched.slots.free_count == 2
    assert sched.admit_order == [0]  # never admitted


def test_overflow_evicts_errors_and_recycles_the_slot():
    # remaining() hits 0 after 2 decode steps; budget wants 10 tokens
    eng = MockEngine(1, max_len=100, overflow_at=6)
    r0 = Request(rid=0, prompt=np.arange(4), max_new_tokens=10, seed=0)
    r1 = Request(rid=1, prompt=np.arange(4), max_new_tokens=1, seed=1)
    sched = Scheduler(eng, _feed([r0, r1]), continuous=True)
    done = sched.run()
    by = {r.rid: r for r in done}
    assert by[0].status == ERRORED and "overflow" in by[0].error
    assert by[0].tokens is not None and len(by[0].tokens) >= 1  # partial
    assert sched.slots.evictions == 1
    assert by[1].status == DONE  # the evicted slot served the next request


def test_prefill_failure_returns_the_lease_and_errors_the_request():
    class FailingEngine(MockEngine):
        def admit(self, slot, prompt, seed):
            if seed == 1:
                raise RuntimeError("prefill exploded")
            return super().admit(slot, prompt, seed)

    eng = FailingEngine(2)
    reqs = _mock_reqs([2, 2, 2])  # seeds == rids; rid 1 fails
    sched = Scheduler(eng, _feed(reqs), continuous=True)
    done = sched.run()
    by = {r.rid: r for r in done}
    assert by[1].status == ERRORED and "prefill exploded" in by[1].error
    assert by[0].status == DONE and by[2].status == DONE
    assert sched.slots.free_count == 2  # nothing leaked


def test_open_loop_traffic_thread_feeds_the_scheduler():
    eng = MockEngine(2)
    q = TrajectoryQueue(depth=4)
    traffic = OpenLoopTraffic(q, 6, seed=3, rate_hz=200.0,
                              prompt_lens=(2, 4), gen_range=(1, 3))
    sched = Scheduler(eng, q, continuous=True)
    traffic.start()
    done = sched.run()
    traffic.join(timeout=10.0)
    assert sorted(r.rid for r in done) == list(range(6))
    assert all(r.status == DONE for r in done)
    assert all(r.t_submit > 0 and r.latency_s >= 0 for r in done)


# ---------------------------------------------------------------------------
# scheduler properties (hypothesis — dev extra)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @given(gens=st.lists(st.integers(1, 8), min_size=1, max_size=12),
           capacity=st.integers(1, 4),
           continuous=st.booleans())
    def test_property_conservation_and_fifo(gens, capacity, continuous):
        """Every request completes exactly once, in FIFO admission order,
        with exactly its token budget — under both scheduling modes."""
        eng = MockEngine(capacity)
        reqs = _mock_reqs(gens)
        sched = Scheduler(eng, _feed(reqs), continuous=continuous)
        done = sched.run()
        assert sorted(r.rid for r in done) == list(range(len(gens)))
        assert len({id(r) for r in done}) == len(done)  # exactly once
        assert sched.admit_order == list(range(len(gens)))
        for r in done:
            assert r.status == DONE
            assert len(r.tokens) == r.max_new_tokens  # no starvation
        assert eng.max_resident <= capacity  # slot bound

    @given(gens=st.lists(st.integers(1, 6), min_size=1, max_size=10),
           capacity=st.integers(1, 3),
           bad=st.sets(st.integers(0, 9)))
    def test_property_errors_conserve_and_free_slots(gens, capacity, bad):
        """Random prefill failures: every request still resolves exactly
        once (done or errored) and no slot leaks."""
        class Failing(MockEngine):
            def admit(self, slot, prompt, seed):
                if seed in bad:
                    raise RuntimeError("boom")
                return super().admit(slot, prompt, seed)

        eng = Failing(capacity)
        sched = Scheduler(eng, _feed(_mock_reqs(gens)), continuous=True)
        done = sched.run()
        assert sorted(r.rid for r in done) == list(range(len(gens)))
        for r in done:
            assert r.status == (ERRORED if r.seed in bad else DONE)
        assert sched.slots.free_count == capacity
        assert eng.max_resident <= capacity

    @given(data=st.data())
    def test_property_slot_cache_never_over_allocates(data):
        """Random allocate/free/evict interleavings keep the ledger sane:
        active never exceeds capacity, frees are exact, double ops raise."""
        capacity = data.draw(st.integers(1, 4))
        c = KVSlotCache(capacity)
        held = {}
        for i in range(data.draw(st.integers(1, 40))):
            op = data.draw(st.sampled_from(["alloc", "free", "evict"]))
            if op == "alloc":
                if len(held) == capacity:
                    with pytest.raises(SlotsExhausted):
                        c.allocate(f"r{i}")
                else:
                    held[c.allocate(f"r{i}")] = f"r{i}"
            elif op == "free" and held:
                slot = data.draw(st.sampled_from(sorted(held)))
                c.free(slot, held.pop(slot))
            elif op == "evict" and held:
                slot = data.draw(st.sampled_from(sorted(held)))
                assert c.evict(slot) == held.pop(slot)
            assert c.active_count == len(held) <= capacity
            assert c.active_count + c.free_count == capacity


# ---------------------------------------------------------------------------
# bitwise equivalence: continuous == solo lockstep, per request
# ---------------------------------------------------------------------------


def _solo_tokens(cfg, params, probe, W, L):
    """Run one request alone, lockstep, on a fresh same-width engine."""
    eng = DecodeEngine(cfg, params, max_slots=W, max_len=L)
    solo = Request(rid=probe.rid, prompt=probe.prompt.copy(),
                   max_new_tokens=probe.max_new_tokens, seed=probe.seed)
    Scheduler(eng, _feed([solo]), continuous=False).run()
    return solo.tokens


@pytest.mark.parametrize("arch", ["qwen2-7b", "mamba2-370m"])
def test_bitwise_continuous_equals_solo_lockstep(arch):
    """The pin: under continuous batching with random co-residents, each
    request's sampled tokens are bitwise identical to running it alone —
    same seed, same compiled fixed-width step, any co-residency."""
    jax = pytest.importorskip("jax")
    from repro.models import init_policy

    cfg = get_config(arch).reduced()
    params = init_policy(jax.random.PRNGKey(0), cfg)
    W, L = 3, 24
    reqs = make_requests(4, seed=11, prompt_lens=(4, 8), gen_range=(3, 8),
                         vocab=cfg.vocab_size)
    eng = DecodeEngine(cfg, params, max_slots=W, max_len=L)
    done = Scheduler(eng, _feed(reqs), continuous=True).run()
    by = {r.rid: r for r in done}
    assert all(r.status == DONE for r in done)
    for probe in reqs:
        solo = _solo_tokens(cfg, params, probe, W, L)
        assert np.array_equal(by[probe.rid].tokens, solo), (
            f"{arch} rid {probe.rid}: continuous "
            f"{by[probe.rid].tokens.tolist()} != solo {solo.tolist()}")


def test_engine_rejects_non_token_families():
    jax = pytest.importorskip("jax")
    from repro.models import init_policy

    cfg = get_config("qwen2-7b").reduced()
    params = init_policy(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError):
        DecodeEngine(cfg, params, max_slots=0, max_len=16)
    with pytest.raises(ValueError):
        DecodeEngine(cfg, params, max_slots=2, max_len=1)
    eng = DecodeEngine(cfg, params, max_slots=2, max_len=8)
    with pytest.raises(ValueError, match="headroom"):
        eng.admit(0, np.arange(8, dtype=np.int32), 0)


# ---------------------------------------------------------------------------
# launcher + telemetry
# ---------------------------------------------------------------------------


def test_demo_streams_are_split_not_reused():
    """Regression: the serve demo once fed init_policy's consumed key back
    into the prompt draw. The three streams must be pairwise distinct."""
    jax = pytest.importorskip("jax")
    from repro.launch.serve import demo_streams

    keys = demo_streams(0)
    data = [np.asarray(jax.random.key_data(k)) for k in keys]
    assert len(data) == 3
    for i in range(3):
        for j in range(i + 1, 3):
            assert not np.array_equal(data[i], data[j])
    root = np.asarray(jax.random.key_data(jax.random.PRNGKey(0)))
    for d in data:
        assert not np.array_equal(d, root)  # root is never handed out


def test_serving_spans_land_in_trace_with_serving_categories(tmp_path):
    hub = Telemetry()
    eng = MockEngine(2, max_len=100, overflow_at=6)
    reqs = [Request(rid=0, prompt=np.arange(4), max_new_tokens=10, seed=0),
            Request(rid=1, prompt=np.arange(4), max_new_tokens=1, seed=1)]
    Scheduler(eng, _feed(reqs), telemetry=hub).run()
    out = tmp_path / "trace.json"
    hub.write_trace(str(out))
    evs = json.loads(out.read_text())["traceEvents"]
    cats = {e["cat"] for e in evs if e.get("ph") == "X"}
    # the full serving vocabulary, including the forced-reclaim path
    assert {"admit", "prefill", "decode", "evict"} <= cats


def test_heartbeat_carries_serving_gauges(tmp_path):
    hub = Telemetry()
    path = tmp_path / "hb.jsonl"
    eng = MockEngine(2)
    q = TrajectoryQueue(depth=8, telemetry=hub)
    sched = Scheduler(eng, q, telemetry=hub)
    hub.heartbeat_start(str(path), interval=0.05)
    try:
        traffic = OpenLoopTraffic(q, 8, seed=5, rate_hz=100.0,
                                  prompt_lens=(2, 4), gen_range=(2, 5))
        traffic.start()
        sched.run()
        traffic.join(timeout=10.0)
        time.sleep(0.12)  # at least one tick after the run drains
    finally:
        hub.heartbeat_stop()
    lines = [json.loads(l) for l in path.read_text().splitlines()]
    assert lines, "heartbeat wrote no lines"
    for line in lines:  # schema: base keys plus the serving gauges
        assert "serve_queue_depth" in line
        assert "serve_active_slots" in line
        assert "steps" in line and "span_drops" in line
    assert lines[-1]["serve_active_slots"] == 0  # drained
    assert lines[-1]["steps"] == sched.steps


def test_serve_launcher_continuous_in_process(tmp_path):
    pytest.importorskip("jax")
    from repro.launch.serve import main

    trace = tmp_path / "serve_trace.json"
    hb = tmp_path / "serve_hb.jsonl"
    main(["--arch", "qwen2-7b", "--reduced", "--continuous",
          "--requests", "3", "--slots", "2", "--prompt-len", "8",
          "--gen", "4", "--trace", str(trace),
          "--metrics-jsonl", str(hb)])
    evs = json.loads(trace.read_text())["traceEvents"]
    cats = {e["cat"] for e in evs if e.get("ph") == "X"}
    assert {"admit", "prefill", "decode"} <= cats
    lines = [json.loads(l) for l in hb.read_text().splitlines()]
    assert lines and "serve_queue_depth" in lines[-1]


def test_example_wrapper_defaults_reduced_without_touching_argv(tmp_path):
    pytest.importorskip("jax")
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                    "examples"))
    try:
        from serve_batch import run
    finally:
        sys.path.pop(0)
    argv_before = list(sys.argv)
    run(["--arch", "qwen2-7b", "--batch", "2", "--prompt-len", "4",
         "--gen", "2"])
    assert sys.argv == argv_before  # no sys.argv mutation
