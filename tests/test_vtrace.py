"""V-trace invariants, deterministically (no dev extras required).

The hypothesis property suite in ``tests/test_returns.py`` fuzzes the same
invariants over random shapes/inputs; this module pins them on seeded
inputs so tier-1 (no ``hypothesis`` installed) still covers the V-trace
math, plus the Pallas-kernel/reference parity sweep.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.returns import n_step_returns, vtrace_returns
from repro.kernels import ref as R
from repro.kernels.vtrace import vtrace_returns_pallas


def _inputs(seed, E=4, T=9, rho_scale=0.5):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    return dict(
        rewards=jax.random.normal(ks[0], (E, T)),
        dones=jax.random.bernoulli(ks[1], 0.25, (E, T)),
        values=jax.random.normal(ks[2], (E, T)),
        bootstrap=jax.random.normal(ks[3], (E,)),
        rho=jnp.exp(rho_scale * jax.random.normal(ks[4], (E, T))),
    )


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_on_policy_equals_nstep(seed):
    """rho == 1 with ρ̄, c̄ >= 1: the recursion telescopes into n-step."""
    x = _inputs(seed)
    vs, pg_adv = vtrace_returns(
        x["rewards"], x["dones"], x["values"], x["bootstrap"],
        jnp.ones_like(x["rho"]), 0.97, rho_bar=1.0, c_bar=1.0,
    )
    ns = n_step_returns(x["rewards"], x["dones"], x["bootstrap"], 0.97)
    np.testing.assert_allclose(vs, ns, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(pg_adv, ns - x["values"], rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("seed", [0, 3])
def test_unclipped_equals_importance_weighted_nstep(seed):
    """ρ̄ = c̄ → ∞: v_s - V_s = Σ_t γ^{t-s} (Π nd·w) δ_t with raw ratios."""
    x = _inputs(seed)
    E, T = x["rewards"].shape
    vs, _ = vtrace_returns(
        x["rewards"], x["dones"], x["values"], x["bootstrap"], x["rho"],
        0.9, rho_bar=1e12, c_bar=1e12,
    )
    r = np.asarray(x["rewards"], np.float32)
    nd = 1.0 - np.asarray(x["dones"], np.float32)
    v = np.asarray(x["values"], np.float32)
    b = np.asarray(x["bootstrap"], np.float32)
    w = np.asarray(x["rho"], np.float32)
    v_next = np.concatenate([v[:, 1:], b[:, None]], axis=1)
    delta = w * (r + 0.9 * nd * v_next - v)
    expect = v.copy()
    for s in range(T):
        for t in range(s, T):
            disc = np.prod(nd[:, s:t] * w[:, s:t], axis=1) * 0.9 ** (t - s)
            expect[:, s] += disc * delta[:, t]
    np.testing.assert_allclose(vs, expect, rtol=1e-3, atol=1e-3)


def test_monotone_nonexpansive_in_c_bar():
    """Raising c̄ moves the targets monotonically (for nonnegative TD
    errors) and stops moving them at all once c̄ saturates the ratios."""
    x = _inputs(0)
    # values = 0, rewards >= 0 => every delta >= 0 => targets monotone in c̄
    rewards = jnp.abs(x["rewards"])
    zeros = jnp.zeros_like(x["values"])
    prev = None
    for c_bar in (0.0, 0.25, 0.5, 1.0, 2.0, 8.0):
        vs, _ = vtrace_returns(rewards, x["dones"], zeros,
                               jnp.zeros_like(x["bootstrap"]), x["rho"],
                               0.95, rho_bar=1e9, c_bar=c_bar)
        if prev is not None:
            assert (np.asarray(vs) >= np.asarray(prev) - 1e-5).all()
        prev = vs
    # saturation: c̄ at/above the max ratio is a fixed point of raising c̄
    cap = float(jnp.max(x["rho"]))
    vs_a, adv_a = vtrace_returns(x["rewards"], x["dones"], x["values"],
                                 x["bootstrap"], x["rho"], 0.95,
                                 rho_bar=1e9, c_bar=cap)
    vs_b, adv_b = vtrace_returns(x["rewards"], x["dones"], x["values"],
                                 x["bootstrap"], x["rho"], 0.95,
                                 rho_bar=1e9, c_bar=10.0 * cap)
    np.testing.assert_allclose(vs_a, vs_b, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(adv_a, adv_b, rtol=1e-6, atol=1e-6)


def test_c_bar_zero_is_one_step_td():
    """c̄ = 0 cuts all bootstrapping through future corrections: the target
    collapses to V + ρ̄-clipped one-step TD error."""
    x = _inputs(1)
    vs, _ = vtrace_returns(x["rewards"], x["dones"], x["values"],
                           x["bootstrap"], x["rho"], 0.9,
                           rho_bar=1.0, c_bar=0.0)
    v = np.asarray(x["values"], np.float32)
    nd = 1.0 - np.asarray(x["dones"], np.float32)
    b = np.asarray(x["bootstrap"], np.float32)
    v_next = np.concatenate([v[:, 1:], b[:, None]], axis=1)
    rc = np.minimum(np.asarray(x["rho"], np.float32), 1.0)
    td = v + rc * (np.asarray(x["rewards"], np.float32)
                   + 0.9 * nd * v_next - v)
    np.testing.assert_allclose(vs, td, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------- kernel
@pytest.mark.parametrize("E,T", [(1, 1), (5, 9), (32, 33), (17, 8)])
@pytest.mark.parametrize("rho_bar,c_bar", [(1.0, 1.0), (2.0, 1.0),
                                           (1e9, 1e9)])
def test_vtrace_kernel_matches_scan_and_ref(E, T, rho_bar, c_bar):
    x = _inputs(7, E=E, T=T)
    args = (x["rewards"], x["dones"], x["values"], x["bootstrap"], x["rho"],
            0.97, rho_bar, c_bar)
    vs_scan, adv_scan = vtrace_returns(*args)
    vs_ref, adv_ref = R.vtrace_returns_ref(*args)
    vs_k, adv_k = vtrace_returns_pallas(*args, block_e=8)
    np.testing.assert_allclose(vs_scan, vs_ref, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(adv_scan, adv_ref, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(vs_k, vs_scan, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(adv_k, adv_scan, rtol=1e-5, atol=1e-5)
