"""Replay plane: ``ReplayRing`` contract, sampling properties, bitwise pins.

Pins the off-policy subsystem's contracts:

* the core ``replay.py`` buffer rejects the two silent-garbage hazards
  (over-wide ``replay_add`` batches whose scatter indices collide;
  ``replay_sample`` on an empty buffer),
* ``ReplayRing`` mirrors the ``DeviceTrajectoryRing`` suite where the
  contracts coincide (device-only payloads, close-wakes-blocked-consumer,
  producer_done drain semantics, multi-producer validation) and inverts
  them where replay semantics demand (put never blocks — full ring evicts
  FIFO-by-ticket; get samples and *retains* slots),
* sampling properties (hypothesis, when installed): uniform draws are
  uniform within statistical bounds, only resident slots are ever drawn on
  a partially-filled ring, eviction retires strictly the oldest tickets,
  prioritized draw frequencies track the priorities,
* the staleness-0 equivalences: a depth-1 lockstep pipelined replay-DQN
  reproduces the serial ``SyncReplayDQN`` reference *bitwise* (threads and
  the ring add zero numerics), and replay-PAAC with infinite V-trace clips
  at capacity 1 reproduces synchronous ``ParallelRL`` bitwise (the
  V-trace-corrected update equals the on-policy update at staleness 0).
"""
import os
import queue as stdlib_queue
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import PipelineConfig, get_config
from repro.core import ParallelRL
from repro.core.agents import DQNAgent, DQNConfig, PAACAgent
from repro.core.agents.replay import replay_add, replay_init, replay_sample
from repro.core.rollout import Transition
from repro.envs import GridWorld
from repro.optim import constant
from repro.pipeline import (
    CLOSED,
    PipelinedRL,
    QueueClosed,
    ReplayRing,
    Rollout,
    SyncReplayDQN,
)

try:  # hypothesis is a dev-extra; the contract tests below run without it
    import hypothesis
    import hypothesis.strategies as st
    from hypothesis import given

    hypothesis.settings.register_profile("ci", deadline=None, max_examples=25)
    hypothesis.settings.register_profile("dev", deadline=None,
                                         max_examples=100)
    hypothesis.settings.load_profile(
        os.environ.get("HYPOTHESIS_PROFILE", "ci"))
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised by the tier-1 CI job
    HAVE_HYPOTHESIS = False


def _dev(x):
    return jnp.asarray(x)


def _mini_rollout(tag: float, version: int = 0, seq: int = 0,
                  E: int = 2, T: int = 3) -> Rollout:
    """A tiny device-resident Rollout whose arrays are filled with ``tag``
    (so a sampled payload identifies which put produced it)."""
    traj = Transition(
        obs=jnp.full((T, E, 2), tag, jnp.float32),
        action=jnp.zeros((T, E), jnp.int32),
        reward=jnp.full((T, E), tag, jnp.float32),
        done=jnp.zeros((T, E), bool),
        value=jnp.zeros((T, E), jnp.float32),
        logp=jnp.zeros((T, E), jnp.float32),
    )
    return Rollout(traj, jnp.full((E, 2), tag, jnp.float32),
                   behavior_version=version, actor_id=0, seq=seq,
                   release=None)


def _vector_cfg(env):
    return get_config("paac_vector").replace(
        obs_shape=env.obs_shape, num_actions=env.num_actions)


# ---------------------------------------------------------------------------
# core replay buffer hazards (repro.core.agents.replay)
# ---------------------------------------------------------------------------


def test_replay_add_overwide_batch_raises():
    """E > capacity means colliding scatter indices with unspecified write
    order — rejected at trace time, not sampled as garbage later."""
    buf = replay_init(4, (3,))
    E = 6
    with pytest.raises(ValueError, match="exceeds capacity"):
        replay_add(buf, jnp.zeros((E, 3)), jnp.zeros((E,), jnp.int32),
                   jnp.zeros((E,)), jnp.zeros((E, 3)), jnp.zeros((E,), bool))


def test_replay_add_exactly_at_capacity_ok():
    E = 4
    buf = replay_init(E, (3,))
    buf = replay_add(buf, jnp.ones((E, 3)), jnp.arange(E, dtype=jnp.int32),
                     jnp.ones((E,)), jnp.ones((E, 3)), jnp.zeros((E,), bool))
    assert int(buf["size"]) == E
    np.testing.assert_array_equal(np.asarray(buf["action"]), np.arange(E))


def test_replay_sample_empty_buffer_raises():
    buf = replay_init(8, (2,))
    with pytest.raises(ValueError, match="empty buffer"):
        replay_sample(buf, jax.random.PRNGKey(0), 4)


def test_replay_sample_after_add_draws_only_stored_rows():
    buf = replay_init(8, (2,))
    E = 3
    buf = replay_add(buf, jnp.ones((E, 2)), jnp.full((E,), 7, jnp.int32),
                     jnp.ones((E,)), jnp.ones((E, 2)), jnp.zeros((E,), bool))
    batch = replay_sample(buf, jax.random.PRNGKey(1), 16)
    # only the 3 written rows are drawable — never the zero-init tail
    np.testing.assert_array_equal(np.asarray(batch["action"]),
                                  np.full(16, 7))


def test_replay_sample_under_jit_traces():
    """The empty-buffer guard must not break the jitted scan path, where
    ``size`` is a tracer and the caller owns the invariant."""
    buf = replay_init(8, (2,))
    E = 2
    buf = replay_add(buf, jnp.ones((E, 2)), jnp.zeros((E,), jnp.int32),
                     jnp.ones((E,)), jnp.ones((E, 2)), jnp.zeros((E,), bool))
    sample = jax.jit(lambda b, k: replay_sample(b, k, 4))
    batch = sample(buf, jax.random.PRNGKey(0))
    assert batch["obs"].shape == (4, 2)


# ---------------------------------------------------------------------------
# ReplayRing contract (mirror of the DeviceTrajectoryRing suite)
# ---------------------------------------------------------------------------


def test_replay_ring_put_never_blocks_and_evicts_fifo():
    ring = ReplayRing(capacity=3)
    t0 = time.perf_counter()
    for i in range(7):
        ring.put(_dev(float(i)))
    assert time.perf_counter() - t0 < 1.0  # no backpressure, ever
    assert ring.tickets_issued == 7
    assert ring.evictions == 4
    assert ring.resident == 3
    # strictly the oldest tickets were retired
    assert ring.resident_tickets() == [4, 5, 6]
    payloads = sorted(float(p) for p in
                      ring.sample(jax.random.PRNGKey(0), 64))
    assert set(payloads) <= {4.0, 5.0, 6.0}
    assert ring.put_wait_s == 0.0  # plane-parity accounting


def test_replay_ring_rejects_host_payloads():
    ring = ReplayRing(capacity=2)
    with pytest.raises(TypeError, match="host"):
        ring.put(np.zeros(3))
    ring.put(_dev(1.0))  # device payloads still fine afterwards
    assert ring.resident == 1


def test_replay_ring_sample_retains_slots():
    ring = ReplayRing(capacity=4)
    for i in range(3):
        ring.put(_dev(float(i)))
    a = ring.sample(jax.random.PRNGKey(5), 8)
    b = ring.sample(jax.random.PRNGKey(5), 8)  # same key -> same draw
    assert [float(x) for x in a] == [float(x) for x in b]
    assert ring.resident == 3  # nothing consumed
    assert len(ring.last_sampled) == 8
    assert set(ring.last_sampled) <= {0, 1, 2}


def test_replay_ring_sample_empty_raises():
    ring = ReplayRing(capacity=4)
    with pytest.raises(stdlib_queue.Empty):
        ring.sample(jax.random.PRNGKey(0))


def test_replay_ring_get_is_ticket_paced():
    """One fresh put licenses exactly one get: residency alone never feeds
    the learner loop (what keeps quotas and lockstep meaningful)."""
    ring = ReplayRing(capacity=4, sample_seed=0)
    ring.put(_mini_rollout(1.0, version=0, seq=0))
    out = ring.get(timeout=1.0)
    assert isinstance(out, Rollout)
    assert out.actor_id == -2 and out.seq == 0
    assert float(out.traj.reward[0, 0]) == 1.0
    assert ring.resident == 1  # retained, not consumed
    with pytest.raises(stdlib_queue.Empty):
        ring.get(timeout=0.05)  # resident but no fresh ticket
    ring.put(_mini_rollout(2.0, version=1, seq=1))
    out2 = ring.get(timeout=1.0)
    assert out2.seq == 1  # consume index advances
    assert float(out2.traj.reward[0, 0]) in (1.0, 2.0)  # sampled, not FIFO


def test_replay_ring_get_batch_concat_and_min_version():
    ring = ReplayRing(capacity=4, batch_size=3, sample_seed=7)
    ring.put(_mini_rollout(1.0, version=0, seq=0))
    ring.put(_mini_rollout(2.0, version=5, seq=1))
    ring.get(timeout=1.0)  # consume ticket 0
    out = ring.get(timeout=1.0)
    # 3 sampled rollouts of E=2 envs concatenated along the env axis
    assert out.traj.reward.shape == (3, 6)
    assert out.last_obs.shape == (6, 2)
    # staleness reports the OLDEST experience in the batch
    assert out.behavior_version == min(
        0 if 0 in ring.last_sampled else 5,
        5 if 1 in ring.last_sampled else 0,
    )


def test_replay_ring_close_wakes_blocked_get():
    ring = ReplayRing(capacity=2)
    got = []

    def consumer():
        got.append(ring.get())

    t = threading.Thread(target=consumer)
    t.start()
    time.sleep(0.1)
    ring.close()
    t.join(timeout=2.0)
    assert not t.is_alive()
    assert got == [CLOSED]


def test_replay_ring_producer_done_drains_then_closes():
    ring = ReplayRing(capacity=4, producers=2)
    ring.put(_mini_rollout(1.0))
    ring.producer_done()
    ring.put(_mini_rollout(2.0, seq=1))  # second producer still live
    ring.producer_done()
    with pytest.raises(QueueClosed):
        ring.put(_dev(3.0))  # closed to producers
    # the consumer still drains the two fresh tickets before CLOSED
    assert isinstance(ring.get(timeout=1.0), Rollout)
    assert isinstance(ring.get(timeout=1.0), Rollout)
    assert ring.get(timeout=1.0) is CLOSED
    assert ring.get(timeout=1.0) is CLOSED  # idempotent


def test_replay_ring_update_priorities_skips_evicted():
    ring = ReplayRing(capacity=2, prioritized=True)
    for i in range(3):  # ticket 0 evicted
        ring.put(_dev(float(i)))
    ring.update_priorities([0, 1, 2], [9.0, 5.0, 3.0])
    slots = {t % 2: ring._slots[t % 2] for t in (1, 2)}
    assert slots[1 % 2].priority == 5.0
    assert slots[2 % 2].priority == 3.0
    ring.update_priorities([1], [0.0])  # clamped to the positive floor
    assert ring._slots[1 % 2].priority == pytest.approx(1e-6)


def test_replay_ring_new_slots_enter_at_max_priority():
    ring = ReplayRing(capacity=4, prioritized=True)
    ring.put(_dev(0.0))
    ring.update_priorities([0], [10.0])
    ring.put(_dev(1.0))  # fresh experience must be sampleable at least once
    assert ring._slots[1].priority == 10.0


def test_replay_ring_constructor_validation():
    with pytest.raises(ValueError):
        ReplayRing(capacity=0)
    with pytest.raises(ValueError):
        ReplayRing(batch_size=0)
    with pytest.raises(ValueError):
        ReplayRing(producers=0)


# ---------------------------------------------------------------------------
# sampling properties (hypothesis — dev extra)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @given(seed=st.integers(0, 2**31 - 1))
    def test_uniform_sampling_is_uniform_within_bounds(seed):
        """Every resident slot's draw frequency lies within 6σ of the
        uniform expectation (binomial bound; ~1e-9 per-example flake)."""
        n, draws = 8, 4096
        ring = ReplayRing(capacity=n)
        for i in range(n):
            ring.put(_dev(float(i)))
        ring.sample(jax.random.PRNGKey(seed), draws)
        counts = np.bincount(np.asarray(ring.last_sampled), minlength=n)
        p = 1.0 / n
        sigma = (draws * p * (1 - p)) ** 0.5
        assert (abs(counts - draws * p) <= 6 * sigma).all(), counts

    @given(
        capacity=st.integers(2, 16),
        n_puts=st.integers(1, 15),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_only_resident_slots_sampled_when_partially_filled(
            capacity, n_puts, seed):
        ring = ReplayRing(capacity=capacity)
        for i in range(n_puts):
            ring.put(_dev(float(i)))
        vals = ring.sample(jax.random.PRNGKey(seed), 64)
        live = set(ring.resident_tickets())
        assert set(ring.last_sampled) <= live
        assert {float(v) for v in vals} <= {float(t) for t in live}

    @given(capacity=st.integers(1, 8), n_puts=st.integers(0, 24))
    def test_eviction_retires_strictly_oldest_tickets(capacity, n_puts):
        ring = ReplayRing(capacity=capacity)
        for i in range(n_puts):
            ring.put(_dev(float(i)))
        expect_evicted = max(0, n_puts - capacity)
        assert ring.evictions == expect_evicted
        assert ring.resident_tickets() == list(
            range(expect_evicted, n_puts))
        assert ring.tickets_issued == n_puts

    @given(
        prios=st.lists(st.floats(0.1, 10.0), min_size=2, max_size=6),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_prioritized_frequencies_track_priorities(prios, seed):
        """Empirical draw frequencies match p_i = prio_i / sum within 6σ."""
        n, draws = len(prios), 4096
        ring = ReplayRing(capacity=n, prioritized=True)
        for i in range(n):
            ring.put(_dev(float(i)))
        ring.update_priorities(list(range(n)), prios)
        ring.sample(jax.random.PRNGKey(seed), draws)
        counts = np.bincount(np.asarray(ring.last_sampled), minlength=n)
        p = np.asarray(prios) / sum(prios)
        sigma = np.sqrt(draws * p * (1 - p))
        assert (np.abs(counts - draws * p) <= 6 * sigma + 1).all(), counts


# ---------------------------------------------------------------------------
# sync equivalence pins
# ---------------------------------------------------------------------------


def _assert_trees_equal(a, b):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_replay_depth1_lockstep_dqn_bitwise_vs_sync():
    """The tentpole pin: a depth-1 lockstep pipelined replay-DQN reproduces
    the serial SyncReplayDQN reference bit for bit — same jitted collect,
    same ring seed, same learner step; the thread/queue machinery adds
    zero numerics. Holds across repeated run() calls (persistent ε index
    and RNG key)."""
    env = GridWorld(8, size=4, max_steps=20)
    agent = DQNAgent(_vector_cfg(env),
                     DQNConfig(t_max=4, eps_steps=50, target_sync=5))
    sync = SyncReplayDQN(env, agent, lr_schedule=constant(0.01), seed=3,
                         replay_capacity=4, replay_batch=1)
    pipe = PipelinedRL(
        GridWorld(8, size=4, max_steps=20), agent,
        lr_schedule=constant(0.01), seed=3,
        pipeline=PipelineConfig(queue_depth=1, lockstep=True,
                                replay_plane=True, replay_capacity=4,
                                replay_batch=1),
    )
    r1, r2 = sync.run(10), pipe.run(10)
    _assert_trees_equal(sync.params, pipe.params)
    assert np.array_equal(np.asarray(sync.key), np.asarray(pipe.key))
    for k, v in r1.mean_metrics.items():
        assert r2.mean_metrics[k] == v, k
    # continuation run stays bitwise (ε schedule and key stream persist)
    sync.run(5)
    pipe.run(5)
    _assert_trees_equal(sync.params, pipe.params)


def test_replay_paac_staleness0_vtrace_equals_onpolicy_bitwise():
    """V-trace-corrected update == on-policy update at staleness 0 with
    infinite clips: replay-PAAC at capacity 1 / batch 1 / lockstep always
    samples the rollout it just produced, so the pipelined replay run must
    reproduce synchronous ParallelRL bitwise."""
    env = GridWorld(8, size=4, max_steps=20)
    agent = PAACAgent(_vector_cfg(env))
    ref = ParallelRL(env, agent, lr_schedule=constant(0.01), seed=1)
    pipe = PipelinedRL(
        GridWorld(8, size=4, max_steps=20), agent,
        lr_schedule=constant(0.01), seed=1,
        pipeline=PipelineConfig(queue_depth=1, lockstep=True,
                                rho_bar=float("inf"), c_bar=float("inf"),
                                replay_plane=True, replay_capacity=1,
                                replay_batch=1),
    )
    r1, r2 = ref.run(10), pipe.run(10)
    _assert_trees_equal(ref.params, pipe.params)
    for k, v in r1.mean_metrics.items():
        assert r2.mean_metrics[k] == v, k


def test_replay_paac_finite_clips_corrects_stale_rollouts():
    """Off-policy PAAC end to end: deep replay (staleness >> 1) under the
    default finite V-trace clips runs and reports the stale regime."""
    env = GridWorld(8, size=4, max_steps=20)
    agent = PAACAgent(_vector_cfg(env))
    pipe = PipelinedRL(
        env, agent, lr_schedule=constant(0.01), seed=1,
        pipeline=PipelineConfig(num_actors=2, replay_plane=True,
                                replay_capacity=16, replay_batch=2),
    )
    res = pipe.run(20)
    assert res.steps == 20 * 4 * agent.hp.t_max  # 2 actors x 4-env shards
    assert res.mean_metrics["staleness"] > 1.0  # genuinely off-policy
    assert np.isfinite(res.mean_metrics["loss"])


def test_replay_multiactor_prioritized_dqn_smoke():
    env = GridWorld(8, size=4, max_steps=20)
    agent = DQNAgent(_vector_cfg(env),
                     DQNConfig(t_max=4, eps_steps=100, target_sync=5))
    pipe = PipelinedRL(
        env, agent, lr_schedule=constant(0.01), seed=7,
        pipeline=PipelineConfig(num_actors=2, replay_plane=True,
                                replay_capacity=8, replay_batch=2,
                                prioritized=True),
    )
    res = pipe.run(12)
    assert res.steps == 12 * 4 * agent.hp.t_max
    assert np.isfinite(res.mean_metrics["loss"])
    assert res.mean_metrics["q_mean"] != 0.0


# ---------------------------------------------------------------------------
# config-matrix validation
# ---------------------------------------------------------------------------


def test_replay_config_matrix_validation():
    with pytest.raises(ValueError, match="prioritized"):
        PipelineConfig(prioritized=True)
    with pytest.raises(ValueError, match="replay_capacity"):
        PipelineConfig(replay_capacity=0)
    with pytest.raises(ValueError, match="replay_batch"):
        PipelineConfig(replay_batch=0)
    with pytest.raises(ValueError, match="thread"):
        PipelineConfig(replay_plane=True, actor_backend="process")
    with pytest.raises(ValueError, match="mesh"):
        PipelineConfig(replay_plane=True, mesh_shape=2)
    with pytest.raises(ValueError, match="device plane"):
        PipelineConfig(replay_plane=True, rollout_plane="host")
    # the valid cells construct fine
    PipelineConfig(replay_plane=True)
    PipelineConfig(replay_plane=True, rollout_plane="device",
                   replay_capacity=128, replay_batch=4, prioritized=True)


def test_dqn_requires_replay_plane():
    env = GridWorld(8, size=4, max_steps=20)
    agent = DQNAgent(_vector_cfg(env), DQNConfig(t_max=4))
    with pytest.raises(ValueError, match="replay"):
        PipelinedRL(env, agent, lr_schedule=constant(0.01),
                    pipeline=PipelineConfig())


def test_replay_rejects_host_envs():
    from repro.envs import HostEnvPool

    def mk():
        class _E:
            def reset(self):
                return np.zeros(3, np.float32)

            def step(self, a):
                return np.zeros(3, np.float32), 0.0, False

        return _E()

    pool = HostEnvPool([mk for _ in range(4)], obs_shape=(3,))
    env = GridWorld(8, size=4, max_steps=20)
    agent = PAACAgent(_vector_cfg(env))
    try:
        with pytest.raises(ValueError, match="JAX-native"):
            PipelinedRL(pool, agent, lr_schedule=constant(0.01),
                        pipeline=PipelineConfig(replay_plane=True))
    finally:
        pool.close()
