"""The multi-process actor plane: shm contracts, equivalence, lifecycle.

Pins the third execution backend (``PipelineConfig.actor_backend =
"process"``):

* the shared-memory primitives honour their thread-plane twins' contracts
  — ``ShmStagingSet`` is writable/readable across attach boundaries with
  the ``StagingSet`` field layout, ``ShmParamSlot`` speaks
  ``PingPongParamSlot``'s reserve/commit protocol with cross-process
  reader leases,
* **equivalence** (the acceptance pin): a seeded single-actor lockstep
  process run learns from the identical rollout stream as the thread host
  plane — final params *bitwise* equal, metrics equal, RNG key synced
  back equal,
* multi-worker runs never drop a rollout (every ``(actor_id, seq)``
  learned exactly once) and zero-quota workers check out cleanly,
* a crashing env inside a worker subprocess surfaces as the actor error
  in ``run()`` without deadlock (EOF/crash propagation),
* config validation: live pools can't ride the process backend, the
  device rollout plane can't either.

Every env recipe here comes from ``repro.envs.pyemu`` (module-level
constructors): spawn ships specs by pickle *reference*, so closures would
die in the child — which is itself pinned in ``test_host_env.py``.
"""
import time

import jax
import numpy as np
import pytest

from repro.configs import PipelineConfig, get_config
from repro.core.agents import PAACAgent, PAACConfig
from repro.envs import HostEnvPool, py_bound_spec
from repro.pipeline import PipelinedRL, ShmParamSlot, ShmParamView, ShmStagingSet


def _vector_agent(obs_dim=4, t_max=3):
    cfg = get_config("paac_vector").replace(obs_shape=(obs_dim,),
                                            num_actions=3)
    return PAACAgent(cfg, PAACConfig(t_max=t_max))


def _pipe(**kw):
    base = dict(queue_depth=2, actor_backend="process")
    base.update(kw)
    return PipelineConfig(**base)


# ---------------------------------------------------------------------------
# shm staging set — StagingSet's layout across an attach boundary
# ---------------------------------------------------------------------------


def test_shm_staging_set_roundtrips_across_attach():
    parent = ShmStagingSet(t_max=2, n_envs=3, obs_shape=(4,),
                           obs_dtype=np.float32)
    try:
        assert parent.traj.obs.shape == (2, 3, 4)
        assert parent.traj.action.dtype == np.int32
        assert parent.last_obs.shape == (3, 4)
        child = ShmStagingSet(t_max=2, n_envs=3, obs_shape=(4,),
                              obs_dtype=np.float32, name=parent.name,
                              create=False)
        # writes through one mapping are visible through the other — the
        # zero-copy contract the drainer's Rollout wrapping relies on
        child.traj.obs[1, 2] = 7.0
        child.traj.reward[0] = [1.0, 2.0, 3.0]
        child.last_obs[:] = 5.0
        np.testing.assert_array_equal(parent.traj.obs[1, 2], np.full(4, 7.0))
        np.testing.assert_array_equal(parent.traj.reward[0], [1.0, 2.0, 3.0])
        np.testing.assert_array_equal(parent.last_obs, np.full((3, 4), 5.0))
        child.close()
    finally:
        parent.close()
        parent.unlink()


def test_shm_staging_set_attach_requires_name():
    with pytest.raises(ValueError):
        ShmStagingSet(1, 1, (), np.float32, create=False)


# ---------------------------------------------------------------------------
# shm param slot — PingPongParamSlot's reserve/commit, cross-process leases
# ---------------------------------------------------------------------------


def test_shm_param_slot_reserve_commit_and_leases():
    import multiprocessing as mp

    ctx = mp.get_context("spawn")
    tree = {"w": np.arange(4, dtype=np.float32), "b": np.zeros(2, np.float32)}
    slot = ShmParamSlot(tree, ctx, version=0)
    try:
        view = ShmParamView(slot.handle())
        params, v = view.read_params()
        assert v == 0
        np.testing.assert_array_equal(np.asarray(params["w"]),
                                      np.arange(4, dtype=np.float32))
        # a held lease on buffer v%2 blocks reserve(v+2) but not reserve(v+1)
        _, v0 = view.acquire()
        assert not slot.reserve(2, timeout=0.1)  # buffer 0 leased
        assert slot.reserve(1, timeout=0.1)      # buffer 1 free
        with pytest.raises(RuntimeError, match="reserve timed out"):
            slot.publish({"w": np.ones(4, np.float32),
                          "b": np.ones(2, np.float32)}, 2, timeout=0.1)
        view.release(v0)
        slot.publish({"w": np.full(4, 9.0, np.float32),
                      "b": np.ones(2, np.float32)}, 2, timeout=1.0)
        assert view.wait_for(2, timeout=1.0)
        params, v = view.read_params()
        assert v == 2
        np.testing.assert_array_equal(np.asarray(params["w"]),
                                      np.full(4, 9.0, np.float32))
        assert not view.wait_for(3, timeout=0.05)
        view.close()
    finally:
        slot.close()
        slot.unlink()


# ---------------------------------------------------------------------------
# end-to-end: the process backend through PipelinedRL.run
# ---------------------------------------------------------------------------


def test_process_backend_end_to_end_never_drops():
    spec = py_bound_spec(4, obs_dim=4, spin=0, n_workers=2)
    agent = _vector_agent()
    with PipelinedRL(spec, agent, lr_schedule=None, seed=0,
                     pipeline=_pipe()) as prl:
        res = prl.run(5)
        assert res.steps == 5 * 4 * 3
        assert np.isfinite(res.mean_metrics["loss"])
        assert sorted(prl.learned_ids) == [(0, s) for s in range(5)]
        # workers persist across runs: a second run reuses them
        res2 = prl.run(3)
        assert res2.steps == 8 * 4 * 3
        assert sorted(prl.learned_ids) == [(0, s) for s in range(3)]


def test_process_backend_multi_worker_spec_shard():
    """A single spec is sharded across workers (each child builds its own
    slice-pool); every (actor_id, seq) is learned exactly once."""
    spec = py_bound_spec(8, obs_dim=4, spin=0, n_workers=4)
    agent = _vector_agent()
    with PipelinedRL(spec, agent, lr_schedule=None, seed=0,
                     pipeline=_pipe(num_actors=2)) as prl:
        res = prl.run(6)
    assert res.steps == 6 * 4 * 3  # 4-env shards, not 8
    assert sorted(prl.learned_ids) == [(a, s) for a in range(2)
                                       for s in range(3)]
    assert len(res.per_actor_idle_s) == 2


def test_process_backend_zero_quota_workers_check_out():
    """iterations < num_actors: quota-0 workers must producer_done cleanly
    (no hang) and the stream still delivers every tagged rollout."""
    specs = [py_bound_spec(2, obs_dim=3, spin=0, n_workers=2,
                           base_seed=10 * a) for a in range(3)]
    agent = _vector_agent(obs_dim=3, t_max=2)
    with PipelinedRL(specs, agent, lr_schedule=None, seed=0,
                     pipeline=_pipe(num_actors=3)) as prl:
        t0 = time.perf_counter()
        res = prl.run(2)  # quota [1, 1, 0]
        assert time.perf_counter() - t0 < 120.0
    assert res.steps == 2 * 2 * 2
    assert sorted(prl.learned_ids) == [(0, 0), (1, 0)]


# ---------------------------------------------------------------------------
# equivalence pin (acceptance): process lockstep == thread lockstep, bitwise
# ---------------------------------------------------------------------------


def test_process_lockstep_bitwise_matches_thread_host_plane():
    """Seeded single-actor lockstep with infinite clips: the worker
    subprocess collects the *identical* rollout stream the thread host
    plane would (same key evolution, same params round-tripped through
    shm), so learning matches bitwise — params, metrics, and the synced
    RNG key."""
    def run_backend(backend):
        spec = py_bound_spec(4, obs_dim=4, spin=0, n_workers=2)
        agent = _vector_agent()
        inf = float("inf")
        with PipelinedRL(
            spec, agent, lr_schedule=None, seed=1,
            pipeline=_pipe(queue_depth=1, rho_bar=inf, c_bar=inf,
                           lockstep=True, actor_backend=backend),
        ) as prl:
            res = prl.run(6)
            params = jax.tree_util.tree_map(np.asarray, prl.params)
            return res, params, np.asarray(prl.key)

    r_t, p_t, k_t = run_backend("thread")
    r_p, p_p, k_p = run_backend("process")
    assert r_p.mean_metrics["staleness"] == 0.0
    for k in ("loss", "policy_loss", "value_loss", "entropy", "reward_sum"):
        assert r_p.mean_metrics[k] == r_t.mean_metrics[k], k
    for a, b in zip(jax.tree_util.tree_leaves(p_t),
                    jax.tree_util.tree_leaves(p_p)):
        np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(k_t, k_p)


# ---------------------------------------------------------------------------
# lifecycle: crash propagation, teardown, validation
# ---------------------------------------------------------------------------


def test_worker_env_crash_propagates_without_deadlock():
    from repro.envs.host_env import HostEnvSpec
    from repro.envs.pyemu import make_py_bound_env

    # obs_dim < 0 makes np.full raise inside the worker's first reset/step
    spec = HostEnvSpec(env_fn=make_py_bound_env, env_args=((0, -1, 0),),
                       n_workers=1, obs_shape=(1,))
    agent = _vector_agent(obs_dim=1, t_max=2)
    prl = PipelinedRL(spec, agent, lr_schedule=None, seed=0,
                      pipeline=_pipe())
    try:
        t0 = time.perf_counter()
        with pytest.raises(RuntimeError, match="actor 0 failed"):
            prl.run(4)
        assert time.perf_counter() - t0 < 120.0  # unwound, not deadlocked
    finally:
        prl.close()


def test_process_backend_rejects_live_pools_and_device_plane():
    agent = _vector_agent(obs_dim=1, t_max=2)
    with HostEnvPool([lambda s=0: None], n_workers=1,
                     obs_shape=(1,)) as pool:
        with pytest.raises(ValueError, match="HostEnvSpec"):
            PipelinedRL(pool, agent, pipeline=_pipe())
    spec = py_bound_spec(2, obs_dim=1, spin=0, n_workers=1)
    with pytest.raises(ValueError, match="host"):
        PipelinedRL(spec, agent,
                    pipeline=_pipe(rollout_plane="device"))
    with pytest.raises(ValueError, match="actor_backend"):
        PipelinedRL(spec, agent,
                    pipeline=PipelineConfig(actor_backend="fork"))


def test_close_is_idempotent_and_reaps_workers():
    spec = py_bound_spec(2, obs_dim=2, spin=0, n_workers=1)
    agent = _vector_agent(obs_dim=2, t_max=2)
    prl = PipelinedRL(spec, agent, lr_schedule=None, seed=0, pipeline=_pipe())
    procs = [w.proc for w in prl._process_plane._workers]
    prl.run(2)
    prl.close()
    prl.close()  # idempotent
    assert all(not p.is_alive() for p in procs)
