"""Fault injection + actor supervision (repro.pipeline.faults/supervisor).

Pins the recovery plane's contracts:

* ``FaultPlan`` validates its schedule and every entry fires exactly once,
* without ``elastic`` the pipeline stays fail-fast: an injected kill
  propagates as the same ``RuntimeError`` a genuine crash would,
* with ``elastic`` a killed replica respawns under the restart budget and
  the run completes its *full* quota under a fresh ``(actor_id, seq)``
  epoch; past the budget the run degrades to the survivors, who absorb the
  dead replica's quota through the ``QuotaLedger`` (work conservation),
* the respawn-vs-``producer_done`` race is closed: survivors wait on the
  ledger instead of checking out while orphaned quota is outstanding,
* the last live replica dying is fatal — a clean error, never a hang,
* a replica crashing while its sibling is blocked in ``put()`` (stalled
  learner, full queue) recovers without deadlock,
* param leases are attributable: ``PingPongParamSlot`` names the holding
  party on timeout, and ``revoke`` clears a dead replica's leases,
* learner-side injections (stall, dropped release) are absorbed by the
  pipeline's sizing contracts,
* the process backend recovers from both planned-``error`` *and* hard
  ``os._exit`` worker deaths.
"""
import numpy as np
import pytest

from repro.configs import PipelineConfig, get_config
from repro.core.agents import PAACAgent, PAACConfig
from repro.envs import GridWorld, HostEnvPool
from repro.pipeline import (
    FaultInjector,
    FaultPlan,
    InjectedActorFault,
    PingPongParamSlot,
    PipelinedRL,
    QuotaLedger,
)


def _grid_agent(t_max=3):
    env = GridWorld(8, size=4, max_steps=20)
    cfg = get_config("paac_vector").replace(
        obs_shape=env.obs_shape, num_actions=env.num_actions)
    return GridWorld(8, size=4, max_steps=20), PAACAgent(
        cfg, PAACConfig(t_max=t_max))


class _ToyGymEnv:
    def __init__(self, seed):
        self.rng = np.random.RandomState(seed)
        self.state = 0

    def reset(self):
        self.state = int(self.rng.randint(0, 100))
        return np.array([self.state % 7], np.float32)

    def step(self, action):
        reward = 1.0 if action == self.state % 3 else 0.0
        self.state += 1
        return np.array([self.state % 7], np.float32), reward, \
            self.state % 10 == 0, {}


def _toy_pool(n=4, n_workers=2):
    return HostEnvPool([lambda s=i: _ToyGymEnv(s) for i in range(n)],
                       n_workers=n_workers, obs_shape=(1,))


def _pool_agent(t_max=3):
    cfg = get_config("paac_vector").replace(obs_shape=(1,), num_actions=3)
    return PAACAgent(cfg, PAACConfig(t_max=t_max))


# ---------------------------------------------------------------------------
# FaultPlan / config validation
# ---------------------------------------------------------------------------


def test_fault_plan_validates_entries():
    with pytest.raises(ValueError, match="mode"):
        FaultPlan(kills=((0, 1, "segfault"),))
    with pytest.raises(ValueError, match=">= 0"):
        FaultPlan(kills=((-1, 0, "error"),))
    with pytest.raises(ValueError, match=">= 0"):
        FaultPlan(lease_delays=((0, 0, -1.0),))
    with pytest.raises(ValueError, match=">= 0"):
        FaultPlan(drop_release=(-2,))
    with pytest.raises(ValueError, match=">= 0"):
        FaultPlan(stall_learner=((0, -0.1),))
    # frozen: the plan rides an (immutable) config
    plan = FaultPlan(kills=((0, 1, "error"),))
    with pytest.raises(Exception):
        plan.kills = ()


def test_fault_injector_entries_fire_exactly_once():
    inj = FaultInjector(FaultPlan(kills=((0, 2, "error"),),
                                  drop_release=(1,)))
    with pytest.raises(InjectedActorFault):
        inj.maybe_kill(0, 2)
    inj.maybe_kill(0, 2)  # fired: the respawned replica sails through
    inj.maybe_kill(1, 2)  # different slot: never planned
    assert inj.drop_release(1) is True
    assert inj.drop_release(1) is False


def test_config_validates_fault_fields():
    with pytest.raises(ValueError, match="restart_budget"):
        PipelineConfig(restart_budget=-1)
    with pytest.raises(ValueError, match="lease_timeout_s"):
        PipelineConfig(lease_timeout_s=0.0)
    with pytest.raises(ValueError, match="checkpoint_dir"):
        PipelineConfig(checkpoint_every=5)
    with pytest.raises(ValueError, match="mesh"):
        PipelineConfig(elastic=True, mesh_shape=2, num_actors=2)
    with pytest.raises(ValueError, match="mesh"):
        PipelineConfig(elastic=True, rollout_plane="mesh")


def test_orchestrator_rejects_non_fault_plan():
    env, agent = _grid_agent()
    with pytest.raises(TypeError, match="FaultPlan"):
        PipelinedRL(env, agent,
                    pipeline=PipelineConfig(fault_plan={"kills": []}))


# ---------------------------------------------------------------------------
# fail-fast default (elastic off)
# ---------------------------------------------------------------------------


def test_injected_kill_fails_fast_without_elastic():
    env, agent = _grid_agent()
    prl = PipelinedRL(
        env, agent, seed=0,
        pipeline=PipelineConfig(
            queue_depth=2, num_actors=2,
            fault_plan=FaultPlan(kills=((0, 1, "error"),))),
    )
    with pytest.raises(RuntimeError, match="pipeline actor") as ei:
        prl.run(8)
    assert isinstance(ei.value.__cause__, InjectedActorFault)
    assert prl.supervisor is None  # fail-fast: no supervisor constructed


# ---------------------------------------------------------------------------
# elastic recovery: respawn and degrade
# ---------------------------------------------------------------------------


def test_thread_respawn_completes_full_quota():
    """Kill one of two replicas mid-run: the supervisor respawns it under a
    fresh actor_id epoch and the run completes every one of its iterations
    (the acceptance scenario)."""
    env, agent = _grid_agent()
    prl = PipelinedRL(
        env, agent, seed=0,
        pipeline=PipelineConfig(
            queue_depth=2, num_actors=2, elastic=True, restart_budget=1,
            restart_backoff_s=0.01,
            fault_plan=FaultPlan(kills=((0, 2, "error"),))),
    )
    res = prl.run(8)
    assert np.isfinite(res.mean_metrics["loss"])
    # full quota: all 8 updates consumed, none dropped
    assert len(prl.learned_ids) == 8
    sup = prl.supervisor
    assert ("respawn", 0, 2) in sup.episodes
    # the replacement epoch produced under its own id
    ids = {a for a, _ in prl.learned_ids}
    assert 2 in ids
    # slot 0's stream: 2 rollouts from the dead epoch + the remainder fresh
    dead = sorted(s for a, s in prl.learned_ids if a == 0)
    fresh = sorted(s for a, s in prl.learned_ids if a == 2)
    assert dead == [0, 1] and fresh == [0, 1]
    # telemetry counters recorded the episode
    counters = prl.telemetry._counters
    assert counters.get("fault.detect") == 1
    assert counters.get("fault.respawn") == 1


def test_degrade_to_fewer_actors_when_budget_exhausted():
    """restart_budget=0: the dead slot's quota is orphaned to the ledger and
    the surviving replica absorbs it — the run still completes in full."""
    env, agent = _grid_agent()
    prl = PipelinedRL(
        env, agent, seed=0,
        pipeline=PipelineConfig(
            queue_depth=2, num_actors=2, elastic=True, restart_budget=0,
            fault_plan=FaultPlan(kills=((0, 1, "error"),))),
    )
    res = prl.run(8)
    assert np.isfinite(res.mean_metrics["loss"])
    assert len(prl.learned_ids) == 8
    sup = prl.supervisor
    assert any(e[0] == "giveup" and e[1] == 0 for e in sup.episodes)
    assert not any(e[0] == "respawn" for e in sup.episodes)
    # survivor (actor 1) produced its own 4 plus the orphaned remainder
    survivor = [s for a, s in prl.learned_ids if a == 1]
    assert len(survivor) == 7 and sorted(survivor) == list(range(7))
    assert prl.telemetry._counters.get("fault.giveup") == 1


def test_last_actor_death_is_fatal_not_a_hang():
    env, agent = _grid_agent()
    prl = PipelinedRL(
        env, agent, seed=0,
        pipeline=PipelineConfig(
            queue_depth=1, num_actors=1, elastic=True, restart_budget=0,
            fault_plan=FaultPlan(kills=((0, 1, "error"),))),
    )
    with pytest.raises(RuntimeError, match="after faults") as ei:
        prl.run(6)
    assert isinstance(ei.value.__cause__, InjectedActorFault)
    assert prl.supervisor.fatal is not None


def test_respawn_after_sibling_finished_quota():
    """The respawn-vs-producer_done race: the kill lands when the *other*
    replica may already be done with its own quota. The ledger keeps the
    survivor from checking out while the orphaned work is outstanding."""
    env, agent = _grid_agent(t_max=2)
    # uneven split: quota [3, 2]; slot 1 dies before producing anything
    prl = PipelinedRL(
        env, agent, seed=0,
        pipeline=PipelineConfig(
            queue_depth=2, num_actors=2, elastic=True, restart_budget=0,
            fault_plan=FaultPlan(kills=((1, 0, "error"),))),
    )
    res = prl.run(5)
    assert np.isfinite(res.mean_metrics["loss"])
    assert len(prl.learned_ids) == 5
    # every payload came from the survivor
    assert all(a == 0 for a, _ in prl.learned_ids)


def test_crash_while_sibling_blocked_in_put():
    """A stalled learner fills the depth-1 queue so the sibling blocks in
    put(); the kill then fires and the recovery episode must complete
    without deadlock (the supervisor runs on the dying thread while the
    queue is full)."""
    env, agent = _grid_agent(t_max=2)
    prl = PipelinedRL(
        env, agent, seed=0,
        pipeline=PipelineConfig(
            queue_depth=1, num_actors=2, elastic=True, restart_budget=1,
            restart_backoff_s=0.01,
            fault_plan=FaultPlan(kills=((0, 1, "error"),),
                                 stall_learner=((0, 0.5),))),
    )
    res = prl.run(6)
    assert np.isfinite(res.mean_metrics["loss"])
    assert len(prl.learned_ids) == 6


def test_zero_budget_no_fault_matches_failfast_stream():
    """elastic with an empty fault plan consumes the identical payload
    stream a fail-fast run does (supervision is pure scaffolding until a
    fault fires)."""
    env, agent = _grid_agent(t_max=2)
    pipe = dict(queue_depth=2, num_actors=2)
    a = PipelinedRL(GridWorld(8, size=4, max_steps=20), agent, seed=3,
                    pipeline=PipelineConfig(**pipe))
    a.run(6)
    b = PipelinedRL(GridWorld(8, size=4, max_steps=20), agent, seed=3,
                    pipeline=PipelineConfig(elastic=True, restart_budget=0,
                                            **pipe))
    b.run(6)
    assert sorted(a.learned_ids) == sorted(b.learned_ids)


# ---------------------------------------------------------------------------
# lease attribution
# ---------------------------------------------------------------------------


def test_pingpong_holders_and_revoke():
    slot = PingPongParamSlot({"w": np.zeros(3, np.float32)}, version=0)
    slot.acquire(holder="actor-0")
    slot.acquire(holder="actor-1")
    assert sorted(slot.holders(0)) == ["actor-0", "actor-1"]
    # a dead replica's leases are cleared wholesale
    assert slot.revoke("actor-0") == 1
    assert slot.holders(0) == ["actor-1"]
    slot.release(0, holder="actor-1")
    assert slot.holders(0) == []
    # publish proceeds now that the buffer is free
    slot.publish({"w": np.ones(3, np.float32)}, 2, timeout=1.0)


def test_publish_timeout_names_the_holder():
    slot = PingPongParamSlot({"w": np.zeros(3, np.float32)}, version=0)
    slot.acquire(holder="actor-7")
    with pytest.raises(RuntimeError, match="actor-7"):
        slot.publish({"w": np.ones(3, np.float32)}, 2, timeout=0.05)


def test_learner_lease_timeout_is_configurable():
    cfg = PipelineConfig(lease_timeout_s=12.5)
    assert cfg.lease_timeout_s == 12.5


# ---------------------------------------------------------------------------
# learner-side injections
# ---------------------------------------------------------------------------


def test_drop_release_absorbed_by_staging_sizing():
    """One deliberately leaked host staging lease must be absorbed by the
    ring's queue_depth + 2 sizing — the run completes regardless."""
    agent = _pool_agent()
    with _toy_pool() as pool:
        prl = PipelinedRL(
            pool, agent, seed=0,
            pipeline=PipelineConfig(
                queue_depth=1,
                fault_plan=FaultPlan(drop_release=(1,))),
        )
        res = prl.run(6)
    assert np.isfinite(res.mean_metrics["loss"])
    assert len(prl.learned_ids) == 6


def test_stall_learner_backpressures_without_fault():
    env, agent = _grid_agent(t_max=2)
    prl = PipelinedRL(
        env, agent, seed=0,
        pipeline=PipelineConfig(
            queue_depth=1, num_actors=2,
            fault_plan=FaultPlan(stall_learner=((1, 0.3),))),
    )
    res = prl.run(6)
    assert np.isfinite(res.mean_metrics["loss"])
    assert len(prl.learned_ids) == 6


# ---------------------------------------------------------------------------
# quota ledger unit
# ---------------------------------------------------------------------------


def test_quota_ledger_work_conservation():
    led = QuotaLedger(4)
    led.produced()
    led.orphan(2)
    assert led.wait_for_work() == 1  # claims one unit
    assert led.claim() == 1  # takes the rest of the pool
    led.produced()
    led.produced()
    led.produced()
    # outstanding drained: waiters check out immediately
    assert led.wait_for_work() == 0
    led2 = QuotaLedger(5)
    led2.abort()
    assert led2.wait_for_work() == 0


# ---------------------------------------------------------------------------
# process backend recovery
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["error", "exit"])
def test_process_backend_respawns_dead_worker(mode):
    """Both planned failure shapes — an in-worker exception and a hard
    os._exit (silent death) — recover via worker respawn and the run
    completes its full quota."""
    from repro.envs import py_bound_spec

    spec = py_bound_spec(4, obs_dim=3, spin=0, n_workers=2)
    cfg = get_config("paac_vector").replace(obs_shape=spec.obs_shape,
                                            num_actions=3)
    agent = PAACAgent(cfg, PAACConfig(t_max=2))
    prl = PipelinedRL(
        spec, agent, seed=0,
        pipeline=PipelineConfig(
            queue_depth=2, num_actors=2, actor_backend="process",
            elastic=True, restart_budget=1, restart_backoff_s=0.01,
            fault_plan=FaultPlan(kills=((0, 1, mode),))),
    )
    try:
        res = prl.run(6)
        assert np.isfinite(res.mean_metrics["loss"])
        assert len(prl.learned_ids) == 6
        assert any(e[0] == "respawn" for e in prl.supervisor.episodes)
    finally:
        prl.close()
