"""Beyond-paper extensions: MLA-decode kernel, PPO, flash custom-VJP grads,
MoE combine equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import ParallelRL
from repro.core.agents import PPOAgent, PPOConfig
from repro.envs import GridWorld
from repro.kernels import ref as R
from repro.kernels.mla_decode import mla_decode_attention_pallas
from repro.optim import constant


# ---------------------------------------------------------------- MLA kernel
@pytest.mark.parametrize("S,H,Rk,Rr,pos", [
    (128, 8, 64, 16, 100),
    (300, 16, 128, 32, 299),
    (512, 4, 32, 8, 0),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mla_decode_kernel(S, H, Rk, Rr, pos, dtype, key):
    B = 2
    scale = 1.0 / np.sqrt(Rk + Rr)
    q_lat = jax.random.normal(key, (B, H, Rk), dtype)
    q_rope = jax.random.normal(key, (B, H, Rr), dtype)
    cc = jax.random.normal(key, (B, S, Rk), dtype)
    kr = jax.random.normal(key, (B, S, Rr), dtype)
    out = mla_decode_attention_pallas(q_lat, q_rope, cc, kr, pos, scale,
                                      block_k=128)
    ref = R.mla_decode_attention_ref(q_lat, q_rope, cc, kr, pos, scale)
    tol = 3e-2 if dtype == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(out.astype(jnp.float32),
                               ref.astype(jnp.float32), rtol=tol, atol=tol)


def test_mla_decode_kernel_matches_model_absorb_path(key):
    """Kernel == the model's absorbed-MLA decode attention core."""
    from repro.models import attention as A

    cfg = get_config("minicpm3-4b").reduced().replace(mla_absorb=True)
    # extract the latent attention math from mla_decode by comparing outputs
    # of the reference formula against the kernel with the same inputs
    B, S, H = 2, 64, cfg.num_heads
    Rk, Rr = cfg.kv_lora_rank, cfg.qk_rope_dim
    scale = 1.0 / np.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    q_lat = jax.random.normal(key, (B, H, Rk))
    q_rope = jax.random.normal(key, (B, H, Rr))
    cc = jax.random.normal(key, (B, S, Rk))
    kr = jax.random.normal(key, (B, S, Rr))
    out_k = mla_decode_attention_pallas(q_lat, q_rope, cc, kr, S - 1, scale,
                                        block_k=32)
    ref = R.mla_decode_attention_ref(q_lat, q_rope, cc, kr, S - 1, scale)
    np.testing.assert_allclose(out_k, ref, rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------- flash VJP
def test_flash_vjp_grads_match_naive(key):
    from repro.models.attention import chunked_attention, naive_attention

    B, S, H, Hkv, D = 2, 64, 4, 2, 32
    q = jax.random.normal(key, (B, S, H, D))
    k = jax.random.normal(key, (B, S, Hkv, D))
    v = jax.random.normal(key, (B, S, Hkv, D))

    def f(att):
        def inner(q, k, v):
            return jnp.sum(jnp.tanh(att(q, k, v, causal=True, window=11)))
        return inner

    g1 = jax.grad(f(lambda *a, **kw: chunked_attention(*a, block_k=16, **kw)),
                  argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f(naive_attention), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------- MoE combine
def test_moe_scatter_combine_equals_gather_reference(key):
    """The psum-friendly scatter-add combine == take_along_axis reference."""
    from repro.models.moe import _route_group

    T, d, E, k = 32, 16, 4, 2
    capacity = int(np.ceil(T * k * 1.25 / E))
    tokens = jax.random.normal(key, (T, d))
    logits = jax.random.normal(key, (T, E))
    buf, slot, top_w, aux, inv_tok, w_slot = _route_group(
        tokens, logits, k=k, capacity=capacity, E=E
    )
    out_e = buf.reshape(E * capacity, d) * 2.0  # pretend expert outputs
    # scatter-add combine (production path)
    y1 = jnp.zeros((T + 1, d)).at[inv_tok].add(
        out_e * w_slot[:, None], mode="drop")[:T]
    # gather reference (the §Perf pair-C baseline formulation)
    flat = jnp.concatenate([out_e, jnp.zeros((1, d))])
    gathered = flat[slot.reshape(-1)].reshape(T, k, d)
    y2 = jnp.sum(gathered * top_w[..., None], axis=1)
    np.testing.assert_allclose(y1, y2, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------- PPO
def test_ppo_learns_gridworld():
    env = GridWorld(32, size=4, max_steps=30)
    cfg = get_config("paac_vector").replace(
        obs_shape=env.obs_shape, num_actions=env.num_actions
    )
    agent = PPOAgent(cfg, PPOConfig(t_max=16, epochs=2))
    rl = ParallelRL(env, agent, optimizer="adam", lr_schedule=constant(3e-3),
                    seed=0)
    before = rl.run(10).mean_metrics["reward_sum"]
    rl.run(60)
    after = rl.run(10).mean_metrics["reward_sum"]
    assert after > before, (before, after)
