"""repro.pipeline: queue semantics, sync equivalence, end-to-end smokes.

Pins the subsystem's three contracts:
* the bounded queue applies backpressure (blocks the producer) and never
  drops a trajectory,
* at queue depth 1 with lockstep + ρ̄→∞ the pipelined backend reproduces
  the synchronous ``ParallelRL`` run (same params, same metrics),
* ``PipelinedRL.run`` works end to end on a JAX-native env, a token env,
  and a ``HostEnvPool`` of external gym-style envs.
"""
import threading
import time

import jax
import numpy as np
import pytest

from repro.configs import PipelineConfig, get_config
from repro.core import ParallelRL
from repro.core.agents import PAACAgent, PAACConfig
from repro.envs import GridWorld, HostEnvPool, TokenEnv
from repro.optim import constant
from repro.pipeline import CLOSED, ParamSlot, PipelinedRL, TrajectoryQueue


# ---------------------------------------------------------------------------
# queue
# ---------------------------------------------------------------------------


def test_queue_backpressure_blocks_and_never_drops():
    q = TrajectoryQueue(depth=2)
    n_items = 7
    produced = []

    def producer():
        for i in range(n_items):
            q.put(i)
            produced.append(i)
        q.close()

    t = threading.Thread(target=producer, daemon=True)
    t.start()
    time.sleep(0.2)
    # bounded: with no consumer the producer is stuck at depth items
    assert q.qsize() == 2
    assert len(produced) == 2  # third put is blocked
    # drain: every item arrives exactly once, in order, then CLOSED
    got = []
    while True:
        item = q.get(timeout=5.0)
        if item is CLOSED:
            break
        got.append(item)
    t.join(timeout=5.0)
    assert got == list(range(n_items))
    assert q.put_wait_s > 0.1  # the actor-idle accounting saw the block


def test_queue_close_is_idempotent_and_rejects_put():
    q = TrajectoryQueue(depth=1)
    q.close()
    q.close()
    assert q.get(timeout=1.0) is CLOSED
    with pytest.raises(RuntimeError):
        q.put(1)


def test_queue_depth_validation():
    with pytest.raises(ValueError):
        TrajectoryQueue(depth=0)


def test_param_slot_versions():
    slot = ParamSlot("v0", version=0)
    assert slot.read() == ("v0", 0)
    slot.publish("v3", 3)
    assert slot.wait_for(2, timeout=1.0)
    assert slot.read() == ("v3", 3)
    assert not slot.wait_for(5, timeout=0.05)


# ---------------------------------------------------------------------------
# pipelined vs sync equivalence (depth 1, lockstep, ρ̄ → ∞)
# ---------------------------------------------------------------------------


def _vector_cfg(env):
    return get_config("paac_vector").replace(
        obs_shape=env.obs_shape, num_actions=env.num_actions
    )


def test_lockstep_pipeline_matches_sync():
    agent = PAACAgent(_vector_cfg(GridWorld(8, size=4, max_steps=20)),
                      PAACConfig(t_max=5))
    rl = ParallelRL(GridWorld(8, size=4, max_steps=20), agent,
                    lr_schedule=constant(0.01), seed=1)
    r_sync = rl.run(10)
    prl = PipelinedRL(
        GridWorld(8, size=4, max_steps=20), agent,
        lr_schedule=constant(0.01), seed=1,
        pipeline=PipelineConfig(queue_depth=1, rho_bar=1e9, lockstep=True),
    )
    r_pipe = prl.run(10)
    # learning metrics match the synchronous baseline
    for k in ("loss", "policy_loss", "value_loss", "entropy", "reward_sum"):
        np.testing.assert_allclose(
            r_pipe.mean_metrics[k], r_sync.mean_metrics[k],
            rtol=1e-4, atol=1e-5, err_msg=k,
        )
    assert r_pipe.mean_metrics["staleness"] == 0.0
    # ... and so do the resulting parameters
    for a, b in zip(jax.tree_util.tree_leaves(rl.params),
                    jax.tree_util.tree_leaves(prl.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_async_pipeline_reports_staleness_and_rho():
    agent = PAACAgent(_vector_cfg(GridWorld(8, size=4, max_steps=20)),
                      PAACConfig(t_max=5))
    prl = PipelinedRL(
        GridWorld(8, size=4, max_steps=20), agent,
        lr_schedule=constant(0.01), seed=0,
        pipeline=PipelineConfig(queue_depth=2, rho_bar=1.0),
    )
    res = prl.run(12)
    assert res.steps == 12 * 8 * 5
    assert res.mean_metrics["staleness"] > 0.0  # actor genuinely ran ahead
    # behaviour ≈ learner policy at tiny lr: ratios near 1, rarely clipped
    assert 0.5 < res.mean_metrics["rho_mean"] < 2.0
    assert res.mean_metrics["rho_clip_frac"] <= 1.0


# ---------------------------------------------------------------------------
# end-to-end smokes
# ---------------------------------------------------------------------------


def test_pipeline_token_env_smoke():
    env = TokenEnv(4, vocab=16, ctx=8, k=2, horizon=16)
    cfg = get_config("qwen2-7b").reduced().replace(
        num_layers=1, d_model=64, d_ff=128, num_heads=2, num_kv_heads=1,
        head_dim=32, vocab_size=16, num_actions=env.vocab,
    )
    agent = PAACAgent(cfg, PAACConfig(t_max=4))
    prl = PipelinedRL(env, agent, lr_schedule=constant(1e-3), seed=0,
                      pipeline=PipelineConfig(queue_depth=2))
    res = prl.run(4)
    assert res.steps == 4 * 4 * 4
    assert np.isfinite(res.mean_metrics["loss"])


class _ToyGymEnv:
    """Gym-style counter env: reward 1 when action == state % 3."""

    def __init__(self, seed):
        self.rng = np.random.RandomState(seed)
        self.state = 0

    def reset(self):
        self.state = int(self.rng.randint(0, 100))
        return np.array([self.state % 7], np.float32)

    def step(self, action):
        reward = 1.0 if action == self.state % 3 else 0.0
        self.state += 1
        done = self.state % 10 == 0
        return np.array([self.state % 7], np.float32), reward, done, {}


def _toy_pool(n=8, n_workers=4):
    return HostEnvPool([lambda s=i: _ToyGymEnv(s) for i in range(n)],
                       n_workers=n_workers, obs_shape=(1,))


def test_pipeline_host_env_pool_smoke():
    cfg = get_config("paac_vector").replace(obs_shape=(1,), num_actions=3)
    agent = PAACAgent(cfg, PAACConfig(t_max=5))
    with _toy_pool() as pool:
        prl = PipelinedRL(pool, agent, lr_schedule=constant(0.003), seed=0,
                          pipeline=PipelineConfig(queue_depth=2))
        res = prl.run(6)
    assert res.steps == 6 * 8 * 5
    assert np.isfinite(res.mean_metrics["loss"])
    assert res.episodes > 0  # toy envs terminate every 10 steps


def test_sync_parallel_rl_drives_host_env_pool():
    """ParallelRL transparently drives external envs (paper §3 literally)."""
    cfg = get_config("paac_vector").replace(obs_shape=(1,), num_actions=3)
    agent = PAACAgent(cfg, PAACConfig(t_max=5))
    with _toy_pool() as pool:
        rl = ParallelRL(pool, agent, lr_schedule=constant(0.003), seed=0)
        res = rl.run(6)
    assert res.steps == 6 * 8 * 5
    assert np.isfinite(res.mean_metrics["loss"])
    # sync host driver is on-policy: importance ratios stay ≈ 1
    np.testing.assert_allclose(res.mean_metrics["rho_mean"], 1.0, atol=1e-3)


def test_pipeline_actor_failure_propagates():
    class ExplodingEnv(_ToyGymEnv):
        def step(self, action):
            raise RuntimeError("emulator crashed")

    cfg = get_config("paac_vector").replace(obs_shape=(1,), num_actions=3)
    agent = PAACAgent(cfg, PAACConfig(t_max=2))
    with HostEnvPool([lambda s=i: ExplodingEnv(s) for i in range(4)],
                     n_workers=2, obs_shape=(1,)) as pool:
        prl = PipelinedRL(pool, agent, lr_schedule=constant(0.003), seed=0)
        with pytest.raises(RuntimeError):
            prl.run(3)
