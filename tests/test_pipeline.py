"""repro.pipeline: queue semantics, sync equivalence, end-to-end smokes.

Pins the subsystem's contracts:
* the bounded queue applies backpressure (blocks producers), never drops a
  trajectory, and a ``close()`` landing on a blocked ``put()`` raises
  promptly instead of hanging — and the device-plane
  ``DeviceTrajectoryRing`` honours the identical contract (plus rejecting
  host-memory payloads),
* at queue depth 1 with lockstep + infinite V-trace clips the pipelined
  backend reproduces the synchronous ``ParallelRL`` run — bitwise on the
  shared-learner ``HostEnvPool`` path *and* bitwise on both queue planes of
  a JAX-native env (ring with full donation included),
* donation safety: the learner really donates its params/opt state (stale
  buffers raise on read), while the ping-pong snapshots actors lease are
  never invalidated,
* N actor replicas never drop a rollout (every ``(actor_id, seq)`` learned
  exactly once), merged idle accounting sums to per-actor totals, and one
  actor crashing propagates without deadlocking the others,
* host staging sets are recycled through the ``Rollout.release`` protocol
  (bounded allocation) and returned exactly once,
* ``PipelinedRL.run`` works end to end on a JAX-native env, a token env,
  and a ``HostEnvPool`` of external gym-style envs.
"""
import threading
import time

import jax
import numpy as np
import pytest

from repro.configs import PipelineConfig, get_config
from repro.core import ParallelRL
from repro.core.agents import PAACAgent, PAACConfig
from repro.envs import GridWorld, HostEnvPool, TokenEnv
from repro.optim import constant
from repro.pipeline import (
    CLOSED,
    DeviceTrajectoryRing,
    HostStagingRing,
    ParamSlot,
    PingPongParamSlot,
    PipelinedRL,
    QueueClosed,
    TrajectoryQueue,
)


# ---------------------------------------------------------------------------
# queue
# ---------------------------------------------------------------------------


def test_queue_backpressure_blocks_and_never_drops():
    q = TrajectoryQueue(depth=2)
    n_items = 7
    produced = []

    def producer():
        for i in range(n_items):
            q.put(i)
            produced.append(i)
        q.close()

    t = threading.Thread(target=producer, daemon=True)
    t.start()
    time.sleep(0.2)
    # bounded: with no consumer the producer is stuck at depth items
    assert q.qsize() == 2
    assert len(produced) == 2  # third put is blocked
    # drain: every item arrives exactly once, in order, then CLOSED
    got = []
    while True:
        item = q.get(timeout=5.0)
        if item is CLOSED:
            break
        got.append(item)
    t.join(timeout=5.0)
    assert got == list(range(n_items))
    assert q.put_wait_s > 0.1  # the actor-idle accounting saw the block


def test_queue_close_is_idempotent_and_rejects_put():
    q = TrajectoryQueue(depth=1)
    q.close()
    q.close()
    assert q.get(timeout=1.0) is CLOSED
    with pytest.raises(RuntimeError):
        q.put(1)


def test_queue_depth_validation():
    with pytest.raises(ValueError):
        TrajectoryQueue(depth=0)
    with pytest.raises(ValueError):
        TrajectoryQueue(depth=1, producers=0)


def test_queue_close_wakes_blocked_put():
    """Regression: a producer blocked in put() when close() lands must raise
    promptly (QueueClosed), not hang until its timeout."""
    q = TrajectoryQueue(depth=1)
    q.put(0)  # fill the queue so the next put blocks
    outcome = {}

    def producer():
        t0 = time.perf_counter()
        try:
            q.put(1, timeout=30.0)
            outcome["result"] = "returned"
        except QueueClosed:
            outcome["result"] = "closed"
        outcome["elapsed"] = time.perf_counter() - t0

    t = threading.Thread(target=producer, daemon=True)
    t.start()
    time.sleep(0.2)  # let the producer block on the full queue
    q.close()
    t.join(timeout=5.0)
    assert not t.is_alive()
    assert outcome["result"] == "closed"
    assert outcome["elapsed"] < 5.0  # woke on close, not the 30s timeout
    # the blocked item was never enqueued; the queued one still drains
    assert q.get(timeout=1.0) == 0
    assert q.get(timeout=1.0) is CLOSED


def test_queue_multi_producer_done():
    """The stream closes only after the *last* producer checks out."""
    q = TrajectoryQueue(depth=4, producers=2)
    q.put("a")
    q.producer_done()  # first producer finishes early
    q.put("b")  # second producer still live
    assert q.get(timeout=1.0) == "a"
    q.producer_done()
    assert q.get(timeout=1.0) == "b"
    assert q.get(timeout=1.0) is CLOSED
    with pytest.raises(QueueClosed):
        q.put("c")


def test_param_slot_versions():
    slot = ParamSlot("v0", version=0)
    assert slot.read() == ("v0", 0)
    slot.publish("v3", 3)
    assert slot.wait_for(2, timeout=1.0)
    assert slot.read() == ("v3", 3)
    assert not slot.wait_for(5, timeout=0.05)


# ---------------------------------------------------------------------------
# device trajectory ring (the device queue plane)
# ---------------------------------------------------------------------------


def _dev(x):
    return jax.numpy.asarray(x)


def test_ring_backpressure_blocks_and_never_drops():
    """Same contract as the host queue: depth bounds in-flight slots by
    blocking producers; every payload is consumed exactly once, in order."""
    ring = DeviceTrajectoryRing(depth=2)
    n_items = 7
    # materialize on the main thread: first-ever device-array creation from
    # a worker thread can block on backend init and skew the timing below
    items = [_dev(i) for i in range(n_items)]
    produced = []

    def producer():
        for i in range(n_items):
            ring.put(items[i])
            produced.append(i)
        ring.close()

    t = threading.Thread(target=producer, daemon=True)
    t.start()
    time.sleep(0.2)
    assert ring.qsize() == 2
    assert len(produced) == 2  # third put is blocked on a full ring
    got = []
    while True:
        item = ring.get(timeout=5.0)
        if item is CLOSED:
            break
        got.append(int(item))
    t.join(timeout=5.0)
    assert got == list(range(n_items))
    assert ring.tickets_issued == n_items
    assert ring.put_wait_s > 0.1  # producer idle accounting saw the block


def test_ring_rejects_host_payloads():
    """The device plane polices itself: a numpy leaf means a host staging
    step crept back in — loud TypeError, not a silent round trip."""
    ring = DeviceTrajectoryRing(depth=2)
    with pytest.raises(TypeError, match="device"):
        ring.put(np.zeros(3))
    ring.put(_dev(np.zeros(3)))  # device arrays are accepted
    assert ring.qsize() == 1


def test_ring_close_wakes_blocked_put_and_drains():
    ring = DeviceTrajectoryRing(depth=1)
    ring.put(_dev(0))
    blocked_item = _dev(1)  # created on the main thread (backend init)
    outcome = {}

    def producer():
        try:
            ring.put(blocked_item, timeout=30.0)
            outcome["result"] = "returned"
        except QueueClosed:
            outcome["result"] = "closed"

    t = threading.Thread(target=producer, daemon=True)
    t.start()
    time.sleep(0.2)
    ring.close()
    t.join(timeout=5.0)
    assert not t.is_alive()
    assert outcome["result"] == "closed"
    assert int(ring.get(timeout=1.0)) == 0  # queued slot still drains
    assert ring.get(timeout=1.0) is CLOSED


def test_ring_multi_producer_done_and_validation():
    ring = DeviceTrajectoryRing(depth=4, producers=2)
    ring.put(_dev(0))
    ring.producer_done()  # first producer checks out early
    ring.put(_dev(1))  # second producer still live
    assert int(ring.get(timeout=1.0)) == 0
    ring.producer_done()
    assert int(ring.get(timeout=1.0)) == 1
    assert ring.get(timeout=1.0) is CLOSED
    with pytest.raises(QueueClosed):
        ring.put(_dev(2))
    with pytest.raises(ValueError):
        DeviceTrajectoryRing(depth=0)
    with pytest.raises(ValueError):
        DeviceTrajectoryRing(depth=1, producers=0)


def test_ring_get_transfers_slot_ownership():
    """After get() the ring holds no reference: consuming (deleting) the
    payload cannot disturb later slots."""
    ring = DeviceTrajectoryRing(depth=2)
    a, b = _dev(np.arange(3)), _dev(np.arange(3, 6))
    ring.put(a)
    ring.put(b)
    got = ring.get(timeout=1.0)
    got.delete()  # learner-side donation/retirement of the slot arrays
    second = ring.get(timeout=1.0)
    np.testing.assert_array_equal(np.asarray(second), np.arange(3, 6))


# ---------------------------------------------------------------------------
# ping-pong param slot (donation-safe publish)
# ---------------------------------------------------------------------------


def test_ping_pong_slot_snapshots_are_copies():
    """Actors must never see the learner's working buffers: the slot copies
    at construction and on publish."""
    params = {"w": jax.numpy.arange(4, dtype=jax.numpy.float32)}
    slot = PingPongParamSlot(params, version=0)
    seen, v = slot.acquire()
    assert v == 0
    assert seen["w"] is not params["w"]
    np.testing.assert_array_equal(np.asarray(seen["w"]), np.asarray(params["w"]))
    # deleting the learner's original (donation) leaves the snapshot intact
    params["w"].delete()
    np.testing.assert_array_equal(np.asarray(seen["w"]), np.arange(4))
    slot.release(v)


def test_ping_pong_reserve_waits_for_readers():
    """reserve(v) must not hand out buffer v%2 while a reader of its current
    contents is still live — the race that made donation unsafe."""
    slot = PingPongParamSlot({"w": jax.numpy.zeros(2)}, version=0)
    params, v = slot.acquire()  # lease buffer 0 (version 0)
    assert slot.reserve(2, timeout=0.1) is None  # buffer 0 busy: times out
    assert slot.reserve(1, timeout=0.1) is not None  # buffer 1 is free
    done = {}

    def learner():
        done["dst"] = slot.reserve(2, timeout=5.0)  # blocks on the lease

    t = threading.Thread(target=learner, daemon=True)
    t.start()
    time.sleep(0.1)
    assert "dst" not in done
    slot.release(v)
    t.join(timeout=5.0)
    assert done["dst"] is not None


def test_ping_pong_publish_alternates_and_versions():
    slot = PingPongParamSlot({"w": jax.numpy.zeros(2)}, version=0)
    for ver in (1, 2, 3):
        slot.publish({"w": jax.numpy.full((2,), float(ver))}, ver)
        params, v = slot.acquire()
        assert v == ver
        np.testing.assert_array_equal(np.asarray(params["w"]),
                                      np.full((2,), float(ver)))
        slot.release(v)
    assert slot.wait_for(3, timeout=0.1)


def test_ping_pong_publish_raises_loudly_on_leased_buffer():
    """Regression: publish() used to discard reserve()'s result, so a
    timed-out reserve fell through to commit on a still-leased buffer —
    handing actors a tree mutating under them. Now it raises."""
    slot = PingPongParamSlot({"w": jax.numpy.zeros(2)}, version=0)
    params, v = slot.acquire()  # lease buffer 0; version-2 publish needs it
    with pytest.raises(RuntimeError, match="still leased"):
        slot.publish({"w": jax.numpy.ones(2)}, 2, timeout=0.1)
    # the leased snapshot was never clobbered mid-lease
    np.testing.assert_array_equal(np.asarray(params["w"]), np.zeros(2))
    slot.release(v)
    slot.publish({"w": jax.numpy.ones(2)}, 2, timeout=0.1)  # now fine
    assert slot.version == 2


# ---------------------------------------------------------------------------
# host staging ring (reusable pinned payload buffers)
# ---------------------------------------------------------------------------


def test_host_staging_ring_recycles_sets():
    ring = HostStagingRing(3, t_max=2, n_envs=4, obs_shape=(5,))
    a = ring.acquire()
    b = ring.acquire()
    c = ring.acquire()
    assert ring.free_sets() == 0
    assert a.traj.obs.shape == (2, 4, 5)
    assert a.last_obs.shape == (4, 5)
    ring.release(b)
    assert ring.acquire() is b  # LIFO reuse of the hot set
    ring.release(a)
    ring.release(c)


def test_host_staging_ring_acquire_timeout_is_loud():
    ring = HostStagingRing(2, t_max=1, n_envs=1, obs_shape=())
    ring.acquire()
    ring.acquire()
    with pytest.raises(RuntimeError, match="release"):
        ring.acquire(timeout=0.1)
    with pytest.raises(ValueError):
        HostStagingRing(1, t_max=1, n_envs=1, obs_shape=())


# ---------------------------------------------------------------------------
# pipelined vs sync equivalence (depth 1, lockstep, ρ̄ → ∞)
# ---------------------------------------------------------------------------


def _vector_cfg(env):
    return get_config("paac_vector").replace(
        obs_shape=env.obs_shape, num_actions=env.num_actions
    )


def test_lockstep_pipeline_matches_sync():
    agent = PAACAgent(_vector_cfg(GridWorld(8, size=4, max_steps=20)),
                      PAACConfig(t_max=5))
    rl = ParallelRL(GridWorld(8, size=4, max_steps=20), agent,
                    lr_schedule=constant(0.01), seed=1)
    r_sync = rl.run(10)
    prl = PipelinedRL(
        GridWorld(8, size=4, max_steps=20), agent,
        lr_schedule=constant(0.01), seed=1,
        pipeline=PipelineConfig(queue_depth=1, rho_bar=1e9, lockstep=True),
    )
    r_pipe = prl.run(10)
    # learning metrics match the synchronous baseline
    for k in ("loss", "policy_loss", "value_loss", "entropy", "reward_sum"):
        np.testing.assert_allclose(
            r_pipe.mean_metrics[k], r_sync.mean_metrics[k],
            rtol=1e-4, atol=1e-5, err_msg=k,
        )
    assert r_pipe.mean_metrics["staleness"] == 0.0
    # ... and so do the resulting parameters
    for a, b in zip(jax.tree_util.tree_leaves(rl.params),
                    jax.tree_util.tree_leaves(prl.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_lockstep_vtrace_inf_clips_bitwise_on_host_pool():
    """Single actor, depth 1, V-trace with ρ̄ = c̄ → ∞ reproduces the
    synchronous ``ParallelRL`` params *bitwise* (the PR-1 equivalence pin
    extended to the V-trace learner: infinite clips compile the correction
    out exactly, and sync + pipelined share the same jitted steps)."""
    cfg = get_config("paac_vector").replace(obs_shape=(1,), num_actions=3)
    agent = PAACAgent(cfg, PAACConfig(t_max=5))
    with _toy_pool() as pool:
        rl = ParallelRL(pool, agent, lr_schedule=constant(0.003), seed=1)
        r_sync = rl.run(8)
    inf = float("inf")
    with _toy_pool() as pool:
        prl = PipelinedRL(
            pool, agent, lr_schedule=constant(0.003), seed=1,
            pipeline=PipelineConfig(queue_depth=1, rho_bar=inf, c_bar=inf,
                                    lockstep=True),
        )
        r_pipe = prl.run(8)
    assert r_pipe.mean_metrics["staleness"] == 0.0
    for k in ("loss", "policy_loss", "value_loss", "entropy", "reward_sum"):
        assert r_pipe.mean_metrics[k] == r_sync.mean_metrics[k], k
    for a, b in zip(jax.tree_util.tree_leaves(rl.params),
                    jax.tree_util.tree_leaves(prl.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _leaves(tree):
    return jax.tree_util.tree_leaves(tree)


@pytest.mark.parametrize("plane", ["device", "host"])
def test_ring_depth1_lockstep_bitwise_vs_sync(plane):
    """The PR-2 bitwise pin extended to the device-resident plane: depth-1
    lockstep with ρ̄ = c̄ = ∞ reproduces synchronous ``ParallelRL`` params
    and metrics *bit for bit* on a JAX-native env — through the ring with
    full params/opt-state donation and the fused publish, and through the
    forced host plane (whose staging D2H/H2D round trip must be lossless).
    """
    agent = PAACAgent(_vector_cfg(GridWorld(8, size=4, max_steps=20)),
                      PAACConfig(t_max=5))
    rl = ParallelRL(GridWorld(8, size=4, max_steps=20), agent,
                    lr_schedule=constant(0.01), seed=1)
    r_sync = rl.run(10)
    inf = float("inf")
    prl = PipelinedRL(
        GridWorld(8, size=4, max_steps=20), agent,
        lr_schedule=constant(0.01), seed=1,
        pipeline=PipelineConfig(queue_depth=1, rho_bar=inf, c_bar=inf,
                                lockstep=True, rollout_plane=plane),
    )
    assert prl._plane == plane
    r_pipe = prl.run(10)
    assert r_pipe.mean_metrics["staleness"] == 0.0
    for k in ("loss", "policy_loss", "value_loss", "entropy", "reward_sum"):
        assert r_pipe.mean_metrics[k] == r_sync.mean_metrics[k], k
    for a, b in zip(_leaves(rl.params), _leaves(prl.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_donated_learner_step_deletes_stale_buffers_only():
    """Donation regression: after a run the learner's pre-run params and opt
    state are genuinely donated (reading them raises the deleted-buffer
    RuntimeError), while the actor-facing published snapshot and the
    learner's live params remain readable — and the backend keeps working
    (a second run from the survivors)."""
    env = GridWorld(8, size=4, max_steps=20)
    agent = PAACAgent(_vector_cfg(env), PAACConfig(t_max=5))
    prl = PipelinedRL(
        GridWorld(8, size=4, max_steps=20), agent,
        lr_schedule=constant(0.01), seed=0,
        pipeline=PipelineConfig(queue_depth=2),
    )
    assert prl._plane == "device"
    old_params, old_opt = prl.params, prl.opt_state
    prl.run(4)
    for leaf in _leaves(old_params) + _leaves(old_opt):
        assert leaf.is_deleted()
    with pytest.raises(RuntimeError):
        np.asarray(_leaves(old_params)[0])
    # live params and a fresh run still work: nothing the actors lease was
    # ever donated
    assert all(not leaf.is_deleted() for leaf in _leaves(prl.params))
    res = prl.run(3)
    assert np.isfinite(res.mean_metrics["loss"])


def test_async_pipeline_reports_staleness_and_rho():
    agent = PAACAgent(_vector_cfg(GridWorld(8, size=4, max_steps=20)),
                      PAACConfig(t_max=5))
    prl = PipelinedRL(
        GridWorld(8, size=4, max_steps=20), agent,
        lr_schedule=constant(0.01), seed=0,
        pipeline=PipelineConfig(queue_depth=2, rho_bar=1.0),
    )
    res = prl.run(12)
    assert res.steps == 12 * 8 * 5
    assert res.mean_metrics["staleness"] > 0.0  # actor genuinely ran ahead
    # behaviour ≈ learner policy at tiny lr: ratios near 1, rarely clipped
    assert 0.5 < res.mean_metrics["rho_mean"] < 2.0
    assert res.mean_metrics["rho_clip_frac"] <= 1.0


# ---------------------------------------------------------------------------
# end-to-end smokes
# ---------------------------------------------------------------------------


def test_pipeline_token_env_smoke():
    env = TokenEnv(4, vocab=16, ctx=8, k=2, horizon=16)
    cfg = get_config("qwen2-7b").reduced().replace(
        num_layers=1, d_model=64, d_ff=128, num_heads=2, num_kv_heads=1,
        head_dim=32, vocab_size=16, num_actions=env.vocab,
    )
    agent = PAACAgent(cfg, PAACConfig(t_max=4))
    prl = PipelinedRL(env, agent, lr_schedule=constant(1e-3), seed=0,
                      pipeline=PipelineConfig(queue_depth=2))
    res = prl.run(4)
    assert res.steps == 4 * 4 * 4
    assert np.isfinite(res.mean_metrics["loss"])


class _ToyGymEnv:
    """Gym-style counter env: reward 1 when action == state % 3."""

    def __init__(self, seed):
        self.rng = np.random.RandomState(seed)
        self.state = 0

    def reset(self):
        self.state = int(self.rng.randint(0, 100))
        return np.array([self.state % 7], np.float32)

    def step(self, action):
        reward = 1.0 if action == self.state % 3 else 0.0
        self.state += 1
        done = self.state % 10 == 0
        return np.array([self.state % 7], np.float32), reward, done, {}


def _toy_pool(n=8, n_workers=4):
    return HostEnvPool([lambda s=i: _ToyGymEnv(s) for i in range(n)],
                       n_workers=n_workers, obs_shape=(1,))


def test_pipeline_host_env_pool_smoke():
    cfg = get_config("paac_vector").replace(obs_shape=(1,), num_actions=3)
    agent = PAACAgent(cfg, PAACConfig(t_max=5))
    with _toy_pool() as pool:
        prl = PipelinedRL(pool, agent, lr_schedule=constant(0.003), seed=0,
                          pipeline=PipelineConfig(queue_depth=2))
        res = prl.run(6)
    assert res.steps == 6 * 8 * 5
    assert np.isfinite(res.mean_metrics["loss"])
    assert res.episodes > 0  # toy envs terminate every 10 steps


def test_sync_parallel_rl_drives_host_env_pool():
    """ParallelRL transparently drives external envs (paper §3 literally)."""
    cfg = get_config("paac_vector").replace(obs_shape=(1,), num_actions=3)
    agent = PAACAgent(cfg, PAACConfig(t_max=5))
    with _toy_pool() as pool:
        rl = ParallelRL(pool, agent, lr_schedule=constant(0.003), seed=0)
        res = rl.run(6)
    assert res.steps == 6 * 8 * 5
    assert np.isfinite(res.mean_metrics["loss"])
    # sync host driver is on-policy: importance ratios stay ≈ 1
    np.testing.assert_allclose(res.mean_metrics["rho_mean"], 1.0, atol=1e-3)


def test_pipeline_actor_failure_propagates():
    class ExplodingEnv(_ToyGymEnv):
        def step(self, action):
            raise RuntimeError("emulator crashed")

    cfg = get_config("paac_vector").replace(obs_shape=(1,), num_actions=3)
    agent = PAACAgent(cfg, PAACConfig(t_max=2))
    with HostEnvPool([lambda s=i: ExplodingEnv(s) for i in range(4)],
                     n_workers=2, obs_shape=(1,)) as pool:
        prl = PipelinedRL(pool, agent, lr_schedule=constant(0.003), seed=0)
        with pytest.raises(RuntimeError):
            prl.run(3)


# ---------------------------------------------------------------------------
# multi-actor contracts (N replicas, one learner)
# ---------------------------------------------------------------------------


def _vector_agent(t_max=5):
    cfg = get_config("paac_vector").replace(obs_shape=(1,), num_actions=3)
    return PAACAgent(cfg, PAACConfig(t_max=t_max))


def test_multi_actor_never_drops_and_merges_idle_accounting():
    """N=3 actors: every (actor_id, seq) is learned exactly once, and the
    merged actor-idle figure is exactly the sum of the per-actor totals."""
    agent = _vector_agent()
    iterations = 9
    with HostEnvPool([lambda s=i: _ToyGymEnv(s) for i in range(6)],
                     n_workers=3, obs_shape=(1,)) as pool:
        prl = PipelinedRL(
            pool, agent, lr_schedule=constant(0.003), seed=0,
            pipeline=PipelineConfig(queue_depth=2, num_actors=3),
        )
        res = prl.run(iterations)
    # each learned rollout is one 2-env shard's t_max steps
    assert res.steps == iterations * 2 * 5
    # never-drop: every (actor_id, seq) consumed exactly once
    expect = [(a, s) for a in range(3) for s in range(3)]
    assert sorted(prl.learned_ids) == expect
    # merged idle accounting sums to the per-actor totals
    assert len(res.per_actor_idle_s) == 3
    assert res.actor_idle_s == pytest.approx(sum(res.per_actor_idle_s))
    assert all(t >= 0.0 for t in res.per_actor_idle_s)


def test_multi_actor_jax_env_axis_split():
    """A single JAX-native env is split along the env axis: 2 actors on an
    8-env GridWorld collect 4-env rollouts each."""
    env = GridWorld(8, size=4, max_steps=20)
    cfg = get_config("paac_vector").replace(
        obs_shape=env.obs_shape, num_actions=env.num_actions
    )
    agent = PAACAgent(cfg, PAACConfig(t_max=5))
    prl = PipelinedRL(
        env, agent, lr_schedule=constant(0.01), seed=0,
        pipeline=PipelineConfig(queue_depth=2, num_actors=2),
    )
    res = prl.run(6)
    assert res.steps == 6 * 4 * 5  # shard width 4, not 8
    assert sorted(prl.learned_ids) == [(a, s) for a in range(2)
                                       for s in range(3)]
    assert np.isfinite(res.mean_metrics["loss"])


def test_multi_actor_per_actor_env_pools():
    """A list of envs gives each replica its own full pool (GA3C sweep)."""
    agent = _vector_agent(t_max=3)
    pools = [HostEnvPool([lambda s=4 * a + i: _ToyGymEnv(s) for i in range(4)],
                         n_workers=2, obs_shape=(1,)) for a in range(2)]
    try:
        prl = PipelinedRL(
            pools, agent, lr_schedule=constant(0.003), seed=0,
            pipeline=PipelineConfig(queue_depth=2, num_actors=2),
        )
        res = prl.run(6)
    finally:
        for p in pools:
            p.close()
    assert res.steps == 6 * 4 * 3  # full 4-env rollouts per actor
    assert sorted(prl.learned_ids) == [(a, s) for a in range(2)
                                       for s in range(3)]


def test_multi_actor_one_crash_propagates_without_deadlock():
    """One of three actors crashing surfaces in run() while the healthy
    replicas unwind cleanly (no deadlock, no secondary errors)."""
    class ExplodingEnv(_ToyGymEnv):
        def step(self, action):
            raise RuntimeError("emulator crashed")

    agent = _vector_agent(t_max=2)
    # envs 0-1 -> actor 0 (healthy), 2-3 -> actor 1 (explodes), 4-5 -> actor 2
    def mk(i):
        return ExplodingEnv(i) if i in (2, 3) else _ToyGymEnv(i)

    with HostEnvPool([lambda s=i: mk(s) for i in range(6)],
                     n_workers=3, obs_shape=(1,)) as pool:
        prl = PipelinedRL(
            pool, agent, lr_schedule=constant(0.003), seed=0,
            pipeline=PipelineConfig(queue_depth=1, num_actors=3),
        )
        t0 = time.perf_counter()
        with pytest.raises(RuntimeError, match="actor 1"):
            prl.run(30)
        assert time.perf_counter() - t0 < 60.0  # unwound, not deadlocked


def test_host_act_step_logp_matches_rollout_gather():
    """The fused host acting step computes the behaviour log-prob the same
    way ``core/rollout.step`` does (gather the sampled logit + logsumexp,
    never the full log_softmax matrix) — the two acting paths must agree on
    log π(a|s) for the V-trace ratios to mean the same thing on both."""
    from repro.models import init_policy
    from repro.pipeline.actor import make_host_act_step

    cfg = get_config("paac_vector").replace(obs_shape=(3,), num_actions=5)
    agent = PAACAgent(cfg, PAACConfig(t_max=2))
    act = agent.act_fn()
    act_step = make_host_act_step(act)
    key = jax.random.PRNGKey(0)
    params = init_policy(jax.random.PRNGKey(1), cfg)
    obs = jax.random.normal(jax.random.PRNGKey(2), (8, 3))
    action, value, logp, _ = act_step(params, obs, key)
    # reference: the full log_softmax gather (the pre-PR-3 formulation)
    logits, _ = act(params, obs)
    ref = jax.numpy.take_along_axis(
        jax.nn.log_softmax(logits), action[:, None], axis=1)[:, 0]
    np.testing.assert_allclose(np.asarray(logp), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


def test_zero_quota_actors_check_out_cleanly_thread_backend():
    """iterations < num_actors hands some replicas quota 0: they must check
    out via producer_done without hanging the stream, and learned_ids still
    covers every (actor_id, seq) exactly once."""
    agent = _vector_agent(t_max=2)
    with HostEnvPool([lambda s=i: _ToyGymEnv(s) for i in range(6)],
                     n_workers=3, obs_shape=(1,)) as pool:
        prl = PipelinedRL(
            pool, agent, lr_schedule=constant(0.003), seed=0,
            pipeline=PipelineConfig(queue_depth=2, num_actors=3),
        )
        t0 = time.perf_counter()
        res = prl.run(2)  # quota [1, 1, 0]
        assert time.perf_counter() - t0 < 60.0  # no shutdown hang
    assert res.steps == 2 * 2 * 2
    assert sorted(prl.learned_ids) == [(0, 0), (1, 0)]


def test_multi_actor_config_validation():
    agent = _vector_agent()
    env = GridWorld(8, size=4, max_steps=20)
    cfg = get_config("paac_vector").replace(
        obs_shape=env.obs_shape, num_actions=env.num_actions
    )
    agent = PAACAgent(cfg, PAACConfig(t_max=5))
    with pytest.raises(ValueError):  # lockstep needs a single actor
        PipelinedRL(env, agent, pipeline=PipelineConfig(num_actors=2,
                                                        lockstep=True))
    with pytest.raises(ValueError):  # 8 envs don't split into 3 shards
        PipelinedRL(env, agent, pipeline=PipelineConfig(num_actors=3))
    with pytest.raises(ValueError):  # env-list length must match num_actors
        PipelinedRL([env], agent, pipeline=PipelineConfig(num_actors=2))
