"""repro.pipeline: queue semantics, sync equivalence, end-to-end smokes.

Pins the subsystem's contracts:
* the bounded queue applies backpressure (blocks producers), never drops a
  trajectory, and a ``close()`` landing on a blocked ``put()`` raises
  promptly instead of hanging,
* at queue depth 1 with lockstep + infinite V-trace clips the pipelined
  backend reproduces the synchronous ``ParallelRL`` run — bitwise on the
  shared-learner ``HostEnvPool`` path,
* N actor replicas never drop a rollout (every ``(actor_id, seq)`` learned
  exactly once), merged idle accounting sums to per-actor totals, and one
  actor crashing propagates without deadlocking the others,
* ``PipelinedRL.run`` works end to end on a JAX-native env, a token env,
  and a ``HostEnvPool`` of external gym-style envs.
"""
import threading
import time

import jax
import numpy as np
import pytest

from repro.configs import PipelineConfig, get_config
from repro.core import ParallelRL
from repro.core.agents import PAACAgent, PAACConfig
from repro.envs import GridWorld, HostEnvPool, TokenEnv
from repro.optim import constant
from repro.pipeline import (
    CLOSED,
    ParamSlot,
    PipelinedRL,
    QueueClosed,
    TrajectoryQueue,
)


# ---------------------------------------------------------------------------
# queue
# ---------------------------------------------------------------------------


def test_queue_backpressure_blocks_and_never_drops():
    q = TrajectoryQueue(depth=2)
    n_items = 7
    produced = []

    def producer():
        for i in range(n_items):
            q.put(i)
            produced.append(i)
        q.close()

    t = threading.Thread(target=producer, daemon=True)
    t.start()
    time.sleep(0.2)
    # bounded: with no consumer the producer is stuck at depth items
    assert q.qsize() == 2
    assert len(produced) == 2  # third put is blocked
    # drain: every item arrives exactly once, in order, then CLOSED
    got = []
    while True:
        item = q.get(timeout=5.0)
        if item is CLOSED:
            break
        got.append(item)
    t.join(timeout=5.0)
    assert got == list(range(n_items))
    assert q.put_wait_s > 0.1  # the actor-idle accounting saw the block


def test_queue_close_is_idempotent_and_rejects_put():
    q = TrajectoryQueue(depth=1)
    q.close()
    q.close()
    assert q.get(timeout=1.0) is CLOSED
    with pytest.raises(RuntimeError):
        q.put(1)


def test_queue_depth_validation():
    with pytest.raises(ValueError):
        TrajectoryQueue(depth=0)
    with pytest.raises(ValueError):
        TrajectoryQueue(depth=1, producers=0)


def test_queue_close_wakes_blocked_put():
    """Regression: a producer blocked in put() when close() lands must raise
    promptly (QueueClosed), not hang until its timeout."""
    q = TrajectoryQueue(depth=1)
    q.put(0)  # fill the queue so the next put blocks
    outcome = {}

    def producer():
        t0 = time.perf_counter()
        try:
            q.put(1, timeout=30.0)
            outcome["result"] = "returned"
        except QueueClosed:
            outcome["result"] = "closed"
        outcome["elapsed"] = time.perf_counter() - t0

    t = threading.Thread(target=producer, daemon=True)
    t.start()
    time.sleep(0.2)  # let the producer block on the full queue
    q.close()
    t.join(timeout=5.0)
    assert not t.is_alive()
    assert outcome["result"] == "closed"
    assert outcome["elapsed"] < 5.0  # woke on close, not the 30s timeout
    # the blocked item was never enqueued; the queued one still drains
    assert q.get(timeout=1.0) == 0
    assert q.get(timeout=1.0) is CLOSED


def test_queue_multi_producer_done():
    """The stream closes only after the *last* producer checks out."""
    q = TrajectoryQueue(depth=4, producers=2)
    q.put("a")
    q.producer_done()  # first producer finishes early
    q.put("b")  # second producer still live
    assert q.get(timeout=1.0) == "a"
    q.producer_done()
    assert q.get(timeout=1.0) == "b"
    assert q.get(timeout=1.0) is CLOSED
    with pytest.raises(QueueClosed):
        q.put("c")


def test_param_slot_versions():
    slot = ParamSlot("v0", version=0)
    assert slot.read() == ("v0", 0)
    slot.publish("v3", 3)
    assert slot.wait_for(2, timeout=1.0)
    assert slot.read() == ("v3", 3)
    assert not slot.wait_for(5, timeout=0.05)


# ---------------------------------------------------------------------------
# pipelined vs sync equivalence (depth 1, lockstep, ρ̄ → ∞)
# ---------------------------------------------------------------------------


def _vector_cfg(env):
    return get_config("paac_vector").replace(
        obs_shape=env.obs_shape, num_actions=env.num_actions
    )


def test_lockstep_pipeline_matches_sync():
    agent = PAACAgent(_vector_cfg(GridWorld(8, size=4, max_steps=20)),
                      PAACConfig(t_max=5))
    rl = ParallelRL(GridWorld(8, size=4, max_steps=20), agent,
                    lr_schedule=constant(0.01), seed=1)
    r_sync = rl.run(10)
    prl = PipelinedRL(
        GridWorld(8, size=4, max_steps=20), agent,
        lr_schedule=constant(0.01), seed=1,
        pipeline=PipelineConfig(queue_depth=1, rho_bar=1e9, lockstep=True),
    )
    r_pipe = prl.run(10)
    # learning metrics match the synchronous baseline
    for k in ("loss", "policy_loss", "value_loss", "entropy", "reward_sum"):
        np.testing.assert_allclose(
            r_pipe.mean_metrics[k], r_sync.mean_metrics[k],
            rtol=1e-4, atol=1e-5, err_msg=k,
        )
    assert r_pipe.mean_metrics["staleness"] == 0.0
    # ... and so do the resulting parameters
    for a, b in zip(jax.tree_util.tree_leaves(rl.params),
                    jax.tree_util.tree_leaves(prl.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_lockstep_vtrace_inf_clips_bitwise_on_host_pool():
    """Single actor, depth 1, V-trace with ρ̄ = c̄ → ∞ reproduces the
    synchronous ``ParallelRL`` params *bitwise* (the PR-1 equivalence pin
    extended to the V-trace learner: infinite clips compile the correction
    out exactly, and sync + pipelined share the same jitted steps)."""
    cfg = get_config("paac_vector").replace(obs_shape=(1,), num_actions=3)
    agent = PAACAgent(cfg, PAACConfig(t_max=5))
    with _toy_pool() as pool:
        rl = ParallelRL(pool, agent, lr_schedule=constant(0.003), seed=1)
        r_sync = rl.run(8)
    inf = float("inf")
    with _toy_pool() as pool:
        prl = PipelinedRL(
            pool, agent, lr_schedule=constant(0.003), seed=1,
            pipeline=PipelineConfig(queue_depth=1, rho_bar=inf, c_bar=inf,
                                    lockstep=True),
        )
        r_pipe = prl.run(8)
    assert r_pipe.mean_metrics["staleness"] == 0.0
    for k in ("loss", "policy_loss", "value_loss", "entropy", "reward_sum"):
        assert r_pipe.mean_metrics[k] == r_sync.mean_metrics[k], k
    for a, b in zip(jax.tree_util.tree_leaves(rl.params),
                    jax.tree_util.tree_leaves(prl.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_pipeline_reports_staleness_and_rho():
    agent = PAACAgent(_vector_cfg(GridWorld(8, size=4, max_steps=20)),
                      PAACConfig(t_max=5))
    prl = PipelinedRL(
        GridWorld(8, size=4, max_steps=20), agent,
        lr_schedule=constant(0.01), seed=0,
        pipeline=PipelineConfig(queue_depth=2, rho_bar=1.0),
    )
    res = prl.run(12)
    assert res.steps == 12 * 8 * 5
    assert res.mean_metrics["staleness"] > 0.0  # actor genuinely ran ahead
    # behaviour ≈ learner policy at tiny lr: ratios near 1, rarely clipped
    assert 0.5 < res.mean_metrics["rho_mean"] < 2.0
    assert res.mean_metrics["rho_clip_frac"] <= 1.0


# ---------------------------------------------------------------------------
# end-to-end smokes
# ---------------------------------------------------------------------------


def test_pipeline_token_env_smoke():
    env = TokenEnv(4, vocab=16, ctx=8, k=2, horizon=16)
    cfg = get_config("qwen2-7b").reduced().replace(
        num_layers=1, d_model=64, d_ff=128, num_heads=2, num_kv_heads=1,
        head_dim=32, vocab_size=16, num_actions=env.vocab,
    )
    agent = PAACAgent(cfg, PAACConfig(t_max=4))
    prl = PipelinedRL(env, agent, lr_schedule=constant(1e-3), seed=0,
                      pipeline=PipelineConfig(queue_depth=2))
    res = prl.run(4)
    assert res.steps == 4 * 4 * 4
    assert np.isfinite(res.mean_metrics["loss"])


class _ToyGymEnv:
    """Gym-style counter env: reward 1 when action == state % 3."""

    def __init__(self, seed):
        self.rng = np.random.RandomState(seed)
        self.state = 0

    def reset(self):
        self.state = int(self.rng.randint(0, 100))
        return np.array([self.state % 7], np.float32)

    def step(self, action):
        reward = 1.0 if action == self.state % 3 else 0.0
        self.state += 1
        done = self.state % 10 == 0
        return np.array([self.state % 7], np.float32), reward, done, {}


def _toy_pool(n=8, n_workers=4):
    return HostEnvPool([lambda s=i: _ToyGymEnv(s) for i in range(n)],
                       n_workers=n_workers, obs_shape=(1,))


def test_pipeline_host_env_pool_smoke():
    cfg = get_config("paac_vector").replace(obs_shape=(1,), num_actions=3)
    agent = PAACAgent(cfg, PAACConfig(t_max=5))
    with _toy_pool() as pool:
        prl = PipelinedRL(pool, agent, lr_schedule=constant(0.003), seed=0,
                          pipeline=PipelineConfig(queue_depth=2))
        res = prl.run(6)
    assert res.steps == 6 * 8 * 5
    assert np.isfinite(res.mean_metrics["loss"])
    assert res.episodes > 0  # toy envs terminate every 10 steps


def test_sync_parallel_rl_drives_host_env_pool():
    """ParallelRL transparently drives external envs (paper §3 literally)."""
    cfg = get_config("paac_vector").replace(obs_shape=(1,), num_actions=3)
    agent = PAACAgent(cfg, PAACConfig(t_max=5))
    with _toy_pool() as pool:
        rl = ParallelRL(pool, agent, lr_schedule=constant(0.003), seed=0)
        res = rl.run(6)
    assert res.steps == 6 * 8 * 5
    assert np.isfinite(res.mean_metrics["loss"])
    # sync host driver is on-policy: importance ratios stay ≈ 1
    np.testing.assert_allclose(res.mean_metrics["rho_mean"], 1.0, atol=1e-3)


def test_pipeline_actor_failure_propagates():
    class ExplodingEnv(_ToyGymEnv):
        def step(self, action):
            raise RuntimeError("emulator crashed")

    cfg = get_config("paac_vector").replace(obs_shape=(1,), num_actions=3)
    agent = PAACAgent(cfg, PAACConfig(t_max=2))
    with HostEnvPool([lambda s=i: ExplodingEnv(s) for i in range(4)],
                     n_workers=2, obs_shape=(1,)) as pool:
        prl = PipelinedRL(pool, agent, lr_schedule=constant(0.003), seed=0)
        with pytest.raises(RuntimeError):
            prl.run(3)


# ---------------------------------------------------------------------------
# multi-actor contracts (N replicas, one learner)
# ---------------------------------------------------------------------------


def _vector_agent(t_max=5):
    cfg = get_config("paac_vector").replace(obs_shape=(1,), num_actions=3)
    return PAACAgent(cfg, PAACConfig(t_max=t_max))


def test_multi_actor_never_drops_and_merges_idle_accounting():
    """N=3 actors: every (actor_id, seq) is learned exactly once, and the
    merged actor-idle figure is exactly the sum of the per-actor totals."""
    agent = _vector_agent()
    iterations = 9
    with HostEnvPool([lambda s=i: _ToyGymEnv(s) for i in range(6)],
                     n_workers=3, obs_shape=(1,)) as pool:
        prl = PipelinedRL(
            pool, agent, lr_schedule=constant(0.003), seed=0,
            pipeline=PipelineConfig(queue_depth=2, num_actors=3),
        )
        res = prl.run(iterations)
    # each learned rollout is one 2-env shard's t_max steps
    assert res.steps == iterations * 2 * 5
    # never-drop: every (actor_id, seq) consumed exactly once
    expect = [(a, s) for a in range(3) for s in range(3)]
    assert sorted(prl.learned_ids) == expect
    # merged idle accounting sums to the per-actor totals
    assert len(res.per_actor_idle_s) == 3
    assert res.actor_idle_s == pytest.approx(sum(res.per_actor_idle_s))
    assert all(t >= 0.0 for t in res.per_actor_idle_s)


def test_multi_actor_jax_env_axis_split():
    """A single JAX-native env is split along the env axis: 2 actors on an
    8-env GridWorld collect 4-env rollouts each."""
    env = GridWorld(8, size=4, max_steps=20)
    cfg = get_config("paac_vector").replace(
        obs_shape=env.obs_shape, num_actions=env.num_actions
    )
    agent = PAACAgent(cfg, PAACConfig(t_max=5))
    prl = PipelinedRL(
        env, agent, lr_schedule=constant(0.01), seed=0,
        pipeline=PipelineConfig(queue_depth=2, num_actors=2),
    )
    res = prl.run(6)
    assert res.steps == 6 * 4 * 5  # shard width 4, not 8
    assert sorted(prl.learned_ids) == [(a, s) for a in range(2)
                                       for s in range(3)]
    assert np.isfinite(res.mean_metrics["loss"])


def test_multi_actor_per_actor_env_pools():
    """A list of envs gives each replica its own full pool (GA3C sweep)."""
    agent = _vector_agent(t_max=3)
    pools = [HostEnvPool([lambda s=4 * a + i: _ToyGymEnv(s) for i in range(4)],
                         n_workers=2, obs_shape=(1,)) for a in range(2)]
    try:
        prl = PipelinedRL(
            pools, agent, lr_schedule=constant(0.003), seed=0,
            pipeline=PipelineConfig(queue_depth=2, num_actors=2),
        )
        res = prl.run(6)
    finally:
        for p in pools:
            p.close()
    assert res.steps == 6 * 4 * 3  # full 4-env rollouts per actor
    assert sorted(prl.learned_ids) == [(a, s) for a in range(2)
                                       for s in range(3)]


def test_multi_actor_one_crash_propagates_without_deadlock():
    """One of three actors crashing surfaces in run() while the healthy
    replicas unwind cleanly (no deadlock, no secondary errors)."""
    class ExplodingEnv(_ToyGymEnv):
        def step(self, action):
            raise RuntimeError("emulator crashed")

    agent = _vector_agent(t_max=2)
    # envs 0-1 -> actor 0 (healthy), 2-3 -> actor 1 (explodes), 4-5 -> actor 2
    def mk(i):
        return ExplodingEnv(i) if i in (2, 3) else _ToyGymEnv(i)

    with HostEnvPool([lambda s=i: mk(s) for i in range(6)],
                     n_workers=3, obs_shape=(1,)) as pool:
        prl = PipelinedRL(
            pool, agent, lr_schedule=constant(0.003), seed=0,
            pipeline=PipelineConfig(queue_depth=1, num_actors=3),
        )
        t0 = time.perf_counter()
        with pytest.raises(RuntimeError, match="actor 1"):
            prl.run(30)
        assert time.perf_counter() - t0 < 60.0  # unwound, not deadlocked


def test_multi_actor_config_validation():
    agent = _vector_agent()
    env = GridWorld(8, size=4, max_steps=20)
    cfg = get_config("paac_vector").replace(
        obs_shape=env.obs_shape, num_actions=env.num_actions
    )
    agent = PAACAgent(cfg, PAACConfig(t_max=5))
    with pytest.raises(ValueError):  # lockstep needs a single actor
        PipelinedRL(env, agent, pipeline=PipelineConfig(num_actors=2,
                                                        lockstep=True))
    with pytest.raises(ValueError):  # 8 envs don't split into 3 shards
        PipelinedRL(env, agent, pipeline=PipelineConfig(num_actors=3))
    with pytest.raises(ValueError):  # env-list length must match num_actors
        PipelinedRL([env], agent, pipeline=PipelineConfig(num_actors=2))
