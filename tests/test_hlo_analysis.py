"""The trip-count-aware HLO analyzer must out-count XLA's cost_analysis on
scanned programs by exactly the loop factor."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import analyze_hlo


def test_scan_flops_multiplied_by_trip_count():
    M, L = 256, 12

    def f(x, w):
        def body(c, wi):
            return c @ wi, None

        y, _ = jax.lax.scan(body, x, w)
        return y

    compiled = jax.jit(f).lower(
        jax.ShapeDtypeStruct((M, M), jnp.float32),
        jax.ShapeDtypeStruct((L, M, M), jnp.float32),
    ).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # older jax returns one dict per computation
        ca = ca[0]
    xla_flops = ca["flops"]
    static = analyze_hlo(compiled.as_text())
    expect = 2.0 * M**3 * L
    # XLA counts the body once; the analyzer must recover the full count
    assert xla_flops < expect / 2
    np.testing.assert_allclose(static["flops"], expect, rtol=0.05)


def test_unlooped_dot_matches_xla():
    M = 512

    def f(a, b):
        return a @ b

    compiled = jax.jit(f).lower(
        jax.ShapeDtypeStruct((M, M), jnp.float32),
        jax.ShapeDtypeStruct((M, M), jnp.float32),
    ).compile()
    static = analyze_hlo(compiled.as_text())
    np.testing.assert_allclose(static["flops"], 2.0 * M**3, rtol=0.05)
    # bytes: at least the three matrices once
    assert static["bytes"] >= 3 * M * M * 4
