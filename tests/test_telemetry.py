"""repro.telemetry: span rings, hub merge, trace export, derived accounting.

Pins the observability subsystem's contracts:

* ``SpanEmitter`` records nested spans inner-first with correct
  containment, a full ring *drops trace detail but never accounting*
  (per-category totals keep accumulating), ``cancel()`` discards an open
  span entirely, and ``set_capture(False)`` keeps totals while skipping
  ring/activity bookkeeping,
* the Chrome trace export is schema-valid (X events over the category
  vocabulary, thread/process metadata) and a pipelined run's trace
  contains spans from the thread plane — and, on the process backend,
  worker-side spans shipped across the process boundary onto ``pid != 0``
  tracks,
* the refactor's acceptance pin: ``RunResult``'s idle fields are *equal*
  (float-for-float — same accumulators) to the span emitters' totals,
* the device-plane ``log_every`` path never calls the draining
  ``cumulative()`` (the hidden-sync regression), and ``drain_ready`` folds
  exactly the already-materialized prefix of pending device metrics,
* the heartbeat emits schema-complete JSONL lines and the stall watchdog
  names the stage a stalled party is blocked in,
* ``repro.utils.logging``: ``REPRO_LOG_LEVEL`` parsing and one-handler
  idempotence.
"""
import json
import logging
import threading
import time

import pytest

from repro.configs import PipelineConfig, get_config
from repro.core.agents import PAACAgent, PAACConfig
from repro.core.framework import MetricsAccumulator
from repro.envs import GridWorld, py_bound_spec
from repro.optim import constant
from repro.pipeline import PipelinedRL
from repro.telemetry import (
    CATEGORIES,
    COLLECT,
    LEASE,
    QUEUE_GET_WAIT,
    QUEUE_PUT_WAIT,
    SpanEmitter,
    Telemetry,
    capture_enabled,
    set_capture,
)
from repro.utils.logging import _env_level, get_logger


def _vector_cfg(env):
    return get_config("paac_vector").replace(
        obs_shape=env.obs_shape, num_actions=env.num_actions
    )


def _grid_pipeline(tmp_path=None, **pipe_kw):
    env = GridWorld(8, size=4, max_steps=20)
    agent = PAACAgent(_vector_cfg(env), PAACConfig(t_max=3))
    return PipelinedRL(
        GridWorld(8, size=4, max_steps=20), agent,
        lr_schedule=constant(0.01), seed=0,
        pipeline=PipelineConfig(queue_depth=2, **pipe_kw),
    )


# ---------------------------------------------------------------------------
# SpanEmitter — ring, nesting, drops, capture switch
# ---------------------------------------------------------------------------


def test_span_nesting_records_inner_first_with_containment():
    em = SpanEmitter("t")
    em.begin(COLLECT)
    em.begin(LEASE)
    em.end()  # closes the inner lease
    em.end()  # closes the outer collect
    spans = em.snapshot()
    assert [c for c, _, _ in spans] == [LEASE, COLLECT]
    (ic, it0, it1), (oc, ot0, ot1) = spans
    assert ot0 <= it0 <= it1 <= ot1  # inner span nested inside the outer
    assert em.total(LEASE) == it1 - it0
    assert em.total(COLLECT) == ot1 - ot0


def test_full_ring_drops_spans_but_never_totals():
    em = SpanEmitter("t", capacity=2)
    for i in range(5):
        em.record(COLLECT, float(i), float(i) + 0.5)
    assert em.count == 2  # ring holds the first two
    assert em.drops == 3  # the rest were dropped...
    assert em.records == 5
    assert em.total(COLLECT) == pytest.approx(5 * 0.5)  # ...but still counted


def test_cancel_discards_the_open_span():
    em = SpanEmitter("t")
    em.begin(COLLECT)
    em.cancel()
    assert em.records == 0
    assert em.total(COLLECT) == 0.0
    # the stack stayed balanced: a fresh begin/end still records
    em.begin(LEASE)
    em.end()
    assert [c for c, _, _ in em.snapshot()] == [LEASE]


def test_set_capture_off_keeps_totals_only():
    em = SpanEmitter("t")
    set_capture(False)
    try:
        assert not capture_enabled()
        em.record(COLLECT, 1.0, 3.0)
    finally:
        set_capture(True)
    assert em.count == 0 and em.drops == 0  # nothing stored, nothing "lost"
    assert em.last_activity == 0.0
    assert em.total(COLLECT) == 2.0  # the accounting of record survived


def test_ship_roundtrips_through_hub_merge():
    em = SpanEmitter("worker0", capacity=8)
    em.record(COLLECT, 1.0, 2.0)
    em.record(QUEUE_PUT_WAIT, 2.0, 2.25)
    hub = Telemetry()
    track = hub.merge_shipped(em.ship(), pid=1)
    # same clock epoch (same process): timestamps arrive unshifted
    assert track.snapshot() == [(COLLECT, 1.0, 2.0), (QUEUE_PUT_WAIT, 2.0, 2.25)]
    assert track.total(COLLECT) == 1.0
    assert any(pid == 1 for pid, _, e in hub.tracks() if e is track)


# ---------------------------------------------------------------------------
# trace export — schema, thread plane, process plane
# ---------------------------------------------------------------------------


def _load_trace(path):
    with open(path) as f:
        doc = json.load(f)
    assert "traceEvents" in doc
    return doc["traceEvents"]


def test_pipelined_run_writes_schema_valid_trace(tmp_path):
    path = str(tmp_path / "trace.json")
    prl = _grid_pipeline(rollout_plane="host", trace_path=path)
    prl.run(6)
    events = _load_trace(path)
    xs = [e for e in events if e["ph"] == "X"]
    metas = [e for e in events if e["ph"] == "M"]
    assert xs and metas
    for e in xs:
        assert e["name"] in CATEGORIES
        assert e["dur"] >= 0 and e["ts"] >= 0
        assert e["pid"] == 0  # thread plane: everything in the parent
    names = {e["name"] for e in xs}
    assert {"collect", "queue.put_wait", "queue.get_wait",
            "learner.update", "publish"} <= names
    # every track is labeled for the viewer
    tracks = {e["args"]["name"] for e in metas if e["name"] == "thread_name"}
    assert {"learner", "queue", "actor0"} <= tracks


def test_device_plane_trace_contains_ring_spans(tmp_path):
    path = str(tmp_path / "trace.json")
    prl = _grid_pipeline(trace_path=path)  # JAX-native env -> device ring
    prl.run(6)
    events = _load_trace(path)
    tracks = {e["args"]["name"] for e in events
              if e["ph"] == "M" and e["name"] == "thread_name"}
    assert "ring" in tracks  # the device ring registered its own track
    names = {e["name"] for e in events if e["ph"] == "X"}
    assert {"collect", "queue.get_wait", "learner.update", "publish"} <= names


def test_replay_span_categories_in_vocabulary():
    """The replay plane's three stages are first-class span categories —
    constants index CATEGORIES exactly and the names are trace-exportable."""
    from repro.telemetry import REPLAY_ADD, REPLAY_EVICT, REPLAY_SAMPLE

    assert CATEGORIES[REPLAY_ADD] == "replay.add"
    assert CATEGORIES[REPLAY_SAMPLE] == "replay.sample"
    assert CATEGORIES[REPLAY_EVICT] == "replay.evict"
    em = SpanEmitter("replay")
    em.record(REPLAY_ADD, 1.0, 2.0)
    em.record(REPLAY_SAMPLE, 2.0, 2.5)
    em.record(REPLAY_EVICT, 2.5, 2.75)
    assert em.total(REPLAY_ADD) == 1.0
    assert em.total(REPLAY_SAMPLE) == 0.5
    assert em.total(REPLAY_EVICT) == 0.25


def test_replay_plane_trace_contains_replay_spans(tmp_path):
    """A replay-plane run's trace records add/sample (and evict once the
    ring wraps) on the replay track, all schema-valid over CATEGORIES."""
    path = str(tmp_path / "trace.json")
    # capacity 2 so 8 iterations force evictions into the trace
    prl = _grid_pipeline(trace_path=path, replay_plane=True,
                         replay_capacity=2, replay_batch=2)
    prl.run(8)
    events = _load_trace(path)
    xs = [e for e in events if e["ph"] == "X"]
    for e in xs:
        assert e["name"] in CATEGORIES
    names = {e["name"] for e in xs}
    assert {"replay.add", "replay.sample", "replay.evict",
            "collect", "queue.get_wait", "learner.update"} <= names
    tracks = {e["args"]["name"] for e in events
              if e["ph"] == "M" and e["name"] == "thread_name"}
    assert "replay" in tracks  # the ring registered its own track


def test_process_plane_ships_worker_spans_into_the_trace(tmp_path):
    path = str(tmp_path / "trace.json")
    spec = py_bound_spec(4, obs_dim=4, spin=0, n_workers=2)
    agent = PAACAgent(
        get_config("paac_vector").replace(obs_shape=(4,), num_actions=3),
        PAACConfig(t_max=3),
    )
    with PipelinedRL(
        spec, agent, lr_schedule=None, seed=0,
        pipeline=PipelineConfig(queue_depth=2, actor_backend="process",
                                trace_path=path),
    ) as prl:
        prl.run(6)
    events = _load_trace(path)
    worker_xs = [e for e in events if e["ph"] == "X" and e["pid"] != 0]
    assert worker_xs, "no worker-side spans made it across the process boundary"
    worker_names = {e["name"] for e in worker_xs}
    assert {"collect", "shm.copy"} <= worker_names
    procs = {e["args"]["name"] for e in events
             if e["ph"] == "M" and e["name"] == "process_name"}
    assert {"parent", "worker0"} <= procs


# ---------------------------------------------------------------------------
# derived accounting — RunResult fields ARE the span totals
# ---------------------------------------------------------------------------


def test_runresult_idle_fields_equal_span_totals():
    prl = _grid_pipeline(num_actors=1)
    res = prl.run(8)
    by_name = {em.name: em for _, _, em in prl.telemetry.tracks()}
    # learner idle == the queue consumer's get-wait total, bit for bit
    # (GridWorld is JAX-native, so the auto plane is the device ring)
    queue_em = by_name.get("ring") or by_name["queue"]
    assert res.learner_idle_s == queue_em.total(QUEUE_GET_WAIT)
    # per-actor idle == that actor's put-wait + lease totals
    actor_em = by_name["actor0"]
    assert res.per_actor_idle_s[0] == (
        actor_em.total(QUEUE_PUT_WAIT) + actor_em.total(LEASE)
    )
    assert res.actor_idle_s == sum(res.per_actor_idle_s)


# ---------------------------------------------------------------------------
# device-plane log_every — the hidden-sync regression
# ---------------------------------------------------------------------------


def test_device_log_every_never_calls_the_draining_cumulative(monkeypatch):
    def boom(self, key, default=0.0):
        raise AssertionError(
            "log_every called cumulative(): a hidden device sync"
        )

    monkeypatch.setattr(MetricsAccumulator, "cumulative", boom)
    prl = _grid_pipeline()  # JAX-native env -> device plane, lazy metrics
    res = prl.run(6, log_every=1)  # logs every iteration without draining
    assert res.steps > 0


def test_drain_ready_folds_only_the_materialized_prefix():
    class FakeScalar:
        def __init__(self, value, ready):
            self.value, self.ready = value, ready

        def is_ready(self):
            return self.ready

        def __float__(self):
            return float(self.value)

    acc = MetricsAccumulator(lazy=True)
    first = FakeScalar(1.0, True)
    second = FakeScalar(10.0, False)  # still executing on device
    third = FakeScalar(100.0, True)
    for s in (first, second, third):
        acc.update({"loss": s})
    # folds stop at the first still-executing update: the ready third dict
    # behind it must NOT be folded out of order
    assert acc.cumulative_nowait("loss") == 1.0
    assert acc.last("loss") == 1.0
    second.ready = True
    assert acc.cumulative_nowait("loss") == 111.0
    assert acc.last("loss") == 100.0
    # host floats have no is_ready and are always foldable
    acc.update({"loss": 0.5})
    assert acc.cumulative_nowait("loss") == 111.5


# ---------------------------------------------------------------------------
# heartbeat + watchdog
# ---------------------------------------------------------------------------


def test_heartbeat_appends_schema_complete_jsonl(tmp_path):
    path = str(tmp_path / "hb.jsonl")
    hub = Telemetry()
    em = hub.emitter("actor0")
    em.record(COLLECT, hub.t0, hub.t0 + 0.01)
    hub.counter_add("steps", 64)
    hub.set_gauge("queue_depth", lambda: 3)
    hub.set_gauge("staleness", 1.0)
    hub.set_gauge("broken", lambda: 1 / 0)  # must never kill the heartbeat
    hub.heartbeat_start(path, interval=0.05, actor_emitters=[em])
    time.sleep(0.2)
    hub.stop()  # writes one final line on the way out
    lines = [json.loads(l) for l in open(path) if l.strip()]
    assert lines
    for line in lines:
        assert {"time_unix", "uptime_s", "steps", "steps_per_s_ema",
                "span_drops", "actor_last_activity_s"} <= set(line)
        assert line["queue_depth"] == 3
        assert line["staleness"] == 1.0
        assert line["broken"] is None
        assert line["actor_last_activity_s"]["actor0"] is not None
    assert lines[-1]["steps"] == 64


def test_watchdog_names_the_blocked_stage(caplog):
    hub = Telemetry()
    learner = hub.emitter("learner")
    actor = hub.emitter("actor0")
    learner.begin(QUEUE_GET_WAIT)  # stuck waiting, recording nothing
    with caplog.at_level(logging.WARNING, logger="repro.telemetry"):
        hub.watchdog_start(0.2, [("learner", learner, None),
                                 ("actor0", actor, lambda: False)])
        time.sleep(0.6)
        hub.stop()
    learner.end()
    text = caplog.text
    assert "stall watchdog" in text
    assert "learner: blocked in queue.get_wait" in text
    assert "actor0: exited" in text
    # one report per stall episode, not one per poll tick
    assert text.count("stall watchdog") == 1


def test_watchdog_stays_quiet_while_progress_flows(caplog):
    hub = Telemetry()
    em = hub.emitter("learner")
    stop = threading.Event()

    def ticker():
        while not stop.is_set():
            em.record(COLLECT, time.perf_counter() - 1e-4)
            time.sleep(0.02)

    t = threading.Thread(target=ticker, daemon=True)
    t.start()
    with caplog.at_level(logging.WARNING, logger="repro.telemetry"):
        hub.watchdog_start(0.15, [("learner", em, None)])
        time.sleep(0.5)
        hub.stop()
    stop.set()
    t.join(timeout=2.0)
    assert "stall watchdog" not in caplog.text


# ---------------------------------------------------------------------------
# utils.logging — REPRO_LOG_LEVEL + handler idempotence
# ---------------------------------------------------------------------------


def test_env_level_parses_names_digits_and_garbage(monkeypatch):
    monkeypatch.delenv("REPRO_LOG_LEVEL", raising=False)
    assert _env_level() == logging.INFO
    monkeypatch.setenv("REPRO_LOG_LEVEL", "debug")
    assert _env_level() == logging.DEBUG
    monkeypatch.setenv("REPRO_LOG_LEVEL", "25")
    assert _env_level() == 25
    monkeypatch.setenv("REPRO_LOG_LEVEL", "LOUD")
    assert _env_level() == logging.INFO  # typo falls back, never raises


def test_get_logger_attaches_exactly_one_handler():
    root = logging.getLogger("repro")
    get_logger("a")
    get_logger("b")
    assert len(root.handlers) == 1
    assert get_logger("a") is logging.getLogger("repro.a")
