"""Properties of the return estimators (hypothesis) — system invariants.

``hypothesis`` is a dev-extra (see requirements-dev.txt) — skip the module
cleanly when it isn't installed instead of erroring the whole collection.
"""
import os

import pytest

hypothesis = pytest.importorskip("hypothesis")

import hypothesis.strategies as st  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from hypothesis import given, settings  # noqa: E402
from hypothesis.extra import numpy as hnp  # noqa: E402

from repro.core.returns import (  # noqa: E402
    gae_advantages,
    n_step_returns,
    vtrace_returns,
)
from repro.kernels.vtrace import vtrace_returns_pallas  # noqa: E402

hypothesis.settings.register_profile("ci", deadline=None, max_examples=25)
hypothesis.settings.register_profile("dev", deadline=None, max_examples=100)
hypothesis.settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "ci"))


@given(
    rewards=hnp.arrays(np.float32, (4, 7), elements=st.floats(-5, 5, width=32)),
    dones=hnp.arrays(np.bool_, (4, 7)),
    bootstrap=hnp.arrays(np.float32, (4,), elements=st.floats(-5, 5, width=32)),
    gamma=st.floats(0.5, 0.999),
)
def test_nstep_recursion_invariant(rewards, dones, bootstrap, gamma):
    """R_t = r_t + gamma*(1-done_t)*R_{t+1} holds pointwise."""
    R = np.asarray(n_step_returns(jnp.asarray(rewards), jnp.asarray(dones),
                                  jnp.asarray(bootstrap), gamma))
    nxt = np.concatenate([R[:, 1:], bootstrap[:, None]], axis=1)
    expect = rewards + gamma * (1.0 - dones.astype(np.float32)) * nxt
    np.testing.assert_allclose(R, expect, rtol=1e-5, atol=1e-5)


@given(
    rewards=hnp.arrays(np.float32, (3, 9), elements=st.floats(0, 1, width=32)),
    gamma=st.floats(0.5, 0.99),
)
def test_nstep_bounds_nonneg_rewards(rewards, gamma):
    """With r in [0,1], no terminals, zero bootstrap: 0 <= R_t <= 1/(1-gamma)."""
    R = np.asarray(
        n_step_returns(jnp.asarray(rewards), jnp.zeros((3, 9), bool),
                       jnp.zeros((3,)), gamma)
    )
    assert (R >= -1e-5).all()
    assert (R <= 1.0 / (1.0 - gamma) + 1e-4).all()


@given(
    dones_col=st.integers(0, 6),
)
def test_terminal_cuts_credit(dones_col):
    """Rewards after a terminal never flow into returns before it."""
    E, T = 1, 7
    rewards = np.zeros((E, T), np.float32)
    rewards[0, -1] = 100.0
    dones = np.zeros((E, T), bool)
    dones[0, dones_col] = True
    R = np.asarray(n_step_returns(jnp.asarray(rewards), jnp.asarray(dones),
                                  jnp.zeros((E,)), 0.9))
    if dones_col < T - 1:
        assert abs(R[0, 0]) < 1e-5  # reward at T-1 blocked by terminal
    else:
        assert R[0, 0] > 0


# ---------------------------------------------------------------------------
# V-trace properties (the pipelined learner's targets)
# ---------------------------------------------------------------------------

_rewards = hnp.arrays(np.float32, (4, 7), elements=st.floats(-5, 5, width=32))
_dones = hnp.arrays(np.bool_, (4, 7))
_values = hnp.arrays(np.float32, (4, 7), elements=st.floats(-5, 5, width=32))
_boot = hnp.arrays(np.float32, (4,), elements=st.floats(-5, 5, width=32))
_logw = hnp.arrays(np.float32, (4, 7), elements=st.floats(-2, 2, width=32))


@given(rewards=_rewards, dones=_dones, values=_values, bootstrap=_boot,
       gamma=st.floats(0.5, 0.999))
def test_vtrace_on_policy_equals_nstep(rewards, dones, values, bootstrap,
                                       gamma):
    """On-policy behaviour (rho == 1) with ρ̄, c̄ >= 1: V-trace targets
    equal the paper's n-step returns pointwise and the pg advantage is the
    paper's (R_t - V_t)."""
    vs, pg_adv = vtrace_returns(
        jnp.asarray(rewards), jnp.asarray(dones), jnp.asarray(values),
        jnp.asarray(bootstrap), jnp.ones((4, 7), jnp.float32), gamma,
        rho_bar=1.0, c_bar=1.0,
    )
    ns = np.asarray(n_step_returns(jnp.asarray(rewards), jnp.asarray(dones),
                                   jnp.asarray(bootstrap), gamma))
    np.testing.assert_allclose(vs, ns, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(pg_adv, ns - values, rtol=1e-4, atol=1e-4)


@given(rewards=_rewards, dones=_dones, values=_values, bootstrap=_boot,
       log_rho=hnp.arrays(np.float32, (4, 7),
                          elements=st.floats(-1, 1, width=32)),
       gamma=st.floats(0.5, 0.99))
def test_vtrace_unclipped_is_importance_weighted_nstep(rewards, dones, values,
                                                       bootstrap, log_rho,
                                                       gamma):
    """ρ̄ = c̄ → ∞: v_s = V_s + Σ_t γ^{t-s}(Π_{i<t} nd_i·w_i)·w_t·δ_t —
    the fully importance-weighted n-step correction, by the definition."""
    rho = np.exp(log_rho).astype(np.float32)
    vs, _ = vtrace_returns(
        jnp.asarray(rewards), jnp.asarray(dones), jnp.asarray(values),
        jnp.asarray(bootstrap), jnp.asarray(rho), gamma,
        rho_bar=1e12, c_bar=1e12,
    )
    # float64 ground truth straight from the definition (double loop)
    nd = (1.0 - dones.astype(np.float64))
    w = rho.astype(np.float64)
    v = values.astype(np.float64)
    v_next = np.concatenate([v[:, 1:], bootstrap[:, None].astype(np.float64)],
                            axis=1)
    delta = w * (rewards.astype(np.float64) + gamma * nd * v_next - v)
    expect = v.copy()
    T = rewards.shape[1]
    for s in range(T):
        for t in range(s, T):
            disc = np.prod(nd[:, s:t] * w[:, s:t], axis=1) * gamma ** (t - s)
            expect[:, s] += disc * delta[:, t]
    np.testing.assert_allclose(vs, expect, rtol=1e-2, atol=1e-2)


@given(rewards=hnp.arrays(np.float32, (3, 8),
                          elements=st.floats(0, 5, width=32)),
       dones=hnp.arrays(np.bool_, (3, 8)),
       log_rho=hnp.arrays(np.float32, (3, 8),
                          elements=st.floats(-1, 1, width=32)),
       c_bars=st.tuples(st.floats(0.0, 4.0), st.floats(0.0, 4.0)),
       gamma=st.floats(0.5, 0.99))
def test_vtrace_monotone_nonexpansive_in_c_bar(rewards, dones, log_rho,
                                               c_bars, gamma):
    """Targets are monotone non-expansive in c̄: with nonnegative TD errors
    raising c̄ never lowers a target, and raising c̄ past the largest ratio
    changes nothing (the clip has saturated)."""
    rho = jnp.exp(jnp.asarray(log_rho))
    zeros = jnp.zeros((3, 8), jnp.float32)
    zb = jnp.zeros((3,), jnp.float32)
    lo, hi = min(c_bars), max(c_bars)
    vs_lo, _ = vtrace_returns(jnp.asarray(rewards), jnp.asarray(dones), zeros,
                              zb, rho, gamma, rho_bar=1e9, c_bar=lo)
    vs_lo = np.asarray(vs_lo)
    vs_hi, _ = vtrace_returns(jnp.asarray(rewards), jnp.asarray(dones), zeros,
                              zb, rho, gamma, rho_bar=1e9, c_bar=hi)
    tol = 1e-4 + 1e-5 * np.abs(vs_lo)  # scale-relative fp32 slack
    assert (np.asarray(vs_hi) >= vs_lo - tol).all()
    cap = float(jnp.max(rho))
    vs_a, _ = vtrace_returns(jnp.asarray(rewards), jnp.asarray(dones), zeros,
                             zb, rho, gamma, rho_bar=1e9, c_bar=cap)
    vs_b, _ = vtrace_returns(jnp.asarray(rewards), jnp.asarray(dones), zeros,
                             zb, rho, gamma, rho_bar=1e9, c_bar=2.0 * cap)
    np.testing.assert_allclose(vs_a, vs_b, rtol=1e-6, atol=1e-6)


@given(rewards=_rewards, dones=_dones, values=_values, bootstrap=_boot,
       log_rho=_logw, gamma=st.floats(0.5, 0.999),
       rho_bar=st.floats(0.5, 4.0), c_bar=st.floats(0.1, 2.0))
def test_vtrace_pallas_matches_reference_scan(rewards, dones, values,
                                              bootstrap, log_rho, gamma,
                                              rho_bar, c_bar):
    """The fused Pallas kernel matches the lax.scan reference to 1e-5."""
    rho = jnp.exp(jnp.asarray(log_rho))
    args = (jnp.asarray(rewards), jnp.asarray(dones), jnp.asarray(values),
            jnp.asarray(bootstrap), rho, gamma, rho_bar, c_bar)
    vs_ref, adv_ref = vtrace_returns(*args)
    vs_k, adv_k = vtrace_returns_pallas(*args, block_e=2)
    np.testing.assert_allclose(vs_k, vs_ref, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(adv_k, adv_ref, rtol=1e-5, atol=1e-5)


def test_gae_lambda1_equals_nstep():
    """GAE(lambda=1) returns == n-step discounted returns."""
    key = jax.random.PRNGKey(0)
    E, T = 4, 11
    rewards = jax.random.normal(key, (E, T))
    dones = jax.random.bernoulli(key, 0.2, (E, T))
    values = jax.random.normal(key, (E, T))
    boot = jax.random.normal(key, (E,))
    adv, rets = gae_advantages(rewards, dones, values, boot, 0.95, lam=1.0)
    nstep = n_step_returns(rewards, dones, boot, 0.95)
    np.testing.assert_allclose(rets, nstep, rtol=1e-4, atol=1e-4)


def test_gae_lambda0_is_td():
    key = jax.random.PRNGKey(1)
    E, T = 2, 6
    rewards = jax.random.normal(key, (E, T))
    dones = jnp.zeros((E, T), bool)
    values = jax.random.normal(key, (E, T))
    boot = jax.random.normal(key, (E,))
    adv, _ = gae_advantages(rewards, dones, values, boot, 0.9, lam=0.0)
    nxt = jnp.concatenate([values[:, 1:], boot[:, None]], axis=1)
    td = rewards + 0.9 * nxt - values
    np.testing.assert_allclose(adv, td, rtol=1e-5, atol=1e-5)
