"""Properties of the return estimators (hypothesis) — system invariants.

``hypothesis`` is a dev-extra (see requirements-dev.txt) — skip the module
cleanly when it isn't installed instead of erroring the whole collection.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")

import hypothesis.strategies as st  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from hypothesis import given, settings  # noqa: E402
from hypothesis.extra import numpy as hnp  # noqa: E402

from repro.core.returns import gae_advantages, n_step_returns

hypothesis.settings.register_profile("ci", deadline=None, max_examples=25)
hypothesis.settings.load_profile("ci")


@given(
    rewards=hnp.arrays(np.float32, (4, 7), elements=st.floats(-5, 5, width=32)),
    dones=hnp.arrays(np.bool_, (4, 7)),
    bootstrap=hnp.arrays(np.float32, (4,), elements=st.floats(-5, 5, width=32)),
    gamma=st.floats(0.5, 0.999),
)
def test_nstep_recursion_invariant(rewards, dones, bootstrap, gamma):
    """R_t = r_t + gamma*(1-done_t)*R_{t+1} holds pointwise."""
    R = np.asarray(n_step_returns(jnp.asarray(rewards), jnp.asarray(dones),
                                  jnp.asarray(bootstrap), gamma))
    nxt = np.concatenate([R[:, 1:], bootstrap[:, None]], axis=1)
    expect = rewards + gamma * (1.0 - dones.astype(np.float32)) * nxt
    np.testing.assert_allclose(R, expect, rtol=1e-5, atol=1e-5)


@given(
    rewards=hnp.arrays(np.float32, (3, 9), elements=st.floats(0, 1, width=32)),
    gamma=st.floats(0.5, 0.99),
)
def test_nstep_bounds_nonneg_rewards(rewards, gamma):
    """With r in [0,1], no terminals, zero bootstrap: 0 <= R_t <= 1/(1-gamma)."""
    R = np.asarray(
        n_step_returns(jnp.asarray(rewards), jnp.zeros((3, 9), bool),
                       jnp.zeros((3,)), gamma)
    )
    assert (R >= -1e-5).all()
    assert (R <= 1.0 / (1.0 - gamma) + 1e-4).all()


@given(
    dones_col=st.integers(0, 6),
)
def test_terminal_cuts_credit(dones_col):
    """Rewards after a terminal never flow into returns before it."""
    E, T = 1, 7
    rewards = np.zeros((E, T), np.float32)
    rewards[0, -1] = 100.0
    dones = np.zeros((E, T), bool)
    dones[0, dones_col] = True
    R = np.asarray(n_step_returns(jnp.asarray(rewards), jnp.asarray(dones),
                                  jnp.zeros((E,)), 0.9))
    if dones_col < T - 1:
        assert abs(R[0, 0]) < 1e-5  # reward at T-1 blocked by terminal
    else:
        assert R[0, 0] > 0


def test_gae_lambda1_equals_nstep():
    """GAE(lambda=1) returns == n-step discounted returns."""
    key = jax.random.PRNGKey(0)
    E, T = 4, 11
    rewards = jax.random.normal(key, (E, T))
    dones = jax.random.bernoulli(key, 0.2, (E, T))
    values = jax.random.normal(key, (E, T))
    boot = jax.random.normal(key, (E,))
    adv, rets = gae_advantages(rewards, dones, values, boot, 0.95, lam=1.0)
    nstep = n_step_returns(rewards, dones, boot, 0.95)
    np.testing.assert_allclose(rets, nstep, rtol=1e-4, atol=1e-4)


def test_gae_lambda0_is_td():
    key = jax.random.PRNGKey(1)
    E, T = 2, 6
    rewards = jax.random.normal(key, (E, T))
    dones = jnp.zeros((E, T), bool)
    values = jax.random.normal(key, (E, T))
    boot = jax.random.normal(key, (E,))
    adv, _ = gae_advantages(rewards, dones, values, boot, 0.9, lam=0.0)
    nxt = jnp.concatenate([values[:, 1:], boot[:, None]], axis=1)
    td = rewards + 0.9 * nxt - values
    np.testing.assert_allclose(adv, td, rtol=1e-5, atol=1e-5)
