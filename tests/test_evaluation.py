"""Paper Table-1 evaluation protocol (best-of-3 actors, 30 runs)."""
import jax

from repro.configs import get_config
from repro.core import ParallelRL
from repro.core.agents import PAACAgent, PAACConfig
from repro.core.evaluation import evaluate
from repro.envs import GridWorld
from repro.optim import constant


def test_evaluate_protocol_and_training_gain():
    env = GridWorld(10, size=4, max_steps=20)
    cfg = get_config("paac_vector").replace(
        obs_shape=env.obs_shape, num_actions=env.num_actions
    )
    agent = PAACAgent(cfg, PAACConfig(t_max=5))
    rl = ParallelRL(env, agent, lr_schedule=constant(0.01), seed=0)
    act = agent.act_fn()
    key = jax.random.PRNGKey(42)

    before = evaluate(act, env, rl.params, key, n_runs=10, n_actor_seeds=3,
                      max_steps=25)
    assert len(before["per_seed"]) == 3
    assert before["best_of_k"] >= before["mean"]

    rl.run(250)
    after = evaluate(act, env, rl.params, key, n_runs=10, n_actor_seeds=3,
                     max_steps=25)
    assert after["best_of_k"] > before["best_of_k"]
