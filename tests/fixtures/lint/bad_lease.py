"""Fixture: lease-pairing violation — acquire without a finally release."""


def leaky_reader(slot):
    params, version = slot.acquire(holder="leaky")
    out = params["w"].sum()        # raises here => lease never returned
    slot.release(version, holder="leaky")
    return out
