"""Fixture: donated-reuse violation — a tree is read after riding a
donated argument position of the registered fused step."""
import jax

update_step = jax.jit(lambda p, o, t: (p, o), donate_argnums=(0, 1))


def learner_iter(params, opt_state, traj):
    new_params, new_opt = update_step(params, opt_state, traj)
    stale_norm = params["w"].sum()   # params was donated: use-after-free
    return new_params, new_opt, stale_norm
