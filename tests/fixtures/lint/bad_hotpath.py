"""Fixture: hot-path-sync violation — a blocking host sync on a hot path."""


# hot-path
def put(ring, item):
    depth = float(item.reward.sum())   # implicit D2H sync in the hot loop
    ring.append((depth, item))
