"""Fixture: span-pairing violation — begin() escapes via an early return."""


def leaky_stage(em, queue, stop):
    em.begin(3)
    item = queue.get()
    if item is None:
        return None                # open span leaks past this return
    em.end()
    return item
