"""Fixture: hostenv-picklable violation — a lambda env_fn cannot cross a
spawned worker boundary."""
from repro.envs.host_env import HostEnvSpec

bad_spec = HostEnvSpec(lambda n: object(), n_envs=4, obs_shape=(16,))
