"""Fixture: the negative — every rule's idiom done right."""
import jax

update_step = jax.jit(lambda p, o, t: (p, o), donate_argnums=(0, 1))


def good_reader(slot):
    params, version = slot.acquire(holder="good")
    try:
        return params["w"].sum()
    finally:
        slot.release(version, holder="good")


def good_stage(em, queue, stop):
    em.begin(3)
    try:
        item = queue.get()
    finally:
        em.end()
    if item is None:
        return None
    return item


def good_learner_iter(params, opt_state, traj):
    params, opt_state = update_step(params, opt_state, traj)
    return params, opt_state


# hot-path
def put(ring, item):
    ring.append(item)              # no host syncs on the hot path
