"""Fixture: lease-pairing violation, serving-plane vocabulary — a cache
slot allocated and never freed (no direct free, no deferred-free
closure handed to the request)."""


def leaky_admit(slots, engine, req):
    slot = slots.allocate(req.rid)
    tok0 = engine.admit(slot, req.prompt, req.seed)  # raises => slot leaks
    req.record_first_token(tok0)
    return slot
