"""Incremental decode == full forward, for every architecture family.

This is the core serving invariant: the master's batched action selection
(decode with cache) must produce the same policy as the training-time
teacher-forced forward.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models import (
    init_policy,
    init_policy_cache,
    policy_apply,
    policy_decode,
    policy_prefill,
)

B, S = 2, 16


def _cfg(arch):
    cfg = get_config(arch).reduced()
    if cfg.num_experts:
        # ample capacity so no tokens drop (grouping differs between paths)
        cfg = cfg.replace(moe_capacity_factor=16.0)
    return cfg


def _prefix(cfg, key):
    if cfg.modality == "vision":
        return jax.random.normal(key, (B, cfg.prefix_len, cfg.frontend_dim))
    if cfg.is_encoder_decoder:
        return jax.random.normal(key, (B, cfg.encoder_seq_len, cfg.frontend_dim))
    return None


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_decode_matches_forward(arch, key):
    cfg = _cfg(arch)
    params = init_policy(key, cfg)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    pre = _prefix(cfg, key)
    logits_full, values_full, _ = policy_apply(params, cfg, toks, pre)
    if cfg.modality == "vision":
        pytest.skip("vlm decode starts from prefill (prefix); covered below")
    cache = init_policy_cache(cfg, B, S)
    if cfg.is_encoder_decoder:
        # decode needs the cross cache -> go through prefill for 1 token
        _, _, cache = policy_prefill(params, cfg, toks[:, :1], pre, max_len=S)
        start = 1
    else:
        start = 0
    err = 0.0
    for t in range(start, S):
        lg, vl, cache = policy_decode(params, cfg, cache, toks[:, t:t + 1], t)
        err = max(err, float(jnp.abs(lg - logits_full[:, t]).max()))
        err = max(err, float(jnp.abs(vl - values_full[:, t]).max()))
    assert err < 5e-4, err


@pytest.mark.parametrize("arch", ["qwen2-7b", "minicpm3-4b", "pixtral-12b",
                                  "seamless-m4t-large-v2", "deepseek-v2-236b"])
def test_prefill_resume(arch, key):
    cfg = _cfg(arch)
    params = init_policy(key, cfg)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    pre = _prefix(cfg, key)
    logits_full, _, _ = policy_apply(params, cfg, toks, pre)
    off = cfg.prefix_len if cfg.modality == "vision" else 0
    half = S // 2
    lg_p, _, cache = policy_prefill(params, cfg, toks[:, :half], pre, max_len=off + S)
    err = float(jnp.abs(lg_p[:, -1] - logits_full[:, off + half - 1]).max())
    for t in range(half, S):
        lg, _, cache = policy_decode(params, cfg, cache, toks[:, t:t + 1], off + t)
        err = max(err, float(jnp.abs(lg - logits_full[:, off + t]).max()))
    assert err < 5e-4, err


def test_sliding_window_ring_decode(key):
    """Ring-buffer cache (window < S) matches windowed full attention."""
    cfg = get_config("qwen2-7b").reduced().replace(sliding_window=8)
    params = init_policy(key, cfg)
    toks = jax.random.randint(key, (B, 24), 0, cfg.vocab_size)
    logits_full, _, _ = policy_apply(params, cfg, toks)
    cache = init_policy_cache(cfg, B, 24)
    assert cache["layers"]["attn"]["k"].shape[2] == 8  # O(window) memory
    err = 0.0
    for t in range(24):
        lg, _, cache = policy_decode(params, cfg, cache, toks[:, t:t + 1], t)
        err = max(err, float(jnp.abs(lg - logits_full[:, t]).max()))
    assert err < 5e-4, err


def test_mla_absorb_matches_naive(key):
    """The absorbed MLA decode (perf variant) equals the naive expansion."""
    cfg = get_config("minicpm3-4b").reduced()
    params = init_policy(key, cfg)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    outs = {}
    for absorb in (False, True):
        c = cfg.replace(mla_absorb=absorb)
        cache = init_policy_cache(c, B, S)
        logs = []
        for t in range(S):
            lg, _, cache = policy_decode(params, c, cache, toks[:, t:t + 1], t)
            logs.append(lg)
        outs[absorb] = jnp.stack(logs, 1)
    err = float(jnp.abs(outs[True] - outs[False]).max())
    assert err < 5e-4, err
