"""Sharding-rule validation against the production mesh (AbstractMesh — no
device allocation, so smoke tests still see 1 real device)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.distributed.sharding import cache_specs, input_sharding, param_specs
from repro.models import init_policy, init_policy_cache

# jax 0.4.37 signature: AbstractMesh(((name, size), ...))
MESH = AbstractMesh((("data", 16), ("model", 16)))
MESH_MP = AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))


def _params_sds(cfg):
    return jax.eval_shape(lambda: init_policy(jax.random.PRNGKey(0), cfg))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
@pytest.mark.parametrize("mesh", [MESH, MESH_MP], ids=["16x16", "2x16x16"])
@pytest.mark.parametrize("mode", ["tp", "fsdp_tp"])
def test_param_specs_divisible(arch, mesh, mode):
    """Every sharded dim divides its mesh axis (no silent padding)."""
    cfg = get_config(arch)
    sds = _params_sds(cfg)
    specs = param_specs(sds, mesh, mode)
    sizes = dict(mesh.shape)

    def axis_size(a):
        if a is None:
            return 1
        if isinstance(a, tuple):
            n = 1
            for x in a:
                n *= sizes[x]
            return n
        return sizes[a]

    flat_s = jax.tree_util.tree_flatten_with_path(specs)[0]
    flat_l = {tuple(p): l for p, l in jax.tree_util.tree_flatten_with_path(sds)[0]}
    n_sharded = 0
    for path, spec in flat_s:
        leaf = flat_l[tuple(path)]
        assert len(spec) <= leaf.ndim
        for dim, a in zip(leaf.shape, tuple(spec) + (None,) * (leaf.ndim - len(spec))):
            s = axis_size(a)
            if s > 1:
                n_sharded += 1
                assert dim % s == 0, (path, leaf.shape, spec)
    assert n_sharded > 0  # something actually shards


@pytest.mark.parametrize("arch", ["deepseek-v2-236b", "dbrx-132b",
                                  "deepseek-coder-33b"])
def test_fsdp_bounds_per_chip_param_bytes(arch):
    """fsdp_tp must fit params+opt-state in HBM: <= 6 GB/chip param bytes
    (leaving room for fp32 RMSProp stats + activations on a 16 GB v5e)."""
    cfg = get_config(arch)
    sds = _params_sds(cfg)
    specs = param_specs(sds, MESH, "fsdp_tp")
    sizes = dict(MESH.shape)

    def axis_size(a):
        if a is None:
            return 1
        if isinstance(a, tuple):
            n = 1
            for x in a:
                n *= sizes[x]
            return n
        return sizes[a]

    per_chip = 0
    for (path, spec), (_, leaf) in zip(
        jax.tree_util.tree_flatten_with_path(specs)[0],
        jax.tree_util.tree_flatten_with_path(sds)[0],
    ):
        shard_elems = leaf.size
        for dim, a in zip(leaf.shape, tuple(spec) + (None,) * (leaf.ndim - len(spec))):
            shard_elems //= axis_size(a) if dim % axis_size(a) == 0 else 1
        per_chip += shard_elems * leaf.dtype.itemsize
    assert per_chip < 6e9, f"{per_chip/1e9:.2f} GB/chip"


def test_moe_experts_shard_over_model():
    cfg = get_config("dbrx-132b")
    sds = _params_sds(cfg)
    specs = param_specs(sds, MESH, "fsdp_tp")
    moe_spec = specs["trunk"]["layers"]["moe"]["wi"]
    # the data dim may be a bare axis name or a (possibly multi-)axis tuple
    assert tuple(moe_spec) in (
        (None, "model", "data", None),
        (None, "model", ("data",), None),
    )


def test_cache_specs_batch_and_heads():
    cfg = get_config("deepseek-coder-33b")
    cache = jax.eval_shape(lambda: init_policy_cache(cfg, 128, 1024))
    specs = cache_specs(cache, MESH)
    k_spec = specs["layers"]["attn"]["k"]  # (L, B, S, Hkv, D)
    assert k_spec[1] in ("data", ("data",))
    # kv=8 heads do not divide model=16 -> unsharded
    assert k_spec[3] is None


def test_input_sharding_batch_only_when_divisible():
    batch = {
        "tokens": jax.ShapeDtypeStruct((256, 4097), jnp.int32),
        "one": jax.ShapeDtypeStruct((1, 5), jnp.float32),
    }
    sh = input_sharding(batch, MESH)
    assert sh["tokens"][0] in ("data", ("data",))
    assert sh["one"] == P(None, None)


def test_multipod_data_axes():
    batch = {"tokens": jax.ShapeDtypeStruct((256, 4097), jnp.int32)}
    sh = input_sharding(batch, MESH_MP)
    assert sh["tokens"][0] == ("pod", "data")
