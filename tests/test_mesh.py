"""Mesh rollout plane: config matrix, sub-ring contracts, sharded learner.

Pins the tentpole contracts of the multi-device plane:

* ``PipelineConfig`` rejects every invalid plane/backend/mesh combination
  with a diagnosable ``ValueError`` (the documented matrix),
* ``MeshTrajectoryRing`` sub-rings transfer per-device slot ownership,
  apply per-lane backpressure, abort every lane on ``close()``, and
  reassemble seq-aligned sub-rollouts into one globally-sharded ``Rollout``
  with zero host round trips,
* a sharded rollout leaking onto the host ``TrajectoryQueue`` raises loudly
  at the ``put`` boundary (the ``validate_picklable`` idiom),
* mesh=1 depth-1 lockstep reproduces the flat device plane (and therefore
  synchronous ``ParallelRL``) **bitwise** through the sharded learner step,
* at mesh=2 the sharded learner step matches the replicated (flat) step
  numerically (allclose — the gradient all-reduce changes float order),
* mesh=2 end-to-end: zero staleness at lockstep, every lane contributes
  exactly one sub-rollout per update (never-drop).

Multi-device cases skip unless >= 2 devices are visible; the mesh-smoke CI
job runs this file under ``XLA_FLAGS=--xla_force_host_platform_device_count
=4`` so the full grid executes there. mesh=1 cases run everywhere.
"""
import queue as stdq
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import PipelineConfig, get_config
from repro.core import ParallelRL
from repro.core.agents import PAACAgent, PAACConfig
from repro.core.rollout import Transition
from repro.envs import GridWorld
from repro.launch.mesh import make_rollout_mesh
from repro.optim import constant, make_optimizer
from repro.pipeline import (
    CLOSED,
    MeshTrajectoryRing,
    PipelinedRL,
    QueueClosed,
    Rollout,
    TrajectoryQueue,
)
from repro.pipeline.learner import make_learner_step, make_sharded_learner_step

needs2 = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs >=2 devices (XLA_FLAGS=--xla_force_host_platform_"
           "device_count=N; the mesh-smoke CI job sets it)",
)


# ---------------------------------------------------------------------------
# config matrix
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kw", [
    dict(mesh_shape=0),
    dict(mesh_shape=2, actor_backend="process"),
    dict(mesh_shape=2, rollout_plane="host"),
    dict(mesh_shape=2, rollout_plane="device"),
    dict(mesh_shape=2, num_actors=3),
    dict(actor_backend="process", rollout_plane="device"),
    dict(actor_backend="process", rollout_plane="mesh"),
])
def test_pipeline_config_rejects_invalid_combos(kw):
    with pytest.raises(ValueError):
        PipelineConfig(**kw)


@pytest.mark.parametrize("kw", [
    dict(),
    dict(mesh_shape=2),
    dict(mesh_shape=4, num_actors=4),
    dict(mesh_shape=2, rollout_plane="mesh"),
    dict(rollout_plane="mesh"),  # 1-lane mesh: the bitwise-pin config
    dict(mesh_shape=2, lockstep=True),
])
def test_pipeline_config_accepts_valid_combos(kw):
    cfg = PipelineConfig(**kw)
    assert cfg.mesh_shape == kw.get("mesh_shape", 1)


def test_make_rollout_mesh_overflow_names_the_flag():
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        make_rollout_mesh(len(jax.devices()) + 1)


def test_mesh_lockstep_with_lanes_is_accepted():
    """lockstep + multiple lanes is valid on the mesh plane only."""
    with pytest.raises(ValueError):
        PipelinedRL(
            GridWorld(8, size=4, max_steps=10),
            PAACAgent(_vector_cfg(GridWorld(8, size=4, max_steps=10)),
                      PAACConfig(t_max=3)),
            pipeline=PipelineConfig(num_actors=2, lockstep=True),
        )


def test_mesh1_plane_rejects_extra_actors():
    """rollout_plane='mesh' with mesh_shape=1 cannot carry >1 actor stream
    (one lane per device) — rejected loudly, not silently normalized."""
    with pytest.raises(ValueError, match="one actor lane per mesh"):
        PipelinedRL(
            GridWorld(8, size=4, max_steps=10),
            PAACAgent(_vector_cfg(GridWorld(8, size=4, max_steps=10)),
                      PAACConfig(t_max=3)),
            pipeline=PipelineConfig(num_actors=4, rollout_plane="mesh"),
        )


# ---------------------------------------------------------------------------
# sub-ring contracts
# ---------------------------------------------------------------------------


def _rollout_on(device, seq=0, t=3, e=2, obs=4, fill=1.0, version=0):
    """A device-committed Rollout with time-major (t, e, ...) leaves."""
    traj = Transition(
        obs=jnp.full((t, e, obs), fill, jnp.float32),
        action=jnp.zeros((t, e), jnp.int32),
        reward=jnp.full((t, e), fill, jnp.float32),
        done=jnp.zeros((t, e), bool),
        value=jnp.zeros((t, e), jnp.float32),
        logp=jnp.zeros((t, e), jnp.float32),
    )
    traj = jax.device_put(traj, device)
    last = jax.device_put(jnp.full((e, obs), fill, jnp.float32), device)
    return Rollout(traj, last, version, 0, seq, None)


def test_mesh_ring_requires_data_axis_mesh():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with pytest.raises(ValueError, match="1-axis"):
        MeshTrajectoryRing(2, mesh)


def test_mesh1_ring_roundtrip_and_ownership():
    """1-lane mesh ring: put/get roundtrip; get() leaves the sub-ring's
    slot free (sole ownership transferred to the assembled payload)."""
    ring = MeshTrajectoryRing(2, make_rollout_mesh(1))
    dev = ring.devices[0]
    ring.lane(0).put(_rollout_on(dev, seq=0, fill=3.0))
    out = ring.get(timeout=5.0)
    assert isinstance(out, Rollout)
    assert out.seq == 0 and out.actor_id == -1 and out.release is None
    np.testing.assert_array_equal(np.asarray(out.traj.reward),
                                  np.full((3, 2), 3.0))
    assert ring.qsize() == 0
    assert ring._subs[0]._slots[0].payload is None  # slot reference cleared
    assert ring.tickets_issued == [1]


def test_mesh_ring_backpressure_blocks_per_lane():
    ring = MeshTrajectoryRing(1, make_rollout_mesh(1))
    dev = ring.devices[0]
    lane = ring.lane(0)
    lane.put(_rollout_on(dev, seq=0))
    with pytest.raises(stdq.Full):
        lane.put(_rollout_on(dev, seq=1), timeout=0.05)
    assert lane.put_wait_s > 0.0
    ring.get(timeout=1.0)
    lane.put(_rollout_on(dev, seq=1), timeout=1.0)  # slot recycled


def test_mesh_ring_close_aborts_every_lane():
    ring = MeshTrajectoryRing(1, make_rollout_mesh(1))
    dev = ring.devices[0]
    lane = ring.lane(0)
    lane.put(_rollout_on(dev, seq=0))
    blocked = {}

    def producer():
        try:
            lane.put(_rollout_on(dev, seq=1), timeout=30.0)
            blocked["result"] = "returned"
        except QueueClosed:
            blocked["result"] = "closed"

    t = threading.Thread(target=producer, daemon=True)
    t.start()
    time.sleep(0.1)
    lane.close()  # a lane abort closes the whole ring
    t.join(timeout=5.0)
    assert blocked["result"] == "closed"
    # drain, then CLOSED
    assert isinstance(ring.get(timeout=1.0), Rollout)
    assert ring.get(timeout=1.0) is CLOSED


def test_mesh_ring_rejects_host_payload():
    ring = MeshTrajectoryRing(2, make_rollout_mesh(1))
    np_rollout = Rollout(
        Transition(*(np.zeros((2, 2)) for _ in range(6))),
        np.zeros((2, 4)), 0, 0, 0, None,
    )
    with pytest.raises(TypeError, match="host staging step"):
        ring.lane(0).put(np_rollout)


def test_mesh_ring_producer_done_is_per_lane():
    ring = MeshTrajectoryRing(2, make_rollout_mesh(1))
    with pytest.raises(RuntimeError, match="lane"):
        ring.producer_done()
    ring.lane(0).producer_done()
    assert ring.get(timeout=1.0) is CLOSED


@needs2
def test_mesh_ring_assembles_globally_sharded_rollout():
    """Two lanes' sub-rollouts reassemble into one env-axis-sharded Rollout
    whose shards are the original per-device buffers (no host round trip:
    lane values land verbatim in their half of the global array)."""
    ring = MeshTrajectoryRing(2, make_rollout_mesh(2))
    d0, d1 = ring.devices
    ring.lane(0).put(_rollout_on(d0, seq=0, fill=1.0, version=5))
    ring.lane(1).put(_rollout_on(d1, seq=0, fill=2.0, version=7))
    out = ring.get(timeout=5.0)
    assert out.seq == 0 and out.actor_id == -1
    assert out.behavior_version == 5  # min across lanes (worst staleness)
    r = out.traj.reward
    assert r.shape == (3, 4)  # (t, 2 lanes * e)
    assert len(r.devices()) == 2  # genuinely sharded, not gathered
    spec = r.sharding.spec
    assert tuple(spec) == (None, "data")
    np.testing.assert_array_equal(
        np.asarray(r), np.concatenate(
            [np.full((3, 2), 1.0), np.full((3, 2), 2.0)], axis=1)
    )
    assert out.last_obs.shape == (4, 4)
    assert out.last_obs.sharding.spec[0] == "data"


@needs2
def test_mesh_ring_get_blocks_until_every_lane_has_a_payload():
    ring = MeshTrajectoryRing(2, make_rollout_mesh(2))
    d0, d1 = ring.devices
    ring.lane(0).put(_rollout_on(d0, seq=0))
    with pytest.raises(stdq.Empty):
        ring.get(timeout=0.05)  # lane 1 empty: no full batch yet
    ring.lane(1).put(_rollout_on(d1, seq=0))
    out = ring.get(timeout=5.0)  # lane 0's stashed payload was not lost
    assert isinstance(out, Rollout) and out.seq == 0


@needs2
def test_mesh_ring_closed_lane_ends_the_stream():
    ring = MeshTrajectoryRing(2, make_rollout_mesh(2))
    d0, _ = ring.devices
    ring.lane(0).put(_rollout_on(d0, seq=0))
    ring.lane(1).producer_done()  # lane 1 checks out without producing
    # a full batch can never assemble again -> CLOSED (partial discarded)
    assert ring.get(timeout=5.0) is CLOSED


@needs2
def test_mesh_lane_rejects_wrong_device_payload():
    ring = MeshTrajectoryRing(2, make_rollout_mesh(2))
    _, d1 = ring.devices
    with pytest.raises(TypeError, match="mesh lane 0"):
        ring.lane(0).put(_rollout_on(d1, seq=0))


@needs2
def test_sharded_rollout_rejected_on_host_plane():
    """The validate_picklable-style loud error: a mesh-sharded rollout on
    the host TrajectoryQueue raises at put() with the routing fix named."""
    ring = MeshTrajectoryRing(2, make_rollout_mesh(2))
    d0, d1 = ring.devices
    ring.lane(0).put(_rollout_on(d0, seq=0))
    ring.lane(1).put(_rollout_on(d1, seq=0))
    sharded = ring.get(timeout=5.0)
    q = TrajectoryQueue(depth=2)
    with pytest.raises(TypeError, match="mesh-plane rollout leaked"):
        q.put(sharded)
    # numpy payloads still pass
    q.put(Rollout(Transition(*(np.zeros((2, 2)) for _ in range(6))),
                  np.zeros((2, 4)), 0, 0, 0, None))


# ---------------------------------------------------------------------------
# sharded learner step vs replicated step
# ---------------------------------------------------------------------------


def _vector_cfg(env):
    return get_config("paac_vector").replace(
        obs_shape=env.obs_shape, num_actions=env.num_actions
    )


@needs2
@pytest.mark.parametrize("clips", [(float("inf"), float("inf")), (1.0, 1.0)])
def test_mesh2_sharded_step_allclose_vs_replicated(clips):
    """One sharded learner step on a mesh=2-sharded batch matches the flat
    step on the same batch numerically (the all-reduce only reorders the
    float reduction), for both the compiled-out and active V-trace paths."""
    from repro.distributed.sharding import (
        batch_sharding, replicated_sharding, traj_sharding,
    )
    from repro.models import init_policy

    rho_bar, c_bar = clips
    t, e, obs_dim = 4, 8, 6
    cfg = get_config("paac_vector").replace(obs_shape=(obs_dim,),
                                            num_actions=3)
    agent = PAACAgent(cfg, PAACConfig(t_max=t))
    opt = make_optimizer("rmsprop")
    key = jax.random.PRNGKey(0)
    params = init_policy(key, cfg)
    opt_state = opt.init(params)
    ks = jax.random.split(key, 4)
    traj = Transition(
        obs=jax.random.normal(ks[0], (t, e, obs_dim)),
        action=jax.random.randint(ks[1], (t, e), 0, 3),
        reward=jax.random.normal(ks[2], (t, e)),
        done=jnp.zeros((t, e), bool),
        value=jnp.zeros((t, e), jnp.float32),
        logp=jnp.full((t, e), -1.1, jnp.float32),
    )
    last_obs = jax.random.normal(ks[3], (e, obs_dim))
    step = jnp.asarray(0, jnp.int32)

    flat = jax.jit(make_learner_step(agent, opt, constant(0.01),
                                     rho_bar=rho_bar, c_bar=c_bar))
    p_flat, o_flat, m_flat = flat(params, opt_state, traj, last_obs, step)

    mesh = make_rollout_mesh(2)
    repl = replicated_sharding(mesh)
    traj_sh = Transition(*(
        jax.device_put(l, traj_sharding(mesh, l.ndim)) for l in traj))
    last_sh = jax.device_put(last_obs, batch_sharding(mesh, last_obs.ndim))
    sharded = make_sharded_learner_step(
        agent, opt, constant(0.01), mesh, rho_bar=rho_bar, c_bar=c_bar,
        fused_publish=False,
    )
    p_mesh, o_mesh, m_mesh = sharded(
        jax.device_put(params, repl), jax.device_put(opt_state, repl),
        traj_sh, last_sh, step,
    )
    for k in ("loss", "policy_loss", "value_loss", "entropy"):
        np.testing.assert_allclose(float(m_mesh[k]), float(m_flat[k]),
                                   rtol=1e-5, atol=1e-6, err_msg=k)
    for a, b in zip(jax.tree_util.tree_leaves(p_flat),
                    jax.tree_util.tree_leaves(p_mesh)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# lockstep equivalence + end-to-end
# ---------------------------------------------------------------------------


def test_mesh1_depth1_lockstep_bitwise_vs_device_plane():
    """The tentpole pin: mesh=1 depth-1 lockstep through the full mesh
    machinery (1-lane sub-ring, sharded reassembly, sharded learner step
    with fused-publish donation) reproduces the flat device plane — and
    therefore synchronous ``ParallelRL`` — bit for bit."""
    inf = float("inf")

    def make(plane, mesh_shape=1):
        agent = PAACAgent(_vector_cfg(GridWorld(8, size=4, max_steps=20)),
                          PAACConfig(t_max=5))
        return PipelinedRL(
            GridWorld(8, size=4, max_steps=20), agent,
            lr_schedule=constant(0.01), seed=1,
            pipeline=PipelineConfig(queue_depth=1, rho_bar=inf, c_bar=inf,
                                    lockstep=True, rollout_plane=plane,
                                    mesh_shape=mesh_shape),
        )

    dev = make("device")
    r_dev = dev.run(10)
    mesh = make("mesh")
    assert mesh._plane == "mesh"
    r_mesh = mesh.run(10)
    assert r_mesh.mean_metrics["staleness"] == 0.0
    for k in ("loss", "policy_loss", "value_loss", "entropy", "reward_sum"):
        assert r_mesh.mean_metrics[k] == r_dev.mean_metrics[k], k
    for a, b in zip(jax.tree_util.tree_leaves(dev.params),
                    jax.tree_util.tree_leaves(mesh.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@needs2
def test_mesh2_end_to_end_lockstep():
    """mesh=2 full run: every update consumes one sub-rollout from every
    lane (zero staleness at lockstep, seqs 0..n-1 learned exactly once),
    learner params stay replicated, and the donated learner state is
    genuinely recycled (stale trees raise on read)."""
    env = GridWorld(8, size=4, max_steps=20)
    agent = PAACAgent(_vector_cfg(env), PAACConfig(t_max=5))
    prl = PipelinedRL(
        env, agent, lr_schedule=constant(0.01), seed=1,
        pipeline=PipelineConfig(queue_depth=1, lockstep=True, mesh_shape=2),
    )
    assert prl._plane == "mesh"
    old_params = prl.params
    iters = 8
    res = prl.run(iters)
    assert res.steps == iters * 8 * 5  # both lanes' envs count
    assert res.mean_metrics["staleness"] == 0.0
    assert prl.learned_ids == [(-1, i) for i in range(iters)]
    # donation really happened (params were consumed by the sharded step)
    assert all(l.is_deleted()
               for l in jax.tree_util.tree_leaves(old_params))
    # live params stay replicated over both mesh devices
    for leaf in jax.tree_util.tree_leaves(prl.params):
        assert len(leaf.sharding.device_set) == 2
    # a second run continues from the survivors
    res2 = prl.run(4)
    assert res2.steps == res.steps + 4 * 8 * 5


@needs2
def test_mesh2_per_lane_env_pools():
    """A list of per-lane envs gives each lane its own full-width pool
    (the weak-scaling shape run_mesh_ring sweeps)."""
    envs = [GridWorld(4, size=4, max_steps=10) for _ in range(2)]
    agent = PAACAgent(_vector_cfg(envs[0]), PAACConfig(t_max=3))
    prl = PipelinedRL(
        envs, agent, lr_schedule=constant(0.01), seed=0,
        pipeline=PipelineConfig(queue_depth=1, lockstep=True, mesh_shape=2,
                                num_actors=2),
    )
    res = prl.run(5)
    assert res.steps == 5 * 2 * 4 * 3
