"""Agent integration: PAAC learns; DQN learns; baseline pathologies behave.

These validate the paper's claims at miniature scale:
* PAAC (synchronous, on-policy) improves reward on GridWorld/Catch quickly,
* the framework is algorithm-agnostic (DQN trains through the same loop),
* lag=1 baselines coincide with PAAC (delay->0 limit sanity).
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core import ParallelRL
from repro.core.agents import (
    DQNAgent,
    DQNConfig,
    LaggedConfig,
    LaggedPAACAgent,
    PAACAgent,
    PAACConfig,
)
from repro.envs import Catch, GridWorld
from repro.optim import constant


def _vector_cfg(env):
    return get_config("paac_vector").replace(
        obs_shape=env.obs_shape, num_actions=env.num_actions
    )


def test_paac_learns_gridworld():
    env = GridWorld(32, size=4, max_steps=30)
    agent = PAACAgent(_vector_cfg(env), PAACConfig(t_max=5))
    rl = ParallelRL(env, agent, lr_schedule=constant(0.01), seed=1)
    first = rl.run(30).mean_metrics["reward_sum"]
    rl.run(250)
    last = rl.run(30).mean_metrics["reward_sum"]
    assert last > first + 0.5, (first, last)


def test_paac_learns_catch():
    env = Catch(32, rows=6, cols=5)
    agent = PAACAgent(_vector_cfg(env), PAACConfig(t_max=5))
    rl = ParallelRL(env, agent, lr_schedule=constant(0.01), seed=2)
    first = rl.run(30).mean_metrics["reward_sum"]
    rl.run(400)
    last = rl.run(30).mean_metrics["reward_sum"]
    assert last > first + 1.0, (first, last)


def test_dqn_learns_gridworld():
    env = GridWorld(16, size=3, max_steps=20)
    agent = DQNAgent(
        _vector_cfg(env),
        DQNConfig(t_max=4, batch_size=64, eps_steps=150, target_sync=25),
    )
    rl = ParallelRL(env, agent, optimizer="adam", lr_schedule=constant(1e-3),
                    seed=3, replay_capacity=5_000)
    first = rl.run(30).mean_metrics["reward_sum"]
    rl.run(400)
    last = rl.run(30).mean_metrics["reward_sum"]
    assert last > first + 0.3, (first, last)


@pytest.mark.parametrize("mode", ["grad", "act"])
def test_lagged_baselines_run(mode):
    env = GridWorld(8, size=3, max_steps=15)
    agent = LaggedPAACAgent(_vector_cfg(env), LaggedConfig(t_max=4, delay=4), mode=mode)
    rl = ParallelRL(env, agent, lr_schedule=constant(0.005), seed=4)
    res = rl.run(40)
    assert jnp.isfinite(res.mean_metrics["loss"])


def test_lag_zero_matches_paac_exactly():
    """delay=0 refreshes the stale copy every update -> PAAC semantics."""
    env = GridWorld(8, size=3, max_steps=15)
    cfg = _vector_cfg(env)
    paac = ParallelRL(env, PAACAgent(cfg, PAACConfig(t_max=4)),
                      lr_schedule=constant(0.005), seed=7)
    lagged = ParallelRL(
        env, LaggedPAACAgent(cfg, LaggedConfig(t_max=4, delay=1), mode="grad"),
        lr_schedule=constant(0.005), seed=7,
    )
    paac.run(10)
    lagged.run(10)
    for a, b in zip(
        jax.tree_util.tree_leaves(paac.params),
        jax.tree_util.tree_leaves(lagged.params),
    ):
        assert float(jnp.abs(a - b).max()) < 1e-5
