"""Agent integration: PAAC learns; DQN learns; baseline pathologies behave.

These validate the paper's claims at miniature scale:
* PAAC (synchronous, on-policy) improves reward on GridWorld/Catch quickly,
* the framework is algorithm-agnostic (DQN trains through the same loop),
* lag=1 baselines coincide with PAAC (delay->0 limit sanity).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import ParallelRL
from repro.core.agents import (
    DQNAgent,
    DQNConfig,
    LaggedConfig,
    LaggedPAACAgent,
    PAACAgent,
    PAACConfig,
)
from repro.envs import Catch, GridWorld
from repro.optim import constant


def _vector_cfg(env):
    return get_config("paac_vector").replace(
        obs_shape=env.obs_shape, num_actions=env.num_actions
    )


def test_paac_learns_gridworld():
    env = GridWorld(32, size=4, max_steps=30)
    agent = PAACAgent(_vector_cfg(env), PAACConfig(t_max=5))
    rl = ParallelRL(env, agent, lr_schedule=constant(0.01), seed=1)
    first = rl.run(30).mean_metrics["reward_sum"]
    rl.run(250)
    last = rl.run(30).mean_metrics["reward_sum"]
    assert last > first + 0.5, (first, last)


def test_paac_learns_catch():
    env = Catch(32, rows=6, cols=5)
    agent = PAACAgent(_vector_cfg(env), PAACConfig(t_max=5))
    rl = ParallelRL(env, agent, lr_schedule=constant(0.01), seed=2)
    first = rl.run(30).mean_metrics["reward_sum"]
    rl.run(400)
    last = rl.run(30).mean_metrics["reward_sum"]
    assert last > first + 1.0, (first, last)


def test_dqn_learns_gridworld():
    env = GridWorld(16, size=3, max_steps=20)
    agent = DQNAgent(
        _vector_cfg(env),
        DQNConfig(t_max=4, batch_size=64, eps_steps=150, target_sync=25),
    )
    rl = ParallelRL(env, agent, optimizer="adam", lr_schedule=constant(1e-3),
                    seed=3, replay_capacity=5_000)
    first = rl.run(30).mean_metrics["reward_sum"]
    rl.run(400)
    last = rl.run(30).mean_metrics["reward_sum"]
    assert last > first + 0.3, (first, last)


def test_dqn_epsilon_schedule_endpoints():
    """Linear ε schedule clamps at both ends and interpolates between."""
    env = GridWorld(8, size=3, max_steps=15)
    hp = DQNConfig(eps_start=1.0, eps_end=0.05, eps_steps=100)
    agent = DQNAgent(_vector_cfg(env), hp)
    assert float(agent.epsilon(0)) == pytest.approx(hp.eps_start)
    assert float(agent.epsilon(50)) == pytest.approx(0.525)
    assert float(agent.epsilon(100)) == pytest.approx(hp.eps_end)
    assert float(agent.epsilon(10_000)) == pytest.approx(hp.eps_end)
    # traced step counters take the same path (the scan body's usage)
    assert float(jax.jit(agent.epsilon)(jnp.asarray(0))) == pytest.approx(
        hp.eps_start)


def test_dqn_target_sync_cadence():
    """The target tree hard-syncs exactly every ``target_sync`` updates and
    holds still in between."""
    from repro.core.agents.dqn import dqn_sync_target

    target = {"w": jnp.zeros(3)}
    updates = jnp.zeros((), jnp.int32)
    synced_at = []
    for step in range(1, 8):
        params = {"w": jnp.full(3, float(step))}
        target, updates = dqn_sync_target(target, params, updates,
                                          target_sync=3)
        assert int(updates) == step
        if float(target["w"][0]) == float(step):
            synced_at.append(step)
        else:
            assert float(target["w"][0]) in (0.0, 3.0, 6.0)
    assert synced_at == [3, 6]


def test_dqn_td_target_matches_numpy_oracle():
    from repro.core.agents.dqn import dqn_td_target

    rng = np.random.default_rng(0)
    B, A, gamma = 16, 4, 0.97
    q_next = rng.normal(size=(B, A)).astype(np.float32)
    reward = rng.normal(size=B).astype(np.float32)
    done = rng.random(B) < 0.3
    got = np.asarray(dqn_td_target(jnp.asarray(q_next), jnp.asarray(reward),
                                   jnp.asarray(done), gamma))
    want = reward + gamma * (1.0 - done.astype(np.float32)) * q_next.max(1)
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_dqn_loss_matches_numpy_oracle():
    """The shared dqn_loss (scan step + replay learner step + this oracle)
    is the TD MSE against the target network, gradients stopped through
    the target."""
    from repro.core.agents.dqn import dqn_loss
    from repro.models import init_policy, policy_apply

    env = GridWorld(8, size=3, max_steps=15)
    cfg = _vector_cfg(env)
    params = init_policy(jax.random.PRNGKey(0), cfg)
    target = init_policy(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(2)
    B, gamma = 12, 0.95
    obs = rng.normal(size=(B,) + env.obs_shape).astype(np.float32)
    batch = {
        "obs": jnp.asarray(obs),
        "action": jnp.asarray(rng.integers(0, env.num_actions, B)),
        "reward": jnp.asarray(rng.normal(size=B).astype(np.float32)),
        "next_obs": jnp.asarray(
            rng.normal(size=(B,) + env.obs_shape).astype(np.float32)),
        "done": jnp.asarray(rng.random(B) < 0.25),
    }
    loss, metrics = dqn_loss(params, target, batch, cfg, gamma)
    q = np.asarray(policy_apply(params, cfg, batch["obs"])[0])
    q_next = np.asarray(policy_apply(target, cfg, batch["next_obs"])[0])
    q_a = q[np.arange(B), np.asarray(batch["action"])]
    td_target = np.asarray(batch["reward"]) + gamma * (
        1.0 - np.asarray(batch["done"]).astype(np.float32)) * q_next.max(1)
    np.testing.assert_allclose(float(loss), np.mean((td_target - q_a) ** 2),
                               rtol=1e-5)
    np.testing.assert_allclose(float(metrics["q_mean"]), q_a.mean(),
                               rtol=1e-5)


@pytest.mark.parametrize("mode", ["grad", "act"])
def test_lagged_baselines_run(mode):
    env = GridWorld(8, size=3, max_steps=15)
    agent = LaggedPAACAgent(_vector_cfg(env), LaggedConfig(t_max=4, delay=4), mode=mode)
    rl = ParallelRL(env, agent, lr_schedule=constant(0.005), seed=4)
    res = rl.run(40)
    assert jnp.isfinite(res.mean_metrics["loss"])


def test_lag_zero_matches_paac_exactly():
    """delay=0 refreshes the stale copy every update -> PAAC semantics."""
    env = GridWorld(8, size=3, max_steps=15)
    cfg = _vector_cfg(env)
    paac = ParallelRL(env, PAACAgent(cfg, PAACConfig(t_max=4)),
                      lr_schedule=constant(0.005), seed=7)
    lagged = ParallelRL(
        env, LaggedPAACAgent(cfg, LaggedConfig(t_max=4, delay=1), mode="grad"),
        lr_schedule=constant(0.005), seed=7,
    )
    paac.run(10)
    lagged.run(10)
    for a, b in zip(
        jax.tree_util.tree_leaves(paac.params),
        jax.tree_util.tree_leaves(lagged.params),
    ):
        assert float(jnp.abs(a - b).max()) < 1e-5
