"""Optimizer + checkpoint unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.optim import clip_by_global_norm, linear_anneal, make_optimizer, paac_scaled_lr


def test_clip_by_global_norm():
    grads = {"a": jnp.full((10,), 100.0), "b": jnp.full((10,), 100.0)}
    clipped, norm = clip_by_global_norm(grads, 40.0)
    total = jnp.sqrt(sum(jnp.sum(x**2) for x in jax.tree_util.tree_leaves(clipped)))
    np.testing.assert_allclose(total, 40.0, rtol=1e-5)
    small = {"a": jnp.ones((4,))}
    clipped, _ = clip_by_global_norm(small, 40.0)
    np.testing.assert_allclose(clipped["a"], small["a"])  # untouched below threshold


def test_rmsprop_decreases_quadratic():
    opt = make_optimizer("rmsprop", eps=1e-8, clip_norm=None)
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state = opt.update(grads, state, params, 0.05)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_adam_decreases_quadratic():
    opt = make_optimizer("adam", clip_norm=None)
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(300):
        grads = {"w": 2 * params["w"]}
        params, state = opt.update(grads, state, params, 0.05)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_rmsprop_shared_statistics_single_copy():
    """One statistics tree (the paper's single synchronous copy)."""
    opt = make_optimizer("rmsprop")
    params = {"w": jnp.zeros((3,))}
    state = opt.init(params)
    assert set(state) == {"sq"}
    assert state["sq"]["w"].dtype == jnp.float32


def test_schedules():
    assert float(paac_scaled_lr(32)(0)) == pytest.approx(0.0224)  # paper §5.1!
    s = linear_anneal(1.0, 100)
    assert float(s(0)) == 1.0
    assert float(s(50)) == pytest.approx(0.5)
    assert float(s(200)) == 0.0


def test_bf16_params_update_in_fp32():
    opt = make_optimizer("rmsprop")
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    state = opt.init(params)
    new_params, _ = opt.update({"w": jnp.ones((4,), jnp.bfloat16)}, state, params, 0.1)
    assert new_params["w"].dtype == jnp.bfloat16


def test_checkpoint_roundtrip(tmp_path, key):
    tree = {
        "a": jax.random.normal(key, (4, 5)),
        "nested": {"b": jnp.arange(7), "c": jnp.ones((2,), jnp.bfloat16)},
    }
    save_checkpoint(str(tmp_path), 42, tree)
    assert latest_step(str(tmp_path)) == 42
    restored = restore_checkpoint(str(tmp_path), 42, jax.eval_shape(lambda: tree))
    for a, b in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(restored)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32)
        )
