"""Per-architecture smoke tests (deliverable f).

Each assigned architecture is instantiated as a REDUCED variant of the same
family (<=2 layers, d_model<=512, <=4 experts) and runs one forward pass and
one PAAC train step on CPU, asserting output shapes and no NaNs. The FULL
configs are exercised only via the dry-run.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.core.agents.paac import PAACAgent, PAACConfig
from repro.models import init_policy, policy_apply
from repro.optim import constant, make_optimizer

B, T = 2, 16


def _batch(cfg, key):
    toks = jax.random.randint(key, (B, T + 1), 0, cfg.vocab_size)
    batch = {
        "tokens": toks,
        "rewards": jax.random.uniform(key, (B, T)),
        "dones": jnp.zeros((B, T), bool),
    }
    if cfg.modality == "vision":
        batch["prefix"] = jnp.ones((B, cfg.prefix_len, cfg.frontend_dim), jnp.float32)
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.ones(
            (B, cfg.encoder_seq_len, cfg.frontend_dim), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_forward_shapes_no_nan(arch, key):
    cfg = get_config(arch).reduced()
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    if cfg.num_experts:
        assert cfg.num_experts <= 4
    params = init_policy(key, cfg)
    batch = _batch(cfg, key)
    tokens = batch["tokens"][:, :-1]
    prefix = batch.get("prefix", batch.get("frames"))
    logits, values, aux = policy_apply(params, cfg, tokens, prefix, train=True)
    S_out = T + (cfg.prefix_len if cfg.modality == "vision" else 0)
    assert logits.shape == (B, S_out, cfg.actions())
    assert values.shape == (B, S_out)
    assert not bool(jnp.isnan(logits).any())
    assert not bool(jnp.isnan(values).any())


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_train_step_no_nan(arch, key):
    cfg = get_config(arch).reduced()
    params = init_policy(key, cfg)
    opt = make_optimizer("rmsprop")
    opt_state = opt.init(params)
    agent = PAACAgent(cfg, PAACConfig())
    step = jax.jit(agent.make_llm_train_step(opt, constant(1e-3)))
    batch = _batch(cfg, key)
    new_params, new_opt, metrics = step(params, opt_state, batch, jnp.int32(0))
    assert jnp.isfinite(metrics["loss"])
    # params actually changed
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max()),
        params, new_params,
    )
    assert max(jax.tree_util.tree_leaves(moved)) > 0.0
    for leaf in jax.tree_util.tree_leaves(new_params):
        assert not bool(jnp.isnan(leaf).any())


def test_paper_cnn_archs(key):
    for arch in ("paac_nips", "paac_nature"):
        cfg = get_config(arch)
        params = init_policy(key, cfg)
        obs = jax.random.uniform(key, (B,) + cfg.obs_shape)
        logits, value, _ = policy_apply(params, cfg, obs)
        assert logits.shape == (B, cfg.num_actions)
        assert value.shape == (B,)
        assert not bool(jnp.isnan(logits).any())
