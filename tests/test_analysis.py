"""repro.analysis: repro-lint rules, lock-order detector, transfer sanitizer.

Pins the correctness-tooling plane's contracts:
* each lint rule fires on its deliberately-broken fixture (exit != 0
  through the CLI) and stays quiet on the clean fixture and on the real
  tree (``python -m repro.analysis.lint src`` exits 0 — the acceptance
  gate CI enforces),
* suppression comments silence exactly the named rule,
* the lock-order monitor flags a synthetic A->B/B->A inversion as a cycle
  and a wait-while-holding-foreign-lock as a hazard, while the factories
  hand back plain threading primitives when the sanitizer is off,
* the device-plane pipelined steady state runs transfer-free for >= 3
  guarded iterations with donated-buffer probes firing, and an implicit
  transfer inside a guard scope raises,
* actor abort paths leave no open span (the audit the span-pairing rule
  machine-checks),
* sanitizer verdicts ride the telemetry hub into the trace artifact.
"""
import json
import subprocess
import sys
import threading
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.analysis import (
    disable_sanitizers,
    enable_sanitizers,
    sanitizer_enabled,
)
from repro.analysis import lint as rlint
from repro.analysis import sanitize
from repro.analysis.lockcheck import (
    SanitizedCondition,
    SanitizedLock,
    make_condition,
    make_lock,
    monitor,
)

REPO = Path(__file__).resolve().parent.parent
FIXTURES = REPO / "tests" / "fixtures" / "lint"


@pytest.fixture(autouse=True)
def _sanitizer_hygiene():
    """Every test starts and ends with sanitizers off and state clean."""
    disable_sanitizers()
    monitor().reset()
    sanitize.reset_stats()
    yield
    disable_sanitizers()
    monitor().reset()
    sanitize.reset_stats()


# ---------------------------------------------------------------------------
# repro-lint rules (in-process)
# ---------------------------------------------------------------------------


def _rules_for(path: Path):
    return {f.rule for f in rlint.lint_paths([str(path)])}


@pytest.mark.parametrize("fixture,rule", [
    ("bad_lease.py", "lease-pairing"),
    ("bad_slot_lease.py", "lease-pairing"),
    ("bad_span.py", "span-pairing"),
    ("bad_donated.py", "donated-reuse"),
    ("bad_hotpath.py", "hot-path-sync"),
    ("bad_hostenv.py", "hostenv-picklable"),
])
def test_each_rule_fires_on_its_fixture(fixture, rule):
    assert rule in _rules_for(FIXTURES / fixture)


def test_clean_fixture_has_no_findings():
    assert _rules_for(FIXTURES / "clean.py") == set()


def test_suppression_comment_silences_named_rule():
    src = (FIXTURES / "bad_span.py").read_text()
    silenced = src.replace(
        "        return None",
        "        return None  # repro-lint: disable=span-pairing",
    )
    assert silenced != src
    findings = rlint.lint_source(silenced, "bad_span.py")
    assert not [f for f in findings if f.rule == "span-pairing"]
    # an unrelated rule name does not silence it
    other = src.replace(
        "        return None",
        "        return None  # repro-lint: disable=lease-pairing",
    )
    assert [f for f in rlint.lint_source(other, "bad_span.py")
            if f.rule == "span-pairing"]


def test_span_rule_forgives_exceptional_paths_and_cancel():
    src = """
def ok(em, q, stop):
    em.begin(1)
    try:
        item = q.get()
    except Exception:
        em.cancel()
        raise
    em.end()
    return item

def ok_loop(em, q, stop):
    while True:
        em.begin(2)
        try:
            item = q.get(timeout=0.1)
        except TimeoutError:
            if stop.is_set():
                em.cancel()
                return None
            em.cancel()
            continue
        em.end()
        return item
"""
    assert not [f for f in rlint.lint_source(src, "x.py")
                if f.rule == "span-pairing"]


def test_lease_rule_accepts_try_finally_and_deferred_release():
    src = """
def ok(slot):
    params, v = slot.acquire()
    try:
        return params
    finally:
        slot.release(v)

def ok_deferred(staging):
    s = staging.acquire()
    return s.traj, (lambda: staging.release(s))
"""
    assert not [f for f in rlint.lint_source(src, "x.py")
                if f.rule == "lease-pairing"]


def test_lease_rule_covers_allocate_free_vocabulary():
    """The serving slot cache's allocate/free pair rides the same rule:
    deferred-free closures and try/finally frees pass; a mixed pairing
    (allocate answered only by release) does not."""
    ok = """
def ok_admit(slots, req):
    slot = slots.allocate(req.rid)
    req.on_retire = (lambda s=slot, r=req.rid: slots.free(s, r))
    return slot

def ok_scoped(slots, rid):
    slot = slots.allocate(rid)
    try:
        return do_work(slot)
    finally:
        slots.free(slot, rid)
"""
    assert not [f for f in rlint.lint_source(ok, "x.py")
                if f.rule == "lease-pairing"]
    mixed = """
def mixed(slots, rid):
    slot = slots.allocate(rid)
    try:
        return do_work(slot)
    finally:
        slots.release(slot)
"""
    assert [f for f in rlint.lint_source(mixed, "x.py")
            if f.rule == "lease-pairing"]


def test_cli_clean_on_real_tree_and_nonzero_on_fixtures():
    """The acceptance gate: lint exits 0 over src/, 1 per broken fixture."""
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"}
    clean = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", "src"],
        cwd=REPO, env=env, capture_output=True, text=True,
    )
    assert clean.returncode == 0, clean.stdout + clean.stderr
    for fixture in sorted(FIXTURES.glob("bad_*.py")):
        broken = subprocess.run(
            [sys.executable, "-m", "repro.analysis.lint", str(fixture)],
            cwd=REPO, env=env, capture_output=True, text=True,
        )
        assert broken.returncode == 1, fixture.name
        assert fixture.name in broken.stdout


# ---------------------------------------------------------------------------
# lock-order detector
# ---------------------------------------------------------------------------


def test_factories_return_plain_primitives_when_off():
    assert not sanitizer_enabled("locks")
    assert not isinstance(make_lock("x"), SanitizedLock)
    assert not isinstance(make_condition("y"), SanitizedCondition)


def test_factories_return_wrappers_when_on():
    enable_sanitizers("locks")
    assert isinstance(make_lock("x"), SanitizedLock)
    assert isinstance(make_condition("y"), SanitizedCondition)


def test_lock_inversion_is_flagged_as_cycle():
    enable_sanitizers("locks")
    a, b = SanitizedLock("testA"), SanitizedLock("testB")
    with a:
        with b:
            pass
    with b:
        with a:
            pass
    rep = monitor().report()
    assert [c for c in rep["cycles"] if set(c) == {"testA", "testB"}]
    edges = {(e["from"], e["to"]) for e in rep["edges"]}
    assert ("testA", "testB") in edges and ("testB", "testA") in edges


def test_consistent_order_is_not_a_cycle():
    enable_sanitizers("locks")
    a, b = SanitizedLock("testA"), SanitizedLock("testB")
    for _ in range(3):
        with a:
            with b:
                pass
    assert monitor().cycles() == []


def test_distinct_instances_of_same_site_nesting_is_a_self_cycle():
    enable_sanitizers("locks")
    l1, l2 = SanitizedLock("same.site"), SanitizedLock("same.site")
    with l1:
        with l2:
            pass
    assert [c for c in monitor().cycles() if set(c) == {"same.site"}]


def test_wait_while_holding_foreign_lock_is_a_hazard():
    enable_sanitizers("locks")
    outer = SanitizedLock("outer.lock")
    cond = SanitizedCondition("inner.cond")
    with outer:
        with cond:
            cond.wait(timeout=0.01)
    hazards = monitor().report()["hazards"]
    assert [h for h in hazards
            if h["waiting_on"] == "inner.cond"
            and "outer.lock" in h["holding"]]
    # waiting on your own condition with nothing else held is fine
    monitor().reset()
    with cond:
        cond.wait(timeout=0.01)
    assert monitor().report()["hazards"] == []


def test_cross_thread_edges_merge_into_one_graph():
    enable_sanitizers("locks")
    a, b = SanitizedLock("testA"), SanitizedLock("testB")

    def t1():
        with a:
            with b:
                pass

    def t2():
        with b:
            with a:
                pass

    th1 = threading.Thread(target=t1)
    th1.start()
    th1.join()
    th2 = threading.Thread(target=t2)
    th2.start()
    th2.join()
    assert [c for c in monitor().cycles() if set(c) == {"testA", "testB"}]


# ---------------------------------------------------------------------------
# transfer/donation sanitizer
# ---------------------------------------------------------------------------


def test_unknown_sanitize_mode_rejected():
    with pytest.raises(ValueError):
        enable_sanitizers("locks,bogus")


def test_guard_is_noop_when_off():
    with sanitize.guard():
        jax.device_get(jax.numpy.zeros(2))  # would raise if guarded
    assert sanitize.stats["guarded"] == 0


def test_implicit_transfer_inside_guard_raises():
    enable_sanitizers("transfers")
    with pytest.raises(Exception, match="[Dd]isallow"):
        with sanitize.guard():
            # numpy operand to a device op is an implicit H2D transfer
            (jax.numpy.ones(4) + np.ones(4)).block_until_ready()
    assert sanitize.stats["guarded"] == 1
    # the named escape re-allows the intended edge
    with sanitize.guard():
        with sanitize.allowed("test edge"):
            (jax.numpy.ones(4) + np.ones(4)).block_until_ready()


# probing is_deleted() is the one legitimate post-donation touch
def test_deleted_buffer_probes():  # repro-lint: disable=donated-reuse
    enable_sanitizers("transfers")
    f = jax.jit(lambda x: x + 1, donate_argnums=(0,))
    x = jax.numpy.ones(8)
    y = f(x)
    sanitize.assert_deleted({"x": x}, "donated x")  # deleted: passes
    with pytest.raises(sanitize.DonationViolation):
        sanitize.assert_deleted({"y": y}, "live y")
    # uniform probe: all-live ok, all-deleted ok, a mix is the bug
    sanitize.assert_uniformly_deleted({"y": y, "z": y + 0}, "all live")
    sanitize.assert_uniformly_deleted({"x": x}, "all deleted")
    with pytest.raises(sanitize.DonationViolation):
        sanitize.assert_uniformly_deleted({"x": x, "y": y}, "mixed")


def test_device_plane_steady_state_is_transfer_free():
    """>= 3 guarded learner iterations + guarded collects run with zero
    disallowed transfers, probes firing every sanitized iteration, and the
    lockcheck verdict riding the run's telemetry hub."""
    from repro.configs import PipelineConfig, get_config
    from repro.core.agents import PAACAgent, PAACConfig
    from repro.envs import GridWorld
    from repro.optim import constant
    from repro.pipeline import PipelinedRL

    enable_sanitizers("locks,transfers")
    env = GridWorld(8, size=4, max_steps=20)
    cfg = get_config("paac_vector").replace(
        obs_shape=env.obs_shape, num_actions=env.num_actions)
    agent = PAACAgent(cfg, PAACConfig(t_max=5))
    prl = PipelinedRL(env, agent, lr_schedule=constant(0.01), seed=0,
                      pipeline=PipelineConfig(queue_depth=2))
    assert prl._plane == "device"
    iters = 5
    res = prl.run(iters)  # any disallowed transfer raises in-run
    assert np.isfinite(res.mean_metrics["loss"])
    # learner loop guards iterations 1..4; collect closures guard all
    # post-warmup calls — comfortably past the >= 3 acceptance bar
    assert sanitize.stats["guarded"] >= 3 + (iters - 1)
    assert sanitize.stats["probed"] >= 2 * (iters - 1)
    rep = prl.telemetry.reports["lockcheck"]
    assert rep["cycles"] == [] and rep["hazards"] == []


def test_actor_stop_during_lockstep_leaves_no_open_span():
    """Abort-path audit regression: a lockstep actor stopped while waiting
    for params cancels its LEASE span — emitter depth returns to zero."""
    from repro.pipeline import ParamSlot, TrajectoryQueue
    from repro.pipeline.actor import ActorThread

    slot = ParamSlot({"w": np.ones(2)}, version=-1)  # version 0 never comes

    def collect(params, key):  # pragma: no cover - actor never collects
        raise AssertionError("collect must not run")

    a = ActorThread(collect, TrajectoryQueue(1), slot, None, iterations=3,
                    lockstep=True)
    a.start()
    a.join(timeout=1.0)
    assert a.is_alive()  # parked in the lease wait
    a.stop()
    a.join(timeout=5.0)
    assert not a.is_alive() and a.error is None
    assert a.span_emitter._depth == 0
    assert a.span_emitter.current() is None


def test_trace_embeds_named_reports(tmp_path):
    from repro.telemetry import Telemetry

    hub = Telemetry()
    hub.report("lockcheck", {"edges": [], "cycles": [], "hazards": []})
    path = tmp_path / "trace.json"
    hub.write_trace(str(path))
    data = json.loads(path.read_text())
    assert data["reports"]["lockcheck"] == {
        "edges": [], "cycles": [], "hazards": []}
