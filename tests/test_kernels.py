"""Per-kernel correctness: sweep shapes/dtypes, assert_allclose vs ref.py.

Kernels run in interpret mode on CPU (the container target); the oracles are
pure jnp.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref as R
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.nstep_returns import nstep_returns_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas


# ---------------------------------------------------------------- nstep
@pytest.mark.parametrize("E,T", [(1, 1), (7, 5), (32, 64), (33, 17)])
@pytest.mark.parametrize("gamma", [0.9, 0.99])
def test_nstep_returns(E, T, gamma, key):
    r = jax.random.normal(key, (E, T))
    d = jax.random.bernoulli(key, 0.3, (E, T))
    b = jax.random.normal(key, (E,))
    out = nstep_returns_pallas(r, d, b, gamma, block_e=8)
    ref = R.nstep_returns_ref(r, d, b, gamma)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_nstep_matches_paper_hand_example():
    # hand-computed: r=[1,0,2], gamma=0.5, bootstrap=4, no terminals
    # R3 = 2 + .5*4 = 4 ; R2 = 0 + .5*4 = 2 ; R1 = 1 + .5*2 = 2
    r = jnp.array([[1.0, 0.0, 2.0]])
    d = jnp.zeros((1, 3), bool)
    b = jnp.array([4.0])
    out = nstep_returns_pallas(r, d, b, 0.5)
    np.testing.assert_allclose(out[0], [2.0, 2.0, 4.0])
    # terminal at t=1 cuts the bootstrap: R2 = 0 (done), R1 = 1 + .5*0
    d = jnp.array([[False, True, False]])
    out = nstep_returns_pallas(r, d, b, 0.5)
    np.testing.assert_allclose(out[0], [1.0, 0.0, 4.0])


# ---------------------------------------------------------------- flash
@pytest.mark.parametrize("Sq,Sk,H,Hkv,D", [
    (64, 64, 4, 4, 32),
    (128, 128, 4, 2, 64),
    (100, 100, 8, 1, 64),   # padded seq, MQA
    (256, 256, 4, 4, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [0, 37])
def test_flash_attention(Sq, Sk, H, Hkv, D, dtype, window, key):
    B = 2
    q = jax.random.normal(key, (B, Sq, H, D), dtype)
    k = jax.random.normal(key, (B, Sk, Hkv, D), dtype)
    v = jax.random.normal(key, (B, Sk, Hkv, D), dtype)
    out = flash_attention_pallas(q, k, v, causal=True, window=window,
                                 block_q=64, block_k=64)
    ref = R.flash_attention_ref(q, k, v, causal=True, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        out.astype(jnp.float32), ref.astype(jnp.float32), rtol=tol, atol=tol
    )


def test_flash_non_causal(key):
    B, S, H, D = 2, 96, 4, 32
    q = jax.random.normal(key, (B, S, H, D))
    k = jax.random.normal(key, (B, S, H, D))
    v = jax.random.normal(key, (B, S, H, D))
    out = flash_attention_pallas(q, k, v, causal=False, block_q=32, block_k=32)
    ref = R.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------- decode
@pytest.mark.parametrize("S,H,Hkv,D,pos", [
    (128, 4, 4, 32, 80),
    (300, 8, 2, 64, 299),
    (512, 8, 1, 128, 0),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(S, H, Hkv, D, pos, dtype, key):
    B = 2
    q = jax.random.normal(key, (B, H, D), dtype)
    kc = jax.random.normal(key, (B, S, Hkv, D), dtype)
    vc = jax.random.normal(key, (B, S, Hkv, D), dtype)
    out = decode_attention_pallas(q, kc, vc, pos, block_k=128)
    ref = R.decode_attention_ref(q, kc, vc, pos)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        out.astype(jnp.float32), ref.astype(jnp.float32), rtol=tol, atol=tol
    )


# ---------------------------------------------------------------- ssd
@pytest.mark.parametrize("S,H,P,N,chunk", [
    (64, 2, 16, 8, 16),
    (256, 4, 32, 16, 64),
    (128, 8, 64, 64, 128),  # single chunk
])
def test_ssd_scan(S, H, P, N, chunk, key):
    B = 2
    x = jax.random.normal(key, (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(key, (B, S, H)))
    A_log = jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32))
    Bm = jax.random.normal(key, (B, S, N))
    Cm = jax.random.normal(key, (B, S, N))
    Dv = jnp.ones((H,))
    y = ssd_scan_pallas(x, dt, A_log, Bm, Cm, Dv, chunk=chunk)
    ref, _ = R.ssd_scan_ref(x, dt, A_log, Bm, Cm, Dv)
    np.testing.assert_allclose(y, ref, rtol=1e-4, atol=2e-3)


def test_ssd_scan_matches_model_chunked(key):
    """The kernel, the chunked model path and the sequential oracle agree."""
    from repro.models.ssm import ssd_chunked

    B, S, H, P, N = 2, 128, 4, 32, 16
    x = jax.random.normal(key, (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(key, (B, S, H)))
    A_log = jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32))
    Bm = jax.random.normal(key, (B, S, N))
    Cm = jax.random.normal(key, (B, S, N))
    Dv = jnp.ones((H,))
    y_k = ssd_scan_pallas(x, dt, A_log, Bm, Cm, Dv, chunk=32)
    y_m, state_m = ssd_chunked(x, dt, A_log, Bm, Cm, Dv, chunk=32)
    y_r, state_r = R.ssd_scan_ref(x, dt, A_log, Bm, Cm, Dv)
    np.testing.assert_allclose(y_k, y_r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(y_m, y_r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(state_m, state_r, rtol=1e-4, atol=1e-4)
