"""Roofline analysis from compiled dry-run artifacts (no hardware needed).

Three terms per (arch, shape, mesh), in seconds (see spec §ROOFLINE):

    compute    = HLO_FLOPs_per_chip / peak_FLOP/s
    memory     = HLO_bytes_per_chip / HBM_bw
    collective = wire_bytes_per_chip / link_bw

``compiled.cost_analysis()`` operates on the SPMD-partitioned per-device
module, so flops/bytes are already per chip. Collective bytes are parsed
from the optimized HLO text: for every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute we take the result shape
bytes and convert to per-chip wire bytes with ring-algorithm factors
(all-reduce 2(N-1)/N, all-gather (N-1)/N of the FULL gathered tensor,
reduce-scatter (N-1)/N, all-to-all (N-1)/N, permute 1.0), N = group size
from replica_groups.
"""
from __future__ import annotations

import re
from typing import Dict, Optional

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(bf16|f64|f32|f16|f8e4m3fn|f8e5m2|s64|s32|s16|s8|u64|u32|u16|u8|pred)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:  # iota format [num_groups, group_size]
        return int(m.group(2))
    return 1


def _wire_factor(op: str, n: int) -> float:
    if n <= 1:
        return 0.0
    if op == "all-reduce":
        return 2.0 * (n - 1) / n
    if op in ("all-gather", "reduce-scatter", "all-to-all"):
        return (n - 1) / n
    return 1.0  # collective-permute


def parse_collectives(hlo_text: str) -> Dict[str, float]:
    """Sum per-chip wire bytes per collective type from optimized HLO."""
    out = {op: 0.0 for op in _COLLECTIVES}
    counts = {op: 0 for op in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|[^\s]+)\s+([\w\-]+)", stripped)
        if not m:
            continue
        op_name = m.group(2)
        base = None
        for op in _COLLECTIVES:
            if op_name == op or op_name.startswith(op + "-start") or op_name.startswith(op + "."):
                base = op
                break
        if base is None:
            continue
        nbytes = _shape_bytes(m.group(1))
        n = _group_size(stripped)
        out[base] += nbytes * _wire_factor(base, n)
        counts[base] += 1
    out["total_wire_bytes"] = sum(out[op] for op in _COLLECTIVES)
    out["counts"] = counts  # type: ignore
    return out


def roofline_terms(
    flops_per_chip: float,
    bytes_per_chip: float,
    wire_bytes_per_chip: float,
) -> Dict[str, float]:
    compute = flops_per_chip / PEAK_FLOPS_BF16
    memory = bytes_per_chip / HBM_BW
    collective = wire_bytes_per_chip / ICI_BW
    terms = {"compute_s": compute, "memory_s": memory, "collective_s": collective}
    dom = max(terms, key=terms.get)
    terms["bottleneck"] = dom.replace("_s", "")
    return terms


def active_params(cfg, params_tree) -> float:
    """Parameter count weighted by MoE activation (top-k of E experts)."""
    import jax

    total = 0.0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_tree)[0]:
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        size = 1
        for d in leaf.shape:
            size *= d
        if cfg.num_experts and "/moe/" in pstr and pstr.split("/")[-2] in ("moe",) or (
            cfg.num_experts and "moe" in pstr and pstr.split("/")[-1] in ("wi", "wg", "wo")
        ):
            size = size * cfg.num_experts_per_tok / cfg.num_experts
        total += size
    return total


def model_flops(cfg, params_tree, tokens: int) -> float:
    """MODEL_FLOPS = 6 · N_active · D (the spec's useful-compute yardstick)."""
    return 6.0 * active_params(cfg, params_tree) * tokens
