"""Training launcher.

Two modes:

* ``--mode rl`` (default): full PAAC RL (Algorithm 1) against a JAX token
  environment — rollout with the current policy, synchronous update.
  Works at reduced scale on CPU; on a pod the same code runs the
  production mesh (actions/envs sharded over the data axes).
* ``--mode synthetic``: the sharded trajectory train step on synthetic
  batches — the profiling configuration matching the dry-run's train_4k.

``--pipeline`` swaps the synchronous ``ParallelRL`` backend for the
asynchronous actor/learner pipeline (``repro.pipeline.PipelinedRL``):
``--num-actors`` replicas (the env axis split between them) collect
rollouts while the learner consumes earlier ones, with ``--queue-depth``
bounding staleness and ``--rho-bar``/``--c-bar`` the V-trace clips on the
off-policy importance correction. ``--rollout-plane`` picks the trajectory
queue plane: the device-resident ring (JAX-native envs, donated buffers —
the fast path) or the host staging queue (external env pools; also the
GA3C-style baseline for benchmarking JAX envs). ``--actor-backend process``
moves each actor replica into a worker subprocess (shared-memory rollouts
and param broadcast) — the only backend that scales GIL-holding Python
emulators; it drives the ``--host-env`` Python-bound emulator pool with
``--env-spin`` pure-Python work per step. ``--mesh D`` scales the device
plane across ``D`` accelerators: one actor lane per device feeds a
per-device sub-ring, the learner consumes a globally-sharded batch and
all-reduces its gradients over the mesh's data axis (on CPU, expose fake
devices first: ``XLA_FLAGS=--xla_force_host_platform_device_count=D``).
``--trace``/``--metrics-jsonl``/``--stall-timeout`` turn on the pipeline's
observability exports (``repro.telemetry``; see docs/observability.md): a
Perfetto-viewable Chrome trace of every plane's spans, a JSONL liveness
heartbeat, and the stall watchdog naming the stage each party is blocked
in when progress stops.

``--elastic`` arms the pipeline's actor supervisor: crashed replicas
respawn under ``--restart-budget`` (exponential ``--restart-backoff``),
then the run degrades to fewer actors with the dead replica's quota
reassigned — instead of the fail-fast default. ``--checkpoint-dir`` +
``--checkpoint-every N`` snapshot the full pipeline state every N updates;
``--resume`` restores the newest snapshot and runs only the remainder
(bitwise-equal to the uninterrupted run on the thread backend's FIFO
planes). ``--fault-kill``/``--fault-stall-learner`` drive the
deterministic fault-injection harness (``repro.pipeline.faults``) for
chaos testing. See docs/fault_tolerance.md.

``--replay`` swaps the pipeline's FIFO trajectory ring for the sampled
``ReplayRing`` (the off-policy plane): actors never block — a full ring
evicts its oldest rollout — and each learner update samples
``--replay-batch`` of the ``--replay-capacity`` resident rollouts
(uniformly, or TD-error-weighted with ``--prioritized``). ``--algo dqn``
selects the value-based agent: synchronous scan-based DQN without
``--pipeline``, the replay-fed pipelined TD learner with
``--pipeline --replay``; ``--algo paac`` (default) under ``--replay``
runs the V-trace learner off-policy on sampled stale rollouts.

Examples:
    PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --reduced \
        --iterations 20
    PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --reduced \
        --iterations 20 --pipeline --queue-depth 2 --rho-bar 1.0
    PYTHONPATH=src python -m repro.launch.train --arch paac_vector \
        --iterations 40 --pipeline --num-actors 4 --n-envs 16
    PYTHONPATH=src python -m repro.launch.train --arch paac_vector \
        --algo dqn --iterations 40 --pipeline --replay --num-actors 2 \
        --replay-capacity 32 --replay-batch 1
    PYTHONPATH=src python -m repro.launch.train --arch mamba2-370m --reduced \
        --mode synthetic --iterations 5
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import save_checkpoint
from repro.configs import ASSIGNED_ARCHS, get_config
from repro.core import ParallelRL
from repro.core.agents import PAACAgent, PAACConfig
from repro.envs import TokenEnv
from repro.launch.steps import build_train_step
from repro.models import init_policy
from repro.optim import constant
from repro.utils import get_logger

log = get_logger("train")


def run_rl(args):
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.actor_backend == "process" and not args.pipeline:
        raise SystemExit(
            "--actor-backend process is a pipeline backend: add --pipeline "
            "(the synchronous ParallelRL driver has no actor replicas)"
        )
    if args.mesh > 1 and not args.pipeline:
        raise SystemExit(
            "--mesh is a pipeline (mesh rollout plane) knob: add --pipeline"
        )
    if (args.trace or args.metrics_jsonl or args.stall_timeout) \
            and not args.pipeline:
        raise SystemExit(
            "--trace/--metrics-jsonl/--stall-timeout observe the pipeline "
            "backend's telemetry hub: add --pipeline"
        )
    if args.sanitize and not args.pipeline:
        raise SystemExit(
            "--sanitize arms the pipeline backend's runtime sanitizers "
            "(repro.analysis): add --pipeline"
        )
    if args.sanitize:
        from repro.analysis import enable_sanitizers

        try:
            modes = enable_sanitizers(args.sanitize)
        except ValueError as e:
            raise SystemExit(f"--sanitize: {e}")
        log.info("sanitizers armed: %s", ",".join(sorted(modes)))
    if args.replay and not args.pipeline:
        raise SystemExit(
            "--replay selects the pipeline's sampled ReplayRing plane: add "
            "--pipeline (the synchronous DQN has its own scan-based replay)"
        )
    if args.prioritized and not args.replay:
        raise SystemExit(
            "--prioritized weights the ReplayRing's sampling: add --replay"
        )
    if args.algo == "dqn" and args.pipeline and not args.replay:
        raise SystemExit(
            "--algo dqn under --pipeline needs the replay plane: add "
            "--replay (the FIFO planes feed the on-policy V-trace learner)"
        )
    if args.replay and (args.host_env or args.actor_backend == "process"):
        raise SystemExit(
            "--replay requires a JAX-native env on the device plane: it "
            "cannot combine with --host-env/--actor-backend process"
        )
    if (args.elastic or args.fault_kill or args.fault_stall_learner
            or args.checkpoint_every or args.resume) and not args.pipeline:
        raise SystemExit(
            "--elastic/--fault-*/--checkpoint-every/--resume drive the "
            "pipeline backend's fault-tolerance plane: add --pipeline"
        )
    if (args.checkpoint_every or args.resume) and not args.checkpoint_dir:
        raise SystemExit(
            "--checkpoint-every/--resume need --checkpoint-dir (where the "
            "pipeline's full-state snapshots live)"
        )
    host_env = args.host_env or args.actor_backend == "process"
    if host_env:
        # GIL-holding external-emulator path (repro.envs.pyemu): the regime
        # --actor-backend process exists for. Needs a policy that acts on
        # the raw vector observation.
        if cfg.family != "cnn":
            raise SystemExit(
                f"--host-env/--actor-backend process need a vector/cnn "
                f"policy (e.g. --arch paac_vector), got {args.arch}"
            )
        from repro.envs import py_bound_spec

        spec = py_bound_spec(args.n_envs, obs_dim=16, spin=args.env_spin,
                             n_workers=min(8, args.n_envs))
        cfg = cfg.replace(obs_shape=spec.obs_shape, num_actions=3)
        env = spec if args.pipeline else spec.build()
    else:
        env = TokenEnv(args.n_envs, vocab=min(cfg.vocab_size, 64),
                       ctx=args.ctx, k=2, horizon=64)
        cfg = cfg.replace(num_actions=env.vocab)
        if cfg.family == "cnn":  # vector/cnn policies act on the raw obs
            cfg = cfg.replace(obs_shape=env.obs_shape)
    if args.algo == "dqn":
        from repro.core.agents import DQNAgent, DQNConfig

        agent = DQNAgent(cfg, DQNConfig(t_max=args.t_max))
    else:
        agent = PAACAgent(cfg, PAACConfig(t_max=args.t_max,
                                          entropy_beta=0.01))
    if args.pipeline:
        from repro.configs import PipelineConfig
        from repro.pipeline import FaultPlan, PipelinedRL

        fault_plan = None
        if args.fault_kill or args.fault_stall_learner:
            kills = []
            for spec in args.fault_kill:
                parts = spec.split(":")
                if len(parts) not in (2, 3):
                    raise SystemExit(
                        f"--fault-kill {spec!r}: expected "
                        "slot:after_rollouts[:mode]"
                    )
                kills.append((int(parts[0]), int(parts[1]),
                              parts[2] if len(parts) == 3 else "error"))
            stalls = []
            for spec in args.fault_stall_learner:
                it, _, sec = spec.partition(":")
                if not sec:
                    raise SystemExit(
                        f"--fault-stall-learner {spec!r}: expected "
                        "iteration:seconds"
                    )
                stalls.append((int(it), float(sec)))
            fault_plan = FaultPlan(kills=tuple(kills),
                                   stall_learner=tuple(stalls))
        rl = PipelinedRL(
            env, agent, lr_schedule=constant(args.lr), seed=args.seed,
            pipeline=PipelineConfig(queue_depth=args.queue_depth,
                                    rho_bar=args.rho_bar, c_bar=args.c_bar,
                                    num_actors=args.num_actors,
                                    rollout_plane=args.rollout_plane,
                                    actor_backend=args.actor_backend,
                                    mesh_shape=args.mesh,
                                    replay_plane=args.replay,
                                    replay_capacity=args.replay_capacity,
                                    replay_batch=args.replay_batch,
                                    prioritized=args.prioritized,
                                    trace_path=args.trace,
                                    metrics_jsonl=args.metrics_jsonl,
                                    stall_timeout_s=args.stall_timeout,
                                    elastic=args.elastic,
                                    restart_budget=args.restart_budget,
                                    restart_backoff_s=args.restart_backoff,
                                    lease_timeout_s=args.lease_timeout,
                                    fault_plan=fault_plan,
                                    checkpoint_dir=args.checkpoint_dir,
                                    checkpoint_every=args.checkpoint_every),
        )
    else:
        rl = ParallelRL(env, agent, lr_schedule=constant(args.lr),
                        seed=args.seed)
    resume_done = 0
    if args.pipeline and args.resume:
        resume_done = rl.restore()
        if resume_done:
            log.info("resume: checkpoint covers %d update(s) — running the "
                     "remainder", resume_done)
    try:
        for epoch in range(args.epochs):
            iters = args.iterations
            if epoch == 0 and resume_done:
                iters = max(args.iterations - resume_done, 0)
                if iters == 0:
                    log.info("resume: epoch 0 fully covered by checkpoint")
                    continue
            res = rl.run(iters,
                         log_every=max(args.iterations // 4, 1))
            log.info(
                "epoch %d steps=%d mean_reward/iter=%.3f tps=%.0f%s",
                epoch, res.steps, res.mean_metrics.get("reward_sum", 0.0),
                res.timesteps_per_sec,
                (f" staleness={res.mean_metrics.get('staleness', 0.0):.1f}"
                 f" actor_idle={res.actor_idle_s:.2f}s"
                 f" learner_idle={res.learner_idle_s:.2f}s"
                 if args.pipeline else ""),
            )
        if args.checkpoint:
            save_checkpoint(args.checkpoint, rl.total_steps, rl.params)
            log.info("checkpoint saved to %s", args.checkpoint)
        if args.sanitize and "locks" in args.sanitize:
            # the run's lock-order verdict (also embedded in --trace output):
            # a cycle or wait-while-holding hazard is a latent deadlock —
            # fail the launch so CI catches it
            from repro.analysis.lockcheck import monitor

            rep = monitor().report()
            if rep["cycles"] or rep["hazards"]:
                for cyc in rep["cycles"]:
                    log.error("lockcheck: lock-order cycle %s",
                              " -> ".join(cyc))
                for h in rep["hazards"]:
                    log.error(
                        "lockcheck: %s waited on %s while holding %s",
                        h["thread"], h["waiting_on"], ", ".join(h["holding"]))
                raise SystemExit(
                    f"lockcheck: {len(rep['cycles'])} cycle(s), "
                    f"{len(rep['hazards'])} hazard(s) — see log"
                )
            log.info("lockcheck: %d lock-order edge(s), no cycles, "
                     "no hazards", len(rep["edges"]))
    finally:
        if hasattr(rl, "close"):
            rl.close()  # worker subprocesses / spec-built pools
        elif host_env and not args.pipeline:
            env.close()
    return rl


def run_synthetic(args):
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    B, T = args.n_envs, args.t_max
    key = jax.random.PRNGKey(args.seed)
    params = init_policy(key, cfg)
    step_fn, opt = build_train_step(cfg, n_e=B)
    opt_state = opt.init(params)
    step_fn = jax.jit(step_fn, donate_argnums=(0, 1))
    batch = {
        "tokens": jax.random.randint(key, (B, T + 1), 0, cfg.vocab_size),
        "rewards": jax.random.uniform(key, (B, T)),
        "dones": jnp.zeros((B, T), bool),
    }
    t0 = time.perf_counter()
    for i in range(args.iterations):
        params, opt_state, metrics = step_fn(params, opt_state, batch,
                                             jnp.asarray(i, jnp.int32))
    jax.block_until_ready(params)
    dt = time.perf_counter() - t0
    log.info(
        "synthetic: %d iters, %.1f tokens/s, loss=%.4f",
        args.iterations, args.iterations * B * T / dt, float(metrics["loss"]),
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ASSIGNED_ARCHS + ["paac_vector"],
                    default="mamba2-370m")
    ap.add_argument("--mode", choices=("rl", "synthetic"), default="rl")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--iterations", type=int, default=50)
    ap.add_argument("--epochs", type=int, default=1)
    ap.add_argument("--n-envs", type=int, default=16)
    ap.add_argument("--t-max", type=int, default=8)
    ap.add_argument("--ctx", type=int, default=32)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--checkpoint", default="")
    ap.add_argument("--pipeline", action="store_true",
                    help="use the asynchronous actor/learner pipeline backend")
    ap.add_argument("--queue-depth", type=int, default=2,
                    help="trajectory queue depth (max rollouts in flight)")
    ap.add_argument("--rho-bar", type=float, default=1.0,
                    help="importance-weight clip for stale rollouts (V-trace ρ̄)")
    ap.add_argument("--c-bar", type=float, default=1.0,
                    help="V-trace c̄: clip on the backward-propagation product")
    ap.add_argument("--num-actors", type=int, default=1,
                    help="actor replicas feeding the learner (env axis split)")
    ap.add_argument("--rollout-plane",
                    choices=("auto", "device", "host", "mesh"),
                    default="auto",
                    help="trajectory queue plane: device-resident ring "
                    "(JAX envs), host staging queue, mesh sub-rings "
                    "(multi-device), or auto by env type / --mesh")
    ap.add_argument("--mesh", type=int, default=1,
                    help="mesh rollout plane over this many devices: one "
                    "actor lane per device, env axis sharded, gradients "
                    "all-reduced over the mesh's data axis (CPU: set "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    ap.add_argument("--algo", choices=("paac", "dqn"), default="paac",
                    help="agent family: on-policy PAAC (V-trace under the "
                    "pipeline) or value-based DQN (scan-based sync, or the "
                    "replay-fed pipelined learner with --pipeline --replay)")
    ap.add_argument("--replay", action="store_true",
                    help="pipeline: swap the FIFO trajectory ring for the "
                    "sampled ReplayRing (off-policy plane; actors never "
                    "block — a full ring evicts its oldest rollout)")
    ap.add_argument("--replay-capacity", type=int, default=64,
                    help="ReplayRing capacity in resident rollouts "
                    "(each n_envs/num_actors × t_max transitions)")
    ap.add_argument("--replay-batch", type=int, default=1,
                    help="rollouts sampled per learner update")
    ap.add_argument("--prioritized", action="store_true",
                    help="TD-error-weighted replay sampling (else uniform)")
    ap.add_argument("--actor-backend", choices=("thread", "process"),
                    default="thread",
                    help="where actor replicas run: threads (GIL-free env "
                    "stepping) or worker subprocesses (GIL-holding Python "
                    "emulators; implies the host-env path)")
    ap.add_argument("--host-env", action="store_true",
                    help="drive the Python-bound emulator pool "
                    "(repro.envs.pyemu) instead of the JAX TokenEnv")
    ap.add_argument("--env-spin", type=int, default=2000,
                    help="pure-Python work per host-env step (GIL-holding "
                    "emulator cost model)")
    ap.add_argument("--trace", default="",
                    help="write a Chrome trace-event JSON of the run's spans "
                    "here (open in Perfetto); pipeline backend only")
    ap.add_argument("--metrics-jsonl", default="",
                    help="append a JSONL metrics heartbeat (steps/s EMA, "
                    "queue depth, staleness, per-actor liveness) here")
    ap.add_argument("--sanitize", default="",
                    help="arm runtime sanitizers (comma-separated: 'locks' "
                    "for the lock-order deadlock detector — the launch "
                    "fails on cycles/wait-while-holding hazards — and "
                    "'transfers' for jax transfer guards + donated-buffer "
                    "probes on the device planes); same effect as the "
                    "REPRO_SANITIZE env var. Pipeline backend only.")
    ap.add_argument("--stall-timeout", type=float, default=0.0,
                    help="stall watchdog window in seconds: when the learner "
                    "or an actor makes no progress for this long, log which "
                    "stage each party is blocked in (0 = off)")
    ap.add_argument("--elastic", action="store_true",
                    help="supervise actor replicas: respawn crashed actors "
                    "under --restart-budget, then degrade to fewer actors "
                    "(default is fail-fast; mesh plane is always fail-fast)")
    ap.add_argument("--restart-budget", type=int, default=1,
                    help="respawns allowed per actor slot before the "
                    "supervisor degrades the run (0 = degrade immediately)")
    ap.add_argument("--restart-backoff", type=float, default=0.05,
                    help="base respawn backoff in seconds (doubles per "
                    "attempt on the same slot)")
    ap.add_argument("--lease-timeout", type=float, default=60.0,
                    help="learner-side param-lease timeout: error naming the "
                    "holding party when a lease is never released")
    ap.add_argument("--fault-kill", action="append", default=[],
                    metavar="SLOT:AFTER[:MODE]",
                    help="deterministic fault injection: kill actor slot "
                    "SLOT after AFTER produced rollouts; MODE is 'error' "
                    "(raise in-replica, default) or 'exit' (hard process "
                    "exit, process backend). Repeatable.")
    ap.add_argument("--fault-stall-learner", action="append", default=[],
                    metavar="ITER:SECONDS",
                    help="deterministic fault injection: sleep SECONDS in "
                    "the learner loop before update ITER. Repeatable.")
    ap.add_argument("--checkpoint-dir", default="",
                    help="directory for the pipeline's full-state "
                    "checkpoints (params, opt state, RNG keys, per-actor "
                    "seq counters, queue tickets)")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="save a pipeline checkpoint every N learner "
                    "updates (0 = off; requires --checkpoint-dir)")
    ap.add_argument("--resume", action="store_true",
                    help="restore the newest checkpoint in --checkpoint-dir "
                    "and run only the remaining iterations (bitwise "
                    "continuation on the thread backend's FIFO planes)")
    args = ap.parse_args()
    if args.mode == "rl":
        run_rl(args)
    else:
        run_synthetic(args)


if __name__ == "__main__":
    main()
