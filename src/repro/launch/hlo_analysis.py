"""Trip-count-aware static analysis of optimized (SPMD-partitioned) HLO.

Why this exists: ``compiled.cost_analysis()`` counts a ``while`` body ONCE,
but our trunks are ``lax.scan``s over layers — so XLA's aggregate FLOPs/bytes
under-count 40–80 layer models by ~the layer count (verified empirically in
EXPERIMENTS.md §Dry-run notes). This module re-derives per-chip costs from
the HLO text with loop multipliers:

* builds the computation call graph (fusion ``calls=``, while ``body=`` /
  ``condition=``, ``to_apply=``),
* extracts while trip counts from the condition computation's s32 constant,
* FLOPs: every ``dot`` (2 · prod(result) · contraction), multiplied along
  the call chain,
* HBM bytes: operands + result of top-level compute instructions (fusions
  count as one unit — the roofline assumption that fused ops make one HBM
  round trip),
* collectives: wire bytes with ring factors × loop multipliers.

This is a static model, not a simulator; EXPERIMENTS.md records both these
corrected numbers and XLA's raw ones.
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "u1": 1, "pred": 1, "token": 0, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(
    r"(bf16|f64|f32|f16|f8e4m3fn|f8e5m2|s64|s32|s16|s8|u64|u32|u16|u8|u4|s4|pred)"
    r"\[([0-9,]*)\]"
)

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# ops that plausibly make an HBM round trip (fusions count once as a unit;
# bare elementwise ops are excluded — the TPU backend would fuse them)
_BYTE_OPS = {
    "fusion", "dot", "convolution", "reduce", "sort", "scatter", "gather",
    "dynamic-slice", "dynamic-update-slice", "copy", "transpose", "concatenate",
    "pad", "slice", "select-and-scatter", "reduce-window", "reverse",
    "cholesky", "triangular-solve",
}


def _shape_elems_bytes(type_str: str) -> Tuple[int, int]:
    elems = 0
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        total += n * _DTYPE_BYTES[dt]
    return elems, total


class Instruction:
    __slots__ = ("name", "type_str", "op", "operands", "attrs", "line")

    def __init__(self, name, type_str, op, operands, attrs, line):
        self.name = name
        self.type_str = type_str
        self.op = op
        self.operands = operands
        self.attrs = attrs
        self.line = line


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*((?:\([^)]*\)|[\w\[\],{}]+))\s+([\w\-]+)\((.*?)\)(.*)$"
)
_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)(?:\.clone)?\s*\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


class HloModule:
    def __init__(self, text: str):
        self.computations: Dict[str, List[Instruction]] = {}
        self.entry: Optional[str] = None
        self.shape_of: Dict[str, str] = {}
        self._parse(text)

    def _parse(self, text: str):
        cur = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if not line:
                continue
            if not line.startswith(" ") and line.rstrip().endswith("{"):
                m = _COMP_START_RE.match(line.strip())
                if m:
                    cur = m.group(1)
                    self.computations[cur] = []
                    if line.strip().startswith("ENTRY"):
                        self.entry = cur
                    continue
            if line.strip() == "}":
                continue
            if cur is None:
                continue
            m = _INSTR_RE.match(line)
            if not m:
                continue
            name, type_str, op, operand_str, attrs = m.groups()
            operands = _OPERAND_RE.findall(operand_str)
            instr = Instruction(name, type_str, op, operands, attrs, line)
            self.computations[cur].append(instr)
            self.shape_of[name] = type_str

    # -- trip counts ---------------------------------------------------------
    def trip_count(self, cond_comp: str) -> int:
        """Largest s32 constant in the condition computation (scan bound)."""
        best = 1
        for instr in self.computations.get(cond_comp, []):
            if instr.op == "constant" and instr.type_str.startswith("s32"):
                m = re.search(r"constant\((-?\d+)\)", instr.line)
                if m:
                    best = max(best, int(m.group(1)))
        return best

    # -- cost traversal ------------------------------------------------------
    def analyze(self) -> Dict[str, float]:
        flops = 0.0
        bytes_hbm = 0.0
        coll_wire = defaultdict(float)
        coll_counts = defaultdict(int)
        visited_stack = []

        def called_comps(instr) -> List[Tuple[str, float]]:
            out = []
            m = re.search(r"calls=%?([\w.\-]+)", instr.attrs)
            if m:
                out.append((m.group(1), 1.0))
            m = re.search(r"body=%?([\w.\-]+)", instr.attrs)
            if m:
                body = m.group(1)
                mc = re.search(r"condition=%?([\w.\-]+)", instr.attrs)
                trips = self.trip_count(mc.group(1)) if mc else 1
                out.append((body, float(trips)))
            m = re.search(r"to_apply=%?([\w.\-]+)", instr.attrs)
            if m:
                out.append((m.group(1), 1.0))
            for m in re.finditer(r"(?:true_computation|false_computation|branch_computations=\{)([^,}]+)", instr.attrs):
                for nm in re.findall(r"%?([\w.\-]+)", m.group(1)):
                    out.append((nm, 1.0))
            return out

        def dot_flops(instr) -> float:
            _, _ = 0, 0
            res_elems, _ = _shape_elems_bytes(instr.type_str)
            # contraction size from lhs shape and lhs_contracting_dims
            if not instr.operands:
                return 0.0
            lhs_shape = self.shape_of.get(instr.operands[0], "")
            dims_m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.attrs)
            mshape = _SHAPE_RE.search(lhs_shape)
            if not (dims_m and mshape):
                return 0.0
            dims = [int(d) for d in dims_m.group(1).split(",") if d]
            lhs_dims = [int(d) for d in mshape.group(2).split(",") if d]
            k = 1
            for d in dims:
                if d < len(lhs_dims):
                    k *= lhs_dims[d]
            return 2.0 * res_elems * k

        def conv_flops(instr) -> float:
            res_elems, _ = _shape_elems_bytes(instr.type_str)
            if len(instr.operands) < 2:
                return 0.0
            ker = self.shape_of.get(instr.operands[1], "")
            m = _SHAPE_RE.search(ker)
            if not m:
                return 0.0
            kdims = [int(d) for d in m.group(2).split(",") if d]
            if not kdims:
                return 0.0
            kelems = 1
            for d in kdims:
                kelems *= d
            # divide by output features (last dim of kernel in HWIO)
            return 2.0 * res_elems * (kelems / max(kdims[-1], 1))

        _PASSTHROUGH = {
            "parameter", "convert", "bitcast", "copy", "constant", "reshape",
            "transpose", "tuple", "get-tuple-element",
        }

        def fusion_projected_bytes(comp_name: str) -> Optional[float]:
            """TPU-projection for two XLA:CPU float-normalization artifacts:

            * (convert-wrapped) dynamic-update-slice fusions: count in-place
              semantics — 2 x update bytes (read-modify-write of the touched
              slice), not whole-buffer traffic. bf16 loop carries get f32
              convert pairs on CPU that break aliasing; a TPU build has none.
            * pure dtype-convert fusions (only converts/copies of bf16
              weights): count zero — the consumer's operand read is already
              counted at its own instruction.
            """
            comp_instrs = self.computations.get(comp_name, [])
            if not comp_instrs:
                return None
            dus = [i for i in comp_instrs if i.op == "dynamic-update-slice"]
            if dus:
                total = 0.0
                for d in dus:
                    if len(d.operands) < 2:
                        return None
                    _, ub = _shape_elems_bytes(self.shape_of.get(d.operands[1], ""))
                    total += 2.0 * ub
                return total if total > 0 else None
            rest = [i for i in comp_instrs if i.op not in _PASSTHROUGH]
            if not rest:
                return 0.0  # pure dtype/layout churn
            if all(i.op in ("dynamic-slice",) for i in rest):
                # fused slice-of-stacked-weights: reads the slice, not the
                # whole (L, ...) stack — count read+write of the slice only
                total = 0.0
                for d in rest:
                    _, rb = _shape_elems_bytes(d.type_str)
                    total += 2.0 * rb
                return total
            return None

        def walk(comp: str, mult: float, count_bytes: bool):
            if comp in visited_stack:  # recursion guard
                return
            visited_stack.append(comp)
            nonlocal flops, bytes_hbm
            for instr in self.computations.get(comp, []):
                op = instr.op
                if op == "dot":
                    flops += mult * dot_flops(instr)
                elif op == "convolution":
                    flops += mult * conv_flops(instr)
                base = None
                for c in _COLLECTIVES:
                    if op == c or op.startswith(c + "-start"):
                        base = c
                        break
                if base:
                    _, nb = _shape_elems_bytes(instr.type_str)
                    n = _group_size(instr.line)
                    coll_wire[base] += mult * nb * _wire_factor(base, n)
                    coll_counts[base] += int(mult)
                if count_bytes and op in _BYTE_OPS:
                    _, rb = _shape_elems_bytes(instr.type_str)
                    dus_b = None
                    if op == "fusion":
                        m = re.search(r"calls=%?([\w.\-]+)", instr.attrs)
                        if m:
                            dus_b = fusion_projected_bytes(m.group(1))
                    if dus_b is not None:
                        bytes_hbm += mult * dus_b
                    elif op in ("dynamic-slice", "slice", "gather"):
                        # reads only the sliced region, writes the result
                        bytes_hbm += mult * 2 * rb
                    elif op == "dynamic-update-slice" and len(instr.operands) >= 2:
                        _, ub = _shape_elems_bytes(
                            self.shape_of.get(instr.operands[1], "")
                        )
                        bytes_hbm += mult * 2 * ub
                    else:
                        ob = 0
                        for o in set(instr.operands):  # dedupe repeated reads
                            _, b = _shape_elems_bytes(self.shape_of.get(o, ""))
                            ob += b
                        bytes_hbm += mult * (rb + ob)
                for sub, m in called_comps(instr):
                    # fusions: traverse for dot flops but not byte accounting
                    sub_bytes = count_bytes and op in ("while", "conditional", "call")
                    walk(sub, mult * m, sub_bytes)
            visited_stack.pop()

        if self.entry:
            walk(self.entry, 1.0, True)
        out = {
            "flops": flops,
            "bytes": bytes_hbm,
            "collective_wire_bytes": float(sum(coll_wire.values())),
        }
        for k, v in coll_wire.items():
            out[f"wire_{k}"] = v
        out["collective_counts"] = dict(coll_counts)
        return out


_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return 1


def _wire_factor(op: str, n: int) -> float:
    if n <= 1:
        return 0.0
    if op == "all-reduce":
        return 2.0 * (n - 1) / n
    if op in ("all-gather", "reduce-scatter", "all-to-all"):
        return (n - 1) / n
    return 1.0


def analyze_hlo(text: str) -> Dict[str, float]:
    return HloModule(text).analyze()
