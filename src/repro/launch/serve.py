"""Serving launcher: lockstep batch demo, or continuous batching.

Two modes:

* **default (lockstep batch)** — the paper's master in isolation:
  batched prefill for ``--batch`` identical-length prompts, then a
  decode loop emitting one token per actor per step through
  ``serve_step``. Every actor starts and stops together.
* **``--continuous``** — the serving plane (``docs/serving.md``): an
  open-loop traffic source feeds a bounded admission queue; the
  ``Scheduler`` leases cache slots and requests join/leave the decode
  batch mid-flight. Reports aggregate tokens/s and p50/p99 request
  latency — the numbers ``benchmarks/serve_bench.py`` sweeps.

``--trace`` records phase spans (lockstep: ``prefill``/``decode``;
continuous: ``admit``/``prefill``/``decode``/``evict``) and writes a
Chrome trace-event JSON at exit. ``--metrics-jsonl`` streams the
heartbeat; in continuous mode it carries the ``serve_queue_depth`` and
``serve_active_slots`` gauges.

Examples:
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --reduced \
        --batch 8 --prompt-len 64 --gen 32
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --reduced \
        --continuous --requests 16 --slots 4 --rate 8 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.launch.steps import build_serve_step
from repro.models import init_policy
from repro.telemetry import Telemetry
from repro.utils import get_logger

log = get_logger("serve")

_PREFILL, _DECODE = 0, 1


def demo_streams(seed: int):
    """Split the demo's root key into its three independent streams.

    ``init_policy`` consumes its key in full; reusing the same key for
    the prompt draw (or the decode loop) would correlate weights with
    data. Split once at the top, hand each consumer its own stream, and
    never touch the root again.
    """
    root = jax.random.PRNGKey(seed)
    params_key, prompt_key, decode_key = jax.random.split(root, 3)
    return params_key, prompt_key, decode_key


def percentile_ms(xs, q: float) -> float:
    """Latency percentile in milliseconds (empty-safe for error-only runs)."""
    if not xs:
        return float("nan")
    return float(np.percentile(np.asarray(xs, np.float64), q) * 1e3)


def _run_lockstep_demo(args, cfg, params, hub, prompt_key, decode_key):
    em = hub.emitter("serve", categories=("prefill", "decode"))
    B, S = args.batch, args.prompt_len
    max_len = S + args.gen
    prompts = jax.random.randint(prompt_key, (B, S), 0, cfg.vocab_size)
    prefix = None
    if cfg.modality == "vision":
        prefix = jnp.ones((B, cfg.prefix_len, cfg.frontend_dim or cfg.d_model))
    if cfg.is_encoder_decoder:
        prefix = jnp.ones((B, cfg.encoder_seq_len,
                           cfg.frontend_dim or cfg.d_model))

    # prefill: cache sized for generation headroom
    t0 = time.perf_counter()
    from repro.models import policy_prefill

    em.begin(_PREFILL)
    try:
        logits, values, cache = jax.jit(
            lambda p, t: policy_prefill(p, cfg, t, prefix, max_len=max_len)
        )(params, prompts)
        jax.block_until_ready(logits)
    finally:
        em.end()
    t_prefill = time.perf_counter() - t0
    log.info("prefill %.3fs (%.0f tok/s)", t_prefill, B * S / t_prefill)

    serve_step = jax.jit(build_serve_step(cfg), donate_argnums=(1,))
    token = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    toks = [token]
    key = decode_key
    t0 = time.perf_counter()
    for i in range(args.gen):
        key, sub = jax.random.split(key)
        em.begin(_DECODE)
        try:
            token, value, cache = serve_step(
                params, cache, token, jnp.asarray(S + i, jnp.int32),
                jax.random.key_data(sub),
            )
        finally:
            em.end()
        toks.append(token)
    jax.block_until_ready(token)
    dt = time.perf_counter() - t0
    out = jnp.concatenate(toks, axis=1)
    log.info("decode %d tokens x %d actors: %.3fs (%.0f tok/s)",
             args.gen, B, dt, args.gen * B / dt)
    log.info("sample actor 0 tokens: %s", out[0, :16].tolist())


def _run_continuous(args, cfg, params, hub):
    from repro.pipeline.queue import TrajectoryQueue
    from repro.serving import DecodeEngine, OpenLoopTraffic, Scheduler

    max_len = args.prompt_len + args.gen
    engine = DecodeEngine(cfg, params, max_slots=args.slots, max_len=max_len)
    queue = TrajectoryQueue(depth=max(2, 2 * args.slots), telemetry=hub)
    sched = Scheduler(engine, queue, continuous=True, telemetry=hub)
    lo = max(1, args.prompt_len // 2)
    traffic = OpenLoopTraffic(
        queue, args.requests, seed=args.seed, rate_hz=args.rate,
        prompt_lens=(lo, args.prompt_len),
        gen_range=(max(1, args.gen // 2), args.gen), vocab=cfg.vocab_size)

    t0 = time.perf_counter()
    traffic.start()
    done = sched.run()
    traffic.join()
    wall = time.perf_counter() - t0

    ok = [r for r in done if r.status == "done"]
    lat = [r.latency_s for r in ok]
    total = sum(r.n_generated for r in ok)
    log.info("continuous: %d/%d requests done, %d tokens in %.3fs "
             "(%.1f tok/s aggregate, %d decode steps)",
             len(ok), len(done), total, wall, total / wall, sched.steps)
    log.info("latency p50 %.1f ms  p99 %.1f ms",
             percentile_ms(lat, 50), percentile_ms(lat, 99))
    for r in done:
        if r.status != "done":
            log.warning("request %d %s: %s", r.rid, r.status, r.error)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ASSIGNED_ARCHS, default="qwen2-7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--continuous", action="store_true",
                    help="continuous-batching service loop instead of the "
                    "lockstep batch demo")
    ap.add_argument("--requests", type=int, default=16,
                    help="[--continuous] total requests the traffic source "
                    "emits")
    ap.add_argument("--slots", type=int, default=4,
                    help="[--continuous] decode-batch width / cache slots")
    ap.add_argument("--rate", type=float, default=0.0,
                    help="[--continuous] open-loop arrival rate in Hz "
                    "(0 = burst)")
    ap.add_argument("--trace", default="",
                    help="write a Chrome trace-event JSON of serving spans "
                    "here (open in Perfetto)")
    ap.add_argument("--metrics-jsonl", default="",
                    help="append a JSONL metrics heartbeat here")
    args = ap.parse_args(argv)

    hub = Telemetry()
    if args.metrics_jsonl:
        hub.heartbeat_start(args.metrics_jsonl, interval=0.25)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params_key, prompt_key, decode_key = demo_streams(args.seed)
    params = init_policy(params_key, cfg)

    try:
        if args.continuous:
            _run_continuous(args, cfg, params, hub)
        else:
            _run_lockstep_demo(args, cfg, params, hub, prompt_key, decode_key)
    finally:
        hub.heartbeat_stop()
        if args.trace:
            hub.write_trace(args.trace)


if __name__ == "__main__":
    main()
