"""Serving launcher: batched prefill + decode loop.

This is the paper's master in isolation — batched action selection for all
actors — i.e. modern batched LLM inference. Prefill builds the KV/state
cache for a batch of prompts; the decode loop then emits one token per
actor per step through ``serve_step``.

``--trace`` records each phase as telemetry spans — one ``prefill`` span,
one ``decode`` span per generated token — and writes a Chrome trace-event
JSON at exit (same format as the pipeline's ``--trace``; ``SpanEmitter``
takes a custom category table, so the serving vocabulary rides the same
machinery).

Example:
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --reduced \
        --batch 8 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.launch.steps import build_prefill_step, build_serve_step
from repro.models import init_policy, init_policy_cache
from repro.telemetry import Telemetry
from repro.utils import get_logger

log = get_logger("serve")

_PREFILL, _DECODE = 0, 1


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ASSIGNED_ARCHS, default="qwen2-7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default="",
                    help="write a Chrome trace-event JSON of prefill/decode "
                    "spans here (open in Perfetto)")
    args = ap.parse_args()

    hub = Telemetry()
    em = hub.emitter("serve", categories=("prefill", "decode"))

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(args.seed)
    params = init_policy(key, cfg)

    B, S = args.batch, args.prompt_len
    max_len = S + args.gen
    prompts = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    prefix = None
    if cfg.modality == "vision":
        prefix = jnp.ones((B, cfg.prefix_len, cfg.frontend_dim or cfg.d_model))
    if cfg.is_encoder_decoder:
        prefix = jnp.ones((B, cfg.encoder_seq_len, cfg.frontend_dim or cfg.d_model))

    # prefill: cache sized for generation headroom
    t0 = time.perf_counter()
    from repro.models import policy_prefill

    em.begin(_PREFILL)
    try:
        logits, values, cache = jax.jit(
            lambda p, t: policy_prefill(p, cfg, t, prefix, max_len=max_len)
        )(params, prompts)
        jax.block_until_ready(logits)
    finally:
        em.end()
    t_prefill = time.perf_counter() - t0
    log.info("prefill %.3fs (%.0f tok/s)", t_prefill, B * S / t_prefill)

    serve_step = jax.jit(build_serve_step(cfg), donate_argnums=(1,))
    token = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    toks = [token]
    t0 = time.perf_counter()
    for i in range(args.gen):
        key, sub = jax.random.split(key)
        em.begin(_DECODE)
        try:
            token, value, cache = serve_step(
                params, cache, token, jnp.asarray(S + i, jnp.int32),
                jax.random.key_data(sub),
            )
        finally:
            em.end()
        toks.append(token)
    jax.block_until_ready(token)
    dt = time.perf_counter() - t0
    out = jnp.concatenate(toks, axis=1)
    log.info("decode %d tokens x %d actors: %.3fs (%.0f tok/s)",
             args.gen, B, dt, args.gen * B / dt)
    log.info("sample actor 0 tokens: %s", out[0, :16].tolist())
    if args.trace:
        hub.write_trace(args.trace)


if __name__ == "__main__":
    main()
