"""Step builders shared by the dry-run, the trainer and the server.

* ``build_train_step(cfg)``   — PAAC synchronous update (Algorithm 1 16-18)
  over a trajectory batch; the lowered unit for ``train_4k``.
* ``build_prefill_step(cfg)`` — batched full-context policy evaluation;
  lowered for ``prefill_32k``.
* ``build_serve_step(cfg)``   — the master's batched action selection
  (paper §3): ONE token per actor against the cache; lowered for
  ``decode_32k`` / ``long_500k``.
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp

from repro.core.agents.paac import PAACAgent, PAACConfig
from repro.models import (
    init_policy,
    init_policy_cache,
    policy_apply,
    policy_decode,
    policy_prefill,
)
from repro.optim import make_optimizer, paac_scaled_lr


def build_train_step(cfg, *, optimizer: str = "rmsprop", n_e: int = 256):
    """Returns (train_step(params, opt_state, batch, step), optimizer)."""
    agent = PAACAgent(cfg, PAACConfig())
    opt = make_optimizer(optimizer)
    step = agent.make_llm_train_step(opt, paac_scaled_lr(n_e))
    return step, opt


def build_prefill_step(cfg):
    def prefill_step(params, batch):
        tokens = batch["tokens"]
        prefix = batch.get("prefix", batch.get("frames"))
        logits, values, cache = policy_prefill(params, cfg, tokens, prefix)
        return logits[:, -1], values[:, -1], cache

    return prefill_step


def build_serve_step(cfg):
    def serve_step(params, cache, token, pos, key_data):
        """One master step: sample π for every actor (batched decode)."""
        key = jax.random.wrap_key_data(key_data)
        logits, value, cache = policy_decode(params, cfg, cache, token, pos)
        action = jax.random.categorical(key, logits)
        return action[:, None].astype(jnp.int32), value, cache

    return serve_step
