"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) pair.

Proves the distribution config is coherent without hardware: 512 host
placeholder devices stand in for 2 TPU v5e pods; ``.lower().compile()``
must succeed and yields ``memory_analysis()`` / ``cost_analysis()`` plus the
optimized HLO that the roofline analysis (EXPERIMENTS.md §Roofline) reads.

Usage:
    python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
    python -m repro.launch.dryrun --all            # every assigned pair
    python -m repro.launch.dryrun --all --multi-pod
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from functools import partial  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, get_config  # noqa: E402
from repro.data.specs import input_specs  # noqa: E402
from repro.distributed.constraints import axis_context  # noqa: E402
from repro.distributed.sharding import (  # noqa: E402
    cache_specs,
    input_sharding,
    param_specs,
    to_named,
)
from repro.launch.analysis import model_flops, roofline_terms  # noqa: E402
from repro.launch.hlo_analysis import analyze_hlo  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import (  # noqa: E402
    build_prefill_step,
    build_serve_step,
    build_train_step,
)
from repro.models import init_policy, init_policy_cache  # noqa: E402

SWA_WINDOW = 8192  # sliding-window variant used for long_500k on attn archs


def adjust_cfg(cfg, shape_name: str):
    """Per-shape config adjustments (documented in DESIGN.md §4)."""
    if shape_name == "long_500k":
        if not cfg.supports_long_context:
            return None  # skipped (seamless enc-dec; DESIGN.md §4)
        if cfg.family not in ("ssm",):
            cfg = cfg.replace(sliding_window=SWA_WINDOW)
    return cfg


def skip_reason(cfg, shape_name: str):
    if shape_name == "long_500k" and not cfg.supports_long_context:
        return "enc-dec speech model: no 500k autoregressive decode (DESIGN.md §4)"
    return None


def _sds_tree(fn, *args):
    return jax.eval_shape(fn, *args)


def lower_pair(arch: str, shape_name: str, *, multi_pod: bool = False,
               sharding_mode: str = "fsdp_tp", mla_absorb: bool = False,
               donate: bool = True, save_hlo: str = "", cfg_overrides=None):
    """Lower + compile one pair. Returns a report dict (or skip record)."""
    cfg0 = get_config(arch)
    reason = skip_reason(cfg0, shape_name)
    if reason:
        return {"arch": arch, "shape": shape_name, "skipped": reason}
    cfg = adjust_cfg(cfg0, shape_name)
    if mla_absorb and cfg.attention == "mla":
        cfg = cfg.replace(mla_absorb=True)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    shp = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.time()

    with axis_context(mesh):
        params_sds = _sds_tree(lambda: init_policy(jax.random.PRNGKey(0), cfg))
        p_shard = to_named(param_specs(params_sds, mesh, sharding_mode), mesh)
        batch_sds = input_specs(cfg, shape_name)
        b_shard = to_named(input_sharding(batch_sds, mesh), mesh)
        repl = NamedSharding(mesh, P())

        if shp.kind == "train":
            step_fn, opt = build_train_step(cfg, n_e=shp.global_batch)
            opt_sds = _sds_tree(opt.init, params_sds)
            # zero1: params replicated over data ("tp" specs) but optimizer
            # state sharded over data ("fsdp_tp" specs) — ZeRO-1
            opt_mode = "fsdp_tp" if sharding_mode == "zero1" else sharding_mode
            o_shard = to_named(param_specs(opt_sds, mesh, opt_mode), mesh)
            jitted = jax.jit(
                step_fn,
                in_shardings=(p_shard, o_shard, b_shard, repl),
                out_shardings=(p_shard, o_shard, None),
                donate_argnums=(0, 1) if donate else (),
            )
            lowered = jitted.lower(
                params_sds, opt_sds, batch_sds, jax.ShapeDtypeStruct((), jnp.int32)
            )
            tokens = shp.global_batch * (shp.seq_len - cfg.prefix_len)
        elif shp.kind == "prefill":
            step_fn = build_prefill_step(cfg)
            jitted = jax.jit(step_fn, in_shardings=(p_shard, b_shard))
            lowered = jitted.lower(params_sds, batch_sds)
            tokens = shp.global_batch * (shp.seq_len - cfg.prefix_len)
        else:  # decode
            step_fn = build_serve_step(cfg)
            cache_sds = _sds_tree(
                lambda: init_policy_cache(cfg, shp.global_batch, shp.seq_len)
            )
            c_shard = to_named(cache_specs(cache_sds, mesh), mesh)
            key_sds = _sds_tree(lambda: jax.random.key_data(jax.random.PRNGKey(0)))
            jitted = jax.jit(
                step_fn,
                in_shardings=(p_shard, c_shard, b_shard["token"], repl, repl),
                out_shardings=(None, None, c_shard),
                donate_argnums=(1,) if donate else (),
            )
            lowered = jitted.lower(
                params_sds, cache_sds, batch_sds["token"],
                jax.ShapeDtypeStruct((), jnp.int32), key_sds,
            )
            tokens = shp.global_batch  # one new token per actor

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    cost = compiled.cost_analysis() or {}
    try:
        mem = compiled.memory_analysis()
        mem_report = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        }
    except Exception as e:  # CPU backend may not implement it
        mem_report = {"error": str(e)}

    hlo = compiled.as_text()
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)
    # trip-count-aware static analysis (XLA cost_analysis counts while
    # bodies once — see hlo_analysis.py docstring); raw numbers kept below
    static = analyze_hlo(hlo)
    flops = static["flops"]
    byac = static["bytes"]
    coll = {
        k.replace("wire_", ""): v for k, v in static.items() if k.startswith("wire_")
    }
    coll["total_wire_bytes"] = static["collective_wire_bytes"]
    terms = roofline_terms(flops, byac, coll["total_wire_bytes"])
    mf6 = model_flops(cfg, params_sds, tokens)  # 6·N_active·tokens
    # 6ND counts fwd+bwd (train); inference is fwd-only -> 2ND
    useful = mf6 if shp.kind == "train" else mf6 / 3.0

    report = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "sharding_mode": sharding_mode,
        "mla_absorb": bool(mla_absorb and cfg0.attention == "mla"),
        "kind": shp.kind,
        "tokens_per_step": tokens,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "flops_per_chip": flops,
        "bytes_per_chip": byac,
        "collectives": coll,
        "collective_counts": static.get("collective_counts", {}),
        "raw_cost_analysis": {
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
            "note": "XLA counts while bodies once; see hlo_analysis.py",
        },
        "memory_analysis": mem_report,
        "roofline": terms,
        "model_flops_global": useful,
        "useful_flops_ratio": (useful / (flops * chips)) if flops else None,
    }
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ASSIGNED_ARCHS + ["all"], default="all")
    ap.add_argument("--shape", choices=list(INPUT_SHAPES) + ["all"], default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--sharding-mode", default="fsdp_tp", choices=("tp", "fsdp_tp"))
    ap.add_argument("--mla-absorb", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()

    archs = ASSIGNED_ARCHS if (args.arch == "all" or args.all) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.shape == "all" or args.all) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}_{shape}_{'2x16x16' if mp else '16x16'}"
                if args.mla_absorb:
                    tag += "_absorb"
                try:
                    rep = lower_pair(
                        arch, shape, multi_pod=mp,
                        sharding_mode=args.sharding_mode,
                        mla_absorb=args.mla_absorb,
                    )
                except Exception:
                    failures += 1
                    rep = {
                        "arch": arch, "shape": shape,
                        "mesh": "2x16x16" if mp else "16x16",
                        "error": traceback.format_exc(limit=20),
                    }
                with open(os.path.join(args.out, tag + ".json"), "w") as f:
                    json.dump(rep, f, indent=2, default=str)
                if "error" in rep:
                    print(f"FAIL {tag}")
                    print(rep["error"].splitlines()[-1])
                elif "skipped" in rep:
                    print(f"SKIP {tag}: {rep['skipped']}")
                else:
                    r = rep["roofline"]
                    print(
                        f"OK   {tag}: compile={rep['compile_s']}s "
                        f"flops/chip={rep['flops_per_chip']:.3e} "
                        f"bytes/chip={rep['bytes_per_chip']:.3e} "
                        f"wire={rep['collectives']['total_wire_bytes']:.3e} "
                        f"bottleneck={r['bottleneck']}"
                    )
    if failures:
        raise SystemExit(f"{failures} pair(s) failed")


if __name__ == "__main__":
    main()
