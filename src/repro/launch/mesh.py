"""Production mesh construction.

Target: TPU v5e, 256 chips per pod. Single pod: (data=16, model=16).
Multi-pod: (pod=2, data=16, model=16) = 512 chips — the "pod" axis extends
data parallelism across the ICI/DCN boundary (PAAC's synchronous gradient
all-reduce spans it; see DESIGN.md §5).

Defined as a FUNCTION so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU smoke paths (constraints become no-ops)."""
    return jax.make_mesh((1, 1), ("data", "model"))


# Hardware constants for the roofline (TPU v5e)
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link
