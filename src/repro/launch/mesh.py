"""Production mesh construction.

Target: TPU v5e, 256 chips per pod. Single pod: (data=16, model=16).
Multi-pod: (pod=2, data=16, model=16) = 512 chips — the "pod" axis extends
data parallelism across the ICI/DCN boundary (PAAC's synchronous gradient
all-reduce spans it; see DESIGN.md §5).

Defined as a FUNCTION so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU smoke paths (constraints become no-ops)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_rollout_mesh(n_devices: int = 0):
    """1-axis ``("data",)`` mesh for the pipeline's mesh rollout plane.

    The RL pipeline (``repro.pipeline``) is pure data parallelism: the env
    axis of every rollout shards over ``"data"`` and the learner's gradients
    all-reduce across it, so its mesh has no ``"model"`` axis (the policy
    networks are small; contrast the production inference mesh above).
    ``n_devices=0`` takes every visible device; CI exercises multi-device
    shapes on CPU via ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
    (set *before* the first jax import — device count is fixed at init).
    """
    devices = jax.devices()
    n = n_devices or len(devices)
    if n > len(devices):
        raise ValueError(
            f"mesh_shape={n} but only {len(devices)} device(s) visible — on "
            "CPU, export XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{n} before the first jax import"
        )
    return jax.make_mesh((n,), ("data",), devices=devices[:n])


# Hardware constants for the roofline (TPU v5e)
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link
