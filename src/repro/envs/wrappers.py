"""Environment wrappers (paper §5.1 pipeline pieces)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.envs.base import VectorEnv


class FrameStack(VectorEnv):
    """Stack the last ``n`` observations along a trailing channel axis.

    Converts (n_e, H, W) frames into (n_e, H, W, n) — the input format of the
    paper's CNNs (84×84×4).
    """

    def __init__(self, env: VectorEnv, n: int = 4):
        super().__init__(env.n_envs)
        self.env = env
        self.n = n
        self.obs_shape = tuple(env.obs_shape) + (n,)
        self.num_actions = env.num_actions

    def reset(self, key):
        inner = self.env.reset(key)
        frame = self.env.observe(inner)
        stack = jnp.repeat(frame[..., None], self.n, axis=-1)
        return {"inner": inner, "stack": stack}

    def observe(self, state):
        return state["stack"]

    def step(self, state, actions, key):
        inner, obs, reward, done = self.env.step(state["inner"], actions, key)
        stack = jnp.concatenate([state["stack"][..., 1:], obs[..., None]], axis=-1)
        # reset stack for finished episodes (avoid cross-episode leakage)
        fresh = jnp.repeat(obs[..., None], self.n, axis=-1)
        mask = done.reshape((-1,) + (1,) * (stack.ndim - 1))
        stack = jnp.where(mask, fresh, stack)
        return {"inner": inner, "stack": stack}, stack, reward, done

    # single-instance hooks unused (we override the vector API)
    def _reset_one(self, key):
        raise NotImplementedError

    def _observe_one(self, state):
        raise NotImplementedError

    def _step_one(self, state, action, key):
        raise NotImplementedError
