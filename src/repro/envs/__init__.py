from repro.envs.base import VectorEnv
from repro.envs.atari_like import AtariLike
from repro.envs.cartpole import CartPole
from repro.envs.catch import Catch
from repro.envs.gridworld import GridWorld
from repro.envs.base import narrow_vector_env
from repro.envs.host_env import HostEnvPool, HostEnvShard, HostEnvSpec
from repro.envs.pyemu import PyBoundEnv, py_bound_spec
from repro.envs.token_env import TokenEnv
from repro.envs.wrappers import FrameStack

__all__ = [
    "VectorEnv",
    "AtariLike",
    "CartPole",
    "Catch",
    "GridWorld",
    "HostEnvPool",
    "HostEnvShard",
    "HostEnvSpec",
    "PyBoundEnv",
    "narrow_vector_env",
    "py_bound_spec",
    "TokenEnv",
    "FrameStack",
]
