"""Host-side environment worker pool — the paper's n_w workers, literally.

The JAX-native environments in this package fuse stepping into the XLA
program (DESIGN.md §2), which is faster but only possible for environments
expressible in JAX. For *external* environments (a C++ emulator like ALE, a
network simulator, a real system), this module reproduces the paper's §3
architecture exactly: ``n_e`` environment instances are partitioned among
``n_w`` Python worker threads; the master hands each worker its slice of
the batched action vector; workers step their environments in parallel and
write observations/rewards into shared pinned buffers.

This path is host-bound by construction — the paper's Fig. 2 "50% env
time" regime. ``ParallelRL`` drives it synchronously (host rollout, then
jitted update) and the asynchronous pipeline (``repro.pipeline``) overlaps
the env stall with learning; ``benchmarks/fig2_time_split.py``'s
``run_pipelined_host`` measures the recovered throughput. Workers release
the GIL while stepping external processes, which is exactly what makes the
overlap real. ``HostEnvPool.shard`` splits the env axis into per-actor
views for the multi-actor pipeline.

**Picklable env-spec contract** (the multi-process actor plane): a live
``HostEnvPool`` holds running env instances and a thread executor — neither
crosses a process boundary. ``HostEnvSpec`` is the picklable *recipe* for a
pool: a module-level constructor ``env_fn`` plus one positional-args tuple
per env instance, and the pool kwargs (``n_workers``/``obs_shape``/
``obs_dtype``). The parent validates picklability loudly before spawning
(``validate_picklable``), ships the spec to each worker subprocess, and the
child rebuilds its private pool with ``spec.build()``. ``spec.shard(n)``
splits the env axis *as specs* — each child owns a full, independent pool
over its slice, so there is no cross-process executor to share (unlike
thread-plane ``HostEnvPool.shard``, whose shards borrow the parent's
workers). Closures and lambdas are rejected: pickle serializes functions by
module-qualified reference, so ``env_fn`` must be importable in a freshly
spawned interpreter.
"""
from __future__ import annotations

import concurrent.futures as cf
import pickle
from dataclasses import dataclass, replace as dataclass_replace
from typing import Any, Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["HostEnvPool", "HostEnvShard", "HostEnvSpec"]


class _EnvStepper:
    """Shared master/worker stepping over ``self.envs`` (paper §3 loop).

    Subclasses provide ``envs``, the output buffers ``_obs``/``_reward``/
    ``_done`` (leading axis ``n_envs``), the worker partition ``_slices``
    (index arrays into ``envs``), ``_executor()``, and a ``_closed`` flag
    (``HostEnvShard`` mirrors its parent's, so closing a pool closes every
    shard view of it at once).
    """

    envs: List
    n_envs: int
    _closed: bool

    def _executor(self) -> cf.ThreadPoolExecutor:
        raise NotImplementedError

    def _check_open(self, op: str) -> None:
        """Loud guard: stepping a closed pool otherwise dies *inside* the
        executor with an opaque ``cannot schedule new futures after
        shutdown`` — indistinguishable from an env crash. Teardown races
        (actor still draining while the pool closes under it — the
        multi-process shutdown path in particular) should fail with a
        message that names the real cause."""
        if self._closed:
            raise RuntimeError(
                f"{type(self).__name__}.{op}() on a closed env pool — the "
                "pool (or its parent) was close()d while this stepper was "
                "still in use; stop actors before closing their envs"
            )

    @property
    def obs_dtype(self):
        """Dtype of the observation buffers (what staging rings preallocate)."""
        return self._obs.dtype

    def _submit_slices(self, fn, *args) -> None:
        futures = [self._executor().submit(fn, idxs, *args)
                   for idxs in self._slices]
        for f in futures:
            f.result()

    def _reset_slice(self, idxs: np.ndarray):
        for i in idxs:
            self._obs[i] = self.envs[i].reset()

    def reset(self) -> jnp.ndarray:
        """Reset all envs, partitioned over the worker pool like ``step``."""
        self._check_open("reset")
        self._submit_slices(self._reset_slice)
        # jnp.array (never asarray) IS the staging copy: one synchronous
        # transfer into a private device buffer the workers can't touch.
        # A host-side bounce buffer here would only add a second memcpy —
        # per-rollout staging reuse lives in the pipeline's HostStagingRing,
        # where rows are written in place instead of stacked per collect.
        return jnp.array(self._obs)

    def _work(self, idxs: np.ndarray, actions: np.ndarray):
        for i in idxs:
            obs, r, done, _ = self.envs[i].step(int(actions[i]))
            if done:  # paper §5.1: restart on terminal
                obs = self.envs[i].reset()
            self._obs[i] = obs
            self._reward[i] = r
            self._done[i] = done

    def step_host(self, actions) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Apply the master's batched actions; workers run in parallel.

        Returns views of the shared host buffers (valid until the next call)
        — the zero-device-op path used by the pipeline's actor threads,
        which copy rows straight into their own trajectory staging sets.
        """
        self._check_open("step_host")
        self._submit_slices(self._work, np.asarray(actions))
        return self._obs, self._reward, self._done

    def step(self, actions) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        """``step_host`` with outputs staged onto the device (snapshots —
        never aliases of the mutable shared buffers)."""
        obs, reward, done = self.step_host(actions)
        return jnp.array(obs), jnp.array(reward), jnp.array(done)


class HostEnvPool(_EnvStepper):
    """Paper §3: n_e external env instances stepped by n_w workers.

    env_fns: callables creating gym-style envs with reset() -> obs and
    step(action) -> (obs, reward, done, info).
    """

    def __init__(self, env_fns: Sequence[Callable], n_workers: int = 8,
                 obs_shape: Tuple[int, ...] = (), obs_dtype=np.float32):
        self.envs = [fn() for fn in env_fns]
        self.n_envs = len(self.envs)
        self.n_workers = min(n_workers, self.n_envs)
        self.obs_shape = tuple(obs_shape)
        # shared output buffers (the paper's shared memory between master
        # and workers)
        self._obs = np.zeros((self.n_envs,) + self.obs_shape, obs_dtype)
        self._reward = np.zeros((self.n_envs,), np.float32)
        self._done = np.zeros((self.n_envs,), bool)
        self._pool = cf.ThreadPoolExecutor(max_workers=self.n_workers)
        self._slices = np.array_split(np.arange(self.n_envs), self.n_workers)
        self._closed = False

    def _executor(self) -> cf.ThreadPoolExecutor:
        return self._pool

    def shard(self, n: int) -> List["HostEnvShard"]:
        """Split the env axis into ``n`` equal per-actor shards.

        Each shard steps only its slice of the envs, with its own output
        buffers, on the *parent's* worker pool — total host concurrency stays
        bounded by ``n_workers`` no matter how many actors drive shards
        concurrently. The parent still owns the envs and the executor:
        close the parent, not the shards.
        """
        if self._closed:
            raise RuntimeError("shard() on a closed HostEnvPool")
        if n < 1 or self.n_envs % n:
            raise ValueError(
                f"cannot shard {self.n_envs} envs into {n} equal actor pools"
            )
        size = self.n_envs // n
        return [HostEnvShard(self, i * size, (i + 1) * size) for i in range(n)]

    def close(self):
        """Shut the worker pool down and close all envs. Idempotent."""
        if self._closed:
            return
        self._closed = True
        self._pool.shutdown(wait=True)
        for env in self.envs:
            if hasattr(env, "close"):
                env.close()

    def __enter__(self) -> "HostEnvPool":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()


class HostEnvShard(_EnvStepper):
    """A per-actor slice [lo, hi) of a parent ``HostEnvPool``'s env axis.

    Same stepping API as the parent (``reset`` / ``step_host`` / ``step``)
    over ``(hi - lo)`` envs, sharing the parent's worker executor so that N
    shards stepped from N actor threads still respect the pool's ``n_w``
    worker bound (the paper's §3 resource model, divided among replicas).
    """

    def __init__(self, parent: HostEnvPool, lo: int, hi: int):
        self._parent = parent
        self.envs = parent.envs[lo:hi]
        self.n_envs = hi - lo
        self.obs_shape = parent.obs_shape
        self._obs = np.zeros((self.n_envs,) + self.obs_shape,
                             parent._obs.dtype)
        self._reward = np.zeros((self.n_envs,), np.float32)
        self._done = np.zeros((self.n_envs,), bool)
        # proportional share of the parent's workers (at least one)
        n_w = max(1, (parent.n_workers * self.n_envs) // parent.n_envs)
        self._slices = np.array_split(np.arange(self.n_envs),
                                      min(n_w, self.n_envs))

    @property
    def _closed(self) -> bool:
        # the parent owns envs + executor, so its close() closes every shard
        return self._parent._closed

    def _executor(self) -> cf.ThreadPoolExecutor:
        return self._parent._pool


# ---------------------------------------------------------------------------
# Picklable pool recipe — the multi-process actor plane's env contract
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class HostEnvSpec:
    """Picklable recipe for a ``HostEnvPool`` (module docstring contract).

    ``env_fn`` is a module-level callable; env instance ``i`` is built as
    ``env_fn(*env_args[i])``. ``build()`` constructs the live pool (in
    whichever process calls it), ``shard(n)`` splits the env axis into ``n``
    equal per-actor specs, and ``validate_picklable()`` fails fast — with
    the offending payload named — before a spawn ships the spec to a child
    that would die trying to unpickle it.
    """

    env_fn: Callable
    env_args: Tuple[Tuple[Any, ...], ...]
    n_workers: int = 8
    obs_shape: Tuple[int, ...] = ()
    obs_dtype: Any = np.float32

    @property
    def n_envs(self) -> int:
        return len(self.env_args)

    def build(self) -> HostEnvPool:
        return HostEnvPool(
            [lambda a=args: self.env_fn(*a) for args in self.env_args],
            n_workers=self.n_workers,
            obs_shape=self.obs_shape,
            obs_dtype=self.obs_dtype,
        )

    def shard(self, n: int) -> List["HostEnvSpec"]:
        """Split the env axis into ``n`` equal per-actor specs.

        Unlike ``HostEnvPool.shard`` (views on one live pool sharing its
        executor), each spec builds a fully independent pool — the worker
        subprocess that receives it owns envs, buffers and executor alike.
        Worker threads are divided proportionally so ``n`` children keep the
        parent spec's total host concurrency budget."""
        if n < 1 or self.n_envs % n:
            raise ValueError(
                f"cannot shard {self.n_envs} envs into {n} equal actor pools"
            )
        size = self.n_envs // n
        n_w = max(1, self.n_workers // n)
        return [
            dataclass_replace(
                self, env_args=self.env_args[i * size:(i + 1) * size],
                n_workers=n_w,
            )
            for i in range(n)
        ]

    def validate_picklable(self) -> None:
        try:
            pickle.dumps(self)
        except Exception as e:
            raise ValueError(
                "HostEnvSpec must pickle (the process actor plane ships it "
                "to spawned workers): use a module-level env_fn and plain "
                f"env_args, not closures/lambdas — pickling failed with: {e!r}"
            ) from e
