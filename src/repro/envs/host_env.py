"""Host-side environment worker pool — the paper's n_w workers, literally.

The JAX-native environments in this package fuse stepping into the XLA
program (DESIGN.md §2), which is faster but only possible for environments
expressible in JAX. For *external* environments (a C++ emulator like ALE, a
network simulator, a real system), this module reproduces the paper's §3
architecture exactly: ``n_e`` environment instances are partitioned among
``n_w`` Python worker threads; the master hands each worker its slice of
the batched action vector; workers step their environments in parallel and
write observations/rewards into shared pinned buffers.

This path is host-bound by construction — the paper's Fig. 2 "50% env
time" regime. ``ParallelRL`` drives it synchronously (host rollout, then
jitted update) and the asynchronous pipeline (``repro.pipeline``) overlaps
the env stall with learning; ``benchmarks/fig2_time_split.py``'s
``run_pipelined_host`` measures the recovered throughput. Workers release
the GIL while stepping external processes, which is exactly what makes the
overlap real.
"""
from __future__ import annotations

import concurrent.futures as cf
from typing import Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class HostEnvPool:
    """Paper §3: n_e external env instances stepped by n_w workers.

    env_fns: callables creating gym-style envs with reset() -> obs and
    step(action) -> (obs, reward, done, info).
    """

    def __init__(self, env_fns: Sequence[Callable], n_workers: int = 8,
                 obs_shape: Tuple[int, ...] = (), obs_dtype=np.float32):
        self.envs = [fn() for fn in env_fns]
        self.n_envs = len(self.envs)
        self.n_workers = min(n_workers, self.n_envs)
        self.obs_shape = tuple(obs_shape)
        # shared output buffers (the paper's shared memory between master
        # and workers)
        self._obs = np.zeros((self.n_envs,) + self.obs_shape, obs_dtype)
        self._reward = np.zeros((self.n_envs,), np.float32)
        self._done = np.zeros((self.n_envs,), bool)
        self._pool = cf.ThreadPoolExecutor(max_workers=self.n_workers)
        self._slices = np.array_split(np.arange(self.n_envs), self.n_workers)
        self._closed = False

    def _reset_slice(self, idxs: np.ndarray):
        for i in idxs:
            self._obs[i] = self.envs[i].reset()

    def reset(self) -> jnp.ndarray:
        """Reset all envs, partitioned over the worker pool like ``step``."""
        futures = [self._pool.submit(self._reset_slice, idxs)
                   for idxs in self._slices]
        for f in futures:
            f.result()
        return jnp.asarray(self._obs)

    def _work(self, idxs: np.ndarray, actions: np.ndarray):
        for i in idxs:
            obs, r, done, _ = self.envs[i].step(int(actions[i]))
            if done:  # paper §5.1: restart on terminal
                obs = self.envs[i].reset()
            self._obs[i] = obs
            self._reward[i] = r
            self._done[i] = done

    def step_host(self, actions) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Apply the master's batched actions; workers run in parallel.

        Returns views of the shared host buffers (valid until the next call)
        — the zero-device-op path used by the pipeline's actor thread.
        """
        actions = np.asarray(actions)
        futures = [
            self._pool.submit(self._work, idxs, actions) for idxs in self._slices
        ]
        for f in futures:
            f.result()
        return self._obs, self._reward, self._done

    def step(self, actions) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        """``step_host`` with outputs staged onto the device."""
        obs, reward, done = self.step_host(actions)
        return jnp.asarray(obs), jnp.asarray(reward), jnp.asarray(done)

    def close(self):
        """Shut the worker pool down and close all envs. Idempotent."""
        if self._closed:
            return
        self._closed = True
        self._pool.shutdown(wait=True)
        for env in self.envs:
            if hasattr(env, "close"):
                env.close()

    def __enter__(self) -> "HostEnvPool":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()
