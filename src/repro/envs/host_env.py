"""Host-side environment worker pool — the paper's n_w workers, literally.

The JAX-native environments in this package fuse stepping into the XLA
program (DESIGN.md §2), which is faster but only possible for environments
expressible in JAX. For *external* environments (a C++ emulator like ALE, a
network simulator, a real system), this module reproduces the paper's §3
architecture exactly: ``n_e`` environment instances are partitioned among
``n_w`` Python worker threads; the master hands each worker its slice of
the batched action vector; workers step their environments in parallel and
write observations/rewards into shared pinned buffers.

This path is host-bound by construction — the paper's Fig. 2 "50% env
time" regime. ``ParallelRL`` drives it synchronously (host rollout, then
jitted update) and the asynchronous pipeline (``repro.pipeline``) overlaps
the env stall with learning; ``benchmarks/fig2_time_split.py``'s
``run_pipelined_host`` measures the recovered throughput. Workers release
the GIL while stepping external processes, which is exactly what makes the
overlap real. ``HostEnvPool.shard`` splits the env axis into per-actor
views for the multi-actor pipeline.
"""
from __future__ import annotations

import concurrent.futures as cf
from typing import Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["HostEnvPool", "HostEnvShard"]


class _EnvStepper:
    """Shared master/worker stepping over ``self.envs`` (paper §3 loop).

    Subclasses provide ``envs``, the output buffers ``_obs``/``_reward``/
    ``_done`` (leading axis ``n_envs``), the worker partition ``_slices``
    (index arrays into ``envs``), and ``_executor()``; ``_init_staging()``
    preallocates the per-stepper staging snapshot reused by every
    ``reset``/``step`` call.
    """

    envs: List
    n_envs: int

    def _executor(self) -> cf.ThreadPoolExecutor:
        raise NotImplementedError

    @property
    def obs_dtype(self):
        """Dtype of the observation buffers (what staging rings preallocate)."""
        return self._obs.dtype

    def _submit_slices(self, fn, *args) -> None:
        futures = [self._executor().submit(fn, idxs, *args)
                   for idxs in self._slices]
        for f in futures:
            f.result()

    def _reset_slice(self, idxs: np.ndarray):
        for i in idxs:
            self._obs[i] = self.envs[i].reset()

    def reset(self) -> jnp.ndarray:
        """Reset all envs, partitioned over the worker pool like ``step``."""
        self._submit_slices(self._reset_slice)
        # jnp.array (never asarray) IS the staging copy: one synchronous
        # transfer into a private device buffer the workers can't touch.
        # A host-side bounce buffer here would only add a second memcpy —
        # per-rollout staging reuse lives in the pipeline's HostStagingRing,
        # where rows are written in place instead of stacked per collect.
        return jnp.array(self._obs)

    def _work(self, idxs: np.ndarray, actions: np.ndarray):
        for i in idxs:
            obs, r, done, _ = self.envs[i].step(int(actions[i]))
            if done:  # paper §5.1: restart on terminal
                obs = self.envs[i].reset()
            self._obs[i] = obs
            self._reward[i] = r
            self._done[i] = done

    def step_host(self, actions) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Apply the master's batched actions; workers run in parallel.

        Returns views of the shared host buffers (valid until the next call)
        — the zero-device-op path used by the pipeline's actor threads,
        which copy rows straight into their own trajectory staging sets.
        """
        self._submit_slices(self._work, np.asarray(actions))
        return self._obs, self._reward, self._done

    def step(self, actions) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        """``step_host`` with outputs staged onto the device (snapshots —
        never aliases of the mutable shared buffers)."""
        obs, reward, done = self.step_host(actions)
        return jnp.array(obs), jnp.array(reward), jnp.array(done)


class HostEnvPool(_EnvStepper):
    """Paper §3: n_e external env instances stepped by n_w workers.

    env_fns: callables creating gym-style envs with reset() -> obs and
    step(action) -> (obs, reward, done, info).
    """

    def __init__(self, env_fns: Sequence[Callable], n_workers: int = 8,
                 obs_shape: Tuple[int, ...] = (), obs_dtype=np.float32):
        self.envs = [fn() for fn in env_fns]
        self.n_envs = len(self.envs)
        self.n_workers = min(n_workers, self.n_envs)
        self.obs_shape = tuple(obs_shape)
        # shared output buffers (the paper's shared memory between master
        # and workers)
        self._obs = np.zeros((self.n_envs,) + self.obs_shape, obs_dtype)
        self._reward = np.zeros((self.n_envs,), np.float32)
        self._done = np.zeros((self.n_envs,), bool)
        self._pool = cf.ThreadPoolExecutor(max_workers=self.n_workers)
        self._slices = np.array_split(np.arange(self.n_envs), self.n_workers)
        self._closed = False

    def _executor(self) -> cf.ThreadPoolExecutor:
        return self._pool

    def shard(self, n: int) -> List["HostEnvShard"]:
        """Split the env axis into ``n`` equal per-actor shards.

        Each shard steps only its slice of the envs, with its own output
        buffers, on the *parent's* worker pool — total host concurrency stays
        bounded by ``n_workers`` no matter how many actors drive shards
        concurrently. The parent still owns the envs and the executor:
        close the parent, not the shards.
        """
        if self._closed:
            raise RuntimeError("shard() on a closed HostEnvPool")
        if n < 1 or self.n_envs % n:
            raise ValueError(
                f"cannot shard {self.n_envs} envs into {n} equal actor pools"
            )
        size = self.n_envs // n
        return [HostEnvShard(self, i * size, (i + 1) * size) for i in range(n)]

    def close(self):
        """Shut the worker pool down and close all envs. Idempotent."""
        if self._closed:
            return
        self._closed = True
        self._pool.shutdown(wait=True)
        for env in self.envs:
            if hasattr(env, "close"):
                env.close()

    def __enter__(self) -> "HostEnvPool":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()


class HostEnvShard(_EnvStepper):
    """A per-actor slice [lo, hi) of a parent ``HostEnvPool``'s env axis.

    Same stepping API as the parent (``reset`` / ``step_host`` / ``step``)
    over ``(hi - lo)`` envs, sharing the parent's worker executor so that N
    shards stepped from N actor threads still respect the pool's ``n_w``
    worker bound (the paper's §3 resource model, divided among replicas).
    """

    def __init__(self, parent: HostEnvPool, lo: int, hi: int):
        self._parent = parent
        self.envs = parent.envs[lo:hi]
        self.n_envs = hi - lo
        self.obs_shape = parent.obs_shape
        self._obs = np.zeros((self.n_envs,) + self.obs_shape,
                             parent._obs.dtype)
        self._reward = np.zeros((self.n_envs,), np.float32)
        self._done = np.zeros((self.n_envs,), bool)
        # proportional share of the parent's workers (at least one)
        n_w = max(1, (parent.n_workers * self.n_envs) // parent.n_envs)
        self._slices = np.array_split(np.arange(self.n_envs),
                                      min(n_w, self.n_envs))

    def _executor(self) -> cf.ThreadPoolExecutor:
        return self._parent._pool
