"""Catch (bsuite-style): a ball falls down a rows×cols board; the paddle on
the bottom row must catch it. Reward ±1 on the final row. Obs: flat board."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.envs.base import VectorEnv


class Catch(VectorEnv):
    def __init__(self, n_envs: int, rows: int = 10, cols: int = 5):
        super().__init__(n_envs)
        self.rows, self.cols = rows, cols
        self.obs_shape = (rows * cols,)
        self.num_actions = 3  # left, stay, right

    def _reset_one(self, key):
        ball_col = jax.random.randint(key, (), 0, self.cols)
        return {
            "ball": jnp.array([0, 0], jnp.int32).at[1].set(ball_col),
            "paddle": jnp.asarray(self.cols // 2, jnp.int32),
        }

    def _observe_one(self, state):
        board = jnp.zeros((self.rows, self.cols), jnp.float32)
        board = board.at[state["ball"][0], state["ball"][1]].set(1.0)
        board = board.at[self.rows - 1, state["paddle"]].set(1.0)
        return board.reshape(-1)

    def _step_one(self, state, action, key):
        paddle = jnp.clip(state["paddle"] + action - 1, 0, self.cols - 1)
        ball = state["ball"] + jnp.array([1, 0])
        done = ball[0] >= self.rows - 1
        caught = ball[1] == paddle
        reward = jnp.where(done, jnp.where(caught, 1.0, -1.0), 0.0)
        return {"ball": ball, "paddle": paddle}, reward, done
