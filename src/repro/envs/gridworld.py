"""GridWorld: N×N grid, agent navigates to a goal.

Reward: +1 at goal (episode ends), -0.01 per step, timeout at ``max_steps``.
Observation: one-hot x/y of agent and goal (4N floats). Actions: 4 moves.
A fast-converging sanity environment for the PAAC learning tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.envs.base import VectorEnv


class GridWorld(VectorEnv):
    def __init__(self, n_envs: int, size: int = 5, max_steps: int = 50):
        super().__init__(n_envs)
        self.size = size
        self.max_steps = max_steps
        self.obs_shape = (4 * size,)
        self.num_actions = 4

    def _reset_one(self, key):
        k1, k2 = jax.random.split(key)
        pos = jax.random.randint(k1, (2,), 0, self.size)
        goal = jax.random.randint(k2, (2,), 0, self.size)
        return {"pos": pos, "goal": goal, "t": jnp.zeros((), jnp.int32)}

    def _observe_one(self, state):
        S = self.size
        return jnp.concatenate(
            [
                jax.nn.one_hot(state["pos"][0], S),
                jax.nn.one_hot(state["pos"][1], S),
                jax.nn.one_hot(state["goal"][0], S),
                jax.nn.one_hot(state["goal"][1], S),
            ]
        ).astype(jnp.float32)

    def _step_one(self, state, action, key):
        moves = jnp.array([[0, 1], [0, -1], [1, 0], [-1, 0]])
        pos = jnp.clip(state["pos"] + moves[action], 0, self.size - 1)
        at_goal = jnp.all(pos == state["goal"])
        t = state["t"] + 1
        reward = jnp.where(at_goal, 1.0, -0.01)
        done = at_goal | (t >= self.max_steps)
        return {"pos": pos, "goal": state["goal"], "t": t}, reward, done
