"""TokenEnv: a token-manipulation game for LLM policies.

This is how the assigned transformer architectures plug into PAAC: the
observation is the token history, the action is the next token, and the
reward is programmatic — exactly the RLHF-style generation setting, which
is the modern instance of the paper's master/actor pattern (batched action
selection = batched decode).

Game ("k-back echo"): at each step the correct action is the token emitted
``k`` steps ago (the prompt seeds the first k tokens). Reward +1 for the
correct token, 0 otherwise. Episodes run ``horizon`` steps. An optimal
policy is learnable by any causal model with ≥k context, so small models
solve it quickly — giving a real learning-signal test for every token arch.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.envs.base import VectorEnv


class TokenEnv(VectorEnv):
    def __init__(self, n_envs: int, vocab: int = 64, ctx: int = 32, k: int = 2,
                 horizon: int = 64):
        super().__init__(n_envs)
        self.vocab = vocab
        self.ctx = ctx
        self.k = k
        self.horizon = horizon
        self.obs_shape = (ctx,)
        self.num_actions = vocab

    def _reset_one(self, key):
        prompt = jax.random.randint(key, (self.ctx,), 0, self.vocab)
        return {"hist": prompt, "t": jnp.zeros((), jnp.int32)}

    def _observe_one(self, state):
        return state["hist"]

    def _step_one(self, state, action, key):
        target = state["hist"][-self.k]
        reward = (action == target).astype(jnp.float32)
        hist = jnp.concatenate([state["hist"][1:], action[None].astype(jnp.int32)])
        t = state["t"] + 1
        done = t >= self.horizon
        return {"hist": hist, "t": t}, reward, done
