"""CartPole with standard Gym dynamics (pure JAX)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.envs.base import VectorEnv


class CartPole(VectorEnv):
    obs_shape = (4,)
    num_actions = 2

    def __init__(self, n_envs: int, max_steps: int = 200):
        super().__init__(n_envs)
        self.max_steps = max_steps
        self.gravity = 9.8
        self.masscart = 1.0
        self.masspole = 0.1
        self.length = 0.5
        self.force_mag = 10.0
        self.tau = 0.02
        self.theta_limit = 12 * 2 * jnp.pi / 360
        self.x_limit = 2.4

    def _reset_one(self, key):
        s = jax.random.uniform(key, (4,), minval=-0.05, maxval=0.05)
        return {"s": s, "t": jnp.zeros((), jnp.int32)}

    def _observe_one(self, state):
        return state["s"].astype(jnp.float32)

    def _step_one(self, state, action, key):
        x, x_dot, theta, theta_dot = state["s"]
        force = jnp.where(action == 1, self.force_mag, -self.force_mag)
        costheta, sintheta = jnp.cos(theta), jnp.sin(theta)
        total_mass = self.masscart + self.masspole
        polemass_length = self.masspole * self.length
        temp = (force + polemass_length * theta_dot**2 * sintheta) / total_mass
        thetaacc = (self.gravity * sintheta - costheta * temp) / (
            self.length * (4.0 / 3.0 - self.masspole * costheta**2 / total_mass)
        )
        xacc = temp - polemass_length * thetaacc * costheta / total_mass
        s = jnp.stack(
            [
                x + self.tau * x_dot,
                x_dot + self.tau * xacc,
                theta + self.tau * theta_dot,
                theta_dot + self.tau * thetaacc,
            ]
        )
        t = state["t"] + 1
        fail = (
            (jnp.abs(s[0]) > self.x_limit)
            | (jnp.abs(s[2]) > self.theta_limit)
        )
        done = fail | (t >= self.max_steps)
        return {"s": s, "t": t}, jnp.asarray(1.0), done
