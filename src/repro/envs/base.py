"""Vectorized pure-JAX environment API.

The paper maintains ``n_e`` environment instances stepped by ``n_w`` worker
threads (§3). Here environments are JAX programs: the whole vector of
``n_e`` instances is one state pytree (leading axis n_e) and ``step`` is
traced/compiled together with action selection — the "workers" are the
vector lanes of the same XLA program (DESIGN.md §2).

Contract (all functions pure, jit/vmap/shard-safe):

* ``reset(key) -> state``          state pytree, leaves (n_e, ...)
* ``observe(state) -> obs``        (n_e, *obs_shape)
* ``step(state, actions, key) -> (state, obs, reward, done)``
    - auto-resets finished instances (paper §5.1 restarts on terminal)
    - reward: (n_e,) f32 — done: (n_e,) bool flags the transition that ended
      an episode (reward is the pre-reset reward)
"""
from __future__ import annotations

import abc
import copy
from typing import Tuple

import jax
import jax.numpy as jnp


def narrow_vector_env(env: "VectorEnv", n_envs: int) -> "VectorEnv":
    """A view of ``env`` batched over ``n_envs`` instances instead.

    The vector API is shape-polymorphic (per-instance dynamics vmapped over
    the leading axis), so a narrowed env is the same object graph with the
    batch width overridden — wrappers are narrowed recursively so e.g. a
    ``FrameStack`` delegates to an inner env of the matching width. Used by
    the asynchronous pipeline to split one env's axis into per-actor shards.
    """
    narrowed = copy.copy(env)
    narrowed.n_envs = n_envs
    inner = getattr(env, "env", None)
    if isinstance(inner, VectorEnv):
        narrowed.env = narrow_vector_env(inner, n_envs)
    return narrowed


class VectorEnv(abc.ABC):
    """Base class: subclasses implement single-instance dynamics; this class
    vectorizes them over n_e with vmap and handles auto-reset."""

    obs_shape: Tuple[int, ...]
    num_actions: int

    def __init__(self, n_envs: int):
        self.n_envs = n_envs

    # -- single-instance dynamics (to be implemented) -----------------------
    @abc.abstractmethod
    def _reset_one(self, key):  # -> state
        ...

    @abc.abstractmethod
    def _observe_one(self, state):  # -> obs
        ...

    @abc.abstractmethod
    def _step_one(self, state, action, key):  # -> (state, reward, done)
        ...

    # -- vectorized API ------------------------------------------------------
    def reset(self, key):
        return jax.vmap(self._reset_one)(jax.random.split(key, self.n_envs))

    def observe(self, state):
        return jax.vmap(self._observe_one)(state)

    def step(self, state, actions, key):
        ks = jax.random.split(key, 2 * self.n_envs).reshape(2, self.n_envs, -1)
        new_state, reward, done = jax.vmap(self._step_one)(state, actions, ks[0])
        # auto-reset finished instances
        reset_state = jax.vmap(self._reset_one)(ks[1])
        new_state = jax.tree_util.tree_map(
            lambda r, n: jnp.where(
                done.reshape((self.n_envs,) + (1,) * (n.ndim - 1)), r, n
            ),
            reset_state, new_state,
        )
        obs = self.observe(new_state)
        return new_state, obs, reward.astype(jnp.float32), done
