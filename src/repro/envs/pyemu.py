"""Python-bound emulator stand-ins — GIL-holding external envs.

``HostEnvPool``'s worker threads only buy parallelism when the env's
``step`` releases the GIL (C++ emulators, syscalls, sleeps —
``benchmarks.fig2_time_split.SleepyExternalEnv`` models those). Real
Python-bound emulators — ALE through old-style Python wrappers, gym envs
with Python-side frame processing, pure-Python simulators — execute
*bytecode* per step, hold the GIL, and serialize every thread in the
process. ``PyBoundEnv`` models exactly that regime: each ``step`` spins a
pure-Python loop for ``spin`` iterations, so thread-backed actor replicas
cannot scale it and the multi-process actor plane
(``PipelineConfig.actor_backend = "process"``) is the only lever left.

Everything here is module-level on purpose: the process plane ships env
recipes to spawned workers by *pickle reference*, so constructors must be
importable (``repro.envs.pyemu.make_py_bound_env``), never closures.
``py_bound_spec`` packages a whole pool as a ``HostEnvSpec``.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.envs.host_env import HostEnvSpec

__all__ = ["PyBoundEnv", "make_py_bound_env", "py_bound_spec"]


class PyBoundEnv:
    """Gym-style counter env whose step cost is pure-Python bytecode.

    Same dynamics as the toy counter envs used across the pipeline tests
    (reward 1 when ``action == state % 3``, episode ends every 10 steps,
    observation is a small float vector derived from the state) plus a
    deliberate GIL-holding workload: ``spin`` iterations of Python
    arithmetic per ``step``. ``spin=0`` makes it a plain fast toy env.
    """

    def __init__(self, seed: int, obs_dim: int = 8, spin: int = 0):
        self.rng = np.random.RandomState(seed)
        self.obs_dim = obs_dim
        self.spin = spin
        self.state = 0

    def _obs(self) -> np.ndarray:
        return np.full((self.obs_dim,), self.state % 7, np.float32)

    def reset(self) -> np.ndarray:
        self.state = int(self.rng.randint(0, 100))
        return self._obs()

    def step(self, action) -> Tuple[np.ndarray, float, bool, dict]:
        # the emulator: pure-Python work that never releases the GIL
        acc = 0
        for i in range(self.spin):
            acc += i * i % 7
        reward = 1.0 if int(action) == self.state % 3 else 0.0
        self.state += 1
        return self._obs(), reward, self.state % 10 == 0, {"spin": acc}


def make_py_bound_env(seed: int, obs_dim: int, spin: int) -> PyBoundEnv:
    """Module-level constructor (the spec contract: picklable by import
    reference so spawned workers can rebuild the pool)."""
    return PyBoundEnv(seed, obs_dim, spin)


def py_bound_spec(n_envs: int, obs_dim: int = 8, spin: int = 0,
                  n_workers: int = 4, base_seed: int = 0) -> HostEnvSpec:
    """A ready-to-ship ``HostEnvSpec`` for a pool of ``PyBoundEnv``s."""
    return HostEnvSpec(
        env_fn=make_py_bound_env,
        env_args=tuple((base_seed + i, obs_dim, spin) for i in range(n_envs)),
        n_workers=n_workers,
        obs_shape=(obs_dim,),
        obs_dtype=np.float32,
    )
