"""AtariLike: a procedural 84×84 pixel game standing in for ALE.

The container has no Atari ROMs/emulator, so this JAX-native pixel game
reproduces the *interface* the paper trains against: 84×84 grayscale frames
(after the §5.1 pipeline), small discrete action set, ±1 rewards, episodic
resets with random no-op starts.

Game ("CatchPixels"): a ball falls from the top at a random column with
random horizontal drift and bounces off walls; the agent moves a paddle
along the bottom row. +1 for a catch, -1 for a miss; episode = `lives`
balls. Rendering (ball sprite + paddle sprite on an 84×84 canvas) is done
with scatter ops inside the step, so the whole env runs on device.

The paper's pre-processing pipeline (§5.1) is built in:
* action repeat 4 with per-pixel max over the last two frames,
* frame stack of 4 (the wrapper in ``wrappers.py``),
* 1–30 random no-op actions after reset.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.envs.base import VectorEnv

SIZE = 84
PADDLE_W = 8
BALL = 3  # ball sprite size
ROW_BOTTOM = SIZE - 4


class AtariLike(VectorEnv):
    obs_shape = (SIZE, SIZE)
    num_actions = 3  # left, stay, right

    def __init__(self, n_envs: int, lives: int = 5, action_repeat: int = 4,
                 max_noops: int = 30):
        super().__init__(n_envs)
        self.lives = lives
        self.action_repeat = action_repeat
        self.max_noops = max_noops

    def _spawn_ball(self, key):
        k1, k2 = jax.random.split(key)
        col = jax.random.randint(k1, (), BALL, SIZE - BALL)
        vx = jax.random.randint(k2, (), -2, 3)  # -2..2 horizontal drift
        return jnp.stack([jnp.asarray(0, jnp.int32), col, jnp.asarray(2, jnp.int32), vx])

    def _reset_one(self, key):
        k1, k2, k3 = jax.random.split(key, 3)
        state = {
            "ball": self._spawn_ball(k1),  # (row, col, vy, vx)
            "paddle": jax.random.randint(k2, (), PADDLE_W, SIZE - PADDLE_W),
            "lives": jnp.asarray(self.lives, jnp.int32),
        }
        # paper §5.1: 1..30 no-op actions before handing control to the agent
        n_noops = jax.random.randint(k3, (), 1, self.max_noops + 1)

        def noop(_, s):
            s, _, _ = self._physics(s, jnp.asarray(1, jnp.int32), key)
            return s

        return jax.lax.fori_loop(0, n_noops, noop, state)

    def _physics(self, state, action, key):
        """One raw emulator frame."""
        paddle = jnp.clip(state["paddle"] + (action - 1) * 3, PADDLE_W, SIZE - PADDLE_W)
        row, col, vy, vx = state["ball"]
        row = row + vy
        col = col + vx
        # bounce off side walls
        vx = jnp.where((col <= BALL) | (col >= SIZE - BALL), -vx, vx)
        col = jnp.clip(col, BALL, SIZE - BALL)
        at_bottom = row >= ROW_BOTTOM
        caught = at_bottom & (jnp.abs(col - paddle) <= PADDLE_W)
        reward = jnp.where(at_bottom, jnp.where(caught, 1.0, -1.0), 0.0)
        lives = state["lives"] - at_bottom.astype(jnp.int32)
        ball = jnp.where(
            at_bottom,
            self._spawn_ball(key),
            jnp.stack([row, col, vy, vx]),
        )
        new_state = {"ball": ball, "paddle": paddle, "lives": lives}
        return new_state, reward, lives <= 0

    def _render(self, state):
        rows = jnp.arange(SIZE)[:, None]
        cols = jnp.arange(SIZE)[None, :]
        ball_r, ball_c = state["ball"][0], state["ball"][1]
        ball = (jnp.abs(rows - ball_r) <= BALL // 2) & (jnp.abs(cols - ball_c) <= BALL // 2)
        paddle = (rows >= ROW_BOTTOM) & (jnp.abs(cols - state["paddle"]) <= PADDLE_W)
        return jnp.clip(ball.astype(jnp.float32) + paddle.astype(jnp.float32), 0, 1)

    def _observe_one(self, state):
        return self._render(state)

    def _step_one(self, state, action, key):
        """Action repeat 4 with per-pixel max of the two latest frames."""
        total_r = jnp.zeros(())
        done_any = jnp.zeros((), bool)
        for _ in range(self.action_repeat):
            key, sub = jax.random.split(key)
            state, r, d = self._physics(state, action, sub)
            total_r = total_r + r
            done_any = done_any | d
        # per-pixel max of the two latest frames is implicit: observe()
        # renders the post-repeat state (sprites cover their travel cells)
        return state, total_r, done_any

    def observe(self, states):
        return jax.vmap(self._render)(states)
