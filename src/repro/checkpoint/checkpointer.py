"""Pytree checkpointing (npz-based, no external deps).

Flattens a pytree with path-derived keys, stores dtype/shape-faithful arrays
plus a manifest, restores into the same structure. Shard-aware in the sense
that callers pass host-local (fully-addressable) arrays; under pjit on a
real pod each host saves its addressable shards with distinct filenames.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, Optional

import jax
import numpy as np

_SEP = "::"


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        arr = np.asarray(leaf) if leaf.dtype != jax.numpy.bfloat16 else np.asarray(
            leaf.astype(jax.numpy.float32)  # numpy has no bf16; f32 is lossless
        )
        flat[key] = arr
    return flat


def save_checkpoint(directory: str, step: int, tree, *, prefix: str = "ckpt") -> str:
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"{prefix}_{step:010d}.npz")
    flat = _flatten(tree)
    np.savez_compressed(path, **flat)
    with open(os.path.join(directory, f"{prefix}_{step:010d}.json"), "w") as f:
        json.dump({"step": step, "keys": sorted(flat)}, f)
    return path


def latest_step(directory: str, prefix: str = "ckpt") -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        m = re.match(rf"{prefix}_(\d+)\.npz", name)
        if m:
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, target_tree, *, prefix: str = "ckpt"):
    """Restore into the structure of ``target_tree`` (shapes must match)."""
    path = os.path.join(directory, f"{prefix}_{step:010d}.npz")
    data = np.load(path)
    paths, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
    leaves = []
    for p, leaf in paths:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k))) for k in p)
        arr = data[key]
        assert arr.shape == leaf.shape, f"{key}: {arr.shape} vs {leaf.shape}"
        leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(target_tree), leaves)
