"""Pytree checkpointing (npz-based, no external deps).

Flattens a pytree with path-derived keys, stores dtype/shape-faithful arrays
plus a manifest, restores into the same structure. Shard-aware in the sense
that callers pass host-local (fully-addressable) arrays; under pjit on a
real pod each host saves its addressable shards with distinct filenames.

Round-trip contract: ``restore_checkpoint(d, s, target)`` returns a tree
with ``target``'s exact leaf types and dtypes — bf16 leaves (saved as
lossless f32, numpy has no bf16) come back bf16 bitwise, numpy leaves stay
numpy (a host-plane resume must not silently promote staging state onto the
device), python scalars come back as 0-d arrays of the saved dtype. The
manifest records each leaf's logical dtype so a checkpoint is
self-describing even where the npz payload dtype differs.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, Optional

import jax
import numpy as np

_SEP = "::"


def _path_key(path) -> str:
    return _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path)


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        # normalize first: python scalars (step counters, seq numbers) have
        # no .dtype — np.asarray gives them one without copying real arrays
        arr = leaf if hasattr(leaf, "dtype") else np.asarray(leaf)
        if arr.dtype == jax.numpy.bfloat16:
            # numpy has no bf16; f32 is lossless — restore casts back
            arr = np.asarray(arr.astype(jax.numpy.float32))
        flat[_path_key(path)] = np.asarray(arr)
    return flat


def save_checkpoint(directory: str, step: int, tree, *, prefix: str = "ckpt") -> str:
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"{prefix}_{step:010d}.npz")
    flat = _flatten(tree)
    # logical dtypes (pre-bf16-widening): the manifest makes the checkpoint
    # self-describing without needing the target tree in hand
    dtypes = {}
    for p, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        dt = getattr(leaf, "dtype", None)
        dtypes[_path_key(p)] = (str(dt) if dt is not None
                                else np.asarray(leaf).dtype.str)
    np.savez_compressed(path, **flat)
    with open(os.path.join(directory, f"{prefix}_{step:010d}.json"), "w") as f:
        json.dump({"step": step, "keys": sorted(flat), "dtypes": dtypes}, f)
    return path


def latest_step(directory: str, prefix: str = "ckpt") -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        # fullmatch: a "ckpt" prefix must not claim "ckpt_extra_..." files
        m = re.fullmatch(rf"{re.escape(prefix)}_(\d+)\.npz", name)
        if m:
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, target_tree, *, prefix: str = "ckpt"):
    """Restore into the structure of ``target_tree`` (shapes must match).

    Each restored leaf takes the *target* leaf's dtype and residency:
    bf16 targets get the saved f32 payload cast back (bitwise — the
    widening was lossless), numpy targets stay host numpy arrays, jax
    targets land on the device.
    """
    path = os.path.join(directory, f"{prefix}_{step:010d}.npz")
    data = np.load(path)
    paths, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
    leaves = []
    for p, leaf in paths:
        key = _path_key(p)
        arr = data[key]
        shape = getattr(leaf, "shape", np.shape(leaf))
        assert arr.shape == tuple(shape), f"{key}: {arr.shape} vs {shape}"
        if isinstance(leaf, np.ndarray):
            leaves.append(arr.astype(leaf.dtype, copy=False))
        elif hasattr(leaf, "dtype"):
            leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
        else:  # python scalar target: give back its type
            leaves.append(type(leaf)(arr.item()))
    return jax.tree_util.tree_unflatten(treedef, leaves)
