"""The paper's primary contribution: the synchronous parallel-actor
framework (master batched action selection + parallel workers + one
synchronous update), algorithm-agnostic per §3.

The asynchronous actor/learner variant — bounded trajectory queue,
double-buffered rollouts, importance-corrected learner — lives in
``repro.pipeline`` and mirrors ``ParallelRL``'s API."""
from repro.core.evaluation import evaluate
from repro.core.framework import ParallelRL, RunResult
from repro.core.returns import gae_advantages, n_step_returns
from repro.core.rollout import Transition, rollout

__all__ = [
    "ParallelRL",
    "RunResult",
    "evaluate",
    "n_step_returns",
    "gae_advantages",
    "rollout",
    "Transition",
]
