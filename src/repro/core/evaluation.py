"""The paper's evaluation protocol (Table 1 caption):

    "Scores are measured from the best performing actor out of three, and
     averaged over 30 runs with up to 30 no-op actions start condition."

``evaluate`` runs `n_runs` complete episodes per actor-seed with a greedy
(or sampled) policy, environments applying their own random no-op starts on
reset (repro.envs.AtariLike builds §5.1's 1–30 no-ops in), and reports the
per-seed mean returns plus the paper's best-of-k statistic.
"""
from __future__ import annotations

from typing import Callable, Dict, List

import jax
import jax.numpy as jnp


def evaluate(
    act_fn: Callable,  # (params, obs) -> (logits, value)
    env,
    params,
    key,
    *,
    n_runs: int = 30,
    n_actor_seeds: int = 3,
    max_steps: int = 1_000,
    greedy: bool = True,
) -> Dict[str, float]:
    """Paper-protocol evaluation. Returns {best_of_k, mean, per_seed}."""

    def run_batch(params, env_state, obs, key):
        """Run all n_envs episodes to completion (or max_steps)."""

        def step(carry, _):
            env_state, obs, key, ep_ret, done_seen = carry
            key, k_act, k_env = jax.random.split(key, 3)
            logits, _ = act_fn(params, obs)
            action = (
                jnp.argmax(logits, axis=-1)
                if greedy
                else jax.random.categorical(k_act, logits)
            )
            env_state, obs, reward, done = env.step(env_state, action, k_env)
            ep_ret = ep_ret + reward * (1.0 - done_seen)
            done_seen = jnp.maximum(done_seen, done.astype(jnp.float32))
            return (env_state, obs, key, ep_ret, done_seen), None

        E = env.n_envs
        init = (env_state, obs, key, jnp.zeros((E,)), jnp.zeros((E,)))
        (env_state, obs, key, ep_ret, done_seen), _ = jax.lax.scan(
            step, init, None, length=max_steps
        )
        return ep_ret, done_seen

    run_batch = jax.jit(run_batch)

    per_seed: List[float] = []
    for seed in range(n_actor_seeds):
        key, k_reset = jax.random.split(jax.random.fold_in(key, seed))
        returns = []
        runs_done = 0
        while runs_done < n_runs:
            k_reset, k_run = jax.random.split(k_reset)
            env_state = env.reset(k_run)  # fresh no-op-start episodes
            obs = env.observe(env_state)
            ep_ret, done_seen = run_batch(params, env_state, obs, k_run)
            take = min(env.n_envs, n_runs - runs_done)
            returns.extend(float(r) for r in ep_ret[:take])
            runs_done += take
        per_seed.append(sum(returns) / len(returns))

    return {
        "best_of_k": max(per_seed),  # the paper's Table-1 statistic
        "mean": sum(per_seed) / len(per_seed),
        "per_seed": per_seed,
    }
