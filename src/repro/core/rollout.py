"""The master loop — paper Algorithm 1 lines 4–10.

One ``lax.scan`` iteration = one framework timestep:

  1. the *master* evaluates the policy for ALL ``n_e`` environments in one
     batched forward (line 5-6),
  2. actions are sampled per environment (the policy "may be sampled
     differently for each environment" — independent categorical draws),
  3. the *workers* apply all actions in parallel (line 7-10) — here the
     vmapped env step fused into the same XLA program.

Because environments are JAX-native, acting, stepping and (in the agents)
learning compile into a single device program per PAAC iteration.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Transition(NamedTuple):
    obs: jnp.ndarray  # (T, E, *obs_shape)
    action: jnp.ndarray  # (T, E)
    reward: jnp.ndarray  # (T, E)
    done: jnp.ndarray  # (T, E)
    value: jnp.ndarray  # (T, E) — V(s_t) computed during acting (line 6)
    logp: jnp.ndarray  # (T, E) — log π(a_t|s_t) at acting time


def rollout(
    act_fn: Callable,  # (params, obs) -> (logits (E,A), value (E,))
    env,
    params,
    env_state,
    obs,
    key,
    t_max: int,
):
    """Collect t_max steps from all n_e environments.

    Returns (env_state, last_obs, key, traj: Transition [time-major]).
    """

    def step(carry, _):
        env_state, obs, key = carry
        key, k_act, k_env = jax.random.split(key, 3)
        logits, value = act_fn(params, obs)
        action = jax.random.categorical(k_act, logits)
        # behaviour log-prob from the sampled action's logit alone:
        # log π(a|s) = logits[a] − logsumexp(logits). Gathering first keeps
        # the acting scan from materializing the full (E, A) log_softmax
        # matrix when only one column per row is ever read.
        action_logit = jnp.take_along_axis(logits, action[:, None], axis=1)[:, 0]
        logp = action_logit - jax.scipy.special.logsumexp(logits, axis=1)
        env_state, next_obs, reward, done = env.step(env_state, action, k_env)
        tr = Transition(obs, action, reward, done, value, logp)
        return (env_state, next_obs, key), tr

    (env_state, obs, key), traj = jax.lax.scan(
        step, (env_state, obs, key), None, length=t_max
    )
    return env_state, obs, key, traj


def make_collect_fn(act_fn: Callable, env, t_max: int) -> Callable:
    """Standalone jittable rollout collector.

    Returns ``collect(params, env_state, obs, key) -> (env_state, last_obs,
    key, traj)`` — exactly the acting half of Algorithm 1, detached from the
    learning half so an asynchronous actor (``repro.pipeline``) can run it on
    its own thread while the learner consumes the previous trajectory. The
    key evolution is identical to the fused train step's, so a lock-stepped
    pipeline reproduces the synchronous trajectory stream bit-for-bit.
    """

    def collect(params, env_state, obs, key):
        return rollout(act_fn, env, params, env_state, obs, key, t_max)

    return collect
