"""Return estimation — paper Algorithm 1 lines 11–15, and V-trace.

``n_step_returns`` is the exact recursion the paper batches over actors:

    R_{t_max+1} = V(s_{t_max+1})        (0 through terminals)
    R_t = r_t + γ · (1 - done_t) · R_{t+1}

vectorized over all ``n_e`` actors — the time dimension is sequential (a
``lax.scan``), the actor dimension is data-parallel. This is the paper's
insight in miniature: parallelism comes from the batch, not the recursion.
``repro/kernels/nstep_returns.py`` is the Pallas twin (batch-tiled VMEM).

``vtrace_returns`` is the full IMPALA V-trace estimator (Espeholt et al.
2018) the asynchronous pipeline uses for queue-stale data: the n-step
targets with truncated-importance corrections folded into the recursion
(ρ̄ clips each step's TD error, the c̄ product discounts how far corrections
propagate backwards). On-policy data with ρ̄, c̄ ≥ 1 recovers
``n_step_returns`` exactly; ``repro/kernels/vtrace.py`` is the Pallas twin.

GAE (Schulman et al. 2015) is provided as a beyond-paper option.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def n_step_returns(
    rewards: jnp.ndarray,  # (E, T) or (T,)
    dones: jnp.ndarray,  # (E, T) bool
    bootstrap: jnp.ndarray,  # (E,) — V(s_{T+1})
    gamma: float,
) -> jnp.ndarray:
    """Discounted n-step returns per actor. Returns (E, T)."""
    rewards = rewards.astype(jnp.float32)
    not_done = 1.0 - dones.astype(jnp.float32)

    def step(carry, xs):
        r, nd = xs
        carry = r + gamma * nd * carry
        return carry, carry

    # scan over time, reversed (time axis last -> move to front)
    _, out = jax.lax.scan(
        step,
        bootstrap.astype(jnp.float32),
        (rewards.T, not_done.T),
        reverse=True,
    )
    return out.T  # (E, T)


def vtrace_returns(
    rewards: jnp.ndarray,  # (E, T)
    dones: jnp.ndarray,  # (E, T) bool
    values: jnp.ndarray,  # (E, T) — V(s_t) under the *learner* params
    bootstrap: jnp.ndarray,  # (E,) — V(s_{T+1}) under the learner params
    rho: jnp.ndarray,  # (E, T) — π_learner(a|s) / π_behaviour(a|s), unclipped
    gamma: float,
    rho_bar: float = 1.0,
    c_bar: float = 1.0,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full V-trace targets (Espeholt et al. 2018, eqs. 1–4), per actor.

    With ρ_t = min(ρ̄, rho_t), c_t = min(c̄, rho_t) and the terminal-aware
    discount γ_t = γ·(1-done_t):

        δ_t  = ρ_t · (r_t + γ_t·V(s_{t+1}) - V(s_t))
        v_t  = V(s_t) + δ_t + γ_t·c_t·(v_{t+1} - V(s_{t+1}))
        adv_t = ρ_t · (r_t + γ_t·v_{t+1} - V(s_t))

    Returns ``(vs, pg_adv)``, both (E, T): the value targets and the policy-
    gradient advantages (the ρ_t factor is already folded into ``pg_adv``).
    On-policy behaviour (rho == 1) with ρ̄, c̄ ≥ 1 makes the recursion
    telescope into the paper's n-step returns; c̄ → 0 collapses it to
    one-step importance-weighted TD.
    """
    rewards = rewards.astype(jnp.float32)
    values = values.astype(jnp.float32)
    bootstrap = bootstrap.astype(jnp.float32)
    rho = rho.astype(jnp.float32)
    not_done = 1.0 - dones.astype(jnp.float32)
    rho_c = jnp.minimum(rho, rho_bar)
    c = jnp.minimum(rho, c_bar)
    v_next = jnp.concatenate([values[:, 1:], bootstrap[:, None]], axis=1)
    delta = rho_c * (rewards + gamma * not_done * v_next - values)

    def step(carry, xs):
        # carry: A_{t+1} = v_{t+1} - V(s_{t+1})
        d, nd, c_t = xs
        carry = d + gamma * nd * c_t * carry
        return carry, carry

    _, acc = jax.lax.scan(
        step,
        jnp.zeros_like(bootstrap),
        (delta.T, not_done.T, c.T),
        reverse=True,
    )
    vs = values + acc.T
    vs_next = jnp.concatenate([vs[:, 1:], bootstrap[:, None]], axis=1)
    pg_adv = rho_c * (rewards + gamma * not_done * vs_next - values)
    return vs, pg_adv


def gae_advantages(
    rewards: jnp.ndarray,  # (E, T)
    dones: jnp.ndarray,  # (E, T)
    values: jnp.ndarray,  # (E, T)
    bootstrap: jnp.ndarray,  # (E,)
    gamma: float,
    lam: float = 0.95,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Generalized advantage estimation. Returns (advantages, returns)."""
    rewards = rewards.astype(jnp.float32)
    values = values.astype(jnp.float32)
    not_done = 1.0 - dones.astype(jnp.float32)
    next_values = jnp.concatenate([values[:, 1:], bootstrap[:, None]], axis=1)
    deltas = rewards + gamma * not_done * next_values - values

    def step(carry, xs):
        delta, nd = xs
        carry = delta + gamma * lam * nd * carry
        return carry, carry

    _, adv = jax.lax.scan(
        step, jnp.zeros_like(bootstrap, jnp.float32), (deltas.T, not_done.T),
        reverse=True,
    )
    adv = adv.T
    return adv, adv + values
