"""Return estimation — paper Algorithm 1 lines 11–15.

``n_step_returns`` is the exact recursion the paper batches over actors:

    R_{t_max+1} = V(s_{t_max+1})        (0 through terminals)
    R_t = r_t + γ · (1 - done_t) · R_{t+1}

vectorized over all ``n_e`` actors — the time dimension is sequential (a
``lax.scan``), the actor dimension is data-parallel. This is the paper's
insight in miniature: parallelism comes from the batch, not the recursion.
``repro/kernels/nstep_returns.py`` is the Pallas twin (batch-tiled VMEM).

GAE (Schulman et al. 2015) is provided as a beyond-paper option.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def n_step_returns(
    rewards: jnp.ndarray,  # (E, T) or (T,)
    dones: jnp.ndarray,  # (E, T) bool
    bootstrap: jnp.ndarray,  # (E,) — V(s_{T+1})
    gamma: float,
) -> jnp.ndarray:
    """Discounted n-step returns per actor. Returns (E, T)."""
    rewards = rewards.astype(jnp.float32)
    not_done = 1.0 - dones.astype(jnp.float32)

    def step(carry, xs):
        r, nd = xs
        carry = r + gamma * nd * carry
        return carry, carry

    # scan over time, reversed (time axis last -> move to front)
    _, out = jax.lax.scan(
        step,
        bootstrap.astype(jnp.float32),
        (rewards.T, not_done.T),
        reverse=True,
    )
    return out.T  # (E, T)


def gae_advantages(
    rewards: jnp.ndarray,  # (E, T)
    dones: jnp.ndarray,  # (E, T)
    values: jnp.ndarray,  # (E, T)
    bootstrap: jnp.ndarray,  # (E,)
    gamma: float,
    lam: float = 0.95,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Generalized advantage estimation. Returns (advantages, returns)."""
    rewards = rewards.astype(jnp.float32)
    values = values.astype(jnp.float32)
    not_done = 1.0 - dones.astype(jnp.float32)
    next_values = jnp.concatenate([values[:, 1:], bootstrap[:, None]], axis=1)
    deltas = rewards + gamma * not_done * next_values - values

    def step(carry, xs):
        delta, nd = xs
        carry = delta + gamma * lam * nd * carry
        return carry, carry

    _, adv = jax.lax.scan(
        step, jnp.zeros_like(bootstrap, jnp.float32), (deltas.T, not_done.T),
        reverse=True,
    )
    adv = adv.T
    return adv, adv + values
