"""DQN inside the PAAC framework — the paper's off-policy/value-based claim.

The same master/worker machinery drives ε-greedy actors; experiences go to
replay memory and the synchronous update is a double-batched Q-learning step
with a periodically-synced target network (Mnih et al. 2015). The policy
head's logits are reused as Q-values (the framework's heads are just output
layers; §3: "the policy function can be represented implicitly, as in value
based methods").
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.agents.base import Agent
from repro.core.agents.replay import replay_add, replay_init, replay_sample
from repro.models import policy_apply


class DQNConfig(NamedTuple):
    gamma: float = 0.99
    eps_start: float = 1.0
    eps_end: float = 0.05
    eps_steps: int = 10_000
    batch_size: int = 128
    target_sync: int = 100
    t_max: int = 5  # env steps per framework iteration (buffer fill rate)


def dqn_td_target(q_next, reward, done, gamma: float):
    """Double-batched Q-learning target: r + γ·(1−done)·max_a' Q_target.

    ``q_next`` is the *target network's* Q-values at the successor states
    (B, A); reward/done are (B,). Shared by the scan-based synchronous step
    and the pipelined replay learner step so the TD math cannot drift."""
    return reward + gamma * (1.0 - done.astype(jnp.float32)) * jnp.max(
        q_next, axis=-1
    )


def dqn_loss(params, target_params, batch, cfg, gamma: float):
    """TD MSE over a transition batch dict (obs/action/reward/next_obs/done).

    Returns ``(loss, metrics)`` — the single loss definition every DQN
    driver (scan train step, replay-plane learner step, test oracles)
    evaluates."""
    def q_of(p, obs):
        q, _, _ = policy_apply(p, cfg, obs)
        return q

    q = q_of(params, batch["obs"])
    q_a = jnp.take_along_axis(q, batch["action"][:, None], axis=1)[:, 0]
    target = dqn_td_target(
        q_of(target_params, batch["next_obs"]), batch["reward"], batch["done"],
        gamma,
    )
    td = jax.lax.stop_gradient(target) - q_a
    return jnp.mean(jnp.square(td)), {"q_mean": jnp.mean(q_a)}


def dqn_sync_target(target, params, updates, target_sync: int):
    """Post-update target maintenance: ``updates + 1`` and a hard sync of
    the target tree every ``target_sync`` updates (Mnih et al. 2015)."""
    updates = updates + 1
    sync = (updates % target_sync) == 0
    target = jax.tree_util.tree_map(
        lambda t, p: jnp.where(sync, p, t), target, params
    )
    return target, updates


class DQNAgent(Agent):
    on_policy = False

    def __init__(self, cfg, hp: DQNConfig = DQNConfig()):
        self.cfg = cfg
        self.hp = hp

    def act_fn(self):
        cfg = self.cfg

        def fn(params, obs):
            q, _, _ = policy_apply(params, cfg, obs)
            return q, jnp.max(q, axis=-1)  # greedy value as "V"

        return fn

    def epsilon(self, step):
        """Linear ε schedule: ``eps_start → eps_end`` over ``eps_steps``
        train steps, clamped at both endpoints. Works on concrete ints and
        traced step counters alike."""
        hp = self.hp
        frac = jnp.clip(step / hp.eps_steps, 0.0, 1.0)
        return hp.eps_start + (hp.eps_end - hp.eps_start) * frac

    def init_state(self, capacity: int, obs_shape, params, obs_dtype=jnp.float32):
        return {
            "replay": replay_init(capacity, obs_shape, obs_dtype),
            "target": params,
            "updates": jnp.zeros((), jnp.int32),
        }

    def make_train_step(self, env, optimizer, lr_schedule):
        cfg, hp = self.cfg, self.hp

        def q_of(params, obs):
            q, _, _ = policy_apply(params, cfg, obs)
            return q

        def loss_fn(params, target_params, batch):
            return dqn_loss(params, target_params, batch, cfg, hp.gamma)

        def train_step(params, opt_state, agent_state, env_state, obs, key, step):
            # ---- acting: ε-greedy master over all actors (lines 4-10) -----
            def body(carry, _):
                env_state, obs, agent_state, key = carry
                key, k_eps, k_act, k_env = jax.random.split(key, 4)
                q = q_of(params, obs)
                greedy = jnp.argmax(q, axis=-1)
                rand = jax.random.randint(k_act, greedy.shape, 0, q.shape[-1])
                explore = (jax.random.uniform(k_eps, greedy.shape)
                           < self.epsilon(step))
                action = jnp.where(explore, rand, greedy)
                env_state, next_obs, reward, done = env.step(env_state, action, k_env)
                replay = replay_add(
                    agent_state["replay"], obs, action, reward, next_obs, done
                )
                agent_state = dict(agent_state, replay=replay)
                return (env_state, next_obs, agent_state, key), (reward, done)

            (env_state, obs, agent_state, key), (rewards, dones) = jax.lax.scan(
                body, (env_state, obs, agent_state, key), None, length=hp.t_max
            )

            # ---- synchronous batched update from replay --------------------
            key, k_s = jax.random.split(key)
            batch = replay_sample(agent_state["replay"], k_s, hp.batch_size)
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, agent_state["target"], batch
            )
            lr = lr_schedule(step)
            params, opt_state = optimizer.update(grads, opt_state, params, lr)

            target, updates = dqn_sync_target(
                agent_state["target"], params, agent_state["updates"],
                hp.target_sync,
            )
            agent_state = dict(agent_state, target=target, updates=updates)
            metrics = dict(metrics)
            metrics["loss"] = loss
            metrics["reward_sum"] = jnp.sum(rewards)
            metrics["episodes"] = jnp.sum(dones)
            return params, opt_state, agent_state, env_state, obs, key, metrics

        return train_step
