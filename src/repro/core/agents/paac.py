"""Parallel Advantage Actor-Critic — the paper's demonstrated instance (§4).

Losses are the paper's equations (10)–(11):

  ∇θ  ≈ 1/(n_e·t_max) Σ_e Σ_t (R_t − V(s_t)) ∇ log π(a_t|s_t) + β ∇ H(π)
  ∇θv ≈ ∇ 1/(n_e·t_max) Σ_e Σ_t (R_t − V(s_t))²

with the shared-trunk two-headed network of §5.1, RMSProp with shared
statistics and global-norm clipping at 40. One ``train_step`` call is one
full Algorithm-1 iteration (rollout → returns → synchronous update) as a
single compiled program.

Two train-step flavours:
* ``make_train_step``      — environment-in-the-loop (CNN/vector envs).
* ``make_llm_train_step``  — trajectory-batch form for token environments /
  the assigned architectures: the batch is {tokens (B,T+1), rewards (B,T),
  dones (B,T)} and one sequence is one actor's trajectory. This is the form
  lowered in the multi-pod dry-run (train_4k).
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.agents.base import Agent
from repro.core.returns import n_step_returns
from repro.core.rollout import rollout
from repro.models import policy_apply


class PAACConfig(NamedTuple):
    gamma: float = 0.99
    entropy_beta: float = 0.01
    t_max: int = 5
    value_coef: float = 0.5
    moe_aux_coef: float = 0.01


def paac_losses(logits, values, actions, returns, beta, value_coef,
                weights=None):
    """Equations (10) and (11), averaged over the n_e·t_max batch.

    logits: (N, A) fp32; values/returns: (N,); actions: (N,) int.
    weights: optional (N,) per-sample importance weights (stop-gradient),
    used by the asynchronous pipeline's staleness correction; ``None`` is the
    paper's on-policy case (all ones).
    """
    logp = jax.nn.log_softmax(logits)
    logp_a = jnp.take_along_axis(logp, actions[:, None], axis=1)[:, 0]
    adv = jax.lax.stop_gradient(returns - values)
    if weights is None:
        w = 1.0
    else:
        w = jax.lax.stop_gradient(weights)
    policy_loss = -jnp.mean(w * adv * logp_a)
    entropy = -jnp.mean(jnp.sum(jnp.exp(logp) * logp, axis=-1))
    value_loss = jnp.mean(w * jnp.square(returns - values))
    total = policy_loss - beta * entropy + value_coef * value_loss
    return total, {
        "policy_loss": policy_loss,
        "value_loss": value_loss,
        "entropy": entropy,
    }


def trajectory_logits_values(params, cfg, traj):
    """One batched learning-pass forward over a time-major ``Transition``.

    Returns ``(logits (N, A), values (N,))`` flattened time-major to the
    n_e·t_max batch (index = t·n_e + e). The pipelined V-trace learner uses
    this directly (it builds its own targets from the importance ratios).
    """
    T, E = traj.action.shape
    obs = traj.obs.reshape((T * E,) + traj.obs.shape[2:])
    if cfg.family == "cnn":
        logits, values, _ = policy_apply(params, cfg, obs)
    else:
        lg, vl, _ = policy_apply(params, cfg, obs)
        logits, values = lg[:, -1], vl[:, -1]
    return logits, values


def trajectory_forward(params, cfg, hp, traj, bootstrap):
    """Recompute the learning-pass forward over a time-major ``Transition``.

    Shared by the fused synchronous train step and the pipelined learner
    (``repro.pipeline.learner``) so the two backends optimize the same
    quantities. Returns ``(logits, values, actions, returns)`` flattened to
    the n_e·t_max batch the paper's equations average over.
    """
    T, E = traj.action.shape
    logits, values = trajectory_logits_values(params, cfg, traj)
    returns = n_step_returns(
        traj.reward.T, traj.done.T, bootstrap, hp.gamma
    )  # (E, T)
    returns = returns.T.reshape(T * E)
    actions = traj.action.reshape(T * E)
    return logits, values, actions, returns


class PAACAgent(Agent):
    """The paper's agent. model cfg + hyperparameters -> jittable steps."""

    on_policy = True

    def __init__(self, cfg, hp: PAACConfig = PAACConfig()):
        self.cfg = cfg
        self.hp = hp

    # -- acting --------------------------------------------------------------
    def act_fn(self):
        cfg = self.cfg

        def fn(params, obs):
            if cfg.family == "cnn":
                logits, value, _ = policy_apply(params, cfg, obs)
                return logits, value
            # token policies: obs is the token context; act on last position
            logits, values, _ = policy_apply(params, cfg, obs)
            return logits[:, -1], values[:, -1]

        return fn

    # -- env-in-the-loop train step (Algorithm 1) ----------------------------
    def make_train_step(self, env, optimizer, lr_schedule):
        cfg, hp = self.cfg, self.hp
        act = self.act_fn()

        def loss_fn(params, traj, bootstrap):
            # recompute forward over the whole n_e·t_max batch (one big
            # batched pass — the paper's batched learning)
            logits, values, actions, returns = trajectory_forward(
                params, cfg, hp, traj, bootstrap
            )
            return paac_losses(
                logits, values, actions, returns, hp.entropy_beta, hp.value_coef
            )

        def train_step(params, opt_state, env_state, obs, key, step):
            env_state, last_obs, key, traj = rollout(
                act, env, params, env_state, obs, key, hp.t_max
            )
            _, bootstrap = act(params, last_obs)  # V(s_{tmax+1})
            bootstrap = jax.lax.stop_gradient(bootstrap)
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, traj, bootstrap
            )
            lr = lr_schedule(step)
            params, opt_state = optimizer.update(grads, opt_state, params, lr)
            metrics = dict(metrics)
            metrics["loss"] = loss
            metrics["reward_sum"] = jnp.sum(traj.reward)
            metrics["episodes"] = jnp.sum(traj.done)
            return params, opt_state, env_state, last_obs, key, metrics

        return train_step

    # -- trajectory-batch train step (token archs; lowered in the dry-run) ---
    def make_llm_train_step(self, optimizer, lr_schedule):
        cfg, hp = self.cfg, self.hp

        def loss_fn(params, batch):
            tokens = batch["tokens"]  # (B, T+1)
            inputs, actions = tokens[:, :-1], tokens[:, 1:]
            prefix = batch.get("prefix", batch.get("frames"))
            logits, values, aux = policy_apply(
                params, cfg, inputs, prefix, train=True
            )
            if cfg.prefix_len:  # score text positions only (vlm)
                logits = logits[:, cfg.prefix_len:]
                values = values[:, cfg.prefix_len:]
            B, T = actions.shape
            bootstrap = values[:, -1]
            returns = n_step_returns(batch["rewards"], batch["dones"], bootstrap, hp.gamma)
            total, metrics = paac_losses(
                logits.reshape(B * T, -1),
                values.reshape(B * T),
                actions.reshape(B * T),
                returns.reshape(B * T),
                hp.entropy_beta,
                hp.value_coef,
            )
            if "moe_aux" in aux:
                total = total + hp.moe_aux_coef * aux["moe_aux"]
            return total, metrics

        def train_step(params, opt_state, batch, step):
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch
            )
            lr = lr_schedule(step)
            params, opt_state = optimizer.update(grads, opt_state, params, lr)
            metrics = dict(metrics)
            metrics["loss"] = loss
            return params, opt_state, metrics

        return train_step
