"""Agent API — the framework is algorithm agnostic (paper §3, §6).

An agent supplies:
* ``act(params, obs, key) -> (action, aux)`` — batched action selection,
* ``update(params, opt_state, agent_state, batch, key) -> (...)`` — one
  synchronous learning step from a batch of experiences.

The PAAC orchestrator (``repro.core.framework``) composes either with the
master/worker rollout. On-policy agents (PAAC-A2C) consume the fresh
trajectory; off-policy agents (DQN) route it through replay memory —
exercising the paper's claim that the framework covers on-policy,
off-policy, value-based and policy-gradient algorithms.
"""
from __future__ import annotations

import abc


class Agent(abc.ABC):
    on_policy: bool = True

    @abc.abstractmethod
    def act_fn(self):
        """Returns (params, obs) -> (logits, value) used by the master."""

    @abc.abstractmethod
    def make_train_step(self, env, optimizer, lr_schedule):
        """Returns a jittable train_step closure."""
