"""PPO inside the PAAC framework — a beyond-paper extension.

The paper argues its framework hosts "any other reinforcement learning
algorithm" (§4). PAAC-A2C takes one gradient step per batch; PPO's clipped
surrogate allows several epochs over the same synchronous batch — a natural
fit because the framework already stores acting-time log-probs in the
trajectory (rollout.Transition.logp). Uses GAE (returns.gae_advantages).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.agents.base import Agent
from repro.core.returns import gae_advantages
from repro.core.rollout import rollout
from repro.models import policy_apply


class PPOConfig(NamedTuple):
    gamma: float = 0.99
    lam: float = 0.95
    clip_eps: float = 0.2
    entropy_beta: float = 0.01
    value_coef: float = 0.5
    t_max: int = 16
    epochs: int = 4


class PPOAgent(Agent):
    on_policy = True

    def __init__(self, cfg, hp: PPOConfig = PPOConfig()):
        self.cfg = cfg
        self.hp = hp

    def act_fn(self):
        cfg = self.cfg

        def fn(params, obs):
            logits, value, _ = policy_apply(params, cfg, obs)
            if cfg.family != "cnn":
                logits, value = logits[:, -1], value[:, -1]
            return logits, value

        return fn

    def make_train_step(self, env, optimizer, lr_schedule):
        cfg, hp = self.cfg, self.hp
        act = self.act_fn()

        def loss_fn(params, traj, adv, returns):
            T, E = traj.action.shape
            obs = traj.obs.reshape((T * E,) + traj.obs.shape[2:])
            logits, values, _ = policy_apply(params, cfg, obs)
            if cfg.family != "cnn":
                logits, values = logits[:, -1], values[:, -1]
            logp_all = jax.nn.log_softmax(logits)
            actions = traj.action.reshape(T * E)
            logp = jnp.take_along_axis(logp_all, actions[:, None], 1)[:, 0]
            ratio = jnp.exp(logp - traj.logp.reshape(T * E))
            a = adv.reshape(T * E)
            a = (a - a.mean()) / (a.std() + 1e-8)
            unclipped = ratio * a
            clipped = jnp.clip(ratio, 1 - hp.clip_eps, 1 + hp.clip_eps) * a
            policy_loss = -jnp.mean(jnp.minimum(unclipped, clipped))
            value_loss = jnp.mean(jnp.square(returns.reshape(T * E) - values))
            entropy = -jnp.mean(jnp.sum(jnp.exp(logp_all) * logp_all, -1))
            total = policy_loss + hp.value_coef * value_loss - hp.entropy_beta * entropy
            return total, {
                "policy_loss": policy_loss,
                "value_loss": value_loss,
                "entropy": entropy,
                "clip_frac": jnp.mean((jnp.abs(ratio - 1) > hp.clip_eps).astype(jnp.float32)),
            }

        def train_step(params, opt_state, env_state, obs, key, step):
            env_state, last_obs, key, traj = rollout(
                act, env, params, env_state, obs, key, hp.t_max
            )
            _, bootstrap = act(params, last_obs)
            adv, returns = gae_advantages(
                traj.reward.T, traj.done.T, traj.value.T,
                jax.lax.stop_gradient(bootstrap), hp.gamma, hp.lam,
            )  # (E, T)
            adv, returns = adv.T, returns.T  # time-major to match traj

            def epoch(carry, _):
                params, opt_state = carry
                (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, traj, adv, returns
                )
                params, opt_state = optimizer.update(
                    grads, opt_state, params, lr_schedule(step)
                )
                return (params, opt_state), (loss, metrics)

            (params, opt_state), (losses, metrics) = jax.lax.scan(
                epoch, (params, opt_state), None, length=hp.epochs
            )
            out = {k: v[-1] for k, v in metrics.items()}
            out["loss"] = losses[-1]
            out["reward_sum"] = jnp.sum(traj.reward)
            out["episodes"] = jnp.sum(traj.done)
            return params, opt_state, env_state, last_obs, key, out

        return train_step
