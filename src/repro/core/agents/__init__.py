from repro.core.agents.base import Agent
from repro.core.agents.paac import PAACAgent, PAACConfig, paac_losses
from repro.core.agents.dqn import DQNAgent, DQNConfig
from repro.core.agents.baselines import LaggedPAACAgent, LaggedConfig
from repro.core.agents.ppo import PPOAgent, PPOConfig

__all__ = [
    "Agent",
    "PAACAgent",
    "PAACConfig",
    "paac_losses",
    "DQNAgent",
    "DQNConfig",
    "LaggedPAACAgent",
    "LaggedConfig",
    "PPOAgent",
    "PPOConfig",
]
