"""Deterministic emulations of the baselines the paper compares against.

The paper's argument (§1, §3) is that PAAC avoids two specific failure
modes, which we reproduce *as controlled pathologies* so benchmarks can
compare convergence:

* **A3C-sim** — stale gradients: gradients are computed w.r.t. a parameter
  copy that lags ``delay`` updates behind (gradients "computed w.r.t. stale
  parameters while updates applied to a new parameter set", fn.1). Updates
  remain sequential (we do not model lock-free write races, which are not
  representable deterministically — noted in DESIGN.md).
* **GA3C-sim** — policy lag: actions are selected with a parameter copy that
  lags ``lag`` updates behind the learner (GA3C's queue between predictor
  and trainer), so learning is slightly off-policy exactly as described in
  Babaeizadeh et al. 2016.

Setting delay/lag = 0 recovers exact PAAC — giving a clean ablation axis.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.agents.paac import PAACAgent, PAACConfig, paac_losses
from repro.core.returns import n_step_returns
from repro.core.rollout import rollout
from repro.models import policy_apply


class LaggedConfig(NamedTuple):
    gamma: float = 0.99
    entropy_beta: float = 0.01
    t_max: int = 5
    value_coef: float = 0.5
    delay: int = 4  # parameter-copy staleness in updates


class LaggedPAACAgent(PAACAgent):
    """A2C with a lagging parameter copy.

    mode="grad"  -> A3C-sim  (gradient computed at stale params)
    mode="act"   -> GA3C-sim (actions sampled from stale params)
    """

    def __init__(self, cfg, hp: LaggedConfig = LaggedConfig(), mode: str = "grad"):
        super().__init__(cfg, PAACConfig(hp.gamma, hp.entropy_beta, hp.t_max, hp.value_coef))
        assert mode in ("grad", "act")
        self.lag_hp = hp
        self.mode = mode

    def init_state(self, params):
        return {"stale": params, "since": jnp.zeros((), jnp.int32)}

    def make_train_step(self, env, optimizer, lr_schedule):
        cfg, hp = self.cfg, self.lag_hp
        act = self.act_fn()
        mode = self.mode

        def loss_fn(params, traj, bootstrap):
            T, E = traj.action.shape
            obs = traj.obs.reshape((T * E,) + traj.obs.shape[2:])
            if cfg.family == "cnn":
                logits, values, _ = policy_apply(params, cfg, obs)
            else:
                lg, vl, _ = policy_apply(params, cfg, obs)
                logits, values = lg[:, -1], vl[:, -1]
            returns = n_step_returns(traj.reward.T, traj.done.T, bootstrap, hp.gamma)
            return paac_losses(
                logits,
                values,
                traj.action.reshape(T * E),
                returns.T.reshape(T * E),
                hp.entropy_beta,
                hp.value_coef,
            )

        def train_step(params, opt_state, agent_state, env_state, obs, key, step):
            stale = agent_state["stale"]
            acting_params = stale if mode == "act" else params
            env_state, last_obs, key, traj = rollout(
                act, env, acting_params, env_state, obs, key, hp.t_max
            )
            _, bootstrap = act(acting_params, last_obs)
            bootstrap = jax.lax.stop_gradient(bootstrap)
            grad_params = stale if mode == "grad" else params
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                grad_params, traj, bootstrap
            )
            # the update is applied to the CURRENT params (the inconsistency)
            lr = lr_schedule(step)
            params, opt_state = optimizer.update(grads, opt_state, params, lr)

            since = agent_state["since"] + 1
            refresh = since >= hp.delay
            stale = jax.tree_util.tree_map(
                lambda s, p: jnp.where(refresh, p, s), stale, params
            )
            agent_state = {"stale": stale, "since": jnp.where(refresh, 0, since)}
            metrics = dict(metrics)
            metrics["loss"] = loss
            metrics["reward_sum"] = jnp.sum(traj.reward)
            metrics["episodes"] = jnp.sum(traj.done)
            return params, opt_state, agent_state, env_state, last_obs, key, metrics

        return train_step
