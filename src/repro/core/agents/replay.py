"""Device-resident circular replay memory (Gorila/DQN-style substrate).

The paper positions multiple parallel actors as an *on-line experience
memory* (§3); this module provides the classic *off-line* one so the
framework also hosts off-policy algorithms (its algorithm-agnosticism
claim). Fixed-capacity ring buffer, pure-functional add/sample.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def replay_init(capacity: int, obs_shape, obs_dtype=jnp.float32) -> Dict:
    return {
        "obs": jnp.zeros((capacity,) + tuple(obs_shape), obs_dtype),
        "action": jnp.zeros((capacity,), jnp.int32),
        "reward": jnp.zeros((capacity,), jnp.float32),
        "next_obs": jnp.zeros((capacity,) + tuple(obs_shape), obs_dtype),
        "done": jnp.zeros((capacity,), bool),
        "ptr": jnp.zeros((), jnp.int32),
        "size": jnp.zeros((), jnp.int32),
    }


def replay_add(buf: Dict, obs, action, reward, next_obs, done) -> Dict:
    """Add a batch of transitions (E, ...) at the ring pointer.

    Requires ``E <= capacity``: with a wider batch the modular index wraps
    onto itself and ``.at[idx].set`` writes duplicate indices, whose
    application order XLA leaves unspecified — the buffer would silently
    hold an arbitrary subset of the batch. Both sizes are static shapes, so
    the misuse is rejected at trace time rather than sampled as garbage
    later.
    """
    E = action.shape[0]
    cap = buf["action"].shape[0]
    if E > cap:
        raise ValueError(
            f"replay_add: batch of {E} transitions exceeds capacity {cap} — "
            "duplicate scatter indices have unspecified write order; grow "
            "the buffer or split the batch"
        )
    idx = (buf["ptr"] + jnp.arange(E)) % cap
    return {
        "obs": buf["obs"].at[idx].set(obs),
        "action": buf["action"].at[idx].set(action.astype(jnp.int32)),
        "reward": buf["reward"].at[idx].set(reward),
        "next_obs": buf["next_obs"].at[idx].set(next_obs),
        "done": buf["done"].at[idx].set(done),
        "ptr": (buf["ptr"] + E) % cap,
        "size": jnp.minimum(buf["size"] + E, cap),
    }


def replay_sample(buf: Dict, key, batch_size: int) -> Dict:
    """Uniformly sample ``batch_size`` stored transitions (with replacement).

    An empty buffer has nothing to sample: the ``max(size, 1)`` guard below
    exists only so the draw bound stays positive *under jit*, where ``size``
    is a tracer and cannot be branched on — there the caller owns the
    never-sample-before-first-add invariant (the scan-based DQN train step
    adds ``t_max·E`` transitions before its first sample, so the invariant
    holds by construction). When ``size`` is concrete (eager callers), an
    empty buffer raises instead of returning the zero-initialized garbage
    rows it used to.
    """
    size = buf["size"]
    if not isinstance(size, jax.core.Tracer) and int(size) == 0:
        raise ValueError(
            "replay_sample on an empty buffer — it would return "
            "zero-initialized garbage transitions; add before sampling"
        )
    idx = jax.random.randint(key, (batch_size,), 0, jnp.maximum(size, 1))
    return {
        "obs": buf["obs"][idx],
        "action": buf["action"][idx],
        "reward": buf["reward"][idx],
        "next_obs": buf["next_obs"][idx],
        "done": buf["done"][idx],
    }
