"""PAAC framework orchestrator — paper Algorithm 1 end to end.

``ParallelRL`` wires environments + agent + optimizer into a single jitted
``train_step`` and runs the outer ``until N >= N_max`` loop (line 3/20) on
the host, tracking throughput (timesteps/s — the paper's Fig. 2/4 metric)
and episode returns.

Two environment regimes:

* JAX-native ``VectorEnv`` — acting, stepping and learning fuse into one
  XLA program per iteration (the fast path).
* ``HostEnvPool`` — external gym-style envs stepped by host worker threads
  (paper §3 literally). Here one iteration is a host-side rollout (jitted
  acting, threaded env stepping) followed by a jitted update. This is the
  paper's Fig. 2 "env time on the critical path" regime; the asynchronous
  pipeline (``repro.pipeline``) exists to overlap exactly that stall.

The run-loop metrics accounting is shared with ``repro.pipeline`` through
``MetricsAccumulator`` so both backends report identical ``RunResult``s.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core.agents.base import Agent
from repro.core.agents.dqn import DQNAgent
from repro.core.agents.baselines import LaggedPAACAgent
from repro.envs.host_env import HostEnvPool
from repro.models import init_policy
from repro.optim import make_optimizer
from repro.utils import get_logger

log = get_logger("framework")


@dataclass
class RunResult:
    steps: int
    episodes: float
    mean_metrics: Dict[str, float]
    episode_reward_rate: List[float] = field(default_factory=list)
    timesteps_per_sec: float = 0.0
    # pipeline accounting (0 for the synchronous backend): time the actors
    # spent blocked on a full queue / waiting for params (merged across
    # replicas), and time the learner spent blocked on an empty queue.
    # ``per_actor_idle_s[i]`` attributes the merged actor idle time to
    # replica i; it sums to ``actor_idle_s`` exactly.
    actor_idle_s: float = 0.0
    learner_idle_s: float = 0.0
    per_actor_idle_s: List[float] = field(default_factory=list)


class MetricsAccumulator:
    """Shared run-loop accounting: per-iteration metric dicts → RunResult.

    Used by both the synchronous ``ParallelRL`` loop and the pipelined
    learner loop so the two backends report identical metric semantics
    (mean-per-iteration metrics, episode counts, timesteps/s over the run's
    wall-clock).

    ``lazy=True`` defers the host conversion of device metric scalars: each
    ``update`` only stashes the dict, and the blocking ``float()`` reads
    happen once, in ``result``. Eager mode forces a device sync every
    iteration — which the synchronous loop doesn't notice (it waits for the
    update anyway) and the host queue plane *requires* (consume-completion
    gates the staging ``release`` protocol), but which would serialize the
    device-ring learner against every update it dispatches. Lazy draining
    accumulates in exactly the same host-side float arithmetic, so the two
    modes report bit-identical metrics; the wall clock is read *after* the
    drain, so timesteps/s still covers the full execution, not just the
    dispatches.
    """

    def __init__(self, lazy: bool = False):
        self.acc: Dict[str, float] = {}
        self.episodes = 0.0
        self.iters = 0
        self.lazy = lazy
        self._pending: List[Dict] = []
        self._last: Dict = {}  # most recently *folded* metrics dict
        self._t0 = time.perf_counter()

    def update(self, metrics: Dict) -> None:
        self.iters += 1
        if self.lazy:
            self._pending.append(metrics)
            return
        self._fold(metrics)

    def _fold(self, metrics: Dict) -> None:
        for k, v in metrics.items():
            self.acc[k] = self.acc.get(k, 0.0) + float(v)
        self.episodes += float(metrics.get("episodes", 0.0))
        self._last = metrics

    def _drain(self) -> None:
        for metrics in self._pending:
            self._fold(metrics)
        self._pending.clear()

    @staticmethod
    def _ready(metrics: Dict) -> bool:
        # jax.Array.is_ready() == "execution producing this buffer retired";
        # host values (python/numpy scalars) have no is_ready and are ready
        return all(
            is_ready() if (is_ready := getattr(v, "is_ready", None)) else True
            for v in metrics.values()
        )

    def drain_ready(self) -> None:
        """Fold only the pending dicts whose device scalars have already
        materialized, front of the queue first, stopping at the first
        still-executing update. Never blocks and never forces a device
        sync — the in-flight tail keeps pipelining."""
        while self._pending and self._ready(self._pending[0]):
            self._fold(self._pending.pop(0))

    def cumulative(self, key: str, default: float = 0.0) -> float:
        """Running sum of one metric (drains pending device scalars first —
        a sync point, so only for explicit logging paths)."""
        self._drain()
        return self.acc.get(key, default)

    def cumulative_nowait(self, key: str, default: float = 0.0) -> float:
        """Running sum over *already-executed* updates only: the hot-loop
        logging read. Same float arithmetic as ``cumulative`` but the tail
        of still-dispatching updates is simply not yet included."""
        self.drain_ready()
        return self.acc.get(key, default)

    def last(self, key: str, default: float = 0.0) -> float:
        """Latest folded value of one metric (already host-side — free)."""
        return float(self._last.get(key, default))

    def result(self, steps: int, steps_per_iter: int, **extra) -> RunResult:
        self._drain()  # blocks until every dispatched update has executed
        dt = time.perf_counter() - self._t0
        mean = {k: v / max(self.iters, 1) for k, v in self.acc.items()}
        return RunResult(
            steps=steps,
            episodes=self.episodes,
            mean_metrics=mean,
            timesteps_per_sec=steps_per_iter * self.iters / max(dt, 1e-9),
            **extra,
        )


def init_rl_common(env, agent, optimizer: str, lr_schedule, seed: int):
    """Shared constructor half of ``ParallelRL`` and ``PipelinedRL``.

    Returns ``(optimizer, lr_schedule, key, k_env, params, opt_state)``. The
    RNG layout here is load-bearing: both backends must split the seed key
    identically so a lock-stepped pipeline reproduces the synchronous run
    bit-for-bit.
    """
    opt = make_optimizer(optimizer)
    if lr_schedule is None:
        from repro.optim import constant

        lr_schedule = constant(0.0007 * env.n_envs)  # paper §5.2 rule
    key, k_init, k_env = jax.random.split(jax.random.PRNGKey(seed), 3)
    params = init_policy(k_init, agent.cfg)
    return opt, lr_schedule, key, k_env, params, opt.init(params)


class ParallelRL:
    """The paper's master/worker framework, compiled to one program/iteration."""

    def __init__(
        self,
        env,
        agent: Agent,
        *,
        optimizer: str = "rmsprop",
        lr_schedule: Optional[Callable] = None,
        seed: int = 0,
        replay_capacity: int = 50_000,
    ):
        self.env = env
        self.agent = agent
        (self.optimizer, self.lr_schedule, self.key, k_env, self.params,
         self.opt_state) = init_rl_common(env, agent, optimizer, lr_schedule,
                                          seed)

        self._host = isinstance(env, HostEnvPool)
        if self._host:
            from repro.core.agents.paac import PAACAgent

            # exact type: subclasses/look-alikes (LaggedPAACAgent, PPOAgent,
            # DQNAgent) need their own update step, which the shared host
            # learner step would silently replace with the plain PAAC loss
            if type(agent) is not PAACAgent:
                raise NotImplementedError(
                    "HostEnvPool currently drives plain PAACAgent "
                    f"(got {type(agent).__name__})"
                )
            self._has_agent_state = False
            self.agent_state = None
            self.env_state = None
            self.obs = env.reset()
            from repro.pipeline.actor import (
                StagingSet,
                collect_host,
                make_host_act_step,
            )

            self._collect_host = collect_host
            self._act = make_host_act_step(agent.act_fn())
            # one reusable trajectory staging set: the synchronous loop fully
            # consumes each update (MetricsAccumulator blocks on the metric
            # scalars) before the next rollout overwrites the buffers, so a
            # single set is race-free — zero numpy allocation per iteration
            self._staging = StagingSet(agent.hp.t_max, env.n_envs,
                                       env.obs_shape, env.obs_dtype)
            # shared with the pipelined learner: same jitted update step,
            # with infinite V-trace clips — the correction compiled out
            # exactly (behaviour == learner here), so a lock-stepped pipeline
            # matches this driver bit-for-bit.
            from repro.pipeline.learner import make_learner_step

            self._update_step = jax.jit(
                make_learner_step(agent, self.optimizer, self.lr_schedule,
                                  rho_bar=float("inf"), c_bar=float("inf")),
                donate_argnums=(1,),
            )
            self._train_step = None
        else:
            self.env_state = env.reset(k_env)
            self.obs = env.observe(self.env_state)

            self._has_agent_state = isinstance(agent, (DQNAgent, LaggedPAACAgent))
            if isinstance(agent, DQNAgent):
                self.agent_state = agent.init_state(
                    replay_capacity, env.obs_shape, self.params, self.obs.dtype
                )
            elif isinstance(agent, LaggedPAACAgent):
                self.agent_state = agent.init_state(self.params)
            else:
                self.agent_state = None

            self._train_step = jax.jit(
                agent.make_train_step(env, self.optimizer, self.lr_schedule)
            )
        self.total_steps = 0
        self._steps_per_iter = env.n_envs * agent.hp.t_max

    # -- one iteration on the HostEnvPool path -------------------------------
    def _host_iteration(self, step_arr):
        self.obs, self.key, traj, last_obs = self._collect_host(
            self._act, self.env, self.params, self.obs, self.key,
            self.agent.hp.t_max, staging=self._staging,
        )
        self.params, self.opt_state, metrics = self._update_step(
            self.params, self.opt_state, traj, last_obs, step_arr
        )
        return metrics

    def run(self, iterations: int, log_every: int = 0) -> RunResult:
        """Run `iterations` framework iterations (each = n_e·t_max timesteps)."""
        acc = MetricsAccumulator()
        step_arr = jnp.asarray(self.total_steps, jnp.int32)
        for i in range(iterations):
            if self._host:
                metrics = self._host_iteration(step_arr)
            elif self._has_agent_state:
                (
                    self.params,
                    self.opt_state,
                    self.agent_state,
                    self.env_state,
                    self.obs,
                    self.key,
                    metrics,
                ) = self._train_step(
                    self.params, self.opt_state, self.agent_state,
                    self.env_state, self.obs, self.key, step_arr,
                )
            else:
                (
                    self.params,
                    self.opt_state,
                    self.env_state,
                    self.obs,
                    self.key,
                    metrics,
                ) = self._train_step(
                    self.params, self.opt_state, self.env_state, self.obs,
                    self.key, step_arr,
                )
            self.total_steps += self._steps_per_iter
            step_arr = step_arr + 1
            acc.update(metrics)
            if log_every and (i + 1) % log_every == 0:
                log.info(
                    "iter %d steps %d reward_sum %.3f loss %.4f",
                    i + 1, self.total_steps,
                    acc.acc.get("reward_sum", 0.0),
                    float(metrics.get("loss", 0.0)),
                )
        return acc.result(self.total_steps, self._steps_per_iter)
