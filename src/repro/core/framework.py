"""PAAC framework orchestrator — paper Algorithm 1 end to end.

``ParallelRL`` wires environments + agent + optimizer into a single jitted
``train_step`` and runs the outer ``until N >= N_max`` loop (line 3/20) on
the host, tracking throughput (timesteps/s — the paper's Fig. 2/4 metric)
and episode returns.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core.agents.base import Agent
from repro.core.agents.dqn import DQNAgent
from repro.core.agents.baselines import LaggedPAACAgent
from repro.models import init_policy
from repro.optim import make_optimizer
from repro.utils import get_logger

log = get_logger("framework")


@dataclass
class RunResult:
    steps: int
    episodes: float
    mean_metrics: Dict[str, float]
    episode_reward_rate: List[float] = field(default_factory=list)
    timesteps_per_sec: float = 0.0


class ParallelRL:
    """The paper's master/worker framework, compiled to one program/iteration."""

    def __init__(
        self,
        env,
        agent: Agent,
        *,
        optimizer: str = "rmsprop",
        lr_schedule: Optional[Callable] = None,
        seed: int = 0,
        replay_capacity: int = 50_000,
    ):
        self.env = env
        self.agent = agent
        self.optimizer = make_optimizer(optimizer)
        if lr_schedule is None:
            from repro.optim import constant

            lr_schedule = constant(0.0007 * env.n_envs)  # paper §5.2 rule
        self.lr_schedule = lr_schedule

        key = jax.random.PRNGKey(seed)
        self.key, k_init, k_env = jax.random.split(key, 3)
        self.params = init_policy(k_init, agent.cfg)
        self.opt_state = self.optimizer.init(self.params)
        self.env_state = env.reset(k_env)
        self.obs = env.observe(self.env_state)

        self._has_agent_state = isinstance(agent, (DQNAgent, LaggedPAACAgent))
        if isinstance(agent, DQNAgent):
            self.agent_state = agent.init_state(
                replay_capacity, env.obs_shape, self.params, self.obs.dtype
            )
        elif isinstance(agent, LaggedPAACAgent):
            self.agent_state = agent.init_state(self.params)
        else:
            self.agent_state = None

        self._train_step = jax.jit(
            agent.make_train_step(env, self.optimizer, self.lr_schedule)
        )
        self.total_steps = 0
        self._steps_per_iter = env.n_envs * agent.hp.t_max

    def run(self, iterations: int, log_every: int = 0) -> RunResult:
        """Run `iterations` framework iterations (each = n_e·t_max timesteps)."""
        acc: Dict[str, float] = {}
        episodes = 0.0
        t0 = time.perf_counter()
        step_arr = jnp.asarray(self.total_steps, jnp.int32)
        for i in range(iterations):
            if self._has_agent_state:
                (
                    self.params,
                    self.opt_state,
                    self.agent_state,
                    self.env_state,
                    self.obs,
                    self.key,
                    metrics,
                ) = self._train_step(
                    self.params, self.opt_state, self.agent_state,
                    self.env_state, self.obs, self.key, step_arr,
                )
            else:
                (
                    self.params,
                    self.opt_state,
                    self.env_state,
                    self.obs,
                    self.key,
                    metrics,
                ) = self._train_step(
                    self.params, self.opt_state, self.env_state, self.obs,
                    self.key, step_arr,
                )
            self.total_steps += self._steps_per_iter
            step_arr = step_arr + 1
            for k, v in metrics.items():
                acc[k] = acc.get(k, 0.0) + float(v)
            episodes += float(metrics.get("episodes", 0.0))
            if log_every and (i + 1) % log_every == 0:
                log.info(
                    "iter %d steps %d reward_sum %.3f loss %.4f",
                    i + 1, self.total_steps,
                    acc.get("reward_sum", 0.0), float(metrics.get("loss", 0.0)),
                )
        dt = time.perf_counter() - t0
        mean = {k: v / iterations for k, v in acc.items()}
        return RunResult(
            steps=self.total_steps,
            episodes=episodes,
            mean_metrics=mean,
            timesteps_per_sec=self._steps_per_iter * iterations / max(dt, 1e-9),
        )
