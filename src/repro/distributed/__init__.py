from repro.distributed.constraints import constrain, axis_context
from repro.distributed.sharding import param_specs, input_sharding, SHARDING_MODES

__all__ = ["constrain", "axis_context", "param_specs", "input_sharding", "SHARDING_MODES"]
