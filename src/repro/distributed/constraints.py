"""Activation sharding constraints that degrade to no-ops off-mesh.

Model code calls ``constrain(x, "data", "model", None, ...)`` with *logical*
axis names. When a mesh context is active (set by the launcher / dry-run via
``axis_context``), this becomes ``jax.lax.with_sharding_constraint``;
in single-device unit tests it is a no-op, so model code is mesh-agnostic.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def _current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def axis_context(mesh: Mesh):
    """Enable sharding constraints for model code within this context."""
    prev = getattr(_state, "mesh", None)
    _state.mesh = mesh
    try:
        with mesh:
            yield mesh
    finally:
        _state.mesh = prev


def _resolve(mesh: Mesh, axis):
    """Map a logical axis to mesh axes actually present on this mesh.

    "data" maps to ("pod", "data") when a pod axis exists, so model code
    never needs to know whether it is running single- or multi-pod.
    """
    if axis is None:
        return None
    if axis == "data" and "pod" in mesh.axis_names:
        return ("pod", "data")
    return axis if axis in mesh.axis_names else None


def mesh_axis_size(axis: str) -> int:
    """Size of a mesh axis in the active context (1 when off-mesh)."""
    mesh = _current_mesh()
    if mesh is None:
        return 1
    sizes = dict(mesh.shape)
    if axis == "data":
        return sizes.get("data", 1) * sizes.get("pod", 1)
    return sizes.get(axis, 1)


def constrain(x, *axes):
    """with_sharding_constraint(x, P(*axes)) if a mesh is active, else x."""
    mesh = _current_mesh()
    if mesh is None:
        return x
    spec = P(*[_resolve(mesh, a) for a in axes])
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
