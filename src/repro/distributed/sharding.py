"""Parameter / cache / input sharding rules (logical-axis based).

Two modes:

* ``"tp"``       — tensor parallel over "model" only; params replicated over
                   the data axis. Right for sub-1B models (mamba2-370m).
* ``"fsdp_tp"``  — 2D: tensor parallel over "model" PLUS parameter sharding
                   over "data" (FSDP/ZeRO-style — the contraction-dim shard
                   makes XLA all-gather weights per layer and reduce-scatter
                   grads). Required for the 100B+ archs whose fp32 optimizer
                   state cannot replicate over the data axis.

Rules are path-based over the plain-dict param trees produced by
``repro.models``. Scan-stacked layers carry extra leading axes; a rule
specifies the spec for the *trailing* dims and leading axes get None.
Any dim that does not divide its mesh axis is left unsharded (e.g. kv=2
heads against a 16-way model axis; batch=1 at long_500k).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.tree_util import DictKey, GetAttrKey, SequenceKey

SHARDING_MODES = ("tp", "fsdp_tp", "zero1")
# "zero1": parameters tensor-parallel only (replicated over data — no
# per-layer weight gathers in fwd/bwd), optimizer state sharded over data
# (ZeRO-1). XLA inserts one grad reduce-scatter + one updated-param
# all-gather per step instead of per-layer-use gathers. Right for dense
# archs whose params fit replicated-over-data (e.g. <=33B bf16 on v5e);
# the 100B+ MoE archs still need fsdp_tp.

# parameter-name classes --------------------------------------------------
_UP_PROJ = {"wq", "wk", "wv", "wuq", "wukv", "wi", "wg", "w_z", "w_x", "w_dt"}
_DOWN_PROJ = {"wo", "out_proj"}
_SMALL_OUT = {"wdq", "wdkv", "w_B", "w_C"}  # fsdp-in, unsharded out
_HEAD_VECS = {"A_log", "D", "dt_bias"}  # per-SSM-head vectors -> "model"
_REPLICATED = {"scale", "bias", "b", "conv_B", "conv_C", "conv_b"}


def _path_str(path) -> str:
    parts = []
    for k in path:
        if isinstance(k, DictKey):
            parts.append(str(k.key))
        elif isinstance(k, SequenceKey):
            parts.append(str(k.idx))
        elif isinstance(k, GetAttrKey):
            parts.append(k.name)
        else:
            parts.append(str(k))
    return "/".join(parts)


def _axis_sizes(mesh) -> Dict[str, int]:
    # works for both Mesh and AbstractMesh
    return dict(mesh.shape)


def _data_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _data_size(mesh: Mesh) -> int:
    s = _axis_sizes(mesh)
    return s.get("data", 1) * s.get("pod", 1)


def _fits(dim: int, size: int) -> bool:
    return size > 1 and dim % size == 0


def _base_spec(path: str, name: str, shape, mesh: Mesh, mode: str):
    """Spec for the trailing dims of one leaf (no scan axes)."""
    sizes = _axis_sizes(mesh)
    msize = sizes.get("model", 1)
    dsize = _data_size(mesh)
    data = _data_axes(mesh)
    fsdp = mode == "fsdp_tp"

    def m(dim):  # model axis if divisible
        return "model" if _fits(dim, msize) else None

    def d(dim):  # (pod,)data axes if divisible
        return data if fsdp and _fits(dim, dsize) else None

    if name in _REPLICATED:
        return (None,) * min(len(shape), 1)
    if name == "embed":
        return (m(shape[-2]) or None, d(shape[-1]))
    if name == "conv_x":
        return (None, m(shape[-1]))
    if name in _HEAD_VECS:
        return (m(shape[-1]),)
    if "moe/" in path or path.endswith("moe"):
        if name in ("wi", "wg"):  # (E, d, ff)
            return (m(shape[-3]), d(shape[-2]), None)
        if name == "wo":  # (E, ff, d)
            return (m(shape[-3]), None, d(shape[-1]))
        if name == "w" and "router" in path:
            return (None, None)
    if "heads" in path:
        if "policy" in path and name == "w":
            return (d(shape[-2]), m(shape[-1]))
        return (None,) * min(len(shape), 2)
    if "frontend_proj" in path:
        return (None, None)
    if name == "w":
        # generic linear inside a named module: infer from parent name
        parent = path.split("/")[-2] if "/" in path else ""
        if parent in _UP_PROJ:
            return (d(shape[-2]), m(shape[-1]))
        if parent in _DOWN_PROJ:
            return (m(shape[-2]), d(shape[-1]))
        if parent in _SMALL_OUT:
            return (d(shape[-2]), None)
        return (None, None)
    return (None,) * min(len(shape), len(shape))


def param_specs(params_tree, mesh: Mesh, mode: str = "fsdp_tp"):
    """PartitionSpec pytree matching ``params_tree`` (arrays or SDS)."""
    assert mode in SHARDING_MODES

    def leaf_spec(path, leaf):
        pstr = _path_str(path)
        name = pstr.split("/")[-1]
        parent_path = "/".join(pstr.split("/")[:-1])
        shape = leaf.shape
        base = _base_spec(parent_path + "/" + name, name, shape, mesh, mode)
        base = tuple(base)[: len(shape)]
        pad = len(shape) - len(base)
        return P(*((None,) * pad + base))

    return jax.tree_util.tree_map_with_path(leaf_spec, params_tree)


def cache_specs(cache_tree, mesh: Mesh):
    """KV/state cache specs: batch -> data axes, head-ish dims -> model.

    Cache leaves look like (L, B, S, Hkv, D) / (L, B, S, rank) /
    (L, B, H, P, N) / (L, B, K-1, C). We shard dim 1 (batch) over data when
    divisible, and any later dim divisible by the model axis that represents
    heads/channels — conservatively only dims whose name implies heads would
    be ideal; shapes suffice here: we try dim -2 for 5D (heads) and dim -1
    for conv channels.
    """
    sizes = _axis_sizes(mesh)
    msize = sizes.get("model", 1)
    dsize = _data_size(mesh)
    data = _data_axes(mesh)

    def leaf_spec(path, leaf):
        shape = leaf.shape
        name = _path_str(path).split("/")[-1]
        spec = [None] * len(shape)
        # batch dim: caches are (L, B, ...) or (L, G, B, ...) for hybrid groups
        bdim = 1
        if len(shape) >= 3 and shape[1] < shape[0] and name in ():
            bdim = 1
        if len(shape) > bdim and _fits(shape[bdim], dsize):
            spec[bdim] = data
        elif len(shape) > bdim + 1 and _fits(shape[bdim + 1], dsize):
            spec[bdim + 1] = data  # hybrid: (G, every?, B, ...)
        if name in ("k", "v") and len(shape) >= 4 and _fits(shape[-2], msize):
            spec[-2] = "model"
        if name == "state" and _fits(shape[-3], msize):
            spec[-3] = "model"  # (.., H, P, N)
        if name == "conv_x" and _fits(shape[-1], msize):
            spec[-1] = "model"
        return P(*spec)

    return jax.tree_util.tree_map_with_path(leaf_spec, cache_tree)


def input_sharding(batch_tree, mesh: Mesh):
    """Batch inputs: dim 0 over (pod, data) when divisible, else replicated."""
    dsize = _data_size(mesh)
    data = _data_axes(mesh)

    def leaf_spec(leaf):
        shape = leaf.shape
        spec = [None] * len(shape)
        if shape and _fits(shape[0], dsize):
            spec[0] = data
        return P(*spec)

    return jax.tree_util.tree_map(leaf_spec, batch_tree)


def to_named(tree_of_specs, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree_of_specs,
        is_leaf=lambda x: isinstance(x, P),
    )


# ---------------------------------------------------------------------------
# Rollout sharding — the pipeline's mesh plane (env/batch data parallelism)
# ---------------------------------------------------------------------------
#
# RL trajectories have two canonical layouts: time-major ``(T, E, ...)``
# (``Transition`` leaves) and batch-leading ``(E, ...)`` (observations,
# bootstrap obs). The mesh rollout plane partitions exactly one axis — the
# env axis E — over the mesh's data axes; everything else (time, feature
# dims) stays unsharded, and the policy params replicate (they are small;
# the learner's gradient all-reduce over "data" is the only collective).


def replicated_sharding(mesh: Mesh) -> NamedSharding:
    """Fully-replicated placement (params, opt state, scalars)."""
    return NamedSharding(mesh, P())


def traj_sharding(mesh: Mesh, ndim: int) -> NamedSharding:
    """Time-major ``(T, E, ...)`` leaf: env axis (dim 1) over the data axes."""
    if ndim < 2:
        raise ValueError(f"time-major trajectory leaves are >= 2D, got {ndim}")
    data = _data_axes(mesh)
    axes = data if len(data) > 1 else data[0]
    return NamedSharding(mesh, P(*((None, axes) + (None,) * (ndim - 2))))


def batch_sharding(mesh: Mesh, ndim: int) -> NamedSharding:
    """Batch-leading ``(E, ...)`` leaf: env axis (dim 0) over the data axes."""
    if ndim < 1:
        raise ValueError("batch-leading leaves are >= 1D")
    data = _data_axes(mesh)
    axes = data if len(data) > 1 else data[0]
    return NamedSharding(mesh, P(*((axes,) + (None,) * (ndim - 1))))
