"""Pallas kernel: fused latent-space (absorbed) MLA decode attention.

The §Perf pair-A analysis ends with: the absorbed MLA decode still reads the
compressed cache twice (score pass + combine pass) — a fused kernel reads it
once. This kernel is that next step: single-token MLA attention entirely in
latent space, streaming the (c ‖ k_rope) cache through VMEM one block at a
time with online-softmax scratch:

    s_k    = q_lat · c_k + q_rope · kr_k          (per cached token k)
    out    = Σ softmax(s)_k · c_k                 (latent-space combine)

Inputs are the *absorbed* queries (W_uk already folded in — see
repro.models.attention.mla_decode); the caller applies W_uv afterwards.
Grid: (B, S/block_k) with fp32 (m, l, acc) scratch per head block.

Arithmetic intensity ≈ 2·H flops/byte over the latent cache — with H=128
(DeepSeek-V2) this is near the bf16 ridge point, i.e. the fused kernel turns
MLA decode from bandwidth- toward compute-bound, unlike GQA decode (G≤8).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(pos_ref, ql_ref, qr_ref, c_ref, kr_ref, o_ref, m_scr, l_scr,
            acc_scr, *, block_k, num_kb, scale):
    ki = pl.program_id(1)
    pos = pos_ref[0]

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    k_start = ki * block_k

    @pl.when(k_start <= pos)
    def _compute():
        ql = ql_ref[0].astype(jnp.float32)  # (H, R)
        qr = qr_ref[0].astype(jnp.float32)  # (H, Rr)
        c = c_ref[0].astype(jnp.float32)  # (bk, R)
        kr = kr_ref[0].astype(jnp.float32)  # (bk, Rr)
        s = jax.lax.dot_general(ql, c, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s + jax.lax.dot_general(qr, kr, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32)
        s = s * scale  # (H, bk)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(k_pos <= pos, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
        # combine in latent space: the SAME c block — one HBM read serves
        # both the score and the combine pass (the fusion win)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot(
            p.astype(c_ref.dtype), c_ref[0], preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(ki == num_kb - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def mla_decode_attention_pallas(
    q_lat: jnp.ndarray,  # (B, H, R)  — absorbed queries (W_uk folded in)
    q_rope: jnp.ndarray,  # (B, H, Rr)
    c_cache: jnp.ndarray,  # (B, S, R)  — compressed latent cache
    kr_cache: jnp.ndarray,  # (B, S, Rr) — shared roped keys
    pos,  # scalar int32: attend to slots <= pos
    scale: float,
    *,
    block_k: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    """Returns latent-space attention output (B, H, R)."""
    B, H, R = q_lat.shape
    _, S, Rr = kr_cache.shape
    block_k = min(block_k, S)
    pad = (-S) % block_k
    cc = jnp.pad(c_cache, ((0, 0), (0, pad), (0, 0))) if pad else c_cache
    kr = jnp.pad(kr_cache, ((0, 0), (0, pad), (0, 0))) if pad else kr_cache
    nk = (S + pad) // block_k
    pos_arr = jnp.full((1,), pos, jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, nk),
        in_specs=[
            pl.BlockSpec((1, H, R), lambda b, ki, pos_ref: (b, 0, 0)),
            pl.BlockSpec((1, H, Rr), lambda b, ki, pos_ref: (b, 0, 0)),
            pl.BlockSpec((1, block_k, R), lambda b, ki, pos_ref: (b, ki, 0)),
            pl.BlockSpec((1, block_k, Rr), lambda b, ki, pos_ref: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, R), lambda b, ki, pos_ref: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((H,), jnp.float32),
            pltpu.VMEM((H,), jnp.float32),
            pltpu.VMEM((H, R), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, block_k=block_k, num_kb=nk, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, R), q_lat.dtype),
        interpret=interpret,
    )(pos_arr, q_lat, q_rope, cc, kr)
