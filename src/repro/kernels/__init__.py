"""Pallas TPU kernels for the framework's compute hot-spots.

* ``nstep_returns``     — Algorithm 1's batched return recursion
* ``vtrace_returns``    — full V-trace targets for the asynchronous pipeline
* ``flash_attention``   — blocked online-softmax prefill attention
* ``decode_attention``  — flash-decoding against long KV caches
* ``ssd_scan``          — fused chunked Mamba2/SSD scan

Each kernel module pairs with ``ops.py`` (jit'd dispatch) and ``ref.py``
(pure-jnp oracle); tests sweep shapes/dtypes and assert allclose.
"""
from repro.kernels.ops import (
    decode_attention,
    flash_attention,
    nstep_returns,
    ssd_scan,
    vtrace_returns,
)

__all__ = [
    "nstep_returns",
    "vtrace_returns",
    "flash_attention",
    "decode_attention",
    "ssd_scan",
]
