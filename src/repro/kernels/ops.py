"""Jit'd dispatch wrappers around the Pallas kernels.

``backend="pallas"`` runs the TPU kernels (interpret mode on CPU — the
container target), ``backend="ref"`` the pure-jnp oracles. Model code and
benchmarks call these; tests sweep both and assert equality.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.mla_decode import mla_decode_attention_pallas
from repro.kernels.nstep_returns import nstep_returns_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas
from repro.kernels.vtrace import vtrace_returns_pallas

_INTERPRET = jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("gamma", "backend"))
def nstep_returns(rewards, dones, bootstrap, gamma: float, backend: str = "pallas"):
    if backend == "ref":
        return _ref.nstep_returns_ref(rewards, dones, bootstrap, gamma)
    return nstep_returns_pallas(rewards, dones, bootstrap, gamma, interpret=_INTERPRET)


@partial(jax.jit, static_argnames=("gamma", "rho_bar", "c_bar", "backend"))
def vtrace_returns(rewards, dones, values, bootstrap, rho, gamma: float,
                   rho_bar: float = 1.0, c_bar: float = 1.0,
                   backend: str = "pallas"):
    if backend == "ref":
        return _ref.vtrace_returns_ref(rewards, dones, values, bootstrap, rho,
                                       gamma, rho_bar, c_bar)
    return vtrace_returns_pallas(rewards, dones, values, bootstrap, rho, gamma,
                                 rho_bar, c_bar, interpret=_INTERPRET)


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k", "backend"))
def flash_attention(q, k, v, *, causal=True, window=0, block_q=128, block_k=128,
                    backend: str = "pallas"):
    if backend == "ref":
        return _ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, block_q=block_q, block_k=block_k,
        interpret=_INTERPRET,
    )


@partial(jax.jit, static_argnames=("block_k", "backend"))
def decode_attention(q, k_cache, v_cache, pos, *, block_k=512, backend: str = "pallas"):
    if backend == "ref":
        return _ref.decode_attention_ref(q, k_cache, v_cache, pos)
    return decode_attention_pallas(
        q, k_cache, v_cache, pos, block_k=block_k, interpret=_INTERPRET
    )


@partial(jax.jit, static_argnames=("scale", "block_k", "backend"))
def mla_decode_attention(q_lat, q_rope, c_cache, kr_cache, pos, scale: float,
                         *, block_k=512, backend: str = "pallas"):
    if backend == "ref":
        return _ref.mla_decode_attention_ref(q_lat, q_rope, c_cache, kr_cache,
                                             pos, scale)
    return mla_decode_attention_pallas(
        q_lat, q_rope, c_cache, kr_cache, pos, scale, block_k=block_k,
        interpret=_INTERPRET,
    )


@partial(jax.jit, static_argnames=("chunk", "backend"))
def ssd_scan(x, dt, A_log, B_mat, C_mat, D_vec, *, chunk=128, backend: str = "pallas"):
    if backend == "ref":
        y, _ = _ref.ssd_scan_ref(x, dt, A_log, B_mat, C_mat, D_vec)
        return y
    return ssd_scan_pallas(x, dt, A_log, B_mat, C_mat, D_vec, chunk=chunk,
                           interpret=_INTERPRET)
