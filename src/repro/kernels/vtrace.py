"""Pallas kernel: batched V-trace targets (Espeholt et al. 2018, eqs. 1–4).

Like ``nstep_returns``, the recursion is sequential in time and data-parallel
over actors; the asynchronous pipeline's learner folds truncated-importance
corrections into the n-step recursion:

    δ_t = min(ρ̄, rho_t)·(r_t + γ_t·V_{t+1} - V_t)     γ_t = γ·(1-done_t)
    A_t = δ_t + γ_t·min(c̄, rho_t)·A_{t+1}             A_T = 0
    v_t = V_t + A_t
    pg_adv_t = min(ρ̄, rho_t)·(r_t + γ_t·v_{t+1} - V_t)

The kernel tiles the actor dimension into VMEM blocks (grid over E/block_e)
and walks t_max backwards inside the block, producing both the value targets
and the policy-gradient advantages in one HBM round-trip per tile.

VMEM budget: (7·block_e·T + 2·block_e) fp32 — block_e=256, T=4096 → 29 MB;
use block_e=64 for long horizons.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(r_ref, nd_ref, v_ref, vnext_ref, rho_ref, boot_ref,
            vs_ref, adv_ref, *, gamma: float, rho_bar: float, c_bar: float,
            T: int):
    zero = jnp.zeros_like(boot_ref[...].astype(jnp.float32))  # A_T = 0
    vs_next0 = boot_ref[...].astype(jnp.float32)  # v_T = V(s_{T+1})

    def body(i, carry):
        acc, vs_next = carry  # A_{t+1}, v_{t+1}
        t = T - 1 - i
        r_t = pl.load(r_ref, (slice(None), pl.dslice(t, 1)))[:, 0]
        nd_t = pl.load(nd_ref, (slice(None), pl.dslice(t, 1)))[:, 0]
        v_t = pl.load(v_ref, (slice(None), pl.dslice(t, 1)))[:, 0]
        vn_t = pl.load(vnext_ref, (slice(None), pl.dslice(t, 1)))[:, 0]
        rho_t = pl.load(rho_ref, (slice(None), pl.dslice(t, 1)))[:, 0]
        rho_t = rho_t.astype(jnp.float32)
        disc = gamma * nd_t.astype(jnp.float32)
        rc = jnp.minimum(rho_t, rho_bar)
        c = jnp.minimum(rho_t, c_bar)
        delta = rc * (r_t.astype(jnp.float32) + disc * vn_t.astype(jnp.float32)
                      - v_t.astype(jnp.float32))
        acc = delta + disc * c * acc
        vs_t = v_t.astype(jnp.float32) + acc
        adv_t = rc * (r_t.astype(jnp.float32) + disc * vs_next
                      - v_t.astype(jnp.float32))
        pl.store(vs_ref, (slice(None), pl.dslice(t, 1)), vs_t[:, None])
        pl.store(adv_ref, (slice(None), pl.dslice(t, 1)), adv_t[:, None])
        return acc, vs_t

    jax.lax.fori_loop(0, T, body, (zero, vs_next0))


def vtrace_returns_pallas(
    rewards: jnp.ndarray,  # (E, T)
    dones: jnp.ndarray,  # (E, T) bool
    values: jnp.ndarray,  # (E, T)
    bootstrap: jnp.ndarray,  # (E,)
    rho: jnp.ndarray,  # (E, T) unclipped importance ratios
    gamma: float,
    rho_bar: float = 1.0,
    c_bar: float = 1.0,
    *,
    block_e: int = 256,
    interpret: bool = True,
):
    """Returns ``(vs, pg_adv)``, both (E, T) fp32 — the Pallas twin of
    ``repro.core.returns.vtrace_returns``."""
    E, T = rewards.shape
    block_e = min(block_e, E)
    pad = (-E) % block_e
    r = rewards.astype(jnp.float32)
    nd = 1.0 - dones.astype(jnp.float32)
    v = values.astype(jnp.float32)
    b = bootstrap.astype(jnp.float32)
    w = rho.astype(jnp.float32)
    vn = jnp.concatenate([v[:, 1:], b[:, None]], axis=1)
    if pad:
        r = jnp.pad(r, ((0, pad), (0, 0)))
        nd = jnp.pad(nd, ((0, pad), (0, 0)))
        v = jnp.pad(v, ((0, pad), (0, 0)))
        vn = jnp.pad(vn, ((0, pad), (0, 0)))
        w = jnp.pad(w, ((0, pad), (0, 0)))
        b = jnp.pad(b, ((0, pad),))
    grid = ((E + pad) // block_e,)
    mat = pl.BlockSpec((block_e, T), lambda e: (e, 0))
    vs, adv = pl.pallas_call(
        functools.partial(_kernel, gamma=gamma, rho_bar=rho_bar, c_bar=c_bar,
                          T=T),
        grid=grid,
        in_specs=[mat, mat, mat, mat, mat,
                  pl.BlockSpec((block_e,), lambda e: (e,))],
        out_specs=(mat, mat),
        out_shape=(
            jax.ShapeDtypeStruct((E + pad, T), jnp.float32),
            jax.ShapeDtypeStruct((E + pad, T), jnp.float32),
        ),
        interpret=interpret,
    )(r, nd, v, vn, w, b)
    return vs[:E], adv[:E]
