"""Pallas kernel: single-token (decode) attention against a long KV cache.

Flash-decoding adapted to TPU: grid (B, Hkv, Sk/block_k) with the KV-block
axis innermost, streaming the cache through VMEM once while fp32 scratch
(m, l, acc) carries the online-softmax state for the G grouped query heads.
The valid-length bound (``pos``) is a scalar-prefetch operand so masked
tail blocks are skipped entirely (``pl.when``), making decode cost
proportional to the *filled* cache, not its capacity.

This is the serve_step hot loop for decode_32k / long_500k: arithmetic
intensity ≈ G flops/byte, i.e. HBM-bandwidth-bound — exactly what the
roofline table shows for the decode shapes.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
            *, scale, block_k, num_kb):
    ki = pl.program_id(2)
    pos = pos_ref[0]

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    k_start = ki * block_k

    @pl.when(k_start <= pos)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale  # (G, D)
        k = k_ref[0, :, 0].astype(jnp.float32)  # (bk, D)
        v = v_ref[0, :, 0].astype(jnp.float32)  # (bk, Dv)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (G, bk)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(k_pos <= pos, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(ki == num_kb - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention_pallas(
    q: jnp.ndarray,  # (B, H, D) — the single query token per sequence
    k_cache: jnp.ndarray,  # (B, S, Hkv, D)
    v_cache: jnp.ndarray,  # (B, S, Hkv, Dv)
    pos,  # scalar int32 — attend to slots <= pos
    *,
    scale=None,
    block_k: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    B, H, D = q.shape
    _, S, Hkv, Dv = v_cache.shape
    G = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    block_k = min(block_k, S)
    pad_k = (-S) % block_k
    kk = jnp.pad(k_cache, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else k_cache
    vv = jnp.pad(v_cache, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else v_cache
    nk = (S + pad_k) // block_k
    qg = q.reshape(B, Hkv, G, D)
    pos_arr = jnp.full((1,), pos, jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hkv, nk),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, ki, pos_ref: (b, h, 0, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, ki, pos_ref: (b, ki, h, 0)),
            pl.BlockSpec((1, block_k, 1, Dv), lambda b, h, ki, pos_ref: (b, ki, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, Dv), lambda b, h, ki, pos_ref: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, Dv), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, block_k=block_k, num_kb=nk),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, Dv), q.dtype),
        interpret=interpret,
    )(pos_arr, qg, kk, vv)
    return out.reshape(B, H, Dv)
