"""Pallas kernel: fused chunked SSD (Mamba2) scan.

TPU adaptation of the CUDA selective-scan (DESIGN.md §6): grid
(B, H, S/chunk) with the chunk axis innermost; the (P, N) state carries in
fp32 VMEM scratch across chunks. Per chunk, everything is dense MXU work:

    scores  = C · Bᵀ               (Q×N · N×Q)
    y_intra = (scores ∘ decay) · (dt·x)
    y_inter = exp(cum) · (C · state)
    state   = exp(total)·state + Σ_j exp(total-cum_j) B_j ⊗ (dt·x)_j

vs. the reference's materialized (B, nc, Q, Q, H) decay tensor, the kernel
keeps only (Q, Q) per head-chunk in VMEM — the memory win that makes
chunk=256 viable on real hardware.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, alog_ref, b_ref, c_ref, d_ref, y_ref, state_scr,
            *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, :, 0].astype(jnp.float32)  # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)  # (Q,)
    B = b_ref[0].astype(jnp.float32)  # (Q, N)
    C = c_ref[0].astype(jnp.float32)  # (Q, N)
    a = -jnp.exp(alog_ref[0]) * dt  # (Q,) negative log-decay
    cum = jnp.cumsum(a)  # inclusive
    total = cum[-1]

    xdt = x * dt[:, None]  # (Q, P)
    scores = jax.lax.dot_general(
        C, B, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Q, Q) = C_i . B_j
    dec = cum[:, None] - cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(jj <= ii, jnp.exp(dec), 0.0)
    y_intra = jax.lax.dot(scores * L, xdt, preferred_element_type=jnp.float32)

    state = state_scr[...]  # (P, N)
    y_inter = jnp.exp(cum)[:, None] * jax.lax.dot_general(
        C, state, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Q, P)

    y = y_intra + y_inter + d_ref[0] * x
    y_ref[0, :, 0] = y.astype(y_ref.dtype)

    # state update: exp(total)*state + sum_j exp(total - cum_j) (dt x)_j ⊗ B_j
    w = jnp.exp(total - cum)[:, None]  # (Q,1)
    state_scr[...] = jnp.exp(total) * state + jax.lax.dot_general(
        xdt * w, B, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (P, N)


def ssd_scan_pallas(
    x: jnp.ndarray,  # (B, S, H, P)
    dt: jnp.ndarray,  # (B, S, H) post-softplus
    A_log: jnp.ndarray,  # (H,)
    B_mat: jnp.ndarray,  # (B, S, N) shared across heads
    C_mat: jnp.ndarray,  # (B, S, N)
    D_vec: jnp.ndarray,  # (H,)
    *,
    chunk: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """Returns y: (B, S, H, P). (Final state stays in scratch — decode uses
    the recurrent path; prefill-with-state uses the reference.)"""
    Bsz, S, H, P = x.shape
    N = B_mat.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, f"S={S} % chunk={chunk}"
    nc = S // chunk

    out = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=(Bsz, H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
        out_shape=jax.ShapeDtypeStruct((Bsz, S, H, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A_log, B_mat, C_mat, D_vec)
    return out
