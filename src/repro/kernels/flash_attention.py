"""Pallas kernel: blocked online-softmax (flash) attention — prefill path.

TPU-native tiling: grid (B, H, Sq/block_q, Sk/block_k); the last grid axis
is innermost on TPU so fp32 scratch (m, l, acc) persists across KV blocks
for a fixed query block. Q/K/V tiles live in VMEM with MXU-aligned shapes
(block_q × D and block_k × D, D a multiple of 64/128). Causal and
sliding-window masks skip fully-masked KV blocks via ``pl.when``
(no FLOPs and no HBM reads for the skipped tiles on real hardware).

GQA: the KV-head index is derived in the BlockSpec index map (h // group),
so K/V stay un-expanded in HBM — the kernel's bandwidth advantage for
kv<<H configs like glm4-9b (kv=2).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale, block_q, block_k, seq_k, causal, window, num_kb,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k

    # block-level mask decisions (static shapes, dynamic offsets)
    run = True
    if causal:
        run = k_start <= q_start + block_q - 1
    if window:
        run_w = k_start + block_k - 1 > q_start - window
        run = run & run_w if causal else run_w

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale  # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)  # (bk, Dv)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bq, bk)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = k_pos < seq_k
        if causal:
            mask &= k_pos <= q_pos
        if window:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(ki == num_kb - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jnp.ndarray,  # (B, Sq, H, D)
    k: jnp.ndarray,  # (B, Sk, Hkv, D)
    v: jnp.ndarray,  # (B, Sk, Hkv, Dv)
    *,
    causal: bool = True,
    window: int = 0,
    scale=None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    B, Sq, H, D = q.shape
    _, Sk, Hkv, Dv = v.shape
    G = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    pad_q = (-Sq) % block_q
    pad_k = (-Sk) % block_k
    qq = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0))) if pad_q else q
    kk = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else k
    vv = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else v
    # layout: (B, H, S, D) blocks
    qq = qq.transpose(0, 2, 1, 3)
    kk = kk.transpose(0, 2, 1, 3)
    vv = vv.transpose(0, 2, 1, 3)
    nq = (Sq + pad_q) // block_q
    nk = (Sk + pad_k) // block_k

    out = pl.pallas_call(
        functools.partial(
            _kernel, scale=scale, block_q=block_q, block_k=block_k,
            seq_k=Sk, causal=causal, window=window, num_kb=nk,
        ),
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, qi, ki: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, block_k, Dv), lambda b, h, qi, ki: (b, h // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, Dv), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq + pad_q, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),  # running max
            pltpu.VMEM((block_q,), jnp.float32),  # running denom
            pltpu.VMEM((block_q, Dv), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(qq, kk, vv)
    out = out.transpose(0, 2, 1, 3)  # (B, Sq+pad, H, Dv)
    return out[:, :Sq]
