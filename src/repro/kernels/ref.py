"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each function is the direct mathematical definition, materializing whatever
intermediate tensors it likes — tests sweep shapes/dtypes and
``assert_allclose`` kernels (interpret mode) against these.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def nstep_returns_ref(rewards, dones, bootstrap, gamma: float):
    """Paper Algorithm 1 lines 11-15. rewards/dones: (E, T); bootstrap: (E,)."""
    E, T = rewards.shape
    nd = 1.0 - dones.astype(jnp.float32)
    out = []
    carry = bootstrap.astype(jnp.float32)
    for t in range(T - 1, -1, -1):
        carry = rewards[:, t].astype(jnp.float32) + gamma * nd[:, t] * carry
        out.append(carry)
    return jnp.stack(out[::-1], axis=1)


def vtrace_returns_ref(rewards, dones, values, bootstrap, rho, gamma: float,
                       rho_bar: float = 1.0, c_bar: float = 1.0):
    """V-trace (Espeholt et al. 2018) by the definition — python time loop.

    rewards/dones/values/rho: (E, T); bootstrap: (E,). Returns (vs, pg_adv).
    """
    E, T = rewards.shape
    r = rewards.astype(jnp.float32)
    nd = 1.0 - dones.astype(jnp.float32)
    v = values.astype(jnp.float32)
    b = bootstrap.astype(jnp.float32)
    rc = jnp.minimum(rho.astype(jnp.float32), rho_bar)
    c = jnp.minimum(rho.astype(jnp.float32), c_bar)
    v_next = jnp.concatenate([v[:, 1:], b[:, None]], axis=1)
    delta = rc * (r + gamma * nd * v_next - v)
    acc = jnp.zeros((E,), jnp.float32)
    out = []
    for t in range(T - 1, -1, -1):
        acc = delta[:, t] + gamma * nd[:, t] * c[:, t] * acc
        out.append(v[:, t] + acc)
    vs = jnp.stack(out[::-1], axis=1)
    vs_next = jnp.concatenate([vs[:, 1:], b[:, None]], axis=1)
    pg_adv = rc * (r + gamma * nd * vs_next - v)
    return vs, pg_adv


def flash_attention_ref(q, k, v, *, causal=True, window=0, scale=None):
    """q: (B, Sq, H, D); k/v: (B, Sk, Hkv, D). Returns (B, Sq, H, D)."""
    B, Sq, H, D = q.shape
    _, Sk, Hkv, Dv = v.shape
    G = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    qg = q.reshape(B, Sq, Hkv, G, D).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qg, k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqhgk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, Dv).astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, pos, *, scale=None):
    """q: (B, H, D); caches: (B, S, Hkv, D); pos: scalar int (attend <= pos)."""
    B, H, D = q.shape
    _, S, Hkv, Dv = v_cache.shape
    G = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    qg = q.reshape(B, Hkv, G, D).astype(jnp.float32)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache.astype(jnp.float32)) * scale
    valid = jnp.arange(S) <= pos
    s = jnp.where(valid[None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, H, Dv).astype(q.dtype)


def mla_decode_attention_ref(q_lat, q_rope, c_cache, kr_cache, pos, scale):
    """Latent-space MLA decode. q_lat: (B,H,R); q_rope: (B,H,Rr);
    c_cache: (B,S,R); kr_cache: (B,S,Rr). Returns (B,H,R)."""
    s = jnp.einsum("bhr,bkr->bhk", q_lat.astype(jnp.float32),
                   c_cache.astype(jnp.float32))
    s = s + jnp.einsum("bhr,bkr->bhk", q_rope.astype(jnp.float32),
                       kr_cache.astype(jnp.float32))
    s = s * scale
    valid = jnp.arange(c_cache.shape[1]) <= pos
    s = jnp.where(valid[None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhk,bkr->bhr", p, c_cache.astype(jnp.float32))
    return out.astype(q_lat.dtype)


def ssd_scan_ref(x, dt, A_log, B_mat, C_mat, D_vec, *, chunk: int = None):
    """Sequential SSD recurrence (exact). x: (B,S,H,P); dt: (B,S,H);
    B_mat/C_mat: (B,S,N); A_log/D_vec: (H,). Returns (y, final_state)."""
    Bsz, S, H, P = x.shape
    N = B_mat.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    lam = jnp.exp(-jnp.exp(A_log)[None, None, :] * dtf)  # (B,S,H)
    Bf = B_mat.astype(jnp.float32)
    Cf = C_mat.astype(jnp.float32)

    def step(state, inp):
        x_t, dt_t, lam_t, B_t, C_t = inp
        state = state * lam_t[:, :, None, None] + jnp.einsum(
            "bh,bhp,bn->bhpn", dt_t, x_t, B_t
        )
        y_t = jnp.einsum("bhpn,bn->bhp", state, C_t)
        return state, y_t

    s0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    state, ys = jax.lax.scan(
        step,
        s0,
        (
            xf.transpose(1, 0, 2, 3),
            dtf.transpose(1, 0, 2),
            lam.transpose(1, 0, 2),
            Bf.transpose(1, 0, 2),
            Cf.transpose(1, 0, 2),
        ),
    )
    y = ys.transpose(1, 0, 2, 3) + D_vec[None, None, :, None] * xf
    return y.astype(x.dtype), state
