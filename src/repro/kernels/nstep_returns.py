"""Pallas kernel: batched n-step discounted returns (Algorithm 1, 13–15).

The recursion R_t = r_t + γ·(1-done_t)·R_{t+1} is sequential in time but
embarrassingly parallel over actors — PAAC's central observation. The
kernel tiles the actor dimension into VMEM blocks (grid over E/block_e) and
walks t_max backwards inside the block; one HBM round-trip per tile instead
of t_max tiny host-side ops.

VMEM budget: (2·block_e·T + 2·block_e) fp32 — block_e=256, T=4096 → 8 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(r_ref, nd_ref, boot_ref, out_ref, *, gamma: float, T: int):
    carry = boot_ref[...].astype(jnp.float32)  # (block_e,)

    def body(i, carry):
        t = T - 1 - i
        r_t = pl.load(r_ref, (slice(None), pl.dslice(t, 1)))[:, 0]
        nd_t = pl.load(nd_ref, (slice(None), pl.dslice(t, 1)))[:, 0]
        carry = r_t.astype(jnp.float32) + gamma * nd_t.astype(jnp.float32) * carry
        pl.store(out_ref, (slice(None), pl.dslice(t, 1)), carry[:, None])
        return carry

    jax.lax.fori_loop(0, T, body, carry)


def nstep_returns_pallas(
    rewards: jnp.ndarray,  # (E, T)
    dones: jnp.ndarray,  # (E, T) bool
    bootstrap: jnp.ndarray,  # (E,)
    gamma: float,
    *,
    block_e: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    E, T = rewards.shape
    block_e = min(block_e, E)
    pad = (-E) % block_e
    nd = 1.0 - dones.astype(jnp.float32)
    r = rewards.astype(jnp.float32)
    b = bootstrap.astype(jnp.float32)
    if pad:
        r = jnp.pad(r, ((0, pad), (0, 0)))
        nd = jnp.pad(nd, ((0, pad), (0, 0)))
        b = jnp.pad(b, ((0, pad),))
    grid = ((E + pad) // block_e,)
    out = pl.pallas_call(
        functools.partial(_kernel, gamma=gamma, T=T),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_e, T), lambda e: (e, 0)),
            pl.BlockSpec((block_e, T), lambda e: (e, 0)),
            pl.BlockSpec((block_e,), lambda e: (e,)),
        ],
        out_specs=pl.BlockSpec((block_e, T), lambda e: (e, 0)),
        out_shape=jax.ShapeDtypeStruct((E + pad, T), jnp.float32),
        interpret=interpret,
    )(r, nd, b)
    return out[:E]
