from repro.data.specs import input_specs, step_kind

__all__ = ["input_specs", "step_kind"]
