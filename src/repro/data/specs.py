"""ShapeDtypeStruct input stand-ins for every (architecture × input shape).

These drive the multi-pod dry-run: weak-type-correct, shardable, no device
allocation. The modality front-ends ([audio] frames, [vlm] patches) are
stubs — we emit precomputed embeddings of the right shape, per the spec
carve-out.

Batch layout per shape kind:

* ``train``   — a PAAC trajectory batch: the environment is a token
  environment, one sequence = one actor's ``t_max``-step trajectory
  (paper Algorithm 1 line 4-10), so the train step receives tokens
  (B, S+1) [obs + actions], per-step rewards and episode-done flags.
* ``prefill`` — batched policy evaluation over full contexts.
* ``decode``  — the master's batched action selection (paper §3): ONE new
  token per actor against a KV/state cache of ``seq_len``.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import INPUT_SHAPES, ArchConfig

F32 = jnp.float32
I32 = jnp.int32


def step_kind(shape_name: str) -> str:
    return INPUT_SHAPES[shape_name].kind


def _text_len(cfg: ArchConfig, seq_len: int) -> int:
    """Token positions available after the multimodal prefix."""
    return seq_len - cfg.prefix_len


def input_specs(cfg: ArchConfig, shape_name: str) -> Dict[str, jax.ShapeDtypeStruct]:
    """Model inputs for one (arch, shape) pair as ShapeDtypeStructs."""
    shp = INPUT_SHAPES[shape_name]
    B, S = shp.global_batch, shp.seq_len
    sd = jax.ShapeDtypeStruct

    if shp.kind == "train":
        T = _text_len(cfg, S)
        specs = {
            "tokens": sd((B, T + 1), I32),
            "rewards": sd((B, T), F32),
            "dones": sd((B, T), jnp.bool_),
        }
        if cfg.modality == "vision":
            specs["prefix"] = sd((B, cfg.prefix_len, cfg.frontend_dim or cfg.d_model), F32)
        if cfg.is_encoder_decoder:
            specs["frames"] = sd(
                (B, cfg.encoder_seq_len, cfg.frontend_dim or cfg.d_model), F32
            )
        return specs

    if shp.kind == "prefill":
        T = _text_len(cfg, S)
        specs = {"tokens": sd((B, T), I32)}
        if cfg.modality == "vision":
            specs["prefix"] = sd((B, cfg.prefix_len, cfg.frontend_dim or cfg.d_model), F32)
        if cfg.is_encoder_decoder:
            specs["frames"] = sd(
                (B, cfg.encoder_seq_len, cfg.frontend_dim or cfg.d_model), F32
            )
        return specs

    # decode: one token per actor; the cache spec is produced separately via
    # jax.eval_shape(init_policy_cache, ...) in the launcher.
    return {"token": sd((B, 1), I32)}
