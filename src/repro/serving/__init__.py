"""Serving plane: continuous batching for policy inference.

Layers (``docs/serving.md``):

* ``request``   — the ``Request`` unit the admission queue carries.
* ``slots``     — ``KVSlotCache``: lease discipline over cache rows.
* ``engine``    — ``DecodeEngine``: one fixed-width jitted decode step.
* ``scheduler`` — continuous / lockstep admission over one engine.
* ``traffic``   — open-loop (Poisson or burst) request sources.
"""
from repro.serving.engine import DecodeEngine
from repro.serving.request import ACTIVE, DONE, ERRORED, QUEUED, Request
from repro.serving.scheduler import SERVE_CATEGORIES, Scheduler
from repro.serving.slots import (KVSlotCache, SlotCacheClosed, SlotError,
                                 SlotsExhausted)
from repro.serving.traffic import OpenLoopTraffic, make_requests

__all__ = [
    "ACTIVE", "DONE", "ERRORED", "QUEUED",
    "DecodeEngine", "KVSlotCache", "OpenLoopTraffic", "Request",
    "SERVE_CATEGORIES", "Scheduler", "SlotCacheClosed", "SlotError",
    "SlotsExhausted", "make_requests",
]
