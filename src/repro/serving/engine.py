"""Fixed-width jitted decode engine shared by every scheduler mode.

The bitwise-equivalence guarantee rests on two properties this module is
careful to preserve:

* **One compiled program.** The decode step is jitted at a fixed batch
  width ``max_slots`` and every run — continuous with random join/leave
  traffic, lockstep generate-then-drain, a solo single-request run —
  executes the *same* compiled step. No shape ever depends on how many
  requests happen to be resident.
* **Row independence.** Every op in the step is per-row: the per-row
  position paths in ``gqa_decode``/``mla_decode`` (one-hot cache writes,
  per-row masks), the pos-free Mamba2 recurrence, and per-request RNG —
  token ``t`` of a request with stream root ``seed`` is sampled with
  ``fold_in(PRNGKey(seed), t)``, never from a batch-shared key. Row
  ``b``'s outputs therefore depend only on row ``b``'s token, position,
  seed and cache row.

Together: a request's sampled tokens are bitwise identical whatever
co-resides in the batch — the pin ``tests/test_serving.py`` enforces
across attention and SSM backbones.

Stale cache rows need no zeroing between leases: admission scatters a
freshly prefilled row over the slot, attention masks any position beyond
the row's own ``pos`` to ``NEG_INF`` (exp -> exactly 0), and the SSM
state is fully overwritten by prefill.

Prefill is **exact-length** (one jit per distinct prompt length, batch
1) because right-padding would corrupt the SSM recurrence; the small
cache is then scattered into the leased row of the big cache in one
jitted donating dispatch. Traffic sources should restrict themselves to
a small prompt-length alphabet to bound compilations.
"""
from __future__ import annotations

from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import init_policy_cache, policy_decode, policy_prefill


class DecodeEngine:
    """W-wide decode batch over the unified policy API.

    Host-side per-slot bookkeeping (``pos``/``tindex``/``seeds``) stays in
    numpy so the step dispatch never reads device memory; the token fed
    back each step stays a device array end to end.
    """

    def __init__(self, cfg, params, *, max_slots: int, max_len: int):
        if cfg.family == "cnn":
            raise ValueError("serving needs a token-model family, not cnn")
        if cfg.is_encoder_decoder or cfg.modality == "vision":
            raise ValueError(
                "serving supports text token models only (no encoder-"
                "decoder / vision prefix plumbing on the admission path)")
        if max_slots < 1:
            raise ValueError(f"max_slots must be >= 1, got {max_slots}")
        if max_len < 2:
            raise ValueError(f"max_len must be >= 2, got {max_len}")
        self.cfg = cfg
        self.params = params
        self.max_slots = max_slots
        self.max_len = max_len
        W = max_slots
        self._pos = np.zeros(W, np.int32)
        self._tindex = np.zeros(W, np.int32)
        self._seeds = np.zeros(W, np.int32)
        self._tokens = jnp.zeros((W, 1), jnp.int32)
        self._cache = init_policy_cache(cfg, W, max_len)
        self._prefill_fns: Dict[int, Any] = {}  # prompt length -> jitted fn
        # device-side token ring log: step g writes its (W,) sampled tokens
        # to row g % max_len, so the decode loop never materializes (or
        # even lazily indexes) per-token scalars — a request's tokens are
        # harvested from its slot's column in ONE slice at retire. A
        # request spans at most max_len - 1 consecutive steps (its decode
        # headroom), so its rows cannot be overwritten before harvest.
        self._log = jnp.zeros((max_len, W), jnp.int32)
        self._glob = 0  # global decode-step counter (host int)
        self._g0 = np.zeros(W, np.int64)  # per-slot _glob at admission
        self._tok0: List[Any] = [None] * W  # per-slot lazy (1,) prefill tok

        def _step(params, cache, tokens, pos, seeds, tindex, log, row):
            logits, _value, cache = policy_decode(params, cfg, cache,
                                                  tokens, pos)
            # per-request RNG streams: token t of stream `seed` is sampled
            # with fold_in(PRNGKey(seed), t) — no batch-shared key anywhere
            keys = jax.vmap(
                lambda s, t: jax.random.fold_in(jax.random.PRNGKey(s), t)
            )(seeds, tindex)
            toks = jax.vmap(jax.random.categorical)(keys, logits)
            toks = toks.astype(jnp.int32)
            log = jax.lax.dynamic_update_slice(
                log, toks[None, :], (row, jnp.int32(0)))
            return toks, cache, log

        self._step_fn = jax.jit(_step, donate_argnums=(1, 6))

        def _place(cache, tokens, small, tok0, slot):
            def scatter(big, one):
                # the batch axis is the unique axis where the 1-row prefill
                # cache differs from the W-row big cache (leaf layouts put
                # it at different depths per family)
                axis = next((i for i, (a, b)
                             in enumerate(zip(big.shape, one.shape))
                             if a != b), None)
                if axis is None:  # max_slots == 1: the row is the cache
                    return one.astype(big.dtype)
                starts = [0] * big.ndim
                starts[axis] = slot
                return jax.lax.dynamic_update_slice(
                    big, one.astype(big.dtype), tuple(starts))

            cache = jax.tree_util.tree_map(scatter, cache, small)
            tokens = jax.lax.dynamic_update_slice(
                tokens, tok0[:, None], (slot, jnp.int32(0)))
            return cache, tokens

        self._place_fn = jax.jit(_place, donate_argnums=(0, 1))

    # -- admission -----------------------------------------------------------
    def _prefill_for(self, length: int):
        fn = self._prefill_fns.get(length)
        if fn is None:
            cfg, max_len = self.cfg, self.max_len

            def _pf(params, tokens, seed):
                logits, _values, cache = policy_prefill(
                    params, cfg, tokens, None, max_len=max_len)
                key = jax.random.fold_in(jax.random.PRNGKey(seed), 0)
                tok0 = jax.random.categorical(key, logits[:, -1])
                return tok0.astype(jnp.int32), cache

            fn = jax.jit(_pf)
            self._prefill_fns[length] = fn
        return fn

    def admit(self, slot: int, prompt: np.ndarray, seed: int) -> None:
        """Prefill ``prompt`` into cache row ``slot``. The first sampled
        token (stream index t=0) stays on device until ``harvest``."""
        prompt = np.asarray(prompt, np.int32)
        S = int(prompt.shape[0])
        if S + 1 > self.max_len:
            raise ValueError(
                f"prompt length {S} leaves no decode headroom in a "
                f"max_len={self.max_len} cache")
        tok0, small = self._prefill_for(S)(self.params, prompt[None, :],
                                           seed)
        self._cache, self._tokens = self._place_fn(
            self._cache, self._tokens, small, tok0, slot)
        self._pos[slot] = S
        self._tindex[slot] = 1
        self._seeds[slot] = seed
        self._g0[slot] = self._glob
        self._tok0[slot] = tok0

    # -- decode --------------------------------------------------------------
    # hot-path
    def step(self) -> None:
        """One fixed-width decode step over every slot (leased or idle).
        Tokens land in the device-side ring log; nothing returns to host."""
        row = self._glob % self.max_len
        toks, self._cache, self._log = self._step_fn(
            self.params, self._cache, self._tokens, self._pos,
            self._seeds, self._tindex, self._log, row)
        self._tokens = toks[:, None]
        self._pos += 1
        self._tindex += 1
        self._glob += 1

    def remaining(self, slot: int) -> int:
        """Decode headroom before the cache row overflows max_len."""
        return self.max_len - int(self._pos[slot])

    def harvest(self, slot: int, n: int) -> np.ndarray:
        """The first ``n`` tokens sampled for the request resident in
        ``slot`` — one column slice + one host transfer, at retire (off
        the decode hot path)."""
        if n < 1:
            return np.zeros(0, np.int32)
        tok0 = np.asarray(self._tok0[slot], np.int32)  # (1,)
        if n == 1:
            return tok0
        col = np.asarray(self._log[:, slot], np.int32)  # (max_len,)
        rows = (self._g0[slot] + np.arange(n - 1)) % self.max_len
        return np.concatenate([tok0, col[rows]])

    def release(self, slot: int) -> None:
        """Reset host bookkeeping for a freed slot. The device rows are
        *not* zeroed — stale cache contents are masked out by
        construction (see module docstring) and stale log rows are
        overwritten before any future harvest can read them; the next
        admit overwrites the rest."""
        self._pos[slot] = 0
        self._tindex[slot] = 0
        self._seeds[slot] = 0
        self._g0[slot] = 0
        self._tok0[slot] = None
