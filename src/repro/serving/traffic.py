"""Open-loop traffic generation for the serving plane.

Open-loop means arrivals follow a fixed schedule (Poisson at a target
rate, or a burst) regardless of how fast the service drains them — the
standard way to expose queueing delay, as opposed to closed-loop clients
that wait for each response. The whole schedule (arrival times, prompt
lengths, generation lengths, per-request seeds) is drawn up front from
one ``numpy`` generator, so a given ``(seed, n)`` pair names a
reproducible workload for benches and the bitwise pin.

Prompt lengths are drawn from a small alphabet (default two lengths)
because the engine compiles one exact-length prefill per distinct prompt
length — see ``engine.py``.
"""
from __future__ import annotations

import threading
import time
from typing import List, Sequence, Tuple

import numpy as np

from repro.serving.request import Request

DEFAULT_PROMPT_LENS = (8, 16)


def make_requests(n: int, *, seed: int,
                  prompt_lens: Sequence[int] = DEFAULT_PROMPT_LENS,
                  gen_range: Tuple[int, int] = (4, 16),
                  vocab: int = 64) -> List[Request]:
    """Draw ``n`` requests (no arrival times — a burst workload)."""
    rng = np.random.default_rng(seed)
    reqs = []
    for rid in range(n):
        S = int(rng.choice(list(prompt_lens)))
        prompt = rng.integers(0, vocab, size=S, dtype=np.int32)
        gen = int(rng.integers(gen_range[0], gen_range[1] + 1))
        reqs.append(Request(rid=rid, prompt=prompt, max_new_tokens=gen,
                            seed=int(rng.integers(0, 2**31 - 1))))
    return reqs


class OpenLoopTraffic(threading.Thread):
    """Feed a pre-drawn schedule into the admission queue on its clock.

    ``rate_hz > 0``: exponential inter-arrivals at that rate (Poisson
    process). ``rate_hz == 0``: a burst — every request enqueued
    immediately (capacity measurement; the queue's bounded depth is the
    only pacing). Calls ``queue.producer_done()`` on exit either way, so
    the scheduler sees ``CLOSED`` after the last request.
    """

    def __init__(self, queue, n: int, *, seed: int, rate_hz: float = 0.0,
                 prompt_lens: Sequence[int] = DEFAULT_PROMPT_LENS,
                 gen_range: Tuple[int, int] = (4, 16), vocab: int = 64):
        super().__init__(name="serve-traffic", daemon=True)
        self.queue = queue
        self.requests = make_requests(n, seed=seed, prompt_lens=prompt_lens,
                                      gen_range=gen_range, vocab=vocab)
        if rate_hz > 0:
            rng = np.random.default_rng(seed + 1)
            gaps = rng.exponential(1.0 / rate_hz, size=n)
            self.arrivals = np.cumsum(gaps)
        else:
            self.arrivals = np.zeros(n)

    def run(self) -> None:
        try:
            t0 = time.perf_counter()
            for req, at in zip(self.requests, self.arrivals):
                delay = (t0 + float(at)) - time.perf_counter()
                if delay > 0:
                    time.sleep(delay)
                req.t_submit = time.perf_counter()
                self.queue.put(req)
        finally:
            self.queue.producer_done()
