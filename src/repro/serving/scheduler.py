"""Continuous-batching scheduler: admission queue -> slot-managed decode.

Two modes over one loop and one engine:

* **continuous** (the service): a request is admitted the moment a cache
  slot frees up, joining the decode batch mid-flight; it leaves the
  moment it finishes. The batch is never drained to admit.
* **lockstep** (the generate-then-drain baseline, and the solo reference
  for the bitwise pin): admission only happens when the batch is empty —
  a wave fills up, decodes until its *longest* request completes, then
  drains. Finished rows idle until the wave ends; that idle is exactly
  what the serve bench measures continuous batching against.

Both modes pull from a ``TrajectoryQueue`` (the host queue plane's
close/backpressure contract — ``docs/queues.md``): the traffic source
``put``s ``Request``s and calls ``producer_done()``; the scheduler
``get``s until ``CLOSED`` and then drains its active batch. Backpressure
toward the traffic source is the queue's own bounded-depth blocking.

Slot discipline: ``KVSlotCache.allocate`` at admission, with the free
deferred into the request's ``_free`` closure (the ring's
``Rollout.release`` handoff idiom — repro-lint's ``lease-pairing`` rule
checks the pairing); the closure runs exactly once, at retire. Eviction
(cache-window overflow) reclaims the slot via ``evict`` and errors the
request.

Telemetry: the scheduler's emitter uses the serving category table
``("admit", "prefill", "decode", "evict")`` — same ``SpanEmitter``
machinery as the pipeline, custom vocabulary — and registers
``serve_queue_depth`` / ``serve_active_slots`` gauges on the hub's
heartbeat. The decode step is ``# hot-path``: no host syncs between
steps (completion is length-based; tokens materialize only at retire).
"""
from __future__ import annotations

import queue as _queue
import time
from typing import Dict, List

import numpy as np

from repro.pipeline.queue import CLOSED
from repro.serving.request import ACTIVE, DONE, ERRORED, Request
from repro.serving.slots import KVSlotCache
from repro.telemetry.spans import SpanEmitter
from repro.utils import get_logger

log = get_logger("serving")

SERVE_CATEGORIES = ("admit", "prefill", "decode", "evict")
_ADMIT, _PREFILL, _DECODE, _EVICT = 0, 1, 2, 3


class Scheduler:
    """Drive one engine from one admission queue until both drain."""

    def __init__(self, engine, queue, *, continuous: bool = True,
                 telemetry=None, name: str = "serve"):
        self.engine = engine
        self.queue = queue
        self.continuous = continuous
        self.slots = KVSlotCache(engine.max_slots)
        self._hub = telemetry
        if telemetry is not None:
            self.em = telemetry.emitter(name, categories=SERVE_CATEGORIES)
            telemetry.set_gauge("serve_queue_depth", queue.qsize)
            telemetry.set_gauge("serve_active_slots",
                                lambda: self.slots.active_count)
        else:
            self.em = SpanEmitter(name, categories=SERVE_CATEGORIES)
        self._active: Dict[int, Request] = {}  # slot -> request
        self.completed: List[Request] = []
        self.admit_order: List[int] = []  # rids, FIFO-fairness pin
        self.steps = 0  # decode steps dispatched (bench: batch occupancy)
        self._drained = False  # queue delivered CLOSED

    # -- lifecycle -----------------------------------------------------------
    def run(self) -> List[Request]:
        """Serve until the admission queue closes+drains and every active
        request retires. Returns every request, completed or errored."""
        while True:
            self._admit()
            self._retire()  # budgets met by the prefill token alone
            if not self._active:
                if self._drained:
                    break
                continue  # _admit blocks for the next request
            self._step()
            self._retire()
        self.slots.close()
        return self.completed

    # -- admission -----------------------------------------------------------
    def _admit(self) -> None:
        if not self.continuous and self._active:
            return  # lockstep: next wave only after a full drain
        while not self._drained and self.slots.free_count > 0:
            block = not self._active  # idle batch: wait for work
            try:
                item = self.queue.get(timeout=None if block else 0.0)
            except _queue.Empty:
                return
            if item is CLOSED:
                self._drained = True
                return
            self._admit_one(item)

    def _admit_one(self, req: Request) -> None:
        self.em.begin(_ADMIT)
        try:
            req.t_admit = time.perf_counter()
            if req.prompt.shape[0] + req.max_new_tokens > self.engine.max_len:
                self._error(req, (
                    f"prompt {req.prompt.shape[0]} + max_new_tokens "
                    f"{req.max_new_tokens} exceeds the engine's "
                    f"max_len={self.engine.max_len}"))
                return
            rid = req.rid
            slot = self.slots.allocate(rid)
            # deferred handoff: the slot frees exactly once, at retire
            req._free = (lambda s=slot, r=rid: self.slots.free(s, r))
            req.slot = slot
            self.em.begin(_PREFILL)
            try:
                self.engine.admit(slot, req.prompt, req.seed)
            except Exception as e:  # prefill failed: lease back, error out
                self.em.cancel()
                req._free()
                req._free = None
                req.slot = None
                self._error(req, f"{type(e).__name__}: {e}")
                return
            self.em.end()
            req.status = ACTIVE
            req.t_first = time.perf_counter()
            req.n_live = 1  # the prefill-sampled token (stream index 0)
            self._active[slot] = req
            self.admit_order.append(rid)
        finally:
            self.em.end()

    def _error(self, req: Request, msg: str,
               tokens: np.ndarray = None) -> None:
        req.status = ERRORED
        req.error = msg
        req.tokens = tokens if tokens is not None else np.zeros(0, np.int32)
        req.t_done = time.perf_counter()
        self.completed.append(req)
        log.warning("request %d errored: %s", req.rid, msg)

    # -- decode --------------------------------------------------------------
    # hot-path
    def _step(self) -> None:
        """One fixed-width decode step. The host side only counts: tokens
        stay in the engine's device ring log until harvest at retire, so
        the loop issues exactly one dispatch per step — no per-row
        gathers, no syncs (completion is length-based)."""
        self.em.begin(_DECODE)
        try:
            self.engine.step()
            for slot, req in self._active.items():
                self.slots.assert_owner(slot, req.rid)
                req.n_live += 1
            self.steps += 1
            if self._hub is not None:
                self._hub.counter_add("steps", 1)
        finally:
            self.em.end()

    # -- retire / evict ------------------------------------------------------
    def _retire(self) -> None:
        for slot in list(self._active):
            req = self._active[slot]
            if req.n_generated >= req.max_new_tokens:
                del self._active[slot]
                req.tokens = self.engine.harvest(slot, req.n_live)
                req.status = DONE
                req.t_done = time.perf_counter()
                req._free()  # the deferred lease handoff, exactly once
                req._free = None
                self.engine.release(slot)
                self.completed.append(req)
            elif self.engine.remaining(slot) <= 0:
                self._evict(slot, req,
                            f"cache row overflow: pos reached max_len="
                            f"{self.engine.max_len} before "
                            f"{req.max_new_tokens} tokens generated")

    def _evict(self, slot: int, req: Request, msg: str) -> None:
        self.em.begin(_EVICT)
        try:
            del self._active[slot]
            evicted = self.slots.evict(slot)
            assert evicted == req.rid, (evicted, req.rid)
            req._free = None  # lease reclaimed by evict, not the closure
            partial = self.engine.harvest(slot, req.n_live)
            self.engine.release(slot)
            self._error(req, msg, tokens=partial)
        finally:
            self.em.end()
