"""Request/response types for the serving plane.

A ``Request`` is the unit the admission queue carries: a host-side
(numpy) prompt plus the per-request RNG stream root (``seed``) that makes
its sampled tokens independent of whatever co-resides in the decode
batch. The scheduler mutates it in place through its lifecycle
(``QUEUED -> ACTIVE -> DONE | ERRORED``) and hands the same object back
from ``Scheduler.run()`` — there is no separate response type; the
filled-in fields (``tokens``, the timing stamps) *are* the response.

Timing stamps (``time.perf_counter`` seconds) support the serve bench's
p50/p99 latency: ``t_submit`` when the traffic source enqueued it,
``t_admit`` when the scheduler took it off the queue, ``t_first`` when
its prefill dispatched (first sampled token in flight), ``t_done`` at
retire/evict.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import numpy as np

QUEUED = "queued"
ACTIVE = "active"
DONE = "done"
ERRORED = "errored"


@dataclass
class Request:
    """One generation request riding the admission queue."""

    rid: int
    prompt: np.ndarray  # (S,) int32 token ids
    max_new_tokens: int
    seed: int  # root of this request's RNG stream (fold_in per token)
    status: str = QUEUED
    slot: Optional[int] = None  # decode-batch row while ACTIVE
    t_submit: float = 0.0
    t_admit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0
    tokens: Optional[np.ndarray] = None  # (n,) int32, set at retire/evict
    error: Optional[str] = None
    # deferred slot handoff (the ring's Rollout.release idiom): installed
    # at admission next to the allocate, invoked exactly once at retire
    _free: Optional[Callable[[], None]] = None
    # tokens sampled so far, counted host-side (completion is length-
    # based); the values stay in the engine's device-side ring log until
    # harvest at retire, so the decode hot path never touches device data
    n_live: int = 0

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32)
        if self.prompt.ndim != 1 or self.prompt.shape[0] < 1:
            raise ValueError(
                f"request {self.rid}: prompt must be a non-empty 1-D int "
                f"array, got shape {self.prompt.shape}"
            )
        if self.max_new_tokens < 1:
            raise ValueError(
                f"request {self.rid}: max_new_tokens must be >= 1, got "
                f"{self.max_new_tokens}"
            )

    @property
    def n_generated(self) -> int:
        """Tokens sampled so far (counted while ACTIVE, final after)."""
        if self.tokens is not None:
            return int(self.tokens.shape[0])
        return self.n_live

    @property
    def latency_s(self) -> float:
        """Submit-to-done latency (the bench's p50/p99 input)."""
        return self.t_done - self.t_submit
