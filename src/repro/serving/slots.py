"""Slot-managed KV/state-cache ownership for the decode batch.

``KVSlotCache`` is the serving twin of ``pipeline/ring.py``'s slot
discipline: the engine's big cache has ``capacity`` batch rows, and each
row is leased to exactly one request for its lifetime. The bookkeeping —
not the arrays — lives here; the ``DecodeEngine`` owns the device cache
and indexes it by the slot ids this class hands out.

Contract (mirrors the ring's ownership transfer, pinned by
``tests/test_serving.py``):

* ``allocate(owner)`` leases the oldest free slot to ``owner``
  (FIFO reuse, like the ring's ticket order). Raises ``SlotsExhausted``
  when every slot is leased — the scheduler checks ``free_count`` and
  applies backpressure by leaving requests on the admission queue — and
  ``SlotCacheClosed`` after ``close()``.
* ``free(slot, owner)`` returns the lease. Freeing a slot you do not own
  (``wrong-owner``), or one already free (``double-free``), raises
  ``SlotError`` loudly — exactly the use-after-free class the ring turns
  into errors instead of silent corruption.
* ``evict(slot)`` is the cache manager's forced reclaim (request over ran
  its cache window, or an abort): it frees the slot *without* the owner
  token and returns the evicted owner so the scheduler can error the
  request. Evicting a free slot raises.
* ``owner_of(slot)`` / ``assert_owner(slot, owner)`` make use-after-free
  loud on the read side: both raise on a free slot, and ``assert_owner``
  raises when the slot was re-leased to someone else.
* ``close()`` stops new leases (``allocate`` raises); ``free``/``evict``
  still work so active requests drain.

A slot is freed only through ``free`` (request completion) or ``evict`` —
never implicitly.
"""
from __future__ import annotations

from collections import deque
from typing import Any, Deque, List, Optional

from repro.analysis.lockcheck import make_condition


class SlotError(RuntimeError):
    """Lease-discipline violation: wrong owner, double free, or
    use-after-free on a slot id."""


class SlotsExhausted(SlotError):
    """allocate() with every slot leased — apply backpressure upstream."""


class SlotCacheClosed(RuntimeError):
    """allocate() on a closed cache."""


class KVSlotCache:
    """Ownership ledger for the decode batch's cache rows."""

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError(f"slot capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._cond = make_condition("slots.cond")
        self._owner: List[Optional[Any]] = [None] * capacity
        self._free: Deque[int] = deque(range(capacity))
        self._closed = False
        self._evictions = 0
        self._leases = 0  # lifetime allocations (monotone, ticket idiom)

    def _check_slot(self, slot: int) -> None:
        if not (0 <= slot < self.capacity):
            raise SlotError(
                f"slot {slot} out of range [0, {self.capacity})")

    # hot-path
    def allocate(self, owner: Any) -> int:
        """Lease the oldest free slot to ``owner``; returns the slot id."""
        if owner is None:
            raise ValueError("owner must not be None (it is the lease token)")
        with self._cond:
            if self._closed:
                raise SlotCacheClosed("allocate() on a closed KVSlotCache")
            if not self._free:
                raise SlotsExhausted(
                    f"all {self.capacity} cache slots are leased — admission "
                    "must wait for a completion or evict")
            slot = self._free.popleft()
            self._owner[slot] = owner
            self._leases += 1
            return slot

    # hot-path
    def free(self, slot: int, owner: Any) -> None:
        """Return ``owner``'s lease on ``slot`` (completion path)."""
        self._check_slot(slot)
        with self._cond:
            cur = self._owner[slot]
            if cur is None:
                raise SlotError(
                    f"double-free: slot {slot} is already free")
            if cur != owner:
                raise SlotError(
                    f"wrong-owner free: slot {slot} is leased to {cur!r}, "
                    f"not {owner!r}")
            self._owner[slot] = None
            self._free.append(slot)
            self._cond.notify_all()

    def evict(self, slot: int) -> Any:
        """Forced reclaim by the cache manager; returns the evicted owner."""
        self._check_slot(slot)
        with self._cond:
            cur = self._owner[slot]
            if cur is None:
                raise SlotError(f"evict of free slot {slot}")
            self._owner[slot] = None
            self._free.append(slot)
            self._evictions += 1
            self._cond.notify_all()
            return cur

    def owner_of(self, slot: int) -> Any:
        """Current lease holder; raises on a free slot (use-after-free)."""
        self._check_slot(slot)
        with self._cond:
            cur = self._owner[slot]
            if cur is None:
                raise SlotError(
                    f"use-after-free: slot {slot} has no lease holder")
            return cur

    # hot-path
    def assert_owner(self, slot: int, owner: Any) -> None:
        """Loud use-after-free / stale-handle check on the read side."""
        cur = self.owner_of(slot)
        if cur != owner:
            raise SlotError(
                f"use-after-free: slot {slot} is leased to {cur!r}, "
                f"not {owner!r} — the slot was reused after this handle's "
                "lease ended")

    def close(self) -> None:
        """Stop new leases; active ones still drain via free/evict."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()

    @property
    def closed(self) -> bool:
        with self._cond:
            return self._closed

    @property
    def active_count(self) -> int:
        with self._cond:
            return self.capacity - len(self._free)

    @property
    def free_count(self) -> int:
        with self._cond:
            return len(self._free)

    @property
    def evictions(self) -> int:
        with self._cond:
            return self._evictions

    @property
    def leases_issued(self) -> int:
        """Lifetime allocations (monotone — the ring's ticket idiom)."""
        with self._cond:
            return self._leases
