from repro.utils.tree import (
    tree_size,
    tree_bytes,
    tree_global_norm,
    tree_zeros_like,
    tree_add,
    tree_scale,
)
from repro.utils.logging import get_logger

__all__ = [
    "tree_size",
    "tree_bytes",
    "tree_global_norm",
    "tree_zeros_like",
    "tree_add",
    "tree_scale",
    "get_logger",
]
