"""Pytree helpers used across the framework (no flax/optax dependency)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_size(tree) -> int:
    """Total number of scalar parameters in a pytree."""
    return sum(x.size for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree) -> int:
    """Total bytes occupied by a pytree's leaves."""
    return sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree))


def tree_global_norm(tree) -> jnp.ndarray:
    """Global L2 norm over all leaves (as used by global-norm clipping)."""
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def tree_zeros_like(tree):
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


def tree_add(a, b):
    return jax.tree_util.tree_map(jnp.add, a, b)


def tree_scale(tree, s):
    return jax.tree_util.tree_map(lambda x: x * s, tree)


def tree_cast(tree, dtype):
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree,
    )
