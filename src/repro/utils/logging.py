"""Minimal structured logging for the framework.

Configuration is idempotent *per process state*, not per module import:
the guard is "does the ``repro`` root logger already have handlers", so a
host application that configured ``logging.getLogger("repro")`` itself is
never double-handled, and a spawned worker subprocess (fresh interpreter,
fresh module globals) configures exactly one handler of its own.

``REPRO_LOG_LEVEL`` (e.g. ``DEBUG``, ``WARNING``, ``25``) overrides the
default ``INFO`` level; an unrecognized value falls back to ``INFO`` with
a one-time warning rather than crashing a launcher over a typo.
"""
from __future__ import annotations

import logging
import os
import sys

_FORMAT = "%(asctime)s %(name)s %(levelname)s %(message)s"
_level_applied = False


def _env_level() -> int:
    raw = os.environ.get("REPRO_LOG_LEVEL", "").strip()
    if not raw:
        return logging.INFO
    level = logging.getLevelName(raw.upper())
    if isinstance(level, int):
        return level
    if raw.isdigit():
        return int(raw)
    logging.getLogger("repro").warning(
        "REPRO_LOG_LEVEL=%r is not a log level; using INFO", raw
    )
    return logging.INFO


def get_logger(name: str) -> logging.Logger:
    global _level_applied
    root = logging.getLogger("repro")
    if not root.handlers:
        # nobody (us on an earlier call, or a host app) has configured the
        # repro root yet: attach exactly one stderr handler
        handler = logging.StreamHandler(sys.stderr)
        handler.setFormatter(logging.Formatter(_FORMAT))
        root.addHandler(handler)
    if not _level_applied:
        # apply the env override once per process, but never clobber a
        # level a host app set explicitly before our first get_logger call
        if root.level == logging.NOTSET or "REPRO_LOG_LEVEL" in os.environ:
            root.setLevel(_env_level())
        _level_applied = True
    return logging.getLogger(f"repro.{name}")
