"""Span recording the pipeline's hot paths can afford.

One ``SpanEmitter`` per *track* — an actor replica, the learner loop, a
queue plane, a worker subprocess — holding a bounded, preallocated ring of
``(category, t0, t1)`` spans on the monotonic clock (``time.perf_counter``:
CLOCK_MONOTONIC on Linux, so parent- and child-process timestamps share an
epoch; ``repro.telemetry.hub`` re-anchors shipped spans only when the
offset says otherwise). The design constraints come from where the
recording happens:

* **never blocks** — ``begin``/``end``/``record`` never wait on anything.
  Emitters written from exactly one thread (actors, the learner) take no
  lock at all; multi-producer emitters (a queue's merged put side) take a
  private uncontended ``threading.Lock`` for the duration of two array
  writes.
* **never allocates in steady state** — the ring, the per-category totals
  and the nesting stack are preallocated ``array('d')``/``array('i')``
  storage; recording is index arithmetic and scalar stores. A full ring
  increments ``drops`` and keeps going (the span's *duration* still lands
  in the totals — dropping trace detail must never corrupt the derived
  idle accounting); nesting deeper than ``_MAX_DEPTH`` likewise counts a
  drop instead of growing a stack.
* **totals are the accounting of record** — ``total(cat)`` accumulates
  ``t1 - t0`` per span in record order, the exact float arithmetic the
  pre-telemetry ad-hoc counters (``put_wait_s`` / ``get_wait_s`` /
  ``wait_s``) performed, which is what lets ``RunResult``'s idle fields be
  *derived from* spans without changing a bit of their semantics.

``set_capture(False)`` is the overhead kill switch the
``telemetry_overhead`` benchmark compares against: totals (and therefore
every ``RunResult`` field) keep accumulating, but ring storage,
stack bookkeeping for the watchdog, and last-activity tracking are
skipped — the pre-refactor cost model.
"""
from __future__ import annotations

import threading
import time
from array import array
from typing import List, Optional, Sequence, Tuple

__all__ = [
    "CATEGORIES",
    "COLLECT",
    "QUEUE_PUT_WAIT",
    "QUEUE_GET_WAIT",
    "LEASE",
    "PUBLISH",
    "LEARNER_UPDATE",
    "SHM_COPY",
    "MESH_REASSEMBLE",
    "REPLAY_ADD",
    "REPLAY_SAMPLE",
    "REPLAY_EVICT",
    "FAULT_DETECT",
    "FAULT_RESPAWN",
    "FAULT_GIVEUP",
    "SpanEmitter",
    "set_capture",
    "capture_enabled",
]

# the fixed pipeline vocabulary — every plane speaks these stages (an
# emitter may carry its own table, e.g. the serve launcher's
# prefill/decode, but the pipeline emitters all use this one). The three
# replay.* stages belong to the sampled ReplayRing plane: add (a producer
# deposit), sample (the learner's batched draw over resident slots) and
# evict (FIFO retirement of the oldest slot when the ring is full). The
# three fault.* stages are the supervisor's recovery episodes (detect a
# replica failure, respawn it, or give up and degrade) — appended, never
# reordered: shipped worker rings carry category *indices*, so existing
# entries must keep their positions across versions.
CATEGORIES: Tuple[str, ...] = (
    "collect",
    "queue.put_wait",
    "queue.get_wait",
    "lease",
    "publish",
    "learner.update",
    "shm.copy",
    "mesh.reassemble",
    "replay.add",
    "replay.sample",
    "replay.evict",
    "fault.detect",
    "fault.respawn",
    "fault.giveup",
)
COLLECT = 0
QUEUE_PUT_WAIT = 1
QUEUE_GET_WAIT = 2
LEASE = 3
PUBLISH = 4
LEARNER_UPDATE = 5
SHM_COPY = 6
MESH_REASSEMBLE = 7
REPLAY_ADD = 8
REPLAY_SAMPLE = 9
REPLAY_EVICT = 10
FAULT_DETECT = 11
FAULT_RESPAWN = 12
FAULT_GIVEUP = 13

_MAX_DEPTH = 8  # open-span nesting the preallocated stack covers

# module-global capture switch (ring/stack/activity bookkeeping only —
# totals always accumulate; see module docstring)
_capture = True


def set_capture(enabled: bool) -> None:
    """Globally enable/disable span *capture* (totals always run)."""
    global _capture
    _capture = bool(enabled)


def capture_enabled() -> bool:
    return _capture


class SpanEmitter:
    """Bounded span ring + per-category duration totals for one track.

    Single-writer by default (no lock — actors and the learner each own
    their emitter); pass ``locked=True`` for emitters recorded into from
    several threads at once (a queue's merged producer side). Readers
    (watchdog, heartbeat, trace export) tolerate torn reads: they only run
    for logging/export, never feed the accounting.
    """

    __slots__ = (
        "name", "categories", "capacity", "drops", "count",
        "_cat", "_t0", "_t1", "_totals",
        "_stack_cat", "_stack_t0", "_depth",
        "last_activity", "_lock",
    )

    def __init__(self, name: str, capacity: int = 4096,
                 categories: Sequence[str] = CATEGORIES,
                 locked: bool = False):
        if capacity < 1:
            raise ValueError(f"span ring capacity must be >= 1, got {capacity}")
        self.name = name
        self.categories = tuple(categories)
        self.capacity = capacity
        self.drops = 0  # spans not stored (ring full / stack overflow)
        self.count = 0  # spans stored in the ring
        self._cat = array("i", bytes(4 * capacity))
        self._t0 = array("d", bytes(8 * capacity))
        self._t1 = array("d", bytes(8 * capacity))
        self._totals = array("d", bytes(8 * len(self.categories)))
        self._stack_cat = array("i", bytes(4 * _MAX_DEPTH))
        self._stack_t0 = array("d", bytes(8 * _MAX_DEPTH))
        self._depth = 0
        self.last_activity = 0.0  # perf_counter of the last recorded end
        self._lock = threading.Lock() if locked else None

    # -- hot path ------------------------------------------------------------
    def begin(self, cat: int) -> None:
        """Open a span of ``cat`` (nesting up to ``_MAX_DEPTH``); pair with
        ``end()``. Single-writer only — multi-threaded emitters must use
        ``record`` (there is no per-thread open-span state to share)."""
        d = self._depth
        self._depth = d + 1
        if d < _MAX_DEPTH:
            self._stack_cat[d] = cat
            self._stack_t0[d] = time.perf_counter()
        else:
            self.drops += 1

    def end(self) -> None:
        """Close the innermost open span and record it."""
        d = self._depth - 1
        self._depth = d
        if d < 0 or d >= _MAX_DEPTH:
            return  # over/underflow: the matching begin already counted it
        self._record(self._stack_cat[d], self._stack_t0[d],
                     time.perf_counter())

    def cancel(self) -> None:
        """Close the innermost open span *without* recording it (abort
        paths whose pre-telemetry counters never accumulated either)."""
        self._depth -= 1

    def record(self, cat: int, t0: float, t1: Optional[float] = None) -> None:
        """After-the-fact span (the multi-writer path: ``locked=True``)."""
        if t1 is None:
            t1 = time.perf_counter()
        if self._lock is None:
            self._record(cat, t0, t1)
        else:
            with self._lock:
                self._record(cat, t0, t1)

    def _record(self, cat: int, t0: float, t1: float) -> None:
        # totals first: the accounting of record, immune to ring pressure
        self._totals[cat] += t1 - t0
        if not _capture:
            return
        self.last_activity = t1
        n = self.count
        if n < self.capacity:
            self._cat[n] = cat
            self._t0[n] = t0
            self._t1[n] = t1
            self.count = n + 1
        else:
            self.drops += 1

    # -- derived accounting ----------------------------------------------------
    def total(self, cat: int) -> float:
        """Cumulative duration of ``cat`` spans (drop-proof; see module doc)."""
        return self._totals[cat]

    @property
    def records(self) -> int:
        """Total spans ever recorded (stored + dropped): the progress
        counter the stall watchdog diffs."""
        return self.count + self.drops

    # -- observer side (watchdog / export; tolerates torn reads) -------------
    def current(self) -> Optional[Tuple[str, float]]:
        """(category name, seconds open) of the innermost open span, or
        ``None`` when the track is between spans."""
        d = min(self._depth, _MAX_DEPTH) - 1
        if d < 0:
            return None
        try:
            cat = self._stack_cat[d]
            return self.categories[cat], time.perf_counter() - self._stack_t0[d]
        except IndexError:  # pragma: no cover - raced a concurrent pop
            return None

    def snapshot(self) -> List[Tuple[int, float, float]]:
        """Copy the stored spans out (allocates — end-of-run export only)."""
        n = min(self.count, self.capacity)
        return [(self._cat[i], self._t0[i], self._t1[i]) for i in range(n)]

    def ship(self) -> dict:
        """Picklable export for cross-process transport (worker → parent):
        the ring contents, category table, drop count and a clock sample
        the receiver uses to detect a foreign monotonic epoch."""
        n = min(self.count, self.capacity)
        return {
            "name": self.name,
            "categories": self.categories,
            "cat": self._cat[:n].tolist(),
            "t0": self._t0[:n].tolist(),
            "t1": self._t1[:n].tolist(),
            "drops": self.drops,
            "totals": self._totals.tolist(),
            "clock": time.perf_counter(),
        }

    def reset(self) -> None:
        """Forget everything recorded (workers reset between run commands
        so re-runs don't re-ship old spans)."""
        self.count = 0
        self.drops = 0
        self._depth = 0
        for i in range(len(self._totals)):
            self._totals[i] = 0.0
