"""Chrome trace-event JSON export (the ``--trace PATH`` artifact).

The format is the Trace Event Format's JSON Object Format — a
``traceEvents`` list of complete-duration (``"ph": "X"``) events plus
metadata (``"ph": "M"``) events naming each process/thread track — which
``chrome://tracing`` and Perfetto (https://ui.perfetto.dev) open directly.

Track mapping: ``pid`` is the execution plane's process (0 = the parent;
worker subprocesses get ``actor_id + 1``), ``tid`` one emitter within it
(an actor replica, the learner loop, a queue plane). Timestamps are
microseconds relative to the run's epoch (the ``Telemetry`` hub's t0), so
the trace starts near 0 regardless of host uptime.
"""
from __future__ import annotations

import json
from typing import List, Optional, Tuple

__all__ = ["write_chrome_trace"]


def write_chrome_trace(path_or_file, tracks: List[Tuple[int, int, object]],
                       epoch: float, reports: Optional[dict] = None) -> int:
    """Write one merged Chrome trace; returns the number of span events.

    ``tracks`` is ``[(pid, tid, emitter), ...]`` (emitters or anything with
    ``name``/``categories``/``snapshot()``); ``epoch`` the perf_counter
    origin subtracted from every timestamp. ``reports`` (optional) is a
    dict of named end-of-run payloads (e.g. the lock-order sanitizer's
    verdict) embedded verbatim as a top-level ``"reports"`` key — trace
    viewers ignore unknown keys, post-mortem tooling greps them.
    """
    events = []
    pids_named = set()
    for pid, tid, em in tracks:
        events.append({
            "name": "thread_name", "ph": "M", "pid": pid, "tid": tid,
            "args": {"name": em.name},
        })
        if pid not in pids_named:
            pids_named.add(pid)
            events.append({
                "name": "process_name", "ph": "M", "pid": pid, "tid": 0,
                "args": {"name": "parent" if pid == 0
                         else f"worker{pid - 1}"},
            })
    n_spans = 0
    for pid, tid, em in tracks:
        cats = em.categories
        for cat, t0, t1 in em.snapshot():
            events.append({
                "name": cats[cat],
                "cat": cats[cat],
                "ph": "X",
                "ts": (t0 - epoch) * 1e6,
                "dur": (t1 - t0) * 1e6,
                "pid": pid,
                "tid": tid,
            })
            n_spans += 1
    payload = {"traceEvents": events, "displayTimeUnit": "ms"}
    if reports:
        payload["reports"] = reports
    if hasattr(path_or_file, "write"):
        json.dump(payload, path_or_file)
    else:
        with open(path_or_file, "w") as f:
            json.dump(payload, f)
    return n_spans
