"""Pipeline observability: spans, traces, heartbeats, stall watchdog.

The paper's whole argument is a timing argument (Fig. 2 decomposes where
wall-clock goes between acting, env stepping and learning); this package
is that decomposition made first-class for the asynchronous pipeline.
Every plane's hot path records bounded-ring monotonic-clock spans over a
fixed stage vocabulary (``CATEGORIES``: collect, queue.put_wait,
queue.get_wait, lease, publish, learner.update, shm.copy,
mesh.reassemble) into per-track ``SpanEmitter``s; a per-run ``Telemetry``
hub merges them into a Chrome trace-event JSON (``--trace``, open in
Perfetto), streams a JSONL metrics heartbeat (``--metrics-jsonl``), and
runs the stall watchdog that names the stage each party is blocked in
when progress stops.

The pre-existing ``RunResult`` idle accounting (``put_wait_s`` /
``get_wait_s`` / ``per_actor_idle_s``) is *derived from* these spans —
the emitters' per-category totals accumulate the exact float arithmetic
the old ad-hoc counters performed — so enabling telemetry changes no
reported number. See ``docs/observability.md``.
"""
from repro.telemetry.hub import ShippedTrack, Telemetry
from repro.telemetry.spans import (
    CATEGORIES,
    COLLECT,
    FAULT_DETECT,
    FAULT_GIVEUP,
    FAULT_RESPAWN,
    LEASE,
    LEARNER_UPDATE,
    MESH_REASSEMBLE,
    PUBLISH,
    QUEUE_GET_WAIT,
    QUEUE_PUT_WAIT,
    REPLAY_ADD,
    REPLAY_EVICT,
    REPLAY_SAMPLE,
    SHM_COPY,
    SpanEmitter,
    capture_enabled,
    set_capture,
)
from repro.telemetry.trace import write_chrome_trace

__all__ = [
    "CATEGORIES",
    "COLLECT",
    "QUEUE_PUT_WAIT",
    "QUEUE_GET_WAIT",
    "LEASE",
    "PUBLISH",
    "LEARNER_UPDATE",
    "SHM_COPY",
    "MESH_REASSEMBLE",
    "REPLAY_ADD",
    "REPLAY_SAMPLE",
    "REPLAY_EVICT",
    "FAULT_DETECT",
    "FAULT_RESPAWN",
    "FAULT_GIVEUP",
    "SpanEmitter",
    "Telemetry",
    "ShippedTrack",
    "write_chrome_trace",
    "set_capture",
    "capture_enabled",
]
