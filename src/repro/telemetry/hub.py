"""The per-run telemetry hub: emitter registry, heartbeat, stall watchdog.

One ``Telemetry`` per ``PipelinedRL`` (or standalone harness). Every track
— actor replicas, the learner loop, each queue plane, shipped worker-side
rings — registers here; at run end the hub merges them into one Chrome
trace (``write_trace``), and during the run two optional daemon threads
observe them:

* **heartbeat** (``--metrics-jsonl``): every ``interval`` seconds, append
  one JSON line of liveness metrics — steps/s EMA, queue depth / ring
  occupancy, latest staleness, per-actor seconds since last activity,
  cumulative span drops. One line per tick, flushed, so ``tail -f`` on a
  live run (or a post-mortem on a dead one) always has current numbers.
* **stall watchdog** (``stall_timeout_s``): when any watched party (the
  learner or an actor) records no span for a full window, log *which
  stage every party is currently blocked in* — the difference between
  "it hangs" and "actor 2 is stuck in queue.put_wait, so the learner
  died" — instead of hanging silently. Logs once per stall episode;
  re-arms when progress resumes.

Observer threads only read emitter state that tolerates torn reads (they
feed logs, never the accounting), so the hot paths stay lock-free.
"""
from __future__ import annotations

import json
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.telemetry.spans import CATEGORIES, SpanEmitter
from repro.telemetry.trace import write_chrome_trace
from repro.utils import get_logger

__all__ = ["Telemetry", "ShippedTrack"]

log = get_logger("telemetry")

# a shipped clock sample further than this from our own monotonic clock
# means the child ran on a different epoch (non-Linux perf_counter):
# re-anchor its spans at receive time. On Linux both processes read
# CLOCK_MONOTONIC, the offset is ~transport latency, and we leave the
# timestamps untouched.
_EPOCH_SLACK_S = 1.0


class ShippedTrack:
    """Read-only emitter stand-in rebuilt from ``SpanEmitter.ship()``."""

    def __init__(self, payload: dict, offset: float = 0.0):
        self.name = payload["name"]
        self.categories = tuple(payload["categories"])
        self.drops = payload["drops"]
        self._spans = [
            (c, t0 + offset, t1 + offset)
            for c, t0, t1 in zip(payload["cat"], payload["t0"], payload["t1"])
        ]
        self._totals = list(payload["totals"])

    def snapshot(self) -> List[Tuple[int, float, float]]:
        return list(self._spans)

    def total(self, cat: int) -> float:
        return self._totals[cat]


class Telemetry:
    """Emitter registry + trace/heartbeat/watchdog for one pipeline run."""

    def __init__(self):
        self.t0 = time.perf_counter()  # trace epoch
        self._reg_lock = threading.Lock()
        self._tracks: List[Tuple[int, int, Any]] = []  # (pid, tid, emitter)
        self._next_tid: Dict[int, int] = {}
        self._counters: Dict[str, float] = {}
        self._gauges: Dict[str, Any] = {}  # name -> value or callable
        # named end-of-run reports (e.g. the lock-order sanitizer verdict):
        # plain JSON-able dicts, embedded in the trace under "reports"
        self.reports: Dict[str, dict] = {}
        self._hb_stop: Optional[threading.Event] = None
        self._hb_thread: Optional[threading.Thread] = None
        self._wd_stop: Optional[threading.Event] = None
        self._wd_thread: Optional[threading.Thread] = None

    # -- emitters -------------------------------------------------------------
    def emitter(self, name: str, capacity: int = 4096,
                categories: Sequence[str] = CATEGORIES,
                locked: bool = False, pid: int = 0) -> SpanEmitter:
        """Create and register one track's emitter."""
        em = SpanEmitter(name, capacity=capacity, categories=categories,
                         locked=locked)
        self.adopt(em, pid=pid)
        return em

    def adopt(self, emitter: Any, pid: int = 0) -> None:
        """Register an emitter created elsewhere (a queue built before the
        hub existed, a ``ShippedTrack``) under process track ``pid``."""
        with self._reg_lock:
            tid = self._next_tid.get(pid, 1)
            self._next_tid[pid] = tid + 1
            self._tracks.append((pid, tid, emitter))

    def merge_shipped(self, payload: dict, pid: int) -> ShippedTrack:
        """Adopt a worker-side ring shipped through the ready queue; the
        per-process track id is ``pid`` (``actor_id + 1``)."""
        offset = time.perf_counter() - payload["clock"]
        track = ShippedTrack(
            payload, offset=offset if abs(offset) > _EPOCH_SLACK_S else 0.0
        )
        self.adopt(track, pid=pid)
        return track

    def tracks(self) -> List[Tuple[int, int, Any]]:
        with self._reg_lock:
            return list(self._tracks)

    def drops(self) -> int:
        return sum(em.drops for _, _, em in self.tracks())

    # -- counters / gauges (heartbeat inputs) ---------------------------------
    def counter_add(self, name: str, value: float) -> None:
        """Accumulate a monotone counter (single-writer per name)."""
        self._counters[name] = self._counters.get(name, 0.0) + value

    def counter(self, name: str) -> float:
        return self._counters.get(name, 0.0)

    def set_gauge(self, name: str, value: Any) -> None:
        """Register a gauge: a value, or a zero-arg callable sampled at each
        heartbeat tick (must be cheap and thread-safe, e.g. ``queue.qsize``)."""
        self._gauges[name] = value

    def _sample_gauges(self) -> Dict[str, Any]:
        out = {}
        for name, v in list(self._gauges.items()):
            try:
                out[name] = v() if callable(v) else v
            except Exception:  # a gauge must never kill the heartbeat
                out[name] = None
        return out

    # -- named reports --------------------------------------------------------
    def report(self, name: str, payload: dict) -> None:
        """Attach a named end-of-run report (overwrites a prior ``name``).

        Used by the sanitizers (``lockcheck``) so their verdicts ride the
        run's telemetry instead of a side channel; harnesses read
        ``hub.reports[name]`` after ``run()`` returns."""
        self.reports[name] = payload

    # -- trace export ---------------------------------------------------------
    def write_trace(self, path) -> int:
        """Merge every registered track into one Chrome trace JSON."""
        n = write_chrome_trace(path, self.tracks(), self.t0,
                               reports=self.reports or None)
        if isinstance(path, str):
            log.info("telemetry: wrote %d spans to %s", n, path)
        return n

    # -- heartbeat ------------------------------------------------------------
    def heartbeat_start(self, path: str, interval: float = 1.0,
                        actor_emitters: Sequence[SpanEmitter] = ()) -> None:
        """Append one JSONL metrics line to ``path`` every ``interval`` s."""
        if self._hb_thread is not None:
            raise RuntimeError("heartbeat already running")
        stop = threading.Event()
        actors = list(actor_emitters)

        def loop():
            ema = 0.0
            last_steps = self.counter("steps")
            last_t = time.perf_counter()
            with open(path, "a") as f:
                while True:
                    stopped = stop.wait(interval)
                    now = time.perf_counter()
                    steps = self.counter("steps")
                    dt = max(now - last_t, 1e-9)
                    inst = (steps - last_steps) / dt
                    # EMA over ticks: alpha=0.5 tracks fast, smooths jitter
                    ema = inst if ema == 0.0 else 0.5 * inst + 0.5 * ema
                    last_steps, last_t = steps, now
                    line = {
                        "time_unix": time.time(),
                        "uptime_s": now - self.t0,
                        "steps": steps,
                        "steps_per_s_ema": ema,
                        "span_drops": self.drops(),
                        "actor_last_activity_s": {
                            em.name: (round(now - em.last_activity, 6)
                                      if em.last_activity else None)
                            for em in actors
                        },
                        # every registered counter (fault.detect/respawn/
                        # giveup land here when the supervisor is active) —
                        # additive: schema consumers key on the fields above
                        "counters": {k: v for k, v in self._counters.items()
                                     if k != "steps"},
                    }
                    line.update(self._sample_gauges())
                    f.write(json.dumps(line) + "\n")
                    f.flush()
                    if stopped:
                        return  # final line written on stop

        self._hb_stop = stop
        self._hb_thread = threading.Thread(
            target=loop, name="telemetry-heartbeat", daemon=True
        )
        self._hb_thread.start()

    def heartbeat_stop(self) -> None:
        if self._hb_thread is None:
            return
        self._hb_stop.set()
        self._hb_thread.join(timeout=10.0)
        self._hb_thread = self._hb_stop = None

    # -- stall watchdog -------------------------------------------------------
    def watchdog_start(
        self,
        window_s: float,
        parties: Sequence[Tuple[str, SpanEmitter, Optional[Callable[[], bool]]]],
    ) -> None:
        """Watch ``parties`` = (label, emitter, alive_fn) for progress.

        A party has made progress when its emitter recorded any span since
        the last check; one that is still alive (``alive_fn`` — ``None``
        means always) but has recorded nothing for ``window_s`` is stalled.
        While any party is stalled, log every party's current stage once
        per episode — then stay quiet until progress resumes.
        """
        if self._wd_thread is not None:
            raise RuntimeError("watchdog already running")
        if window_s <= 0:
            raise ValueError(f"watchdog window must be > 0, got {window_s}")
        stop = threading.Event()
        watched = [(label, em, alive) for label, em, alive in parties]

        def loop():
            last = {label: (em.records, time.perf_counter())
                    for label, em, _ in watched}
            reported = False
            while not stop.wait(min(window_s / 4.0, 1.0)):
                now = time.perf_counter()
                stalled = []
                for label, em, alive in watched:
                    recs, since = last[label]
                    if em.records != recs:
                        last[label] = (em.records, now)
                        continue
                    if now - since >= window_s and (alive is None or alive()):
                        stalled.append(label)
                if not stalled:
                    reported = False
                    continue
                if reported:
                    continue  # one report per stall episode
                reported = True
                stages = []
                for label, em, alive in watched:
                    cur = em.current()
                    if cur is not None:
                        stages.append(f"{label}: blocked in {cur[0]}"
                                      f" for {cur[1]:.1f}s")
                    elif alive is not None and not alive():
                        stages.append(f"{label}: exited")
                    else:
                        stages.append(f"{label}: idle (no open span)")
                log.warning(
                    "stall watchdog: no progress from %s for %.1fs — %s",
                    ", ".join(stalled), window_s, "; ".join(stages),
                )

        self._wd_stop = stop
        self._wd_thread = threading.Thread(
            target=loop, name="telemetry-watchdog", daemon=True
        )
        self._wd_thread.start()

    def watchdog_stop(self) -> None:
        if self._wd_thread is None:
            return
        self._wd_stop.set()
        self._wd_thread.join(timeout=10.0)
        self._wd_thread = self._wd_stop = None

    def stop(self) -> None:
        """Stop both observer threads (idempotent; run-exit path)."""
        self.heartbeat_stop()
        self.watchdog_stop()
