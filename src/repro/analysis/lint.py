"""repro-lint — stdlib-``ast`` checks for the repo's unchecked invariants.

The pipeline's correctness rests on conventions that, before this tool,
lived only in docstrings and review memory. Each is now a named rule
(``docs/static_analysis.md`` has the catalog with rationale):

* ``lease-pairing`` — every acquire-side lease call is paired with its
  release-side twin on the same receiver in the same function:
  ``<recv>.acquire(...)``/``<recv>.release(...)`` (param slots, staging
  rings, shm views) and ``<recv>.allocate(...)``|``<recv>.alloc(...)``/
  ``<recv>.free(...)`` (the serving plane's cache slots). When the
  release happens in this function's own control flow it must sit under
  a ``try/finally`` so error paths cannot leak the lease (a leaked lease
  deadlocks the learner's ``reserve`` or starves the ring/slot pool). A
  release inside a nested ``lambda``/``def`` is the *deferred handoff*
  idiom (the payload's ``release``/``free`` callback) and satisfies the
  rule. ``reserve`` must likewise pair with ``commit`` (no finally
  needed: reserve only waits, it holds nothing on failure).
* ``span-pairing`` — every ``SpanEmitter.begin`` is balanced by ``end()``
  or ``cancel()`` on every early-return path and on normal completion
  (an unbalanced span corrupts the emitter's open-span stack and every
  later total). Checked by abstract interpretation over the function
  body tracking per-receiver open-span depth through if/while/for/try;
  exceptional exits are exempt (an uncaught exception tears the whole
  track down and ``reset()`` re-zeroes it).
* ``donated-reuse`` — a variable passed in a donated argument position of
  a known fused call (any name assigned from ``jax.jit(...,
  donate_argnums=...)`` in the same module) must not be read again before
  being reassigned: its buffer is deleted the moment the call dispatches.
* ``hot-path-sync`` — no implicit host syncs (``float()``/``int()``/
  ``bool()`` on non-constants, ``.item()``, ``.tolist()``,
  ``np.asarray``/``np.array``, ``jax.device_get``) inside functions
  marked with a ``# hot-path`` comment (on or directly above the
  ``def``) or on the built-in allowlist (the span-emitter hot path).
* ``hostenv-picklable`` — ``HostEnvSpec(...)`` must be constructed from a
  module-level callable: a lambda or locally-defined ``env_fn`` dies at
  pickling time inside a spawned worker, far from the author.

Suppression: append ``# repro-lint: disable=<rule>[,<rule>...]`` to the
offending line, or to the ``def`` line to waive a whole function.

Run as ``python -m repro.analysis.lint [paths...]`` (default ``src``);
exit 0 clean, 1 with findings, 2 on usage errors. ``scripts/lint.py``
wraps this with a ``--diff`` mode. Pure stdlib — no new dependencies.
"""
from __future__ import annotations

import argparse
import ast
import re
import sys
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Set, Tuple

__all__ = ["Finding", "RULES", "lint_paths", "lint_source", "main"]

RULES: Dict[str, str] = {
    "lease-pairing": "acquire/release, allocate/free (and reserve/commit) "
                     "pairing under try/finally on all paths",
    "span-pairing": "SpanEmitter.begin balanced by end() or cancel() on "
                    "every non-exceptional path",
    "donated-reuse": "no use of a variable after it rode a donated "
                     "argument position of a fused jitted call",
    "hot-path-sync": "no implicit host syncs inside # hot-path functions",
    "hostenv-picklable": "HostEnvSpec built from module-level callables "
                         "only (spawned workers unpickle the recipe)",
}

# lease verbs: acquire-side name -> its matching release-side name.
# acquire/release is the pipeline ring's vocabulary; allocate|alloc/free
# is the serving slot cache's (KVSlotCache). Same rule, same deferred-
# handoff and try/finally semantics for every pair.
_LEASE_PAIRS = {"acquire": "release", "allocate": "free", "alloc": "free"}
_LEASE_ACQ = set(_LEASE_PAIRS)
_LEASE_REL = set(_LEASE_PAIRS.values())

# function names that ARE the lease protocol implementation (their bodies
# legitimately touch one side of a pair)
_LEASE_IMPL = {
    "acquire", "release", "reserve", "commit", "publish", "revoke",
    "read", "__enter__", "__exit__",
    "allocate", "alloc", "free", "evict",
}

# hot by construction, no comment marker needed (the rule's allowlist arm)
HOT_PATH_QUALNAMES = {
    "SpanEmitter.begin", "SpanEmitter.end", "SpanEmitter.cancel",
    "SpanEmitter.record", "SpanEmitter._record",
}

_SYNC_CALLS = {"float", "int", "bool"}
_SYNC_DOTTED = {
    "np.asarray", "np.array", "numpy.asarray", "numpy.array",
    "jax.device_get",
}
_SYNC_ATTRS = {"item", "tolist"}

_SUPPRESS_RE = re.compile(r"#\s*repro-lint:\s*disable=([\w,\- ]+)")
_HOT_RE = re.compile(r"#\s*hot-path\b")


@dataclass(frozen=True)
class Finding:
    path: str
    line: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


def _dotted(node: ast.AST) -> Optional[str]:
    """'self._slot' for Attribute chains over Names; None otherwise."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = _dotted(node.value)
        return f"{base}.{node.attr}" if base else None
    return None


def _attr_call(node: ast.AST, attrs: Set[str]) -> Optional[Tuple[str, str]]:
    """(receiver, attr) when node is a ``<recv>.<attr>(...)`` call with
    attr in ``attrs`` and a resolvable dotted receiver."""
    if (isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute)
            and node.func.attr in attrs):
        recv = _dotted(node.func.value)
        if recv is not None:
            return recv, node.func.attr
    return None


def _direct_statements(func: ast.AST):
    """Every statement in ``func``'s own body, not descending into nested
    function/class definitions (those run at other times)."""
    todo = list(func.body)
    while todo:
        stmt = todo.pop(0)
        yield stmt
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            continue
        for field in ("body", "orelse", "finalbody"):
            todo.extend(getattr(stmt, field, []) or [])
        for h in getattr(stmt, "handlers", []) or []:
            todo.extend(h.body)


def _direct_expr_walk(stmt: ast.stmt):
    """Walk a statement's expressions without entering nested defs or
    lambdas (their bodies execute later, under different pairing)."""
    todo = [stmt]
    while todo:
        node = todo.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda, ast.ClassDef)):
            continue
        todo.extend(ast.iter_child_nodes(node))


class _FileLint:
    def __init__(self, path: str, source: str):
        self.path = path
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=path)
        self.findings: List[Finding] = []
        # line -> suppressed rule names
        self.suppress: Dict[int, Set[str]] = {}
        for i, line in enumerate(self.lines, 1):
            m = _SUPPRESS_RE.search(line)
            if m:
                self.suppress[i] = {r.strip()
                                    for r in m.group(1).split(",") if r.strip()}
        # (func node, qualname, enclosing-function chain)
        self.functions: List[Tuple[ast.AST, str, int]] = []
        self._collect_functions(self.tree, prefix="", depth=0)

    def _collect_functions(self, node: ast.AST, prefix: str, depth: int):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qual = f"{prefix}{child.name}"
                self.functions.append((child, qual, depth))
                self._collect_functions(child, f"{qual}.", depth + 1)
            elif isinstance(child, ast.ClassDef):
                self._collect_functions(child, f"{child.name}.", depth)
            else:
                self._collect_functions(child, prefix, depth)

    def _suppressed(self, rule: str, line: int, func: ast.AST = None) -> bool:
        if rule in self.suppress.get(line, ()):
            return True
        if func is not None and rule in self.suppress.get(func.lineno, ()):
            return True
        return False

    def emit(self, rule: str, node: ast.AST, message: str,
             func: ast.AST = None) -> None:
        line = getattr(node, "lineno", 1)
        if not self._suppressed(rule, line, func):
            f = Finding(self.path, line, rule, message)
            if f not in self.findings:
                self.findings.append(f)

    def run(self) -> List[Finding]:
        donated = self._donation_registry()
        for func, qual, _depth in self.functions:
            self._check_leases(func, qual)
            self._check_spans(func)
            self._check_donated(func, donated)
            self._check_hot_path(func, qual)
        self._check_hostenv()
        return self.findings

    # -- rule: lease-pairing -------------------------------------------------
    def _check_leases(self, func: ast.AST, qual: str) -> None:
        name = qual.rsplit(".", 1)[-1]
        if name in _LEASE_IMPL:
            return
        in_finally: Set[int] = set()
        for stmt in _direct_statements(func):
            if isinstance(stmt, ast.Try):
                for fstmt in stmt.finalbody:
                    for sub in ast.walk(fstmt):
                        in_finally.add(id(sub))
        # acquire-side calls keyed by (recv, acquire-verb); a release-side
        # call matches when its recv and verb agree with _LEASE_PAIRS
        acquires: Dict[Tuple[str, str], ast.Call] = {}
        reserves: Dict[str, ast.Call] = {}
        direct_rel: Dict[Tuple[str, str], List[bool]] = {}  # in_finally?
        commits: Set[str] = set()
        deferred_rel: Set[Tuple[str, str]] = set()
        verbs = _LEASE_ACQ | _LEASE_REL | {"reserve", "commit"}
        for stmt in _direct_statements(func):
            for node in _direct_expr_walk(stmt):
                hit = _attr_call(node, verbs)
                if hit is None:
                    continue
                recv, attr = hit
                if attr in _LEASE_ACQ:
                    acquires.setdefault((recv, attr), node)
                elif attr == "reserve":
                    reserves.setdefault(recv, node)
                elif attr in _LEASE_REL:
                    direct_rel.setdefault((recv, attr), []).append(
                        id(node) in in_finally)
                elif attr == "commit":
                    commits.add(recv)
        # releases handed off into nested lambdas/defs (payload callbacks)
        for stmt in _direct_statements(func):
            for node in _direct_expr_walk(stmt):
                if isinstance(node, (ast.Lambda, ast.FunctionDef)):
                    for sub in ast.walk(node):
                        hit = _attr_call(sub, _LEASE_REL)
                        if hit is not None:
                            deferred_rel.add(hit)
        for (recv, acq), call in acquires.items():
            rel = _LEASE_PAIRS[acq]
            rels = direct_rel.get((recv, rel), [])
            if not rels and (recv, rel) not in deferred_rel:
                self.emit(
                    "lease-pairing", call,
                    f"{recv}.{acq}() has no matching {recv}.{rel}() in "
                    "this function — a leaked lease starves the ring or "
                    "slot pool and deadlocks upstream admission", func)
            elif rels and not any(rels):
                self.emit(
                    "lease-pairing", call,
                    f"{recv}.{rel}() is not under try/finally — an "
                    f"exception between {acq} and {rel} leaks the "
                    "lease", func)
        for recv, call in reserves.items():
            if recv not in commits:
                self.emit(
                    "lease-pairing", call,
                    f"{recv}.reserve() without {recv}.commit() in this "
                    "function — the reserved buffer never publishes and "
                    "readers wait on a version that never lands", func)

    # -- rule: span-pairing --------------------------------------------------
    def _check_spans(self, func: ast.AST) -> None:
        recvs: List[str] = []
        for stmt in _direct_statements(func):
            for node in _direct_expr_walk(stmt):
                hit = _attr_call(node, {"begin"})
                if hit is not None and hit[0] not in recvs:
                    recvs.append(hit[0])
        if not recvs:
            return
        idx = {r: i for i, r in enumerate(recvs)}
        # a state is (per-receiver open-span depths, tainted): tainted
        # states descend from an exception-handler entry — exceptional
        # paths, which this rule forgives — and are simulated only so
        # handler-side cancel()/reset() keep downstream states accurate
        zero = ((0,) * len(recvs), False)

        def apply_stmt(stmt: ast.stmt, state) -> tuple:
            depths, tainted = list(state[0]), state[1]
            for node in _direct_expr_walk(stmt):
                hit = _attr_call(node, {"begin", "end", "cancel", "reset"})
                if hit is None or hit[0] not in idx:
                    continue
                r, attr = hit
                if attr == "begin":
                    depths[idx[r]] += 1
                elif attr == "reset":
                    depths[idx[r]] = 0
                else:
                    depths[idx[r]] = max(depths[idx[r]] - 1, 0)
            return tuple(depths), tainted

        returns: List[Tuple[ast.stmt, tuple]] = []
        loop_bad: List[ast.stmt] = []

        def untainted(states):
            return {s for s in states if not s[1]}

        def exec_block(stmts, states):
            """-> (normal, breaks, continues, during); returns accumulate."""
            cur = set(states)
            breaks: Set[tuple] = set()
            continues: Set[tuple] = set()
            during: Set[tuple] = set(cur)
            for stmt in stmts:
                if not cur:
                    break
                if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                     ast.ClassDef)):
                    continue
                if isinstance(stmt, ast.Return):
                    for s in cur:
                        returns.append((stmt, s))
                    cur = set()
                elif isinstance(stmt, ast.Raise):
                    cur = set()  # exceptional exits are exempt by design
                elif isinstance(stmt, ast.Break):
                    breaks |= cur
                    cur = set()
                elif isinstance(stmt, ast.Continue):
                    continues |= cur
                    cur = set()
                elif isinstance(stmt, ast.If):
                    n1, b1, c1, d1 = exec_block(stmt.body, cur)
                    n2, b2, c2, d2 = exec_block(stmt.orelse, cur)
                    cur = n1 | n2
                    breaks |= b1 | b2
                    continues |= c1 | c2
                    during |= d1 | d2
                elif isinstance(stmt, (ast.While, ast.For)):
                    entry = cur
                    n, b, c, d = exec_block(stmt.body, entry)
                    during |= d
                    if not untainted(n | c) <= untainted(entry):
                        loop_bad.append(stmt)
                    infinite = (isinstance(stmt, ast.While)
                                and isinstance(stmt.test, ast.Constant)
                                and bool(stmt.test.value))
                    cur = (b if infinite else entry | b)
                    if stmt.orelse:
                        cur, b2, c2, d2 = exec_block(stmt.orelse, cur)
                        breaks |= b2
                        continues |= c2
                        during |= d2
                elif isinstance(stmt, ast.Try):
                    n, b, c, d = exec_block(stmt.body, cur)
                    during |= d
                    # an exception can surface at any body state: handlers
                    # enter with every depth seen during the body, tainted
                    # (exceptional paths are forgiven, but the handler's own
                    # cancel()/reset() must still shape what flows onward)
                    hentry = {(depths, True) for depths, _t in d}
                    hn: Set[tuple] = set()
                    hb: Set[tuple] = set()
                    hc: Set[tuple] = set()
                    for handler in stmt.handlers:
                        n3, b3, c3, d3 = exec_block(handler.body, hentry)
                        hn |= n3
                        hb |= b3
                        hc |= c3
                        during |= d3
                    if stmt.orelse:
                        n, b4, c4, d4 = exec_block(stmt.orelse, n)
                        b |= b4
                        c |= c4
                        during |= d4
                    n |= hn
                    b |= hb
                    c |= hc
                    if stmt.finalbody:
                        def through(states_in):
                            out, _fb, _fc, fd = exec_block(stmt.finalbody,
                                                           states_in)
                            during.update(fd)
                            return out
                        # returns recorded inside the try ran the finally
                        # first: re-map the recorded states
                        fixed = []
                        for node, s in returns:
                            if (stmt.lineno <= node.lineno
                                    and node.end_lineno >= node.lineno
                                    and node.end_lineno <= stmt.end_lineno):
                                for s2 in through({s}) or {s}:
                                    fixed.append((node, s2))
                            else:
                                fixed.append((node, s))
                        returns[:] = fixed
                        n = through(n) if n else n
                        b = through(b) if b else b
                        c = through(c) if c else c
                    cur = n
                    breaks |= b
                    continues |= c
                elif isinstance(stmt, ast.With):
                    n, b, c, d = exec_block(stmt.body, cur)
                    cur = n
                    breaks |= b
                    continues |= c
                    during |= d
                else:
                    cur = {apply_stmt(stmt, s) for s in cur}
                during |= cur
            return cur, breaks, continues, during

        final, _b, _c, _d = exec_block(func.body, {zero})
        for stmt, state in returns:
            if state[1]:
                continue  # exceptional path — forgiven
            for r, i in idx.items():
                if state[0][i] > 0:
                    self.emit(
                        "span-pairing", stmt,
                        f"returns with {state[0][i]} open span(s) on {r} — "
                        "call end() (or cancel() on abort paths) before "
                        "this return", func)
        for state in untainted(final):
            for r, i in idx.items():
                if state[0][i] > 0:
                    self.emit(
                        "span-pairing", func,
                        f"function can complete with {state[0][i]} open "
                        f"span(s) on {r} — begin() without end()/cancel()",
                        func)
        for stmt in loop_bad:
            for r in recvs:
                self.emit(
                    "span-pairing", stmt,
                    f"loop body leaves {r}'s open-span depth changed "
                    "across an iteration — begin()/end() unbalanced "
                    "inside the loop", func)
                break

    # -- rule: donated-reuse -------------------------------------------------
    def _donation_registry(self) -> Dict[str, List[Tuple[int, ...]]]:
        """name (last dotted component) -> donated position tuples, from
        ``<name> = jax.jit(..., donate_argnums=...)`` in this module."""
        reg: Dict[str, List[Tuple[int, ...]]] = {}
        for node in ast.walk(self.tree):
            if not (isinstance(node, ast.Assign) and len(node.targets) == 1):
                continue
            call = node.value
            if not (isinstance(call, ast.Call)
                    and _dotted(call.func) in ("jax.jit", "jit")):
                continue
            pos: Optional[Tuple[int, ...]] = None
            for kw in call.keywords:
                if kw.arg == "donate_argnums":
                    v = kw.value
                    if isinstance(v, ast.Constant) and isinstance(v.value, int):
                        pos = (v.value,)
                    elif isinstance(v, (ast.Tuple, ast.List)) and all(
                            isinstance(e, ast.Constant) for e in v.elts):
                        pos = tuple(e.value for e in v.elts)
                    elif isinstance(v, ast.IfExp):
                        # `(0, 1, 5) if fused else (0, 1)`: union — a
                        # position donated under either branch is hot
                        cands = []
                        for side in (v.body, v.orelse):
                            if isinstance(side, (ast.Tuple, ast.List)) and all(
                                    isinstance(e, ast.Constant)
                                    for e in side.elts):
                                cands.extend(e.value for e in side.elts)
                        pos = tuple(sorted(set(cands))) if cands else None
            if pos is None:
                continue
            target = _dotted(node.targets[0])
            if target is None:
                continue
            reg.setdefault(target.rsplit(".", 1)[-1], []).append(pos)
        return reg

    def _check_donated(self, func: ast.AST,
                       reg: Dict[str, List[Tuple[int, ...]]]) -> None:
        if not reg:
            return
        # parent statement of every node in this function's direct scope
        stmt_of: Dict[int, ast.stmt] = {}
        for stmt in _direct_statements(func):
            for node in _direct_expr_walk(stmt):
                stmt_of.setdefault(id(node), stmt)
        loads: List[Tuple[int, str]] = []
        stores: List[Tuple[int, str]] = []
        for stmt in _direct_statements(func):
            for node in _direct_expr_walk(stmt):
                if isinstance(node, (ast.Name, ast.Attribute)):
                    key = _dotted(node)
                    if key is None:
                        continue
                    if isinstance(node.ctx, ast.Store):
                        stores.append((node.lineno, key))
                    elif isinstance(node.ctx, ast.Load):
                        loads.append((node.lineno, key))
        for stmt in _direct_statements(func):
            for node in _direct_expr_walk(stmt):
                if not isinstance(node, ast.Call):
                    continue
                fname = _dotted(node.func)
                if fname is None:
                    continue
                sets = reg.get(fname.rsplit(".", 1)[-1])
                if not sets:
                    continue
                call_stmt = stmt_of.get(id(node), stmt)
                end = getattr(call_stmt, "end_lineno", call_stmt.lineno)
                for positions in sets:
                    for p in positions:
                        if p >= len(node.args):
                            continue
                        key = _dotted(node.args[p])
                        if key is None:
                            continue
                        for lline, lkey in loads:
                            if lkey != key or lline <= end:
                                continue
                            redefined = any(
                                skey == key
                                and call_stmt.lineno <= sline <= lline
                                for sline, skey in stores)
                            if not redefined:
                                self.emit(
                                    "donated-reuse", node,
                                    f"{key} is donated (arg {p} of "
                                    f"{fname}) but read again on line "
                                    f"{lline} — its buffer is deleted the "
                                    "moment the call dispatches", func)
                                break

    # -- rule: hot-path-sync -------------------------------------------------
    def _is_hot(self, func: ast.AST, qual: str) -> bool:
        if qual in HOT_PATH_QUALNAMES:
            return True
        for line in (func.lineno, func.lineno - 1):
            if 1 <= line <= len(self.lines) and _HOT_RE.search(
                    self.lines[line - 1]):
                return True
        return False

    def _check_hot_path(self, func: ast.AST, qual: str) -> None:
        if not self._is_hot(func, qual):
            return
        for stmt in _direct_statements(func):
            for node in _direct_expr_walk(stmt):
                if not isinstance(node, ast.Call):
                    continue
                msg = None
                if (isinstance(node.func, ast.Name)
                        and node.func.id in _SYNC_CALLS
                        and node.args
                        and not isinstance(node.args[0], ast.Constant)):
                    msg = (f"{node.func.id}() on a runtime value blocks on "
                           "device execution")
                elif isinstance(node.func, ast.Attribute):
                    if node.func.attr in _SYNC_ATTRS:
                        msg = f".{node.func.attr}() syncs device to host"
                    elif _dotted(node.func) in _SYNC_DOTTED:
                        msg = (f"{_dotted(node.func)}() pulls the value to "
                               "host")
                if msg is not None:
                    self.emit(
                        "hot-path-sync", node,
                        f"implicit host sync in # hot-path function "
                        f"{qual}: {msg}", func)

    # -- rule: hostenv-picklable ---------------------------------------------
    def _check_hostenv(self) -> None:
        module_defs: Set[str] = set()
        local_defs: Set[str] = set()
        lambda_names: Set[str] = set()
        for node, qual, depth in self.functions:
            (local_defs if depth > 0 else module_defs).add(
                qual.rsplit(".", 1)[-1])
        for node in ast.walk(self.tree):
            if (isinstance(node, ast.Assign)
                    and isinstance(node.value, ast.Lambda)):
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        lambda_names.add(t.id)
        for node in ast.walk(self.tree):
            if not (isinstance(node, ast.Call)
                    and _dotted(node.func) is not None
                    and _dotted(node.func).rsplit(".", 1)[-1]
                    == "HostEnvSpec"):
                continue
            env_fn = node.args[0] if node.args else None
            for kw in node.keywords:
                if kw.arg == "env_fn":
                    env_fn = kw.value
            if env_fn is None:
                continue
            if isinstance(env_fn, ast.Lambda):
                self.emit(
                    "hostenv-picklable", env_fn,
                    "HostEnvSpec(env_fn=<lambda>): lambdas cannot pickle "
                    "into spawned workers — use a module-level function")
            elif isinstance(env_fn, ast.Name):
                n = env_fn.id
                if n in lambda_names or (n in local_defs
                                         and n not in module_defs):
                    self.emit(
                        "hostenv-picklable", env_fn,
                        f"HostEnvSpec(env_fn={n}): bound to a lambda or "
                        "locally-defined function — only module-level "
                        "callables survive pickling into spawned workers")


def lint_source(source: str, path: str = "<string>") -> List[Finding]:
    return _FileLint(path, source).run()


def _iter_py_files(paths: Sequence[str]):
    for p in paths:
        pth = Path(p)
        if pth.is_dir():
            yield from sorted(pth.rglob("*.py"))
        elif pth.suffix == ".py":
            yield pth
        else:
            raise FileNotFoundError(f"not a .py file or directory: {p}")


def lint_paths(paths: Sequence[str]) -> List[Finding]:
    findings: List[Finding] = []
    for f in _iter_py_files(paths):
        try:
            src = f.read_text()
        except (OSError, UnicodeDecodeError) as e:
            findings.append(Finding(str(f), 1, "parse",
                                    f"unreadable: {e}"))
            continue
        try:
            findings.extend(lint_source(src, str(f)))
        except SyntaxError as e:
            findings.append(Finding(str(f), e.lineno or 1, "parse",
                                    f"syntax error: {e.msg}"))
    return findings


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="repro-lint: stdlib-ast invariant checks "
                    "(docs/static_analysis.md has the rule catalog)")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to lint (default: src)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    args = ap.parse_args(argv)
    if args.list_rules:
        for rule, desc in RULES.items():
            print(f"{rule}: {desc}")
        return 0
    try:
        findings = lint_paths(args.paths)
    except FileNotFoundError as e:
        print(f"repro-lint: {e}", file=sys.stderr)
        return 2
    for f in findings:
        print(f)
    n_files = len(list(_iter_py_files(args.paths)))
    status = f"{len(findings)} finding(s)" if findings else "clean"
    print(f"repro-lint: {n_files} file(s), {status}", file=sys.stderr)
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
