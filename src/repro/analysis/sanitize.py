"""Transfer/donation sanitizer for the device and mesh rollout planes.

The device planes' perf contract is *no implicit host traffic in steady
state*: rollouts never leave the accelerator, the fused learner step is
one dispatch with donated params/opt/publish buffers, and every D2H/H2D
edge that does exist (shm param broadcast, end-of-run metrics drain, the
DQN collector's epsilon-schedule scalar) is deliberate and documented. A
regression — a stray ``np.asarray`` on a device value, a forgotten
``device_put`` — doesn't fail anything today; it just quietly serializes
the learner against PCIe. This module makes it fail loudly instead.

Two probes, both no-ops unless ``REPRO_SANITIZE=transfers`` (or the
launcher's ``--sanitize transfers``) is on:

* :func:`guard` — a ``jax.transfer_guard("disallow")`` scope wrapping the
  steady-state regions (``PipelinedRL.run``'s get/reserve/update/commit
  block and the device-plane collect closures, both from their second
  iteration on — the first call compiles, and compilation may legally
  materialize constants). Any implicit transfer inside raises.
* :func:`allowed` — the explicit escape marking an *intended* edge (e.g.
  ``_ShmSlotBridge`` publish's D2H param copy, the metrics drain, the
  DQN epsilon index H2D). Each use names its edge, so the allowed surface
  is grep-able and reviewed.

Plus the **deleted-buffer probe**: :func:`assert_deleted` checks that a
donated tree's buffers were actually invalidated by the donation — on a
backend/jit change that silently drops input-output aliasing, the
"alloc-free steady state" claim breaks with no other symptom than
memory growth. ``PipelinedRL.run`` probes the donated previous params
and the reserved publish buffer after every sanitized update.

``stats`` counts guarded/allowed/probed activations so tests can pin
"the device-plane steady state ran transfer-free for >= N iterations"
without parsing logs.
"""
from __future__ import annotations

import contextlib
from typing import Any, Dict

from repro.analysis import sanitizer_enabled

__all__ = [
    "DonationViolation", "allowed", "assert_deleted",
    "assert_uniformly_deleted", "deleted_leaves", "guard", "reset_stats",
    "stats", "transfers_enabled",
]

# activation counters (observability for tests / reports); reset_stats()
# between runs that want per-run numbers
stats: Dict[str, int] = {"guarded": 0, "allowed": 0, "probed": 0}


class DonationViolation(AssertionError):
    """A buffer the fused step was told to donate is still live."""


def transfers_enabled() -> bool:
    return sanitizer_enabled("transfers")


def reset_stats() -> None:
    for k in stats:
        stats[k] = 0


@contextlib.contextmanager
def guard(active: bool = True):
    """Disallow implicit transfers inside the scope (no-op when the
    transfers sanitizer is off or ``active`` is False — callers pass
    their own warmed-up predicate so compilation stays exempt)."""
    if not (active and transfers_enabled()):
        yield
        return
    import jax

    stats["guarded"] += 1
    with jax.transfer_guard("disallow"):
        yield


@contextlib.contextmanager
def allowed(edge: str):
    """Escape hatch naming an intended D2H/H2D edge inside a guarded
    region. No-op when the sanitizer is off."""
    if not transfers_enabled():
        yield
        return
    import jax

    stats["allowed"] += 1
    with jax.transfer_guard("allow"):
        yield


def deleted_leaves(tree: Any):
    """``(deleted, live)`` partition of the tree's jax.Array leaves
    (non-array leaves are ignored). Unconditional — test helper."""
    import jax

    deleted, live = [], []
    for leaf in jax.tree_util.tree_leaves(tree):
        if isinstance(leaf, jax.Array):
            (deleted if leaf.is_deleted() else live).append(leaf)
    return deleted, live


def assert_deleted(tree: Any, what: str) -> None:
    """Deleted-buffer probe: every jax.Array leaf of ``tree`` must have
    been invalidated (the visible effect of donation). No-op when the
    transfers sanitizer is off."""
    if not transfers_enabled():
        return
    stats["probed"] += 1
    deleted, live = deleted_leaves(tree)
    if live:
        raise DonationViolation(
            f"{what}: {len(live)}/{len(live) + len(deleted)} donated "
            "buffer(s) still live after the update — donation was dropped "
            "(backend/jit change?), the alloc-free steady state is gone"
        )


def assert_uniformly_deleted(tree: Any, what: str) -> None:
    """Donation *consistency* probe for buffers a backend may decline to
    alias wholesale (e.g. the ping-pong publish target on CPU, where XLA
    routes the published output through the params donation instead):
    all-deleted and all-live are both coherent outcomes, but a *mix* means
    the executable aliased some leaves and silently copied the rest —
    exactly the half-donated state that corrupts the ping-pong contract.
    No-op when the transfers sanitizer is off."""
    if not transfers_enabled():
        return
    stats["probed"] += 1
    deleted, live = deleted_leaves(tree)
    if deleted and live:
        raise DonationViolation(
            f"{what}: donation split — {len(deleted)} leaf buffer(s) "
            f"invalidated but {len(live)} still live; the executable "
            "aliased part of the tree and copied the rest"
        )
