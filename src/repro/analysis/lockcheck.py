"""Runtime lock-order detector for the pipeline's synchronization sites.

The pipeline holds seven ``threading.Lock``/``Condition`` sites (queue,
device ring, replay ring, param slots, staging ring, actor state log,
ledger/supervisor) plus the shared-memory param slot's multiprocessing
condition. They are individually simple, but deadlock is a *global*
property: it needs only two sites acquired in opposite orders by two
threads — the exact class of bug GA3C and Accelerated-Methods report as
their hardest. No test can enumerate interleavings; what a test *can* do
is run the real pipeline once and check the **lock-order graph** it
traced stays acyclic.

Mechanism: every pipeline lock is built through ``make_lock(name)`` /
``make_condition(name)``. Off (the default), the factories return plain
``threading`` primitives — zero overhead. Under ``REPRO_SANITIZE=locks``
they return ``SanitizedLock``/``SanitizedCondition`` wrappers that tell a
process-global :class:`LockOrderMonitor` about every acquire/release/
wait. The monitor keeps:

* a per-thread stack of currently-held locks;
* a directed graph over lock *names* (site identity, not instance — the
  invariant worth checking is "sites of kind A are never taken while
  holding kind B", across all the per-queue/per-slot instances): an edge
  A->B with the acquisition stack that first witnessed it, recorded
  whenever B is acquired while A is held;
* **hazards**: a ``Condition.wait``/``wait_for`` entered while the thread
  holds a *different* lock — the foreign lock stays held for the whole
  (possibly unbounded) wait, the classic lost-wakeup/deadlock shape.

``cycles()`` runs DFS over the name graph; any cycle is a potential
deadlock (two threads can interleave the recorded orders fatally even if
this run got lucky). ``report()`` packages edges/cycles/hazards as a
plain dict; ``PipelinedRL.run`` dumps it through the telemetry hub
(``Telemetry.report("lockcheck", ...)``) at the end of every sanitized
run and the launcher's ``--sanitize locks`` exits non-zero on findings.

Wrappers accept an ``inner`` primitive so non-``threading`` conditions
(the shm slot's ``multiprocessing`` condition) ride the same monitor on
the parent side; a wrapper shipped to a spawned child simply feeds that
child's own (separate, unreported) monitor. Self-edges A->A are reported
as cycles only when two *distinct instances* of a site nest — nesting
the same instance would have deadlocked on the spot already.
"""
from __future__ import annotations

import threading
import traceback
from typing import Dict, List, Optional, Tuple

from repro.analysis import sanitizer_enabled

__all__ = [
    "LockOrderMonitor", "SanitizedCondition", "SanitizedLock",
    "locks_enabled", "make_condition", "make_lock", "monitor",
]

_STACK_LIMIT = 12  # frames kept per recorded edge/hazard


def locks_enabled() -> bool:
    return sanitizer_enabled("locks")


def _site_stack() -> List[str]:
    frames = traceback.extract_stack(limit=_STACK_LIMIT + 3)[:-3]
    return [f"{f.filename}:{f.lineno} {f.name}" for f in frames]


class LockOrderMonitor:
    """Process-global lock-order graph fed by the sanitized wrappers."""

    def __init__(self):
        self._mu = threading.Lock()  # raw: guards the graph, never wrapped
        self._tls = threading.local()
        # (held_name, acquired_name) -> {count, distinct, stack, thread}
        self._edges: Dict[Tuple[str, str], dict] = {}
        self._hazards: List[dict] = []

    def _held(self) -> List[Tuple[int, str]]:
        held = getattr(self._tls, "held", None)
        if held is None:
            held = self._tls.held = []
        return held

    # -- wrapper hooks -------------------------------------------------------
    def on_acquire(self, lock_id: int, name: str) -> None:
        held = self._held()
        if held:
            stack = None
            with self._mu:
                for hid, hname in held:
                    e = self._edges.get((hname, name))
                    if e is None:
                        if stack is None:
                            stack = _site_stack()
                        self._edges[(hname, name)] = {
                            "count": 1,
                            "distinct": hid != lock_id,
                            "stack": stack,
                            "thread": threading.current_thread().name,
                        }
                    else:
                        e["count"] += 1
                        e["distinct"] = e["distinct"] or hid != lock_id
        held.append((lock_id, name))

    def on_release(self, lock_id: int, name: str) -> None:
        held = self._held()
        # release order may not be LIFO (bare acquire/release pairs): drop
        # the newest matching entry
        for i in range(len(held) - 1, -1, -1):
            if held[i][0] == lock_id:
                del held[i]
                return

    def on_wait(self, lock_id: int, name: str) -> None:
        """A condition wait releases *its own* lock but keeps every other
        held lock pinned for the full (unbounded) wait — record those."""
        foreign = [hname for hid, hname in self._held() if hid != lock_id]
        if foreign:
            with self._mu:
                self._hazards.append({
                    "waiting_on": name,
                    "holding": foreign,
                    "thread": threading.current_thread().name,
                    "stack": _site_stack(),
                })

    # -- analysis ------------------------------------------------------------
    def cycles(self) -> List[List[str]]:
        """Elementary cycles in the name graph (DFS back-edge closure);
        self-loops only when two distinct instances of the site nested."""
        with self._mu:
            graph: Dict[str, set] = {}
            for (a, b), e in self._edges.items():
                if a == b and not e["distinct"]:
                    continue
                graph.setdefault(a, set()).add(b)
        out: List[List[str]] = []
        seen_cycles = set()
        for root in sorted(graph):
            path: List[str] = []
            on_path: Dict[str, int] = {}

            def dfs(node: str) -> None:
                if node in on_path:
                    cyc = path[on_path[node]:] + [node]
                    key = frozenset(cyc)
                    if key not in seen_cycles:
                        seen_cycles.add(key)
                        out.append(cyc)
                    return
                on_path[node] = len(path)
                path.append(node)
                for nxt in sorted(graph.get(node, ())):
                    dfs(nxt)
                path.pop()
                del on_path[node]

            dfs(root)
        return out

    def report(self) -> dict:
        cycles = self.cycles()
        with self._mu:
            edges = [
                {"from": a, "to": b, "count": e["count"],
                 "thread": e["thread"], "stack": e["stack"]}
                for (a, b), e in sorted(self._edges.items())
            ]
            hazards = [dict(h) for h in self._hazards]
        return {"edges": edges, "cycles": cycles, "hazards": hazards}

    def reset(self) -> None:
        """Forget everything (tests; per-thread held stacks of *live*
        threads are intentionally kept — they describe the present)."""
        with self._mu:
            self._edges.clear()
            self._hazards.clear()


_MONITOR = LockOrderMonitor()


def monitor() -> LockOrderMonitor:
    return _MONITOR


class SanitizedLock:
    """``threading.Lock`` look-alike reporting to the global monitor."""

    def __init__(self, name: str, inner=None):
        self._name = name
        self._inner = inner if inner is not None else threading.Lock()

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        got = self._inner.acquire(blocking, timeout)
        if got:
            _MONITOR.on_acquire(id(self), self._name)
        return got

    def release(self) -> None:
        _MONITOR.on_release(id(self), self._name)
        self._inner.release()

    def locked(self) -> bool:
        return self._inner.locked()

    def __enter__(self) -> "SanitizedLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def __repr__(self) -> str:
        return f"SanitizedLock({self._name!r})"


class SanitizedCondition:
    """``threading.Condition`` look-alike reporting to the monitor.

    ``inner`` may be any condition speaking the stdlib surface —
    including a ``multiprocessing`` condition (the shm param slot), whose
    parent-side acquisition order then lands in the same graph.
    """

    def __init__(self, name: str, inner=None):
        self._name = name
        self._inner = inner if inner is not None else threading.Condition()

    def acquire(self, *args) -> bool:
        got = self._inner.acquire(*args)
        if got:
            _MONITOR.on_acquire(id(self), self._name)
        return got

    def release(self) -> None:
        _MONITOR.on_release(id(self), self._name)
        self._inner.release()

    def wait(self, timeout: Optional[float] = None) -> bool:
        _MONITOR.on_wait(id(self), self._name)
        return self._inner.wait(timeout)

    def wait_for(self, predicate, timeout: Optional[float] = None):
        _MONITOR.on_wait(id(self), self._name)
        return self._inner.wait_for(predicate, timeout)

    def notify(self, n: int = 1) -> None:
        self._inner.notify(n)

    def notify_all(self) -> None:
        self._inner.notify_all()

    def __enter__(self) -> "SanitizedCondition":
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def __repr__(self) -> str:
        return f"SanitizedCondition({self._name!r})"


def make_lock(name: str):
    """A lock for pipeline site ``name``: plain ``threading.Lock`` unless
    ``REPRO_SANITIZE=locks`` is on at construction time."""
    return SanitizedLock(name) if locks_enabled() else threading.Lock()


def make_condition(name: str, inner=None):
    """A condition for pipeline site ``name`` (optionally wrapping a
    caller-built primitive, e.g. a multiprocessing condition)."""
    if locks_enabled():
        return SanitizedCondition(name, inner)
    return inner if inner is not None else threading.Condition()
