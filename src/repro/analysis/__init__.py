"""Correctness tooling for the pipeline's unchecked invariants.

Three tools, one package (ISSUE 9 / docs/static_analysis.md):

* ``repro.analysis.lint`` — **repro-lint**, stdlib-``ast`` static checks
  over ``src/`` enforcing the conventions the pipeline's correctness
  rests on: lease acquire/release pairing under ``try/finally``,
  ``SpanEmitter`` begin/end-or-cancel balance, no reuse of donated
  buffers, no host syncs on ``# hot-path`` functions, and picklable
  ``HostEnvSpec`` construction. ``python -m repro.analysis.lint src``.
* ``repro.analysis.lockcheck`` — runtime lock-order detector: the
  pipeline's ``Lock``/``Condition`` sites are built through
  ``make_lock``/``make_condition`` factories that return instrumented
  wrappers under ``REPRO_SANITIZE=locks``, recording per-thread
  acquisition stacks into a global lock-order graph and flagging cycles
  (potential deadlock) and wait-while-holding-foreign-lock hazards.
* ``repro.analysis.sanitize`` — transfer/donation sanitizer: under
  ``REPRO_SANITIZE=transfers`` the device/mesh-plane steady state runs
  inside ``jax.transfer_guard("disallow")`` scopes (explicit ``allowed``
  escapes mark the intended D2H/H2D edges) and a deleted-buffer probe
  asserts donated params/opt/publish buffers actually invalidated.

The sanitizers are **off by default and free when off**: the factories
hand back plain ``threading`` primitives and the guard scopes are no-op
context managers, so the hot paths are untouched unless the env var
``REPRO_SANITIZE`` (comma-separated modes) or ``enable_sanitizers()``
(the ``--sanitize`` launcher flag) turns a mode on.
"""
from __future__ import annotations

import os
from typing import Iterable, Set

SANITIZE_ENV = "REPRO_SANITIZE"
SANITIZE_MODES = ("locks", "transfers")

# modes forced on programmatically (the --sanitize flag / tests); unioned
# with the env var at every query so either switch works mid-process
_forced: Set[str] = set()


def _parse(spec: str) -> Set[str]:
    modes = {m.strip() for m in spec.split(",") if m.strip()}
    bad = modes - set(SANITIZE_MODES)
    if bad:
        raise ValueError(
            f"unknown sanitize mode(s) {sorted(bad)}: pick from "
            f"{SANITIZE_MODES} (comma-separated)"
        )
    return modes


def enable_sanitizers(spec) -> Set[str]:
    """Force sanitizer modes on for this process (``"locks,transfers"``
    or an iterable of mode names). Returns the modes enabled."""
    if isinstance(spec, str):
        modes = _parse(spec)
    else:
        modes = set()
        for m in spec:
            modes |= _parse(m)
    _forced.update(modes)
    return modes


def disable_sanitizers(spec=None) -> None:
    """Drop programmatically-forced modes (all of them when ``spec`` is
    None). The env var, if set, still applies."""
    if spec is None:
        _forced.clear()
    else:
        _forced.difference_update(
            _parse(spec) if isinstance(spec, str) else set(spec))


def sanitizer_enabled(mode: str) -> bool:
    """Is ``mode`` on — via ``REPRO_SANITIZE`` or ``enable_sanitizers``?
    Read at call time so tests and the launcher can flip it dynamically
    (objects built *before* the flip stay uninstrumented)."""
    if mode not in SANITIZE_MODES:
        raise ValueError(f"unknown sanitize mode {mode!r}")
    if mode in _forced:
        return True
    env = os.environ.get(SANITIZE_ENV, "")
    return mode in _parse(env) if env else False
