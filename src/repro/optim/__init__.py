from repro.optim.optimizer import Optimizer, make_optimizer, clip_by_global_norm
from repro.optim.schedules import constant, linear_anneal, paac_scaled_lr

__all__ = [
    "Optimizer",
    "make_optimizer",
    "clip_by_global_norm",
    "constant",
    "linear_anneal",
    "paac_scaled_lr",
]
