"""Optimizers (no optax): the paper's shared-statistics RMSProp, Adam, SGD.

The paper (§5.1) trains with RMSProp (decay 0.99, ε=0.1) and global-norm
gradient clipping at 40 (Pascanu et al. 2012). "Shared statistics" in
A3C/PAAC means a single copy of the second-moment accumulator updated
synchronously — which is exactly what a single optimizer state is here
(PAAC's single-parameter-copy invariant; contrast A3C's per-thread RMSProp).

Optimizer state lives in fp32 and is sharded like the parameters (see
repro.distributed.sharding), giving ZeRO-style state sharding for free in
``fsdp_tp`` mode.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.utils.tree import tree_global_norm


def clip_by_global_norm(grads, max_norm: float):
    """Paper §5.1: gradient clipping with threshold 40."""
    norm = tree_global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm


@dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable  # (grads, state, params, lr) -> (new_params, new_state)


def _f32(x):
    return x.astype(jnp.float32)


def make_optimizer(
    kind: str = "rmsprop",
    *,
    decay: float = 0.99,
    eps: float = 0.1,
    beta1: float = 0.9,
    beta2: float = 0.999,
    momentum: float = 0.0,
    clip_norm: Optional[float] = 40.0,
) -> Optimizer:
    """Build an optimizer. Defaults follow the paper's hyperparameters."""

    def maybe_clip(grads):
        if clip_norm is not None:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        return grads

    if kind == "rmsprop":

        def init(params):
            return {
                "sq": jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            }

        def update(grads, state, params, lr):
            grads = maybe_clip(grads)
            sq = jax.tree_util.tree_map(
                lambda s, g: decay * s + (1.0 - decay) * jnp.square(_f32(g)),
                state["sq"], grads,
            )
            new_params = jax.tree_util.tree_map(
                lambda p, g, s: (
                    _f32(p) - lr * _f32(g) / (jnp.sqrt(s) + eps)
                ).astype(p.dtype),
                params, grads, sq,
            )
            return new_params, {"sq": sq}

        return Optimizer(init, update)

    if kind == "adam":

        def init(params):
            zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
            return {
                "m": jax.tree_util.tree_map(zeros, params),
                "v": jax.tree_util.tree_map(zeros, params),
                "t": jnp.zeros((), jnp.int32),
            }

        def update(grads, state, params, lr):
            grads = maybe_clip(grads)
            t = state["t"] + 1
            m = jax.tree_util.tree_map(
                lambda m_, g: beta1 * m_ + (1 - beta1) * _f32(g), state["m"], grads
            )
            v = jax.tree_util.tree_map(
                lambda v_, g: beta2 * v_ + (1 - beta2) * jnp.square(_f32(g)),
                state["v"], grads,
            )
            bc1 = 1 - beta1 ** t.astype(jnp.float32)
            bc2 = 1 - beta2 ** t.astype(jnp.float32)
            new_params = jax.tree_util.tree_map(
                lambda p, m_, v_: (
                    _f32(p) - lr * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + 1e-8)
                ).astype(p.dtype),
                params, m, v,
            )
            return new_params, {"m": m, "v": v, "t": t}

        return Optimizer(init, update)

    if kind == "sgd":

        def init(params):
            if momentum:
                return {
                    "mom": jax.tree_util.tree_map(
                        lambda p: jnp.zeros(p.shape, jnp.float32), params
                    )
                }
            return {}

        def update(grads, state, params, lr):
            grads = maybe_clip(grads)
            if momentum:
                mom = jax.tree_util.tree_map(
                    lambda m_, g: momentum * m_ + _f32(g), state["mom"], grads
                )
                new_params = jax.tree_util.tree_map(
                    lambda p, m_: (_f32(p) - lr * m_).astype(p.dtype), params, mom
                )
                return new_params, {"mom": mom}
            new_params = jax.tree_util.tree_map(
                lambda p, g: (_f32(p) - lr * _f32(g)).astype(p.dtype), params, grads
            )
            return new_params, state

        return Optimizer(init, update)

    raise ValueError(f"unknown optimizer {kind}")
