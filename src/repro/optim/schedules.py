"""Learning-rate schedules.

``paac_scaled_lr`` implements the paper's §5.2 batch-size rule: the base
learning rate is scaled linearly with the number of actors,
``α = 0.0007 · n_e`` — the paper shows this holds up to n_e ≈ 128 and
diverges at 256 (we reproduce that sweep in benchmarks/fig34_ne_scaling.py).
"""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def linear_anneal(lr: float, total_steps: int, floor: float = 0.0):
    """A3C-style anneal to `floor` over `total_steps`."""

    def fn(step):
        frac = jnp.clip(1.0 - step / total_steps, 0.0, 1.0)
        return jnp.asarray(floor + (lr - floor) * frac, jnp.float32)

    return fn


def paac_scaled_lr(n_e: int, base: float = 0.0007):
    """Paper §5.2: learning rate scaled with actor count."""
    return constant(base * n_e)
