"""The multi-process actor plane: worker subprocesses for GIL-bound envs.

The thread plane (``ActorThread``) scales exactly as far as the emulator
releases the GIL: a C++ simulator stepped through a thin binding overlaps
fine, but a *Python-bound* emulator (ALE-style wrappers, pure-Python
simulators) serializes every replica's env stepping on one interpreter
lock — adding actors adds nothing (A3C and Stooke & Abbeel's accelerated
methods both reach for processes at this exact wall). This module is the
third execution plane: ``PipelineConfig.actor_backend = "process"`` puts
each actor replica in its own interpreter.

Topology (everything below the ``TrajectoryQueue`` is new; everything
above it — learner loop, V-trace update, ping-pong donation, metrics — is
untouched)::

    worker subprocess i                     parent process
    ───────────────────                     ──────────────
    spec.build() → private HostEnvPool      ProcessActorDrainer i (thread)
    jitted act_step (own compile)             ready_q.get() → wrap shm views
    loop: lease params ← ShmParamView         → Rollout → TrajectoryQueue
          free_q.get() → ShmStagingSet        (ActorBase quota/shutdown/
          collect_host(staging=set)            never-drop protocol, shared
          ready_q.put(set index)               verbatim with ActorThread)
                                            learner: get → update → commit
    params ← shm ping-pong slot  ◀──────────  (D2H publish once per update)

Wire protocol (per worker, all ``mp.Queue``):

* ``cmd_q``   parent→child: ``("run", quota, lockstep)`` | ``("stop",)``
* ``ready_q`` child→parent: ``("rollout", set_idx, seq, version)`` …
  then ``("spans", SpanEmitter.ship())`` — the child's telemetry ring
  (collect / lease / shm.copy / staging-wait spans, recorded child-side),
  merged parent-side under per-process trace track ``actor_id + 1`` —
  terminated by exactly one of ``("done", final_key)`` (quota finished —
  graceful checkout), ``("aborted",)`` (stop event honoured), or
  ``("error", traceback)`` (collection died; the drainer re-raises it so
  the stream hard-closes exactly like a crashed ``ActorThread``).
* ``free_q``  both ways: staging-set indices — the cross-process
  ``HostStagingRing`` lease. The parent seeds ``queue_depth + 2`` indices
  (the ring's sizing contract), the child acquires before writing, the
  learner's ``Rollout.release`` returns them after consuming.

Child lifecycle: workers are spawned once per ``PipelinedRL`` (spawn
context — fork would duplicate JAX runtime state) and persist across
``run()`` calls so re-runs don't pay the child's jit compile; they are
daemonic *and* poll ``multiprocessing.parent_process().is_alive()`` in
every blocking loop, so neither a clean parent exit nor a hard kill
leaves orphans stepping envs. A worker that dies silently (segfault, OOM
kill) is detected by its drainer's liveness poll and surfaced as the
actor error — EOF propagation without deadlock.
"""
from __future__ import annotations

import atexit
import multiprocessing as mp
import os
import queue as _stdlib_queue
import traceback
import weakref
from typing import Any, List, Optional, Sequence, Tuple

import numpy as np

from repro.envs.host_env import HostEnvSpec
from repro.analysis import sanitize
from repro.pipeline.actor import ActorBase, Rollout, _copy_tree
from repro.pipeline.shm import ShmParamSlot, ShmStagingSet
from repro.telemetry.spans import (
    COLLECT,
    LEASE,
    QUEUE_PUT_WAIT,
    SHM_COPY,
    SpanEmitter,
)

__all__ = ["ProcessActorPlane", "ProcessActorDrainer"]


def _parent_alive() -> bool:
    p = mp.parent_process()
    return p is not None and p.is_alive()


def _orphan_unlink(sets, slot) -> None:
    """Child-side last resort for the shm estate: the parent normally owns
    every unlink, but a parent killed hard (SIGKILL) never runs its atexit
    reaper — the orphaned child destroys the segments on its way out so
    /dev/shm does not leak. POSIX unlink is safe under live mappings, and a
    sibling orphan racing us sees FileNotFoundError, which is success."""
    for s in sets or ():
        try:
            s.shm.unlink()
        except Exception:
            pass
    if slot is not None:
        for shm in getattr(slot, "_shms", ()) or ():
            try:
                shm.unlink()
            except Exception:
                pass


def _worker_main(spec: HostEnvSpec, arch_cfg, hp, slot_handle,
                 set_names: Sequence[str], key_host: np.ndarray,
                 cmd_q, ready_q, free_q, stop_evt, actor_id: int) -> None:
    """Child entry point: rebuild the env pool + acting step, then serve
    ``run`` commands until ``stop`` (or the parent disappears)."""
    import jax.numpy as jnp  # deferred: spawned child initializes its own JAX

    from repro.core.agents.paac import PAACAgent
    from repro.pipeline.actor import collect_host, make_host_act_step
    from repro.pipeline.shm import ShmParamView

    pool = sets = slot = None
    try:
        agent = PAACAgent(arch_cfg, hp)
        act_step = make_host_act_step(agent.act_fn())
        t_max = hp.t_max
        pool = spec.build()
        sets = [
            ShmStagingSet(t_max, spec.n_envs, spec.obs_shape, spec.obs_dtype,
                          name=n, create=False)
            for n in set_names
        ]
        # reader_id = this worker's slot: its param leases are attributable
        # (reserve-timeout diagnostics) and revocable (supervisor respawn)
        slot = ShmParamView(slot_handle, reader_id=actor_id)
        key = jnp.asarray(key_host)
        obs = pool.reset()
        # this worker's span track: recorded here (the spans describe *this*
        # process's blocking), shipped to the parent with the terminal
        # message of each run, merged into the run trace under pid
        # actor_id + 1
        em = SpanEmitter(f"worker{actor_id}")
    except Exception:
        # setup died (unbuildable env, shm attach failure): report it so the
        # first begin_run surfaces a traceback, not a bare dead child
        ready_q.put(("error", traceback.format_exc()))
        if pool is not None:
            pool.close()
        return
    try:
        while True:
            try:
                cmd = cmd_q.get(timeout=1.0)
            except _stdlib_queue.Empty:
                if not _parent_alive():
                    # orphaned: the parent died without "stop" — and without
                    # its unlink duty (hard kill bypasses atexit)
                    _orphan_unlink(sets, slot)
                    return
                continue
            if cmd[0] == "stop":
                return
            # 4th element (absent pre-fault-plan): planned (after, mode)
            # kills this run executes in its own process
            _, quota, lockstep = cmd[0], cmd[1], cmd[2]
            faults = tuple(cmd[3]) if len(cmd) > 3 else ()
            try:
                aborted = False
                for seq in range(quota):
                    for after, mode in faults:
                        if after == seq:
                            if mode == "exit":
                                # the segfault/OOM-kill shape: no message,
                                # no traceback — the drainer's liveness
                                # poll must detect the silent death
                                os._exit(17)
                            raise RuntimeError(
                                f"FaultPlan: injected worker fault on actor "
                                f"{actor_id} after {seq} rollouts "
                                f"(mode={mode!r})"
                            )
                    if lockstep:
                        em.begin(LEASE)
                        while not slot.wait_for(seq, timeout=0.1):
                            if stop_evt.is_set() or not _parent_alive():
                                aborted = True
                                break
                        if aborted:  # abort mid-wait never counted as waiting
                            em.cancel()
                        else:
                            em.end()
                    if aborted or stop_evt.is_set():
                        aborted = True
                        break
                    # params lease is just the copy-out (inside read_params):
                    # the shm→host copy is the span, not a blocking wait
                    em.begin(SHM_COPY)
                    try:
                        params, version = slot.read_params()
                    finally:
                        em.end()
                    # cross-process staging lease: blocked here = the
                    # child-side backpressure stage (the parent hasn't
                    # recycled a set), this plane's queue.put_wait analog
                    em.begin(QUEUE_PUT_WAIT)
                    idx: Optional[int] = None
                    while idx is None:
                        try:
                            idx = free_q.get(timeout=0.1)
                        except _stdlib_queue.Empty:
                            if stop_evt.is_set() or not _parent_alive():
                                aborted = True
                                break
                    if aborted:
                        em.cancel()
                        break
                    em.end()
                    em.begin(COLLECT)
                    try:
                        obs, key, _traj, _last = collect_host(
                            act_step, pool, params, obs, key, t_max,
                            staging=sets[idx],
                        )
                    except Exception:
                        free_q.put(idx)  # don't leak the staging lease
                        raise
                    finally:
                        em.end()
                    ready_q.put(("rollout", idx, seq, version))
                ready_q.put(("spans", em.ship()))
                em.reset()  # a later run must not re-ship this run's spans
                if aborted:
                    ready_q.put(("aborted",))
                else:
                    ready_q.put(("done", np.asarray(key)))
            except Exception:
                # collection died (env crash, shm torn down, ...): report and
                # survive — the drainer turns this into the actor error and
                # the plane decides whether to reuse or stop us.
                tb = traceback.format_exc()
                try:
                    ready_q.put(("spans", em.ship()))
                    em.reset()
                except Exception:  # never mask the real failure
                    pass
                ready_q.put(("error", tb))
    finally:
        pool.close()
        for s in sets:
            s.close()
        slot.close()


class _WorkerHandle:
    """Parent-side bookkeeping for one spawned worker."""

    def __init__(self, actor_id: int, proc, cmd_q, ready_q, free_q, stop_evt,
                 sets: List[ShmStagingSet]):
        self.actor_id = actor_id
        self.proc = proc
        self.cmd_q = cmd_q
        self.ready_q = ready_q
        self.free_q = free_q
        self.stop_evt = stop_evt
        self.sets = sets  # parent-side views of the same shm blocks


class ProcessActorDrainer(ActorBase):
    """Parent-side thread standing in for one worker subprocess.

    To everything above the plane split this *is* the actor replica: it
    honours ``ActorBase``'s quota/shutdown/never-drop protocol (checkout
    via ``producer_done``, hard ``close()`` on error) — it just sources
    payloads from its worker's ``ready_q`` instead of collecting them
    itself, wrapping the named shm staging set each descriptor points at
    into a zero-copy ``Rollout`` whose ``release`` returns the set index
    to the worker's free list.
    """

    def __init__(self, worker: _WorkerHandle, queue, telemetry=None,
                 actor_id: Optional[int] = None, ledger=None,
                 lockstep: bool = False):
        # actor_id can differ from the worker's slot: a respawned replica
        # gets a fresh epoch id while the child keeps its slot (which is
        # also its shm reader_id)
        super().__init__(
            queue, worker.actor_id if actor_id is None else actor_id,
            telemetry=telemetry)
        self._worker = worker
        self._telemetry = telemetry
        self.slot_index = worker.actor_id
        self._ledger = ledger
        self._lockstep = lockstep
        # seq offset for ledger-continuation runs: the child restarts its
        # local seq at 0 per run command, the stream must not
        self._seq_base = 0
        self.final_key: Optional[np.ndarray] = None

    def stop(self) -> None:
        super().stop()
        self._worker.stop_evt.set()  # reaches the child's blocking loops

    def _next_msg(self) -> Tuple:
        while True:
            try:
                return self._worker.ready_q.get(timeout=0.1)
            except _stdlib_queue.Empty:
                if not self._worker.proc.is_alive():
                    raise RuntimeError(
                        f"actor worker {self.slot_index} died without a "
                        f"message (exitcode "
                        f"{self._worker.proc.exitcode}) — envs or shm torn "
                        "down underneath it?"
                    ) from None

    def _produce(self) -> None:
        discard = False  # after stop/close: recycle sets, put nothing
        while True:
            msg = self._next_msg()
            kind = msg[0]
            if kind == "rollout":
                idx, seq, version = msg[1], msg[2], msg[3]
                free_q = self._worker.free_q
                if discard or self._stop_requested.is_set():
                    free_q.put(idx)  # keep the child's lease flowing
                    discard = True
                    continue
                s = self._worker.sets[idx]
                if not self._put(Rollout(
                    s.traj, s.last_obs, version, self.actor_id,
                    self._seq_base + seq,
                    release=(lambda i=idx: free_q.put(i)),
                )):
                    free_q.put(idx)
                    discard = True  # drain to the terminal message
                else:
                    self.produced += 1
                    if self._ledger is not None:
                        self._ledger.produced()
            elif kind == "spans":
                # the child's telemetry ring, shipped just before its
                # terminal message: give it a trace track of its own process
                if self._telemetry is not None:
                    self._telemetry.merge_shipped(
                        msg[1], pid=self.slot_index + 1
                    )
            elif kind == "done":
                self.final_key = msg[1]
                if self._ledger is not None and not discard \
                        and not self._stop_requested.is_set():
                    # quota done — a dead sibling may have orphaned more:
                    # claim it and send the idle child another run command
                    got = self._ledger.wait_for_work(
                        stop=self._stop_requested.is_set)
                    if got > 0:
                        extra = got + self._ledger.claim()
                        self._seq_base = self.produced
                        self.assigned += extra
                        self._worker.cmd_q.put(
                            ("run", int(extra), self._lockstep, ()))
                        continue
                return  # graceful checkout (ActorBase -> producer_done)
            elif kind == "aborted":
                return
            elif kind == "error":
                raise RuntimeError(
                    f"actor worker {self.slot_index} failed:\n{msg[1]}"
                )
            else:  # pragma: no cover - protocol violation
                raise RuntimeError(f"unknown worker message {msg!r}")


class _ShmSlotBridge:
    """Learner-facing twin of ``PingPongParamSlot`` for the process plane.

    ``reserve`` waits out the *cross-process* readers of shm buffer
    ``v % 2`` and hands back the device-side stale buffer (the fused
    step's donation target, exactly like the thread slot); ``commit``
    stores the published device copy and lands it in shared memory (the
    one D2H param copy per update that broadcasting to subprocesses
    costs). No in-process readers exist, so the device buffers need no
    reference counting.
    """

    def __init__(self, params: Any, shm_slot: ShmParamSlot, emitter=None):
        self._bufs = [_copy_tree(params), _copy_tree(params)]
        self._shm = shm_slot
        self._emitter = emitter  # learner-thread-only writer (no lock)

    def reserve(self, version: int, timeout: Optional[float] = None):
        if not self._shm.reserve(version, timeout=timeout):
            return None
        return self._bufs[version % 2]

    def holders(self, idx: int) -> List[str]:
        """Which workers still lease shm buffer ``idx`` (timeout naming)."""
        return self._shm.holders(idx)

    def commit(self, published: Any, version: int) -> None:
        self._bufs[version % 2] = published
        if self._emitter is not None:
            # the one per-update D2H param copy the process plane costs —
            # worth its own shm.copy span on the publish track; an intended
            # transfer edge, so it escapes the learner loop's guard scope
            self._emitter.begin(SHM_COPY)
            try:
                with sanitize.allowed("shm param publish"):
                    self._shm.commit(published, version)
            finally:
                self._emitter.end()
        else:
            with sanitize.allowed("shm param publish"):
                self._shm.commit(published, version)


class ProcessActorPlane:
    """Owner of the worker subprocesses and their shared-memory estate.

    Spawned once per ``PipelinedRL`` (process backend): allocates the
    param slot + per-worker staging sets, validates and ships each
    ``HostEnvSpec``, and keeps the children alive across ``run()`` calls.
    ``begin_run`` rebroadcasts the current params as version 0, hands each
    worker its quota, and returns the learner-side slot bridge plus one
    ``ProcessActorDrainer`` per worker; ``close`` is the orderly teardown
    (stop command, bounded join, terminate stragglers, unlink shm).
    """

    def __init__(self, specs: Sequence[HostEnvSpec], agent, queue_depth: int,
                 params: Any, keys: Sequence) -> None:
        if len(keys) != len(specs):
            raise ValueError("one RNG key per worker spec required")
        self._ctx = mp.get_context("spawn")
        self._slot = ShmParamSlot(params, self._ctx,
                                  max_readers=max(len(specs), 1))
        self._n_sets = queue_depth + 2  # the HostStagingRing sizing contract
        self._workers: List[_WorkerHandle] = []
        # retired handles of hard-killed workers: their staging sets may
        # still back in-flight payloads (and their free_q still receives
        # those payloads' release()s), so the estate is only torn down at
        # plane close, never at respawn time
        self._graveyard: List[_WorkerHandle] = []
        self._closed = False
        self._specs = list(specs)
        self._agent = agent
        self._initial_keys = [np.asarray(k) for k in keys]
        self._epochs = [0] * len(specs)  # respawn generation per slot
        _LIVE_PLANES.add(self)
        try:
            for i, spec in enumerate(specs):
                spec.validate_picklable()
                self._workers.append(self._spawn(i, self._initial_keys[i]))
        except BaseException:
            self.close()
            raise

    def _spawn(self, slot_idx: int, key_host: np.ndarray) -> _WorkerHandle:
        """Allocate one worker's estate (staging sets, queues, stop event)
        and start its process. The child's actor_id stays the *slot* index
        — it doubles as the shm param reader_id and trace track."""
        spec = self._specs[slot_idx]
        sets = [
            ShmStagingSet(self._agent.hp.t_max, spec.n_envs,
                          spec.obs_shape, spec.obs_dtype)
            for _ in range(self._n_sets)
        ]
        cmd_q = self._ctx.Queue()
        ready_q = self._ctx.Queue()
        free_q = self._ctx.Queue()
        for j in range(self._n_sets):
            free_q.put(j)
        stop_evt = self._ctx.Event()
        epoch = self._epochs[slot_idx]
        proc = self._ctx.Process(
            target=_worker_main,
            args=(spec, self._agent.cfg, self._agent.hp, self._slot.handle(),
                  [s.name for s in sets], key_host,
                  cmd_q, ready_q, free_q, stop_evt, slot_idx),
            name=(f"pipeline-worker-{slot_idx}" if epoch == 0
                  else f"pipeline-worker-{slot_idx}e{epoch}"),
            daemon=True,  # orphan reaping: die with the parent
        )
        proc.start()
        return _WorkerHandle(slot_idx, proc, cmd_q, ready_q, free_q,
                             stop_evt, sets)

    @property
    def n_workers(self) -> int:
        return len(self._workers)

    def begin_run(self, queue, quota: Sequence[int], lockstep: bool,
                  params: Any, telemetry=None, ledger=None, injector=None):
        """Start one ``run()``'s worth of collection on every worker.

        Returns ``(slot, drainers)`` with ``slot`` speaking the learner
        loop's reserve/commit protocol. The version counter rewinds to 0
        each run (workers are idle between runs, so no reader can hold a
        stale lease across the reset) — identical to the thread plane
        building a fresh ``PingPongParamSlot`` per run. With a ``telemetry``
        hub the drainers merge each worker's shipped span ring into it and
        the slot bridge spans its per-update D2H publish copy.
        """
        if self._closed:
            raise RuntimeError("begin_run() on a closed ProcessActorPlane")
        self._slot.publish(params, 0)
        drainers = []
        for w, q in zip(self._workers, quota):
            w.stop_evt.clear()
            faults = (injector.kills_for_worker(w.actor_id)
                      if injector is not None else ())
            w.cmd_q.put(("run", int(q), bool(lockstep), faults))
            d = ProcessActorDrainer(w, queue, telemetry=telemetry,
                                    ledger=ledger, lockstep=bool(lockstep))
            d.assigned = int(q)
            drainers.append(d)
        publish_em = (telemetry.emitter("shm.publish")
                      if telemetry is not None else None)
        return _ShmSlotBridge(params, self._slot, emitter=publish_em), drainers

    def respawn_worker(self, slot_idx: int, actor_id: int, quota: int,
                       lockstep: bool, queue, telemetry=None, ledger=None):
        """Stand a dead slot back up mid-run (supervisor path).

        Clears the dead replica's leaked param lease, then either reuses
        the still-alive child (an injected/in-child error leaves it parked
        at its command loop) or retires the handle to the graveyard and
        spawns a fresh process with a fresh shm estate and a fold_in-derived
        key (deterministic per (slot, epoch), never a key replay). Returns
        a started ``ProcessActorDrainer`` carrying the fresh epoch
        ``actor_id``; the caller starts it.
        """
        import jax

        if self._closed:
            raise RuntimeError("respawn_worker() on a closed plane")
        self._slot.revoke(slot_idx)
        self._epochs[slot_idx] += 1
        w = self._workers[slot_idx]
        if not w.proc.is_alive():
            w.proc.join(timeout=1.0)
            self._graveyard.append(w)
            key = np.asarray(jax.random.fold_in(
                jax.numpy.asarray(self._initial_keys[slot_idx]),
                self._epochs[slot_idx]))
            w = self._spawn(slot_idx, key)
            self._workers[slot_idx] = w
        w.stop_evt.clear()
        w.cmd_q.put(("run", int(quota), bool(lockstep), ()))
        d = ProcessActorDrainer(w, queue, telemetry=telemetry,
                                actor_id=actor_id, ledger=ledger,
                                lockstep=bool(lockstep))
        d.assigned = int(quota)
        return d

    def close(self, join_timeout: float = 10.0) -> None:
        """Stop workers (politely, then hard) and release the shm estate —
        including the graveyard of handles retired by respawns. Idempotent;
        safe to call with workers already dead."""
        if self._closed:
            return
        self._closed = True
        _LIVE_PLANES.discard(self)
        handles = self._workers + self._graveyard
        for w in handles:
            w.stop_evt.set()
            try:
                w.cmd_q.put(("stop",))
            except (ValueError, OSError):  # queue already torn down
                pass
        for w in handles:
            w.proc.join(timeout=join_timeout)
            if w.proc.is_alive():  # hung child: reap it hard
                w.proc.terminate()
                w.proc.join(timeout=join_timeout)
        for w in handles:
            for q in (w.cmd_q, w.ready_q, w.free_q):
                q.cancel_join_thread()
                q.close()
            for s in w.sets:
                s.close()
                s.unlink()
        self._slot.close()
        self._slot.unlink()


# Interpreter-exit reaper, replacing the old per-plane ``__del__``: CPython
# gives no ordering (or execution) guarantee for __del__ at shutdown — a
# plane caught in a reference cycle was torn down after the shm module's
# globals were cleared, or not at all, leaking /dev/shm segments and child
# processes. One atexit hook over a WeakSet runs while the interpreter is
# still whole; a plane closed normally has already removed itself.
_LIVE_PLANES: "weakref.WeakSet" = weakref.WeakSet()


def _reap_planes() -> None:  # pragma: no cover - exercised by test via call
    for plane in list(_LIVE_PLANES):
        try:
            plane.close(join_timeout=1.0)
        except Exception:
            pass


atexit.register(_reap_planes)
