"""``PipelinedRL`` — the asynchronous actor/learner backend.

Drop-in alternative to ``repro.core.ParallelRL`` (same constructor shape,
same ``run(iterations) -> RunResult``) that splits Algorithm 1 across two
threads joined by a bounded ``TrajectoryQueue``:

    actor thread:   read latest params → collect rollout → queue.put
    learner thread: queue.get → importance-corrected update → publish params

With queue depth d the actor runs at most d rollouts ahead (depth 1 =
double buffering: rollout i+1 is collected while the learner consumes
rollout i). Staleness is bounded by the depth and corrected by the
learner's truncated importance weights (``PipelineConfig.rho_bar``); in
``lockstep`` mode the actor always waits for fresh params and the pipeline
reproduces the synchronous trajectory stream exactly.

The win is wall-clock overlap: on the ``HostEnvPool`` path the env workers
hold no GIL while stepping, so host env time and the jitted update run
concurrently instead of serially — the paper's Fig. 2 "50% env time" recovered.
"""
from __future__ import annotations

import queue as _stdlib_queue
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import PipelineConfig
from repro.core.framework import MetricsAccumulator, RunResult, init_rl_common
from repro.core.rollout import make_collect_fn
from repro.envs.host_env import HostEnvPool
from repro.pipeline.actor import ActorThread, ParamSlot, Rollout, collect_host
from repro.pipeline.learner import make_learner_step
from repro.pipeline.queue import CLOSED, TrajectoryQueue
from repro.utils import get_logger

log = get_logger("pipeline")


class PipelinedRL:
    """Asynchronous actor/learner pipeline over the PAAC framework."""

    def __init__(
        self,
        env,
        agent,
        *,
        optimizer: str = "rmsprop",
        lr_schedule: Optional[Callable] = None,
        seed: int = 0,
        pipeline: PipelineConfig = PipelineConfig(),
    ):
        from repro.core.agents.paac import PAACAgent

        # exact type: subclasses (LaggedPAACAgent) and look-alikes (PPOAgent)
        # carry their own loss/state that make_learner_step would silently drop
        if type(agent) is not PAACAgent:
            raise NotImplementedError(
                f"PipelinedRL drives plain PAACAgent (got {type(agent).__name__}); "
                "its learner step hard-codes the importance-weighted PAAC loss"
            )
        self.env = env
        self.agent = agent
        self.pipeline = pipeline
        # shared with ParallelRL — identical RNG layout so a lock-stepped
        # pipeline reproduces the synchronous run bit-for-bit.
        (self.optimizer, self.lr_schedule, self.key, k_env, self.params,
         self.opt_state) = init_rl_common(env, agent, optimizer, lr_schedule,
                                          seed)

        self._host = isinstance(env, HostEnvPool)
        act = agent.act_fn()
        if self._host:
            from repro.pipeline.actor import make_host_act_step

            self.env_state = None
            self.obs = env.reset()
            self._act = make_host_act_step(act)
            self._collect_jit = None
        else:
            self.env_state = env.reset(k_env)
            self.obs = env.observe(self.env_state)
            self._act = None
            self._collect_jit = jax.jit(make_collect_fn(act, env, agent.hp.t_max))

        # donate the optimizer state (learner-private). Params must NOT be
        # donated: the actor thread still reads the behaviour snapshot.
        self._update_step = jax.jit(
            make_learner_step(agent, self.optimizer, self.lr_schedule,
                              rho_bar=pipeline.rho_bar),
            donate_argnums=(1,),
        )
        self.total_steps = 0
        self._steps_per_iter = env.n_envs * agent.hp.t_max

    # -- rollout collection closure (runs on the actor thread) ---------------
    def _make_collect(self) -> Callable:
        if self._host:
            env, act, t_max = self.env, self._act, self.agent.hp.t_max

            def collect(params, key):
                obs, key, traj, last_obs = collect_host(
                    act, env, params, self.obs, key, t_max
                )
                self.obs = obs
                return key, traj, last_obs

        else:
            collect_jit = self._collect_jit

            def collect(params, key):
                env_state, last_obs, key, traj = collect_jit(
                    params, self.env_state, self.obs, key
                )
                # block so queue depth genuinely bounds in-flight rollouts
                jax.block_until_ready(traj.reward)
                self.env_state, self.obs = env_state, last_obs
                return key, traj, last_obs

        return collect

    def run(self, iterations: int, log_every: int = 0) -> RunResult:
        """Run `iterations` pipelined iterations (each = n_e·t_max timesteps)."""
        queue = TrajectoryQueue(self.pipeline.queue_depth)
        slot = ParamSlot(self.params, version=0)
        actor = ActorThread(
            self._make_collect(), queue, slot, self.key, iterations,
            lockstep=self.pipeline.lockstep,
        )
        acc = MetricsAccumulator()
        actor.start()
        # same step-counter semantics as ParallelRL.run (lr_schedule parity)
        step_arr = jnp.asarray(self.total_steps, jnp.int32)
        completed = 0
        try:
            for i in range(iterations):
                payload = queue.get()
                if payload is CLOSED:  # actor died early
                    break
                assert isinstance(payload, Rollout)
                self.params, self.opt_state, metrics = self._update_step(
                    self.params, self.opt_state, payload.traj,
                    payload.last_obs, step_arr,
                )
                slot.publish(self.params, i + 1)
                step_arr = step_arr + 1
                self.total_steps += self._steps_per_iter
                completed += 1
                metrics = dict(metrics)
                metrics["staleness"] = float(i - payload.behavior_version)
                acc.update(metrics)
                if log_every and (i + 1) % log_every == 0:
                    log.info(
                        "iter %d steps %d staleness %.0f reward_sum %.3f "
                        "loss %.4f",
                        i + 1, self.total_steps, metrics["staleness"],
                        acc.acc.get("reward_sum", 0.0),
                        float(metrics.get("loss", 0.0)),
                    )
        finally:
            # reap the actor on every exit path (normal, learner exception,
            # KeyboardInterrupt): signal stop, then keep draining so a put
            # blocked on a full queue can finish and the thread can exit.
            actor.stop()
            while actor.is_alive():
                try:
                    queue.get(timeout=0.05)
                except _stdlib_queue.Empty:
                    pass
                actor.join(timeout=0.05)
        if actor.error is not None:
            raise RuntimeError("pipeline actor failed") from actor.error
        if completed != iterations:
            raise RuntimeError(
                f"pipeline stopped early: {completed}/{iterations} iterations"
            )
        self.key = actor._key
        return acc.result(
            self.total_steps,
            self._steps_per_iter,
            actor_idle_s=queue.put_wait_s + actor.wait_s,
            learner_idle_s=queue.get_wait_s,
        )
