"""``PipelinedRL`` — the asynchronous multi-actor/learner backend.

Drop-in alternative to ``repro.core.ParallelRL`` (same constructor shape,
same ``run(iterations) -> RunResult``) that splits Algorithm 1 across
``num_actors`` actor threads and one learner thread joined by a bounded
trajectory stream:

    actor thread i: lease latest params → collect rollout → put
    learner thread: get → fused (V-trace update + publish) → commit params

The stream runs on one of two *queue planes* (``PipelineConfig.
rollout_plane``): the device-resident ``DeviceTrajectoryRing`` for
JAX-native envs — trajectories never leave the accelerator, and ``get()``
hands each slot to the learner with sole ownership so its memory is
reclaimed the moment the update consumes it — or the host ``TrajectoryQueue``
for ``HostEnvPool``, whose rollouts are born in host memory and ride
reusable ``HostStagingRing`` buffers (returned to their ring by the
payload's ``release`` callback once the learner has consumed the update).

Params flow the other way through a ``PingPongParamSlot``: the learner's
working params and opt state are private (and therefore donated — the
update runs alloc-free in steady state), while each update publishes a
bitwise snapshot into one of two alternating actor-facing buffers inside
the same fused dispatch. Actors lease a snapshot for exactly one rollout;
the learner reuses a stale buffer only after its last reader released.

Orthogonal to the queue plane is the *actor backend* (``PipelineConfig.
actor_backend``): ``"thread"`` replicas are ``ActorThread``s in this
process (fine whenever env stepping releases the GIL), while ``"process"``
moves each replica into a worker subprocess (``repro.pipeline.worker``) —
the only backend that scales GIL-holding Python emulators. Process workers
rebuild their env pools from picklable ``HostEnvSpec`` recipes, collect
into ``multiprocessing.shared_memory`` staging sets, and are drained by
parent-side ``ProcessActorDrainer`` threads into the same
``TrajectoryQueue``; params broadcast worker-ward through a shared-memory
ping-pong slot speaking the same reserve/commit protocol. The learner loop
below the ``run()`` plane split is byte-for-byte shared between backends.

Each actor replica owns a private slice of the environments: a single env is
split along the env axis (``HostEnvPool.shard`` for external pools,
``narrow_vector_env`` for JAX-native envs, ``HostEnvSpec.shard`` for
process workers), or a list of envs gives each replica its own full pool
(GA3C's n_actors sweep — more emulators hide more env latency). With queue
depth d the actors collectively run at most d
rollouts ahead; staleness is bounded by the depth and corrected by the
learner's full V-trace targets (``PipelineConfig.rho_bar`` / ``c_bar``). In
``lockstep`` mode (single actor) the actor always waits for fresh params and
the pipeline reproduces the synchronous trajectory stream exactly — bitwise,
on either plane, when the clips are infinite.

The win is wall-clock overlap: on the ``HostEnvPool`` path the env workers
hold no GIL while stepping, so N actors' env latencies, their jitted acting
steps, and the learner's jitted update all run concurrently — the paper's
Fig. 2 "50% env time" recovered, and scaled past what one actor can hide.
On the device plane the win is the removed host round trip plus full
donation: one fused dispatch per iteration, no staging copies, no
steady-state allocation (``benchmarks/fig2_time_split.run_device_ring``).

A third stream variant is the *replay plane* (``PipelineConfig.
replay_plane``): the FIFO ring is swapped for a sampled ``ReplayRing`` —
actors never block (a full ring evicts its oldest rollout), each update
*samples* ``replay_batch`` retained rollouts, and the learner step is
either DQN's replay-fed TD update (``repro.pipeline.offpolicy``) or the
same V-trace PAAC step consuming rollouts whose staleness the clips
correct. The run() loop below is unchanged: the ring speaks the queue
surface (one ``get()`` per fresh rollout ticket), and ``_apply_update``
hides which learner-private state rides the update signature.
"""
from __future__ import annotations

import queue as _stdlib_queue
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import sanitize
from repro.analysis.lockcheck import locks_enabled, monitor
from repro.checkpoint.checkpointer import (
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.configs.base import PipelineConfig
from repro.core.framework import MetricsAccumulator, RunResult, init_rl_common
from repro.core.rollout import make_collect_fn
from repro.envs.base import narrow_vector_env
from repro.envs.host_env import HostEnvPool, HostEnvShard, HostEnvSpec
from repro.pipeline.actor import (
    ActorThread,
    HostStagingRing,
    PingPongParamSlot,
    Rollout,
    collect_host,
)
from repro.pipeline.faults import FaultInjector, FaultPlan
from repro.pipeline.learner import make_learner_step, make_sharded_learner_step
from repro.pipeline.queue import CLOSED, TrajectoryQueue
from repro.pipeline.ring import DeviceTrajectoryRing, MeshTrajectoryRing
from repro.pipeline.supervisor import ActorSupervisor, QuotaLedger
from repro.telemetry import (
    LEARNER_UPDATE,
    LEASE,
    PUBLISH,
    QUEUE_GET_WAIT,
    Telemetry,
)
from repro.utils import get_logger

log = get_logger("pipeline")


def _device_view(tree, device):
    """Zero-copy single-device view of a mesh-replicated param tree.

    A fully-replicated global array holds one shard per mesh device;
    ``addressable_shards[i].data`` *is* the device-local array backing that
    shard — no copy, no host round trip. Actor lane ``i`` extracts its view
    under the ping-pong read lease, feeds its single-device collect, and
    drops it before release, so the learner's donation of the stale buffer
    can never race a live view (same invariant as the flat device plane).
    """
    def leaf(l):
        for s in l.addressable_shards:
            if s.device == device:
                return s.data
        raise RuntimeError(
            f"replicated param leaf has no shard on {device} — params are "
            "not placed on the rollout mesh"
        )

    return jax.tree_util.tree_map(leaf, tree)


class PipelinedRL:
    """Asynchronous multi-actor/learner pipeline over the PAAC framework."""

    def __init__(
        self,
        env,
        agent,
        *,
        optimizer: str = "rmsprop",
        lr_schedule: Optional[Callable] = None,
        seed: int = 0,
        pipeline: PipelineConfig = PipelineConfig(),
    ):
        from repro.core.agents.dqn import DQNAgent
        from repro.core.agents.paac import PAACAgent

        # exact types: subclasses (LaggedPAACAgent) and look-alikes (PPOAgent)
        # carry their own loss/state that make_learner_step would silently
        # drop. DQNAgent rides only the replay plane (its learner step is the
        # replay-fed TD update, not V-trace).
        self._replay = pipeline.replay_plane
        self._dqn = type(agent) is DQNAgent
        if self._dqn and not self._replay:
            raise ValueError(
                "DQNAgent needs the replay plane: pass PipelineConfig("
                "replay_plane=True) — the FIFO planes feed the on-policy "
                "V-trace learner"
            )
        if not self._dqn and type(agent) is not PAACAgent:
            raise NotImplementedError(
                f"PipelinedRL drives plain PAACAgent (got {type(agent).__name__}) "
                "on the FIFO planes, plus DQNAgent on the replay plane; other "
                "agents carry losses the learner steps would silently drop"
            )
        n_actors = pipeline.num_actors
        if n_actors < 1:
            raise ValueError(f"num_actors must be >= 1, got {n_actors}")
        # the mesh plane runs one actor lane per mesh device: num_actors is
        # normalized to mesh_shape (PipelineConfig rejects anything else)
        self._want_mesh = pipeline.rollout_plane == "mesh" or (
            pipeline.rollout_plane == "auto" and pipeline.mesh_shape > 1
        )
        if self._want_mesh:
            if pipeline.num_actors not in (1, pipeline.mesh_shape):
                raise ValueError(
                    "the mesh plane runs exactly one actor lane per mesh "
                    f"device: num_actors must be 1 (auto) or mesh_shape="
                    f"{pipeline.mesh_shape}, got {pipeline.num_actors}"
                )
            n_actors = pipeline.mesh_shape
        if pipeline.lockstep and n_actors > 1 and not self._want_mesh:
            raise ValueError(
                "lockstep (synchronous semantics) requires num_actors == 1 "
                "(or the mesh plane, whose lanes are consumed in lockstep "
                "sets — one sub-rollout per lane per update)"
            )
        self._backend = pipeline.actor_backend
        if self._backend not in ("thread", "process"):
            raise ValueError(
                "actor_backend must be 'thread' or 'process', got "
                f"{pipeline.actor_backend!r}"
            )
        self._owned_pools: List = []  # pools built here from HostEnvSpec
        self._process_plane = None
        # thread backend accepts HostEnvSpec as sugar: build the pool(s)
        # here (and own their close()) so everything downstream is uniform
        if self._backend == "thread":
            if isinstance(env, HostEnvSpec):
                env = env.build()
                self._owned_pools.append(env)
            elif isinstance(env, (list, tuple)) and any(
                isinstance(e, HostEnvSpec) for e in env
            ):
                env = [e.build() if isinstance(e, HostEnvSpec) else e
                       for e in env]
                self._owned_pools.extend(
                    e for e in env if isinstance(e, HostEnvPool))
        if isinstance(env, (list, tuple)):
            if len(env) != n_actors:
                raise ValueError(
                    f"got {len(env)} per-actor envs for num_actors={n_actors}"
                )
            per_actor_envs: Optional[List] = list(env)
            env = per_actor_envs[0]
        else:
            per_actor_envs = None
        self.env = env
        self.agent = agent
        self.pipeline = pipeline
        if self._backend == "process":
            # the process plane rebuilds env pools inside worker subprocesses
            # from picklable specs — live pools can't cross the boundary
            if not isinstance(env, HostEnvSpec) or any(
                not isinstance(e, HostEnvSpec)
                for e in (per_actor_envs or [])
            ):
                raise ValueError(
                    "actor_backend='process' requires a HostEnvSpec (or a "
                    "per-actor list of them): worker subprocesses rebuild "
                    "their env pools from the picklable spec — a live "
                    f"{type(env).__name__} cannot be shipped to a child"
                )
            if per_actor_envs is not None:
                if any(e.n_envs != env.n_envs for e in per_actor_envs):
                    raise ValueError("per-actor specs must have equal n_envs")
                self._proc_specs = list(per_actor_envs)
            else:
                self._proc_specs = (env.shard(n_actors) if n_actors > 1
                                    else [env])
            self._host = True  # process rollouts are born in host shm
        else:
            self._proc_specs = None
            self._host = hasattr(env, "step_host")
        self._n_actors = n_actors  # mesh plane: one lane per mesh device
        self._seed = seed  # the ReplayRing's sample stream seed
        self._plane = self._resolve_plane(pipeline.rollout_plane)
        if self._replay and self._plane != "device":
            raise ValueError(
                "replay_plane requires a JAX-native env on the device plane: "
                "the ReplayRing retains sampled rollouts on the accelerator, "
                "which host-born payloads (HostEnvPool / process backend) "
                "cannot do"
            )
        if self._plane == "mesh":
            from repro.launch.mesh import make_rollout_mesh

            self._rollout_mesh = make_rollout_mesh(pipeline.mesh_shape)
            self._mesh_devices = list(self._rollout_mesh.devices.flat)
        else:
            self._rollout_mesh = None
            self._mesh_devices = None
        # shared with ParallelRL — identical RNG layout so a lock-stepped
        # single-actor pipeline reproduces the synchronous run bit-for-bit.
        (self.optimizer, self.lr_schedule, self.key, k_env, self.params,
         self.opt_state) = init_rl_common(env, agent, optimizer, lr_schedule,
                                          seed)
        if self._dqn:
            # learner-private DQN state rides the update signature next to
            # params/opt state. The target tree must be a *copy*: the first
            # update donates self.params, and an aliased target would have
            # its buffers deleted out from under the TD evaluation.
            self._target = jax.tree_util.tree_map(
                lambda a: a.copy(), self.params)
            self._updates = jnp.zeros((), jnp.int32)
        if self._plane == "mesh":
            # learner state lives replicated on the rollout mesh: every
            # device holds a full copy, the sharded step's gradient
            # all-reduce keeps the copies bit-identical, and actor lanes
            # read their device-local shard view for free
            from repro.distributed.sharding import replicated_sharding

            repl = replicated_sharding(self._rollout_mesh)
            self.params = jax.device_put(self.params, repl)
            self.opt_state = jax.device_put(self.opt_state, repl)

        act = agent.act_fn()
        if self._backend == "process":
            # no parent-side acting or env state: each worker owns its pool,
            # jitted act_step and RNG key. Key layout matches the thread
            # plane's run(); the single-worker key syncs back after each run.
            from repro.pipeline.worker import ProcessActorPlane

            self._actor_envs = self._actor_obs = self._actor_env_state = None
            self._act = self._collect_jit = None
            self._process_plane = ProcessActorPlane(
                self._proc_specs, agent, pipeline.queue_depth, self.params,
                self._actor_keys(n_actors),
            )
        else:
            self._actor_envs, self._actor_obs, self._actor_env_state = \
                self._split_envs(env, per_actor_envs, n_actors, k_env)
            if self._plane == "mesh":
                # pin each lane's carried state to its mesh device: with all
                # of a lane's inputs committed there, the shared collect jit
                # dispatches to that device (one executable per device, all
                # lanes same shapes) and its outputs land in the lane's
                # sub-ring already device-resident
                for i, d in enumerate(self._mesh_devices):
                    self._actor_obs[i] = jax.device_put(self._actor_obs[i], d)
                    self._actor_env_state[i] = jax.device_put(
                        self._actor_env_state[i], d)
            if self._host:
                from repro.pipeline.actor import make_host_act_step

                self._act = make_host_act_step(act)
                self._collect_jit = None
            else:
                self._act = None
                # all replicas share one jitted collector (same shard shapes)
                if self._dqn:
                    from repro.pipeline.offpolicy import make_dqn_collect_fn

                    self._collect_jit = jax.jit(make_dqn_collect_fn(
                        agent, self._actor_envs[0], agent.hp.t_max))
                else:
                    self._collect_jit = jax.jit(make_collect_fn(
                        act, self._actor_envs[0], agent.hp.t_max))
        # per-replica lifetime rollout counters: the DQN collector's ε-schedule
        # index (persists across run() calls, like the synchronous schedule)
        self._actor_seq = [0] * n_actors

        # the fused learner step: dequeue-consume + update + publish in one
        # dispatch. Donated: params and opt state (learner-private — actors
        # only lease ping-pong snapshots) and the stale publish buffer from
        # reserve(), each of which aliases a matching output (new params, new
        # opt state, published snapshot) so the update runs alloc-free in
        # steady state. The trajectory needs no donation: ring.get()
        # transferred sole ownership, so its buffers are reclaimed the moment
        # this execution retires them — donating them would only warn
        # (nothing output-shaped to alias). The bootstrap obs must NOT be
        # donated on the device plane: the actor carries the same array into
        # its next rollout.
        if self._plane == "mesh":
            # the sharded twin: same math, jitted with shardings, per-device
            # partial gradients all-reduced over the mesh's data axis
            self._update_step = make_sharded_learner_step(
                agent, self.optimizer, self.lr_schedule, self._rollout_mesh,
                rho_bar=pipeline.rho_bar, c_bar=pipeline.c_bar,
                fused_publish=True,
            )
        elif self._dqn:
            # the replay-fed TD step: target tree and updates counter are
            # learner-private donated state exactly like params/opt state
            from repro.pipeline.offpolicy import make_dqn_learner_step

            self._update_step = jax.jit(
                make_dqn_learner_step(agent, self.optimizer, self.lr_schedule,
                                      fused_publish=True),
                donate_argnums=(0, 1, 2, 3, 7),
            )
        else:
            self._update_step = jax.jit(
                make_learner_step(agent, self.optimizer, self.lr_schedule,
                                  rho_bar=pipeline.rho_bar,
                                  c_bar=pipeline.c_bar, fused_publish=True),
                donate_argnums=(0, 1, 5),
            )
        # one adapter per agent family so the run() loop stays agnostic:
        # (traj, last_obs, step, publish_dst) -> (published, metrics),
        # threading whatever learner-private state the step carries
        if self._dqn:
            def _apply(traj, last_obs, step_arr, publish_dst):
                (self.params, self.opt_state, self._target, self._updates,
                 published, metrics) = self._update_step(
                    self.params, self.opt_state, self._target, self._updates,
                    traj, last_obs, step_arr, publish_dst,
                )
                return published, metrics
        else:
            def _apply(traj, last_obs, step_arr, publish_dst):
                self.params, self.opt_state, published, metrics = \
                    self._update_step(
                        self.params, self.opt_state, traj, last_obs,
                        step_arr, publish_dst,
                    )
                return published, metrics
        self._apply_update = _apply
        self.total_steps = 0
        # one learned rollout = one actor shard's n_envs·t_max timesteps —
        # except on the mesh plane, where every update consumes one
        # sub-rollout from each of the n_actors lanes
        shard_envs = (self._proc_specs[0].n_envs if self._proc_specs
                      else self._actor_envs[0].n_envs)
        lanes_per_update = n_actors if self._plane == "mesh" else 1
        self._steps_per_iter = lanes_per_update * shard_envs * agent.hp.t_max
        # (actor_id, seq) of every payload consumed by the last run() —
        # the never-drop contract the pipeline tests pin down (mesh payloads
        # are lane-assembled: actor_id is -1, seq the common lane seq)
        self.learned_ids: List[Tuple[int, int]] = []

        # -- fault tolerance + checkpoint state --------------------------------
        if pipeline.fault_plan is not None and not isinstance(
                pipeline.fault_plan, FaultPlan):
            raise TypeError(
                "PipelineConfig.fault_plan must be a repro.pipeline.faults."
                f"FaultPlan, got {type(pipeline.fault_plan).__name__}"
            )
        # full (bitwise) resume needs the actor-side carried state; that only
        # exists parent-side on the thread backend's FIFO planes. Everywhere
        # else a checkpoint is a *warm* restart: params/opt state/counters
        # restore exactly, actors re-reset their envs (docs/fault_tolerance.md)
        self._ckpt_slots = (self._backend == "thread"
                            and self._plane in ("device", "host")
                            and not self._replay)
        self._iters_done = 0  # cumulative completed updates (checkpoint id)
        self._resume_step = None  # step_arr override set by restore()
        self._consumed_seq = [0] * n_actors  # per-slot consumed rollout count
        # slot -> (key, env_state, obs) after the newest *consumed* rollout
        self._live_slot_state: Dict[int, tuple] = {}
        self._resume_slot_state: Optional[Dict[int, tuple]] = None
        self.supervisor = None  # the last run()'s ActorSupervisor (elastic)

    # -- queue plane ---------------------------------------------------------
    def _resolve_plane(self, plane: str) -> str:
        if plane not in ("auto", "device", "host", "mesh"):
            raise ValueError(
                "rollout_plane must be 'auto', 'device', 'host' or 'mesh', "
                f"got {plane!r}"
            )
        if self._want_mesh:
            if self._host:
                raise ValueError(
                    "rollout_plane='mesh' requires a JAX-native env: "
                    "HostEnvPool (and process-backend) rollouts are born in "
                    "host memory and cannot ride per-device sub-rings"
                )
            return "mesh"
        if plane == "auto":
            return "host" if self._host else "device"
        if plane == "device" and self._host:
            raise ValueError(
                "rollout_plane='device' requires a JAX-native env: "
                "HostEnvPool (and process-backend) rollouts are born in "
                "host memory and must ride the host TrajectoryQueue plane"
            )
        return plane

    def _make_queue(self, n_actors: int, telemetry=None):
        if self._replay:
            from repro.pipeline.replay_ring import ReplayRing

            return ReplayRing(
                capacity=self.pipeline.replay_capacity,
                batch_size=self.pipeline.replay_batch,
                producers=n_actors,
                prioritized=self.pipeline.prioritized,
                sample_seed=self._seed,
                telemetry=telemetry,
            )
        if self._plane == "mesh":
            return MeshTrajectoryRing(self.pipeline.queue_depth,
                                      self._rollout_mesh, telemetry=telemetry)
        if self._plane == "device":
            return DeviceTrajectoryRing(self.pipeline.queue_depth,
                                        producers=n_actors,
                                        telemetry=telemetry)
        return TrajectoryQueue(self.pipeline.queue_depth, producers=n_actors,
                               telemetry=telemetry)

    # -- env splitting -------------------------------------------------------
    def _split_envs(self, env, per_actor_envs, n_actors: int, k_env):
        """Per-actor env replicas + their initial obs/state.

        Returns ``(envs, obs_list, env_state_list)`` (state ``None`` per
        entry on the host path, which keeps env state inside the pool).
        """
        if per_actor_envs is not None:
            envs = per_actor_envs
            if any(hasattr(e, "step_host") != self._host for e in envs):
                raise ValueError("per-actor envs must be all host or all JAX")
            if any(e.n_envs != env.n_envs for e in envs):
                raise ValueError("per-actor envs must have equal n_envs")
        elif n_actors == 1:
            envs = [env]
        elif self._host:
            envs = env.shard(n_actors)
        else:
            if env.n_envs % n_actors:
                raise ValueError(
                    f"cannot split {env.n_envs} envs across {n_actors} actors"
                )
            envs = [narrow_vector_env(env, env.n_envs // n_actors)
                    for _ in range(n_actors)]
        if self._host:
            return envs, [e.reset() for e in envs], [None for _ in envs]
        if len(envs) == 1:
            states = [envs[0].reset(k_env)]
        else:
            states = [e.reset(k) for e, k in
                      zip(envs, jax.random.split(k_env, len(envs)))]
        return envs, [e.observe(s) for e, s in zip(envs, states)], states

    # -- rollout collection closure (runs on actor thread i) -----------------
    def _make_collect(self, i: int) -> Callable:
        """``collect(params, key) -> (key, traj, last_obs, release)``.

        Host path: rollouts accumulate into a per-actor ``HostStagingRing``
        set; ``release`` returns the set once the learner consumed it.
        Device path: the jitted collector's output feeds the ring directly
        (``release`` is ``None`` — the learner's donation recycles it).
        """
        if self._host:
            env, act, t_max = self._actor_envs[i], self._act, self.agent.hp.t_max
            staging = HostStagingRing(
                self.pipeline.queue_depth + 2, t_max, env.n_envs,
                env.obs_shape, env.obs_dtype,
            )

            def collect(params, key):
                s = staging.acquire()
                obs, key, traj, last_obs = collect_host(
                    act, env, params, self._actor_obs[i], key, t_max,
                    staging=s,
                )
                # the carried obs lives in set s; the next rollout copies it
                # out before anything can overwrite it (per-actor sets are
                # written serially by this thread only)
                self._actor_obs[i] = obs
                return key, traj, last_obs, (lambda: staging.release(s))

        else:
            collect_jit, t_max = self._collect_jit, self.agent.hp.t_max
            if self._plane == "host":
                # forced host plane on a JAX env (the GA3C-style baseline):
                # stage the device trajectory into reusable pinned buffers
                env = self._actor_envs[i]
                obs_dtype = np.asarray(self._actor_obs[i]).dtype
                staging = HostStagingRing(
                    self.pipeline.queue_depth + 2, t_max, env.n_envs,
                    env.obs_shape, obs_dtype,
                )

                def collect(params, key):
                    env_state, last_obs, key, traj = collect_jit(
                        params, self._actor_env_state[i], self._actor_obs[i],
                        key,
                    )
                    self._actor_env_state[i] = env_state
                    self._actor_obs[i] = last_obs
                    s = staging.acquire()
                    # D2H into the preallocated staging set (np.copyto pulls
                    # each device array to host exactly once, no fresh allocs)
                    for dst, src in zip(s.traj, traj):
                        np.copyto(dst, np.asarray(src))
                    np.copyto(s.last_obs, np.asarray(last_obs))
                    return key, s.traj, s.last_obs, \
                        (lambda: staging.release(s))

            elif self._plane == "mesh":
                dev = self._mesh_devices[i]
                warm = [False]  # first call compiles — exempt from the guard

                def collect(params, key):
                    # params arrive as the leased replicated snapshot; the
                    # lane consumes its zero-copy device-local view so the
                    # shared collect jit dispatches on this lane's device
                    with sanitize.guard(active=warm[0]):
                        pv = _device_view(params, dev)
                        env_state, last_obs, key, traj = collect_jit(
                            pv, self._actor_env_state[i], self._actor_obs[i],
                            key,
                        )
                        # block before the lease is released: the learner may
                        # donate the stale snapshot the moment readers reach
                        # zero, so the collect must have fully executed (and
                        # the view dropped) first — also what bounds
                        # in-flight work
                        jax.block_until_ready(traj.reward)
                    warm[0] = True
                    self._actor_env_state[i] = env_state
                    self._actor_obs[i] = last_obs
                    return key, traj, last_obs, None

            elif self._dqn:
                warm = [False]

                def collect(params, key):
                    # the ε-schedule index: this replica's lifetime rollout
                    # count (in lockstep it equals the learner step, matching
                    # the synchronous schedule). Its H2D copy is an intended
                    # edge, hoisted ahead of the transfer-guarded dispatch.
                    n = self._actor_seq[i]
                    n_dev = jnp.asarray(n, jnp.int32)
                    with sanitize.guard(active=warm[0]):
                        env_state, last_obs, key, traj = collect_jit(
                            params, self._actor_env_state[i],
                            self._actor_obs[i], key, n_dev,
                        )
                        jax.block_until_ready(traj.reward)
                    warm[0] = True
                    self._actor_seq[i] = n + 1
                    self._actor_env_state[i] = env_state
                    self._actor_obs[i] = last_obs
                    return key, traj, last_obs, None

            else:
                warm = [False]

                def collect(params, key):
                    with sanitize.guard(active=warm[0]):
                        env_state, last_obs, key, traj = collect_jit(
                            params, self._actor_env_state[i],
                            self._actor_obs[i], key,
                        )
                        # block so queue depth genuinely bounds in-flight
                        # rollouts
                        jax.block_until_ready(traj.reward)
                    warm[0] = True
                    self._actor_env_state[i] = env_state
                    self._actor_obs[i] = last_obs
                    return key, traj, last_obs, None

        return collect

    def _actor_keys(self, n_actors: int) -> List:
        if n_actors == 1:
            return [self.key]  # PR-1 layout: the single actor owns self.key
        keys = jax.random.split(self.key, n_actors + 1)
        self.key = keys[0]
        return list(keys[1:])

    # -- checkpoint / resume ---------------------------------------------------
    def _make_snapshot(self, i: int) -> Callable:
        """Post-rollout actor-state capture for slot ``i`` (thread backend).

        Called by the actor thread right after each successful collect;
        the learner stores the snapshot of the newest *consumed* rollout as
        the slot's resume point. Device path: the carried arrays are
        immutable jax values — keep references. Host path: the env state
        lives inside the pool (unrecoverable — warm restart) and the obs
        rides a recycled staging buffer, so it must be copied out.
        """
        if self._host:
            def snap(key, i=i):
                return (key, None, np.array(self._actor_obs[i]))
        else:
            def snap(key, i=i):
                return (key, self._actor_env_state[i], self._actor_obs[i])
        return snap

    def _checkpoint_template(self):
        """The checkpoint pytree *structure* (placeholder leaves carry the
        dtypes/shapes/residency ``restore_checkpoint`` restores into).
        Save and restore both derive it from the live model, so a resume
        must run under the same config — asserted by leaf-shape checks."""
        n = self._n_actors
        tree = {
            "params": self.params,
            "opt_state": self.opt_state,
            "key": self.key,
            "counters": {
                "total_steps": np.asarray(0, np.int64),
                "step_value": np.asarray(0, np.int64),
                "iters_done": np.asarray(0, np.int64),
                "actor_seq": np.zeros(n, np.int64),
                "consumed_seq": np.zeros(n, np.int64),
                # lifetime queue tickets (issued, consumed) at save time:
                # audit metadata for how many in-flight rollouts a kill
                # dropped (re-collected on resume, never silently skipped)
                "tickets": np.zeros(2, np.int64),
            },
        }
        if self._dqn:
            tree["dqn_target"] = self._target
            tree["dqn_updates"] = self._updates
        if self._ckpt_slots:
            tree["slots"] = {
                str(i): {
                    "key": jax.random.PRNGKey(0),
                    "env_state": self._actor_env_state[i],
                    "obs": self._actor_obs[i],
                }
                for i in range(n)
            }
        return tree

    @staticmethod
    def _ticket_counts(queue) -> Tuple[int, int]:
        issued = getattr(queue, "tickets_issued", 0)
        consumed = getattr(queue, "tickets_consumed", 0)
        if isinstance(issued, (list, tuple)):
            issued = sum(issued)
        if isinstance(consumed, (list, tuple)):
            consumed = sum(consumed)
        return int(issued), int(consumed)

    def _save_checkpoint(self, queue, step_value: int) -> str:
        """Snapshot the full pipeline state after the update that just
        committed. Runs on the learner thread between updates, so
        ``self.params``/``opt_state`` are quiescent; ``np.asarray`` inside
        the checkpointer blocks until the update producing them retired."""
        tree = self._checkpoint_template()
        issued, consumed = self._ticket_counts(queue)
        tree["counters"] = {
            "total_steps": np.asarray(self.total_steps, np.int64),
            "step_value": np.asarray(step_value, np.int64),
            "iters_done": np.asarray(self._iters_done, np.int64),
            "actor_seq": np.asarray(self._actor_seq, np.int64),
            "consumed_seq": np.asarray(self._consumed_seq, np.int64),
            "tickets": np.asarray([issued, consumed], np.int64),
        }
        if self._ckpt_slots:
            slots = {}
            for i in range(self._n_actors):
                st = self._live_slot_state.get(i)
                if st is None:  # nothing consumed from this slot yet
                    st = (jax.random.PRNGKey(0), self._actor_env_state[i],
                          self._actor_obs[i])
                slots[str(i)] = {"key": st[0], "env_state": st[1],
                                 "obs": st[2]}
            tree["slots"] = slots
        path = save_checkpoint(self.pipeline.checkpoint_dir,
                               self._iters_done, tree, prefix="pipe")
        log.info("checkpoint: saved %s (update %d, %d steps)",
                 path, self._iters_done, self.total_steps)
        return path

    def restore(self, directory: Optional[str] = None, *,
                prefix: str = "pipe") -> int:
        """Restore the newest checkpoint; returns the number of learner
        updates already done (0 = nothing to restore). The caller runs the
        *remaining* iterations: on the thread backend's FIFO planes the
        resumed run continues the interrupted one bitwise under lockstep
        (the tests pin this); elsewhere it is a warm restart."""
        directory = directory or self.pipeline.checkpoint_dir
        if not directory:
            raise ValueError("no checkpoint directory: pass one or set "
                             "PipelineConfig.checkpoint_dir")
        step = latest_step(directory, prefix=prefix)
        if step is None:
            return 0
        tree = restore_checkpoint(directory, step,
                                  self._checkpoint_template(), prefix=prefix)
        self.params = tree["params"]
        self.opt_state = tree["opt_state"]
        self.key = tree["key"]
        if self._plane == "mesh":
            from repro.distributed.sharding import replicated_sharding

            repl = replicated_sharding(self._rollout_mesh)
            self.params = jax.device_put(self.params, repl)
            self.opt_state = jax.device_put(self.opt_state, repl)
        if self._dqn:
            self._target = tree["dqn_target"]
            self._updates = tree["dqn_updates"]
        c = tree["counters"]
        self.total_steps = int(c["total_steps"])
        self._iters_done = int(c["iters_done"])
        self._resume_step = int(c["step_value"])
        self._actor_seq = [int(x) for x in np.asarray(c["actor_seq"])]
        self._consumed_seq = [int(x) for x in np.asarray(c["consumed_seq"])]
        if self._ckpt_slots:
            self._resume_slot_state = {
                i: (tree["slots"][str(i)]["key"],
                    tree["slots"][str(i)]["env_state"],
                    tree["slots"][str(i)]["obs"])
                for i in range(self._n_actors)
            }
        issued, consumed = (int(x) for x in np.asarray(c["tickets"]))
        log.info(
            "checkpoint: restored update %d (%d steps) from %s; "
            "%d in-flight rollout(s) at save time will be re-collected",
            self._iters_done, self.total_steps, directory,
            max(issued - consumed, 0))
        return self._iters_done

    def run(self, iterations: int, log_every: int = 0) -> RunResult:
        """Run `iterations` learner updates (each = one shard's n_e·t_max
        timesteps), fed by ``num_actors`` concurrent actor replicas."""
        n_actors = self._n_actors
        # fresh telemetry hub per run (queues, actors and their emitters are
        # per-run objects); kept on self so harnesses can read the tracks —
        # e.g. benchmarks/fig2_time_split cross-checks RunResult's time
        # split against the trace — after run() returns
        hub = self.telemetry = Telemetry()
        learner_em = hub.emitter("learner")
        queue = self._make_queue(n_actors, telemetry=hub)
        if self._plane == "mesh":
            # every lane contributes one sub-rollout to every update: the
            # quota is `iterations` per lane, not split across lanes
            quota = [iterations] * n_actors
        else:
            quota = [iterations // n_actors
                     + (1 if i < iterations % n_actors else 0)
                     for i in range(n_actors)]
        cfg = self.pipeline
        # fault harness + recovery scaffolding. The injector exists with or
        # without elastic (deterministic fail-fast chaos tests); the ledger
        # and supervisor only when elastic arms recovery. Config already
        # rejected elastic on the mesh plane (fail-fast by design).
        injector = (FaultInjector(cfg.fault_plan)
                    if cfg.fault_plan is not None else None)
        elastic = cfg.elastic
        ledger = QuotaLedger(sum(quota)) if elastic else None
        ckpt_every = cfg.checkpoint_every
        snapshots = ckpt_every > 0 and self._ckpt_slots
        # resume: restore() stashed per-slot actor state; apply it exactly
        # once — the resumed actors re-enter the key/env/obs stream at the
        # checkpointed rollout boundary with seq numbering continuing where
        # the consumed stream left off (in-flight rollouts re-collect)
        resume = self._resume_slot_state
        self._resume_slot_state = None
        if resume:
            start_seqs = list(self._consumed_seq)
            self._live_slot_state = dict(resume)
        else:
            start_seqs = [0] * n_actors
            self._consumed_seq = [0] * n_actors
            self._live_slot_state = {}
        # the actor-plane split: everything below this differs by backend
        # (thread replicas collecting in-process vs subprocess workers with
        # parent-side drainers); everything after it is backend-agnostic —
        # both backends expose the same queue payloads and the same
        # reserve/commit param-slot protocol to the learner loop.
        if self._backend == "process":
            slot, actors = self._process_plane.begin_run(
                queue, quota, cfg.lockstep, self.params,
                telemetry=hub, ledger=ledger, injector=injector,
            )
        else:
            slot = PingPongParamSlot(self.params, version=0)
            keys = self._actor_keys(n_actors)
            if resume:
                keys = [resume[i][0] for i in range(n_actors)]
                for i in range(n_actors):
                    if not self._host:
                        self._actor_env_state[i] = resume[i][1]
                    self._actor_obs[i] = resume[i][2]
            if self._plane == "mesh":
                # each lane's RNG stream is pinned to its device so the
                # collect jit (whose other inputs live there) never pulls
                # the key across devices
                keys = [jax.device_put(k, d)
                        for k, d in zip(keys, self._mesh_devices)]
            actors = [
                ActorThread(
                    self._make_collect(i),
                    queue.lane(i) if self._plane == "mesh" else queue,
                    slot, key, quota[i],
                    lockstep=cfg.lockstep, actor_id=i,
                    telemetry=hub, start_seq=start_seqs[i],
                    ledger=ledger, injector=injector,
                    snapshot=self._make_snapshot(i) if snapshots else None,
                )
                for i, key in enumerate(keys)
            ]
        actors_by_id: Dict[int, object] = {a.actor_id: a for a in actors}
        sup = None
        if elastic:
            if self._backend == "process":
                def respawner(dead, new_id, remaining):
                    d = self._process_plane.respawn_worker(
                        dead.slot_index, new_id, remaining, cfg.lockstep,
                        queue, telemetry=hub, ledger=ledger,
                    )
                    actors_by_id[new_id] = d
                    d.start()
                    return d
            else:
                def respawner(dead, new_id, remaining):
                    # the replacement resumes the dead replica's RNG stream
                    # and carried env state (mutated only on a *successful*
                    # collect, so both sit at the last rollout boundary) but
                    # gets a fresh staging ring via _make_collect — the dead
                    # replica's in-flight set may be unrecoverable
                    a = ActorThread(
                        self._make_collect(dead.slot_index),
                        queue, slot, dead._key, remaining,
                        lockstep=cfg.lockstep, actor_id=new_id,
                        telemetry=hub, slot_index=dead.slot_index,
                        ledger=ledger, injector=injector,
                        snapshot=(self._make_snapshot(dead.slot_index)
                                  if snapshots else None),
                    )
                    actors_by_id[new_id] = a
                    a.start()
                    return a
            sup = ActorSupervisor(
                queue, ledger, respawner,
                restart_budget=cfg.restart_budget,
                backoff_s=cfg.restart_backoff_s, telemetry=hub,
            )
            for a in actors:
                sup.register(a)
        # kept on self (like .telemetry) so harnesses/tests can audit the
        # run's fault episodes after run() returns
        self.supervisor = sup
        # device plane: never sync the learner loop — metric scalars are
        # stashed and converted once at result(), so update i+1 dispatches
        # while update i still executes. Host plane: eager (the blocking
        # float() conversion is what certifies consume-completion before a
        # staging set is release()d back to its ring).
        acc = MetricsAccumulator(lazy=self._plane in ("device", "mesh"))
        self.learned_ids = []
        for a in actors:
            a.start()
        # observability side-cars: both optional, both read-only observers
        # of the emitters the hot paths write anyway
        hub.set_gauge("queue_depth", queue.qsize)
        if self.pipeline.metrics_jsonl:
            hub.heartbeat_start(
                self.pipeline.metrics_jsonl,
                interval=self.pipeline.heartbeat_s,
                actor_emitters=[a.span_emitter for a in actors],
            )
        if self.pipeline.stall_timeout_s > 0:
            hub.watchdog_start(self.pipeline.stall_timeout_s, [
                ("learner", learner_em, None),
                *[(f"actor{a.actor_id}", a.span_emitter, a.is_alive)
                  for a in actors],
            ])
        # same step-counter semantics as ParallelRL.run (lr_schedule parity);
        # a restore() overrides the start value so the resumed run's schedule
        # continues exactly where the interrupted one left off
        start_step = (self._resume_step if self._resume_step is not None
                      else self.total_steps)
        self._resume_step = None
        step_arr = jnp.asarray(start_step, jnp.int32)
        step0 = int(start_step)
        completed = 0
        # transfer sanitizer: the device planes' steady state (get → reserve
        # → fused update → commit) must stay free of implicit host traffic.
        # Iteration 0 is exempt (compilation may materialize constants); the
        # step counter bump and metric bookkeeping stay OUTSIDE the guard —
        # they are host-side by design. Host plane: the staged payload's H2D
        # is the plane's whole point, so it is never guarded.
        san = (sanitize.transfers_enabled()
               and self._plane in ("device", "mesh"))
        try:
            for i in range(iterations):
                if injector is not None:
                    injector.stall_learner(i)
                with sanitize.guard(active=san and i > 0):
                    learner_em.begin(QUEUE_GET_WAIT)
                    try:
                        payload = queue.get()
                    finally:
                        learner_em.end()
                    if payload is CLOSED:  # an actor died early
                        break
                    assert isinstance(payload, Rollout)
                    # claim the stale ping-pong buffer; bounded by one
                    # in-flight collect (actors release before blocking on the
                    # queue), so a long wait means an actor died without
                    # releasing — bail out (naming the holder) instead of
                    # hanging
                    learner_em.begin(LEASE)
                    try:
                        deadline = time.monotonic() + cfg.lease_timeout_s
                        while True:
                            publish_dst = slot.reserve(i + 1, timeout=1.0)
                            if publish_dst is not None:
                                break
                            live = (sup.all_actors() if sup is not None
                                    else actors)
                            if not any(a.is_alive() for a in live):
                                raise RuntimeError(
                                    "param lease never released "
                                    "(all actors exited)"
                                )
                            if time.monotonic() >= deadline:
                                stale = (i + 1) % 2
                                held = ", ".join(
                                    slot.holders(stale)
                                    if hasattr(slot, "holders") else ()
                                ) or "an unknown party"
                                raise RuntimeError(
                                    f"param buffer {stale} still leased after "
                                    f"lease_timeout_s={cfg.lease_timeout_s:g}s "
                                    f"— held by {held}"
                                )
                    finally:
                        learner_em.end()
                    if san:
                        prev_params = self.params
                    # on the device planes this span covers the async
                    # *dispatch*, not the execution — by design: the learner
                    # thread's own time is what the trace's learner track
                    # attributes
                    learner_em.begin(LEARNER_UPDATE)
                    try:
                        published, metrics = self._apply_update(
                            payload.traj, payload.last_obs, step_arr,
                            publish_dst,
                        )
                    finally:
                        learner_em.end()
                    learner_em.begin(PUBLISH)
                    try:
                        slot.commit(published, i + 1)
                    finally:
                        learner_em.end()
                if san:
                    # deleted-buffer probes: donation marks inputs deleted at
                    # dispatch, so still-live donated params mean aliasing
                    # was dropped and the alloc-free steady state is gone.
                    # The publish target is consistency-checked only — a
                    # backend may route the published output through the
                    # params donation and decline this alias wholesale, but
                    # a *partial* donation is always a bug.
                    sanitize.assert_deleted(prev_params, "donated params")
                    sanitize.assert_uniformly_deleted(
                        publish_dst, "reserved publish buffer")
                step_arr = step_arr + 1
                self.total_steps += self._steps_per_iter
                completed += 1
                hub.counter_add("steps", self._steps_per_iter)
                self.learned_ids.append((payload.actor_id, payload.seq))
                metrics = dict(metrics)
                metrics["staleness"] = float(i - payload.behavior_version)
                hub.set_gauge("staleness", metrics["staleness"])
                if self._replay and self.pipeline.prioritized:
                    # feed the update's |TD| back as the sampled slots' new
                    # priorities (the float() syncs on the metric scalar —
                    # the prioritized path trades one async dispatch for the
                    # feedback loop)
                    p = metrics.get("td_abs")
                    pr = float(jnp.abs(metrics["loss"]) if p is None else p)
                    queue.update_priorities(
                        queue.last_sampled,
                        [pr] * len(queue.last_sampled),
                    )
                # eager (host plane): blocks on the metric scalars => the
                # update (and the H2D copy of the staged payload) has fully
                # executed. Lazy (device plane): no sync — just stashes.
                acc.update(metrics)
                if payload.release is not None:
                    if injector is not None and injector.drop_release(i):
                        # injected lease-drop: the set is deliberately leaked
                        # — the staging ring's +2 sizing must absorb it and
                        # the run must complete regardless
                        pass
                    else:
                        payload.release()  # consume certified: set reusable
                self._iters_done += 1
                if ckpt_every:
                    # track the newest consumed rollout per slot: its
                    # post-collect actor snapshot is the slot's resume point
                    owner = actors_by_id.get(payload.actor_id)
                    if owner is not None:
                        self._consumed_seq[owner.slot_index] = payload.seq + 1
                        st = (owner.consume_state(payload.seq)
                              if hasattr(owner, "consume_state") else None)
                        if st is not None:
                            self._live_slot_state[owner.slot_index] = st
                    if completed % ckpt_every == 0:
                        self._save_checkpoint(queue, step0 + completed)
                if log_every and (i + 1) % log_every == 0:
                    # never sync the device planes for a log line: fold only
                    # the already-executed updates (cumulative() would drain
                    # every pending device scalar — a hidden blocking sync
                    # serializing the learner against its own dispatches)
                    log.info(
                        "iter %d steps %d actor %d staleness %.0f "
                        "reward_sum %.3f loss %.4f",
                        i + 1, self.total_steps, payload.actor_id,
                        metrics["staleness"],
                        acc.cumulative_nowait("reward_sum"),
                        acc.last("loss"),
                    )
        finally:
            # disarm recovery FIRST: a replica dying during teardown must
            # not respawn a fresh one under the sweeps below
            if sup is not None:
                sup.shutdown()
                actors = sup.all_actors()  # epochs included in the sweeps
            # reap all actors on every exit path (normal, learner exception,
            # KeyboardInterrupt): signal stop, then keep draining so puts
            # blocked on a full queue can finish and the threads can exit —
            # releasing discarded staged payloads so no actor can wedge on an
            # empty staging ring while unwinding.
            for a in actors:
                a.stop()
            while any(a.is_alive() for a in actors):
                try:
                    p = queue.get(timeout=0.05)
                    if p is not CLOSED and getattr(p, "release", None):
                        p.release()
                except _stdlib_queue.Empty:
                    pass
                for a in actors:
                    a.join(timeout=0.02)
            # actors are gone, but the queue may still hold unconsumed
            # payloads (learner bailed with rollouts buffered): fire their
            # release() hooks so staging buffers return to their pools —
            # on the process plane the free-lists persist across run()
            # calls, and leaked indices would starve the next run.
            while True:
                try:
                    p = queue.get(timeout=0)
                except _stdlib_queue.Empty:
                    break
                if p is CLOSED:
                    break
                if getattr(p, "release", None):
                    p.release()
            # lock-order verdict for this run: everything the sanitized
            # wrappers witnessed, attached to the trace by name so the
            # launcher (and CI) can fail on cycles/hazards post-run
            if locks_enabled():
                hub.report("lockcheck", monitor().report())
            # observers down, then export — after the joins above, so
            # worker-shipped span rings have merged into the hub. Runs on
            # every exit path: a post-mortem trace of a failed run is the
            # tool's whole point.
            hub.stop()
            if self.pipeline.trace_path:
                hub.write_trace(self.pipeline.trace_path)
        if sup is not None and sup.fatal is not None:
            raise RuntimeError(
                f"pipeline stopped early after faults: {completed}/"
                f"{iterations} iterations — last live actor died"
            ) from sup.fatal.error
        # supervised deaths (fault_handled) were absorbed — respawned or
        # degraded — and must not fail a run that completed its quota
        errors = [a for a in actors
                  if a.error is not None
                  and not getattr(a, "fault_handled", False)]
        if errors:
            raise RuntimeError(
                f"pipeline actor {errors[0].actor_id} failed"
            ) from errors[0].error
        if completed != iterations:
            raise RuntimeError(
                f"pipeline stopped early: {completed}/{iterations} iterations"
            )
        if sup is not None and sup.episodes:
            log.warning("pipeline recovered from %d fault episode(s): %s",
                        len(sup.episodes), sup.episodes)
        if n_actors == 1:
            # with a supervisor the slot's newest epoch carries the stream
            last = sup.slot_actor(0) if sup is not None else actors[0]
            if self._backend == "process":
                # the worker owns the acting key; sync it back so repeated
                # run() calls continue the same stream the thread plane would
                if last.final_key is not None:
                    self.key = jnp.asarray(last.final_key)
            else:
                self.key = last._key
        per_actor_idle = [a.put_wait_s + a.wait_s for a in actors]
        # the end-of-run metrics drain pulls every stashed device scalar to
        # host in one batch — the device planes' one intended D2H sync
        with sanitize.allowed("metrics drain"):
            return acc.result(
                self.total_steps,
                self._steps_per_iter,
                actor_idle_s=sum(per_actor_idle),
                learner_idle_s=queue.get_wait_s,
                per_actor_idle_s=per_actor_idle,
            )

    # -- teardown (process plane + pools built from specs) -------------------
    def close(self) -> None:
        """Release resources this backend *owns*: worker subprocesses and
        their shared memory (process backend), and any ``HostEnvPool`` built
        here from a ``HostEnvSpec``. Live pools the caller handed in stay
        the caller's to close. Idempotent."""
        if self._process_plane is not None:
            self._process_plane.close()
            self._process_plane = None
        for pool in self._owned_pools:
            pool.close()
        self._owned_pools = []

    def __enter__(self) -> "PipelinedRL":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()
