"""Asynchronous multi-actor/learner pipeline — the beyond-paper throughput lever.

The paper's framework (``repro.core``) is fully synchronous: acting,
stepping and learning serialize into one program per iteration, so the
accelerator idles whenever the host is on the critical path (Fig. 2's
"50% env time" regime). Following GA3C (Babaeizadeh et al., 2017) and
IMPALA (Espeholt et al., 2018), this subsystem decouples the two halves
behind a bounded queue, with N acting replicas feeding one learner.

N-actor dataflow::

    actor 0 ──collect(env shard 0)──put──▶ ┌─────────────────┐
    actor 1 ──collect(env shard 1)──put──▶ │ TrajectoryQueue │──get──▶ learner
      ...                                  │   (depth d)     │           │
    actor N-1 ──collect(shard N-1)──put──▶ └─────────────────┘           │
        ▲                                                                │
        └───────────── ParamSlot.read ◀── ParamSlot.publish ◀────────────┘

Each replica owns a private slice of the environments — a single env's axis
is split N ways (``HostEnvPool.shard`` / ``narrow_vector_env``), or a list
of envs gives each replica its own full pool (GA3C's n_actors sweep). Every
queue payload (``Rollout``) is tagged ``(actor_id, seq, behavior_version)``
so the learner can attribute idle time and staleness per replica, and so the
tests can prove no trajectory is ever dropped or learned twice.

Staleness model: the learner stamps params with a monotone version (one per
update) published through the shared ``ParamSlot``; each actor snapshots the
newest version before collecting, and a rollout consumed at learner version
v carries ``staleness = v - behavior_version``. The queue depth bounds the
number of rollouts in flight *collectively* (backpressure blocks producers;
nothing is dropped), so staleness ≤ depth + num_actors in steady state. The
learner compensates with full V-trace (``rho_bar``/``c_bar`` clips): ρ̄
bounds each step's importance-weighted TD error and the c̄ product bounds
backward propagation through the n-step targets, keeping deep queues
unbiased; infinite clips compile the correction out exactly (the
synchronous PAAC update, pinned bitwise by the lockstep tests).

Modules:

* ``TrajectoryQueue`` — bounded, never-dropping multi-producer rollout queue
  with actor/learner idle-time accounting and prompt close-on-abort
  (``repro.pipeline.queue``),
* ``ActorThread`` / ``ParamSlot`` / ``collect_host`` — double-buffered
  rollout collection for JAX-native envs and ``HostEnvPool``
  (``repro.pipeline.actor``),
* ``make_learner_step`` — PAAC update with full V-trace staleness
  correction (``repro.pipeline.learner``),
* ``PipelinedRL`` — orchestrator mirroring ``ParallelRL``'s API
  (``repro.pipeline.orchestrator``).

Configure via ``repro.configs.PipelineConfig`` (num_actors, queue depth,
ρ̄/c̄, lockstep); select from the launcher with ``repro.launch.train
--pipeline --num-actors N``.
"""
from repro.configs.base import PipelineConfig
from repro.pipeline.actor import ActorThread, ParamSlot, Rollout, collect_host
from repro.pipeline.learner import make_learner_step
from repro.pipeline.orchestrator import PipelinedRL
from repro.pipeline.queue import CLOSED, QueueClosed, TrajectoryQueue

__all__ = [
    "ActorThread",
    "CLOSED",
    "ParamSlot",
    "PipelineConfig",
    "PipelinedRL",
    "QueueClosed",
    "Rollout",
    "TrajectoryQueue",
    "collect_host",
    "make_learner_step",
]
