"""Asynchronous actor/learner pipeline — the beyond-paper throughput lever.

The paper's framework (``repro.core``) is fully synchronous: acting,
stepping and learning serialize into one program per iteration, so the
accelerator idles whenever the host is on the critical path (Fig. 2's
"50% env time" regime). Following GA3C (Babaeizadeh et al., 2017) and
Accelerated Methods (Stooke & Abbeel, 2018), this subsystem decouples the
two halves behind a bounded queue:

* ``TrajectoryQueue`` — bounded, never-dropping rollout queue with
  actor/learner idle-time accounting (``repro.pipeline.queue``),
* ``ActorThread`` / ``ParamSlot`` / ``collect_host`` — double-buffered
  rollout collection for JAX-native envs and ``HostEnvPool``
  (``repro.pipeline.actor``),
* ``make_learner_step`` — PAAC update with truncated-importance staleness
  correction à la V-trace (``repro.pipeline.learner``),
* ``PipelinedRL`` — orchestrator mirroring ``ParallelRL``'s API
  (``repro.pipeline.orchestrator``).

Configure via ``repro.configs.PipelineConfig`` (queue depth, ρ̄, lockstep);
select from the launcher with ``repro.launch.train --pipeline``.
"""
from repro.configs.base import PipelineConfig
from repro.pipeline.actor import ActorThread, ParamSlot, Rollout, collect_host
from repro.pipeline.learner import make_learner_step
from repro.pipeline.orchestrator import PipelinedRL
from repro.pipeline.queue import CLOSED, TrajectoryQueue

__all__ = [
    "ActorThread",
    "CLOSED",
    "ParamSlot",
    "PipelineConfig",
    "PipelinedRL",
    "Rollout",
    "TrajectoryQueue",
    "collect_host",
    "make_learner_step",
]
