"""Asynchronous multi-actor/learner pipeline — the beyond-paper throughput lever.

The paper's framework (``repro.core``) is fully synchronous: acting,
stepping and learning serialize into one program per iteration, so the
accelerator idles whenever the host is on the critical path (Fig. 2's
"50% env time" regime). Following GA3C (Babaeizadeh et al., 2017) and
IMPALA (Espeholt et al., 2018), this subsystem decouples the two halves
behind a bounded trajectory stream, with N acting replicas feeding one
learner.

The stream runs on one of **two queue planes** (``PipelineConfig.
rollout_plane``), chosen by where the rollout is born:

Device plane — JAX-native envs (the fast path; GA3C's host↔device staging
leak removed)::

    actor 0 ──collect_jit──▶ ┌───────────────────────┐
    actor 1 ──collect_jit──▶ │ DeviceTrajectoryRing  │──get──▶ fused learner
      ...       (device      │  depth d slots, all   │   (one jitted dispatch:
    actor N-1    arrays)───▶ │  payloads on device)  │    update + publish;
        ▲                    └───────────────────────┘    donates params, opt
        │                        slot ownership moves     state & the stale
        │                        to the learner on get    publish buffer)
        │    lease  ┌────────────────────┐   commit             │
        └─acquire/──│ PingPongParamSlot  │◀─(published──────────┘
          release   │  two snapshots     │   copy)
                    └────────────────────┘

Host plane — ``HostEnvPool`` (external emulators; rollouts born in host
memory) and the GA3C-style baseline for JAX envs (``rollout_plane="host"``)::

    actor i ──collect_host──▶ HostStagingRing set ──put──▶ TrajectoryQueue
                 (rows written in place, reused            (numpy payloads)
                  via Rollout.release after the                  │
                  learner consumes the update)                 learner (H2D
                                                               at dispatch)

Mesh plane — the device plane scaled across a multi-device mesh
(``PipelineConfig.mesh_shape = D``, following Stooke & Abbeel 2018's
synchronous multi-GPU regime): a 1-axis ``("data",)`` ``jax.sharding.Mesh``
over ``D`` devices, one actor lane pinned to each. Every lane collects into
its own per-device sub-ring (``MeshTrajectoryRing`` — the device ring grown
one sub-ring per device), and ``get()`` reassembles one seq-aligned
sub-rollout from *every* lane into a single globally-sharded ``Rollout``
(env axis partitioned over ``"data"`` via
``jax.make_array_from_single_device_arrays`` — a zero-copy view, no host
round trip). The learner runs the sharded twin of its update
(``make_learner_step`` → ``make_sharded_learner_step``): params/opt state
replicated, batch sharded, per-device partial gradients all-reduced across
the data axis inside the same fused-publish donated dispatch. CPU CI
exercises the full grid via
``XLA_FLAGS=--xla_force_host_platform_device_count=N``.

Replay plane — the off-policy stream (``PipelineConfig.replay_plane``):
the FIFO device ring swapped for a sampled ``ReplayRing``
(``repro.pipeline.replay_ring``). Same device-resident never-drop payload
contract, but ``put()`` *never blocks* — a full ring evicts its oldest
rollout FIFO-by-ticket instead of backpressuring, so actors never stall on
a slow learner — and ``get()`` *samples* ``replay_batch`` resident
rollouts (uniform, or TD-error-weighted with ``prioritized=True``),
retaining the slots for reuse. One ``get()`` is licensed per fresh rollout
ticket, so the learner loop's cadence (and the lockstep/quota machinery)
is unchanged. It feeds two learners: ``DQNAgent`` via the replay-fed TD
step (``repro.pipeline.offpolicy`` — collection is the jitted ε-greedy
scan, the TD target is staleness-proof by construction) and off-policy
PAAC, whose V-trace clips correct sampled rollouts of staleness ≫ 1.
``SyncReplayDQN`` (same module) is the serial reference driver the
bitwise lockstep pin compares against.

Process plane — *GIL-holding* Python emulators (``PipelineConfig.
actor_backend = "process"``): the host plane's actor replicas moved into
worker subprocesses, because a Python-bound emulator's ``step`` executes
bytecode and serializes every thread on the interpreter lock (A3C's and
Stooke & Abbeel's regime). Each worker rebuilds its env pool from a
picklable ``repro.envs.HostEnvSpec``, collects into
``multiprocessing.shared_memory`` staging sets (``ShmStagingSet``, the
``HostStagingRing`` sizing/lease contract stretched across the process
boundary), and a parent-side ``ProcessActorDrainer`` wraps the shared
blocks into the same ``TrajectoryQueue`` payloads — the learner loop
cannot tell the backends apart. Params broadcast worker-ward through a
shared-memory ping-pong slot (``ShmParamSlot``) speaking
``PingPongParamSlot``'s reserve/commit protocol (``repro.pipeline.shm`` /
``repro.pipeline.worker``).

The three knobs compose along a **valid matrix** (anything else raises a
``ValueError`` at config or construction time): the thread backend drives
any plane; the process backend forces the host plane (its rollouts are
born in worker shared memory — ``rollout_plane="device"``/``"mesh"`` or
``mesh_shape > 1`` with it is a contradiction); the mesh plane requires
JAX-native envs and runs exactly one lane per mesh device (``num_actors``
must be 1 or ``mesh_shape``); and a mesh-sharded rollout that leaks onto
the host ``TrajectoryQueue`` raises loudly at ``put()`` rather than
silently forcing a cross-device gather. See ``PipelineConfig``'s docstring
for the full table.

Each replica owns a private slice of the environments — a single env's axis
is split N ways (``HostEnvPool.shard`` / ``narrow_vector_env``), or a list
of envs gives each replica its own full pool (GA3C's n_actors sweep). Every
queue payload (``Rollout``) is tagged ``(actor_id, seq, behavior_version)``
so the learner can attribute idle time and staleness per replica, and so the
tests can prove no trajectory is ever dropped or learned twice.

Staleness model: the learner stamps params with a monotone version (one per
update) published through the shared param slot; each actor leases the
newest version around its collect, and a rollout consumed at learner version
v carries ``staleness = v - behavior_version``. The queue depth bounds the
number of rollouts in flight *collectively* (backpressure blocks producers;
nothing is dropped), so staleness ≤ depth + num_actors in steady state. The
learner compensates with full V-trace (``rho_bar``/``c_bar`` clips): ρ̄
bounds each step's importance-weighted TD error and the c̄ product bounds
backward propagation through the n-step targets, keeping deep queues
unbiased; infinite clips compile the correction out exactly (the
synchronous PAAC update, pinned bitwise by the lockstep tests on both
planes).

Donation safety: the learner's working params/opt state are private —
actors only ever lease the ping-pong snapshots — so the fused step donates
params, opt state and the stale publish buffer (each aliasing a
shape-identical output) and runs alloc-free in steady state, while ring
slots are consumed under sole ownership and return to the allocator as the
update retires them. The regression tests pin that the donated buffers
really are deleted and that the actor-facing snapshots never are.

Modules:

* ``TrajectoryQueue`` — bounded, never-dropping multi-producer rollout queue
  for host payloads, with idle-time accounting and prompt close-on-abort
  (``repro.pipeline.queue``),
* ``DeviceTrajectoryRing`` — its device-plane twin: ticket-ordered
  preallocated slots whose payloads never leave the accelerator
  (``repro.pipeline.ring``),
* ``MeshTrajectoryRing`` — the device ring grown per-device sub-rings for
  the mesh plane, reassembling lane sub-rollouts into globally-sharded
  payloads (``repro.pipeline.ring``),
* ``ReplayRing`` — the sampled off-policy twin: never-block evicting
  ``put``, retained-slot sampling ``get``, optional TD-error priorities
  (``repro.pipeline.replay_ring``),
* ``make_dqn_collect_fn`` / ``make_dqn_learner_step`` / ``SyncReplayDQN``
  — ε-greedy collection, the replay-fed TD learner step, and the serial
  reference driver for the replay plane (``repro.pipeline.offpolicy``),
* ``ActorThread`` / ``ParamSlot`` / ``PingPongParamSlot`` /
  ``HostStagingRing`` / ``collect_host`` — leased double-buffered rollout
  collection for JAX-native envs and ``HostEnvPool``
  (``repro.pipeline.actor``),
* ``make_learner_step`` — PAAC update with full V-trace staleness
  correction, optionally fused with the param publish for full donation;
  ``make_sharded_learner_step`` is its mesh twin (jit-with-shardings,
  gradients all-reduced over the data axis — ``repro.pipeline.learner``),
* ``PipelinedRL`` — orchestrator mirroring ``ParallelRL``'s API
  (``repro.pipeline.orchestrator``).

Observability: every plane's hot path records bounded-ring monotonic-clock
spans (``repro.telemetry`` — collect, queue.put_wait, queue.get_wait,
lease, publish, learner.update, shm.copy, mesh.reassemble), and the
``RunResult`` idle accounting (``put_wait_s``/``get_wait_s``/
``per_actor_idle_s``) is *derived from* those spans' per-category totals,
so the numbers the benchmarks report and the trace the hub exports can
never disagree. ``PipelineConfig.trace_path``/``metrics_jsonl``/
``stall_timeout_s`` turn on the Chrome trace export, the JSONL liveness
heartbeat, and the stall watchdog (see ``docs/observability.md``).

Configure via ``repro.configs.PipelineConfig`` (num_actors, queue depth,
ρ̄/c̄, lockstep, rollout_plane, actor_backend, mesh_shape, plus the
observability knobs above); select from the launcher with
``repro.launch.train --pipeline --num-actors N --rollout-plane device`` /
``--actor-backend process`` / ``--mesh D`` / ``--trace out.json``.
"""
from repro.configs.base import PipelineConfig
from repro.pipeline.actor import (
    ActorBase,
    ActorThread,
    HostStagingRing,
    ParamSlot,
    PingPongParamSlot,
    Rollout,
    StagingSet,
    collect_host,
)
from repro.pipeline.faults import FaultInjector, FaultPlan, InjectedActorFault
from repro.pipeline.learner import make_learner_step, make_sharded_learner_step
from repro.pipeline.offpolicy import (
    SyncReplayDQN,
    make_dqn_collect_fn,
    make_dqn_learner_step,
)
from repro.pipeline.orchestrator import PipelinedRL
from repro.pipeline.queue import CLOSED, QueueClosed, TrajectoryQueue
from repro.pipeline.replay_ring import ReplayRing
from repro.pipeline.ring import DeviceTrajectoryRing, MeshTrajectoryRing
from repro.pipeline.shm import ShmParamSlot, ShmParamView, ShmStagingSet
from repro.pipeline.supervisor import ActorSupervisor, QuotaLedger
from repro.pipeline.worker import ProcessActorDrainer, ProcessActorPlane

__all__ = [
    "ActorBase",
    "ActorSupervisor",
    "ActorThread",
    "CLOSED",
    "DeviceTrajectoryRing",
    "FaultInjector",
    "FaultPlan",
    "HostStagingRing",
    "InjectedActorFault",
    "MeshTrajectoryRing",
    "ParamSlot",
    "PingPongParamSlot",
    "PipelineConfig",
    "PipelinedRL",
    "ProcessActorDrainer",
    "ProcessActorPlane",
    "QueueClosed",
    "QuotaLedger",
    "ReplayRing",
    "Rollout",
    "ShmParamSlot",
    "SyncReplayDQN",
    "ShmParamView",
    "ShmStagingSet",
    "StagingSet",
    "TrajectoryQueue",
    "collect_host",
    "make_dqn_collect_fn",
    "make_dqn_learner_step",
    "make_learner_step",
    "make_sharded_learner_step",
]
