"""Actor supervision: respawn, degrade, or fail — but never hang.

The pre-supervisor pipeline is deliberately fail-fast: a dying replica
hard-``close()``s the trajectory stream so the learner and its siblings
unwind promptly (``ActorBase.run``'s epilogue). That is the right default
for bitwise reproducibility work, but a long training run on flaky envs
wants the GA3C/IMPALA operational posture instead: a crashed actor is an
*event*, not a verdict. ``PipelineConfig.elastic=True`` arms this module.

Two pieces:

* ``QuotaLedger`` — the run's work-conservation account. ``outstanding``
  is total quota not yet produced anywhere; a dead replica's unproduced
  remainder is ``orphan``ed into an unassigned pool that surviving
  replicas ``wait_for_work`` on *instead of checking out* when their own
  quota is done. The ledger is what closes the respawn-vs-``producer_done``
  race: a survivor cannot check out while a dead sibling's quota is still
  outstanding, so the stream never loses its last producer to a timing
  window.

* ``ActorSupervisor`` — the recovery policy, run *on the dying replica's
  own thread* (``ActorBase.run`` consults it before hard-closing, so the
  thread is still alive — and still counted by the learner's liveness
  checks — for the whole recovery episode). Per slot, under
  ``restart_budget``: sleep the exponential backoff, respawn a replacement
  with a fresh ``(actor_id, seq)`` epoch (it re-leases current params on
  its first acquire, and inherits the dead replica's producer slot — no
  queue accounting changes hands). Past the budget: orphan the remainder
  to the ledger, check the slot out, and let the run degrade to fewer
  actors. Only when *no* live replica remains to absorb the work does the
  supervisor declare the fault fatal and fall back to the fail-fast
  close. Every episode is a ``fault.detect`` / ``fault.respawn`` /
  ``fault.giveup`` span on the supervisor's trace track plus a heartbeat
  counter.

The mesh plane never gets a supervisor: one dead lane leaves every
subsequent globally-sharded batch unassemblable, so respawn-into-a-fresh-
epoch cannot preserve its semantics. ``PipelineConfig`` rejects the
combination; the mesh plane stays fail-fast (see docs/fault_tolerance.md).
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Dict, List, Optional

from repro.analysis.lockcheck import make_condition, make_lock
from repro.pipeline.faults import InjectedActorFault
from repro.telemetry.spans import (
    FAULT_DETECT,
    FAULT_GIVEUP,
    FAULT_RESPAWN,
)
from repro.utils import get_logger

__all__ = ["QuotaLedger", "ActorSupervisor"]

log = get_logger("pipeline")


class QuotaLedger:
    """Work-conservation account for one elastic ``run()``.

    ``outstanding`` = payloads the run still owes the learner, wherever
    they come from; ``unassigned`` = orphaned quota awaiting a claimant.
    Replicas call ``produced()`` per successful put; the supervisor calls
    ``orphan(n)`` when it degrades a slot; survivors block in
    ``wait_for_work`` at the end of their own quota until either orphaned
    work appears (claim it, keep producing) or no work can remain (check
    out). ``abort()`` releases every waiter (fatal fault / learner stop).
    """

    def __init__(self, total: int):
        self._cond = make_condition("quota_ledger.cond")
        self._outstanding = int(total)
        self._unassigned = 0
        self._aborted = False

    def produced(self) -> None:
        with self._cond:
            self._outstanding -= 1
            self._cond.notify_all()

    def orphan(self, n: int) -> None:
        """Credit a dead replica's unproduced remainder to the pool."""
        if n <= 0:
            return
        with self._cond:
            self._unassigned += int(n)
            self._cond.notify_all()

    def claim(self) -> int:
        """Take the whole unassigned pool (respawn / continuation path)."""
        with self._cond:
            n = self._unassigned
            self._unassigned = 0
            return n

    def wait_for_work(self, stop: Optional[Callable[[], bool]] = None,
                      tick: float = 0.1) -> int:
        """Block until orphaned quota exists (claim and return 1) or no
        work can remain — outstanding drained, aborted, or ``stop()`` —
        (return 0). Claiming one unit at a time spreads a degrade across
        every surviving replica instead of dogpiling the first waiter."""
        with self._cond:
            while True:
                if self._aborted or self._outstanding <= 0:
                    return 0
                if self._unassigned > 0:
                    self._unassigned -= 1
                    return 1
                if stop is not None and stop():
                    return 0
                self._cond.wait(tick)

    def abort(self) -> None:
        with self._cond:
            self._aborted = True
            self._cond.notify_all()

    @property
    def outstanding(self) -> int:
        with self._cond:
            return self._outstanding


class ActorSupervisor:
    """Recovery policy for dying actor replicas (see module docstring).

    ``respawner(dead, new_actor_id, remaining)`` is the backend-specific
    factory the orchestrator provides: build **and start** a replacement
    replica covering ``remaining`` payloads under the fresh epoch id, or
    return ``None`` to decline (the episode then degrades). The supervisor
    owns the dynamic replica list — the orchestrator's join/stop/error
    sweeps run over ``all_actors()``.
    """

    def __init__(self, queue, ledger: QuotaLedger,
                 respawner: Callable, restart_budget: int = 1,
                 backoff_s: float = 0.05, telemetry=None):
        self._queue = queue
        self._ledger = ledger
        self._respawner = respawner
        self._budget = int(restart_budget)
        self._backoff = float(backoff_s)
        self._telemetry = telemetry
        # locked: episodes can fire from several dying threads at once
        self._em = (telemetry.emitter("supervisor", locked=True)
                    if telemetry is not None else None)
        self._lock = make_lock("supervisor.lock")
        self._actors: List = []
        self._attempts: Dict[int, int] = {}  # slot -> respawns so far
        self._next_id = 0
        self._shutdown = False
        self.fatal = None  # the replica whose death ended the run, if any
        # audit trail of (kind, slot, actor_id) episodes for tests/logs
        self.episodes: List[tuple] = []

    # -- replica registry -----------------------------------------------------
    def register(self, actor) -> None:
        with self._lock:
            self._actors.append(actor)
            self._next_id = max(self._next_id, actor.actor_id + 1)
        actor.supervisor = self

    def all_actors(self) -> List:
        with self._lock:
            return list(self._actors)

    def slot_actor(self, slot: int):
        """The newest replica occupying ``slot`` (epochs shadow earlier)."""
        with self._lock:
            for a in reversed(self._actors):
                if a.slot_index == slot:
                    return a
        return None

    def shutdown(self) -> None:
        """Disarm recovery (run teardown): deaths stop respawning."""
        with self._lock:
            self._shutdown = True

    def _count(self, name: str) -> None:
        if self._telemetry is not None:
            self._telemetry.counter_add(name, 1)

    def _span(self, cat: int, t0: float) -> None:
        if self._em is not None:
            self._em.record(cat, t0)

    # -- the recovery episode (runs on the dying replica's thread) -----------
    def on_actor_error(self, actor) -> bool:
        """Handle ``actor``'s death. True = recovered (respawned replica
        inherits the producer slot, or the slot was checked out after
        orphaning its quota); False = fatal, caller falls back to the
        fail-fast ``queue.close()``."""
        t0 = time.perf_counter()
        kind = ("injected" if isinstance(actor.error, InjectedActorFault)
                or "FaultPlan" in str(actor.error) else "crash")
        remaining = max(int(actor.assigned) - int(actor.produced), 0)
        self._count("fault.detect")
        self._span(FAULT_DETECT, t0)
        log.warning(
            "supervisor: actor %d (slot %d) died after %d/%d rollouts "
            "(%s): %s", actor.actor_id, actor.slot_index, actor.produced,
            actor.assigned, kind, actor.error)
        with self._lock:
            if self._shutdown:
                return False
            attempts = self._attempts.get(actor.slot_index, 0)
            can_respawn = attempts < self._budget
            if can_respawn:
                self._attempts[actor.slot_index] = attempts + 1
                new_id = self._next_id
                self._next_id += 1
        if can_respawn:
            # exponential backoff on the dying thread: the replica stays
            # alive (and visibly so, for the learner's liveness checks)
            # for the whole recovery episode
            time.sleep(self._backoff * (2 ** attempts))
            with self._lock:
                disarmed = self._shutdown
            replacement = None
            if not disarmed:
                t1 = time.perf_counter()
                try:
                    replacement = self._respawner(actor, new_id, remaining)
                except Exception:
                    log.exception(
                        "supervisor: respawn of slot %d failed — degrading",
                        actor.slot_index)
                if replacement is not None:
                    with self._lock:
                        self._actors.append(replacement)
                    replacement.supervisor = self
                    self._count("fault.respawn")
                    self._span(FAULT_RESPAWN, t1)
                    self.episodes.append(
                        ("respawn", actor.slot_index, new_id))
                    log.warning(
                        "supervisor: respawned slot %d as actor %d "
                        "(attempt %d/%d, %d rollouts remaining)",
                        actor.slot_index, new_id, attempts + 1,
                        self._budget, remaining)
                    # the replacement inherits this replica's producer
                    # slot: neither close nor producer_done here
                    return True
        # give up on the slot: degrade if any sibling can absorb the work
        t2 = time.perf_counter()
        self._count("fault.giveup")
        self._span(FAULT_GIVEUP, t2)
        self.episodes.append(("giveup", actor.slot_index, actor.actor_id))
        others = [a for a in self.all_actors()
                  if a is not actor and a.is_alive()]
        if others or remaining == 0:
            self._ledger.orphan(remaining)
            self._queue.producer_done()  # check the dead slot out
            log.warning(
                "supervisor: gave up on slot %d — %d rollouts reassigned, "
                "run degrades to %d live actor(s)",
                actor.slot_index, remaining, len(others))
            return True
        self.fatal = actor
        self._ledger.abort()
        log.error(
            "supervisor: actor %d was the last live replica — aborting run",
            actor.actor_id)
        return False
