"""Deterministic fault injection for the pipeline's recovery paths.

Fault tolerance that is only exercised by real crashes is fault tolerance
that rots: the respawn/degrade/resume machinery in
``repro.pipeline.supervisor`` and the checkpoint plane must be drivable
from a test, bit-reproducibly, on every CI run. ``FaultPlan`` is that
driver — a frozen description of *exactly which* fault fires *exactly
when*, carried on ``PipelineConfig.fault_plan`` and armed once per
``PipelinedRL.run()``:

* ``kills`` — kill actor slot *k* after it has produced *n* rollouts.
  Mode ``"error"`` raises ``InjectedActorFault`` inside the replica (the
  env-crash shape: thread actors die on their own thread; process workers
  report a traceback and survive for reuse). Mode ``"exit"`` hard-exits
  the worker process (``os._exit`` — the segfault/OOM-kill shape the
  drainer's liveness poll detects as silent death); on the thread backend,
  where a thread cannot be killed from outside, it degrades to ``"error"``.
* ``lease_delays`` — sleep before slot *k*'s param acquire on rollout
  *n*: widens the lease window so reserve/timeout races become schedulable.
* ``drop_release`` — skip the learner's ``payload.release()`` once at
  iteration *n*: proves the ``queue_depth + 2`` staging-ring sizing
  absorbs one leaked lease instead of deadlocking the producer.
* ``stall_learner`` — sleep *s* seconds in the learner loop before update
  *n*: the slow-learner regime (backpressure, watchdog, crash-during-
  blocked-put scheduling).

Every entry is **one-shot**: the runtime ``FaultInjector`` marks it fired,
so a respawned replica re-collecting the same rollout index does not die
again — which is precisely what lets a test assert "kill once, recover,
finish the full quota". The plan object itself stays immutable/hashable
(it rides a frozen config).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Tuple

__all__ = ["FaultPlan", "FaultInjector", "InjectedActorFault"]

_KILL_MODES = ("error", "exit")


class InjectedActorFault(RuntimeError):
    """The planned failure a ``FaultPlan.kills`` entry raises inside an
    actor replica. Distinct type so the supervisor (and tests) can tell a
    scheduled fault from a genuine env/plumbing crash."""


@dataclass(frozen=True)
class FaultPlan:
    """Immutable schedule of pipeline faults (see module docstring).

    Field formats (all tuples — the plan rides a frozen, hashable config):

    * ``kills``: ``(slot, after_rollouts, mode)`` — kill the replica on
      slot ``slot`` when its produced-rollout count reaches
      ``after_rollouts`` (0 = before its first rollout); ``mode`` is
      ``"error"`` (raise in-replica) or ``"exit"`` (hard process exit).
    * ``lease_delays``: ``(slot, rollout, seconds)`` — sleep before the
      slot's param acquire on local rollout index ``rollout``.
    * ``drop_release``: learner iteration indices whose payload release
      is skipped (once each).
    * ``stall_learner``: ``(iteration, seconds)`` — sleep in the learner
      loop before that update dispatches.
    """

    kills: Tuple[Tuple[int, int, str], ...] = ()
    lease_delays: Tuple[Tuple[int, int, float], ...] = ()
    drop_release: Tuple[int, ...] = ()
    stall_learner: Tuple[Tuple[int, float], ...] = ()

    def __post_init__(self):
        for slot, after, mode in self.kills:
            if slot < 0 or after < 0:
                raise ValueError(
                    f"FaultPlan.kills entry ({slot}, {after}, {mode!r}): "
                    "slot and after_rollouts must be >= 0")
            if mode not in _KILL_MODES:
                raise ValueError(
                    f"FaultPlan.kills mode must be one of {_KILL_MODES}, "
                    f"got {mode!r}")
        for slot, rollout, seconds in self.lease_delays:
            if slot < 0 or rollout < 0 or seconds < 0:
                raise ValueError(
                    f"FaultPlan.lease_delays entry ({slot}, {rollout}, "
                    f"{seconds}): all fields must be >= 0")
        for it in self.drop_release:
            if it < 0:
                raise ValueError(
                    f"FaultPlan.drop_release iteration must be >= 0, got {it}")
        for it, seconds in self.stall_learner:
            if it < 0 or seconds < 0:
                raise ValueError(
                    f"FaultPlan.stall_learner entry ({it}, {seconds}): "
                    "iteration and seconds must be >= 0")


class FaultInjector:
    """Per-run arming of a ``FaultPlan``: fires each entry exactly once.

    Thread-safe — entries are consulted from actor threads, drainer
    threads and the learner loop concurrently. A fired entry never fires
    again within the run, so a respawned replica replaying the fatal
    rollout index sails through (the recovery test contract).
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._fired: set = set()
        self._lock = threading.Lock()

    def _claim(self, token) -> bool:
        with self._lock:
            if token in self._fired:
                return False
            self._fired.add(token)
            return True

    # -- actor-side hooks ----------------------------------------------------
    def maybe_kill(self, slot: int, produced: int) -> None:
        """Raise the planned fault for ``slot`` once its produced count
        matches. Thread backend only — ``"exit"`` degrades to ``"error"``
        here (a thread cannot be hard-killed from outside the interpreter;
        the process backend gets true hard exits via ``kills_for_worker``).
        """
        for i, (s, after, mode) in enumerate(self.plan.kills):
            if s == slot and after == produced and self._claim(("kill", i)):
                raise InjectedActorFault(
                    f"FaultPlan: killed actor slot {slot} after "
                    f"{produced} rollouts (mode={mode!r})"
                )

    def kills_for_worker(self, slot: int) -> Tuple[Tuple[int, str], ...]:
        """Claim and return ``(after_rollouts, mode)`` entries to ship in a
        worker's run command — the child executes them in its own process
        (including true ``os._exit`` hard kills). Claimed here so a
        respawned worker's fresh run command carries no faults."""
        out = []
        for i, (s, after, mode) in enumerate(self.plan.kills):
            if s == slot and self._claim(("kill", i)):
                out.append((after, mode))
        return tuple(out)

    def lease_delay(self, slot: int, rollout: int) -> None:
        for i, (s, r, seconds) in enumerate(self.plan.lease_delays):
            if s == slot and r == rollout and self._claim(("delay", i)):
                time.sleep(seconds)

    # -- learner-side hooks --------------------------------------------------
    def drop_release(self, iteration: int) -> bool:
        """True exactly once per planned iteration: the learner skips this
        payload's ``release()`` (a deliberately leaked staging lease)."""
        for i, it in enumerate(self.plan.drop_release):
            if it == iteration and self._claim(("drop", i)):
                return True
        return False

    def stall_learner(self, iteration: int) -> None:
        for i, (it, seconds) in enumerate(self.plan.stall_learner):
            if it == iteration and self._claim(("stall", i)):
                time.sleep(seconds)
