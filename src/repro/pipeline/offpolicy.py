"""DQN through the pipeline: ε-greedy collection + replay-fed learner step.

The paper's framework claims algorithm agnosticism (§3); the pipeline
cashes the off-policy half of that claim here. Three pieces:

* ``make_dqn_collect_fn`` — the acting half of the scan-based DQN train
  step (``repro.core.agents.dqn``) detached into a standalone jittable
  rollout collector, exactly as ``make_collect_fn`` detaches PAAC acting:
  one jitted program collects ``t_max`` ε-greedy steps whose output feeds
  the device-resident ``ReplayRing`` without touching host memory. The ε
  schedule is driven by the *rollout index* the caller threads through
  (each actor replica counts its own rollouts); in lockstep mode that
  index equals the learner step, matching the synchronous schedule.
* ``make_dqn_learner_step`` — the learning half on a *sampled rollout*
  batch: flatten the ``(T, E)`` trajectory into ``T·E`` transitions
  (successor observations reconstructed from the time axis plus the
  bootstrap ``last_obs``), one double-batched TD update against the target
  network, periodic hard target sync. Same fused-publish/donation shape as
  ``make_learner_step``: the extra ``target``/``updates`` state rides the
  signature as explicit donated arguments (the orchestrator keeps them
  learner-private, like params/opt state).
* ``SyncReplayDQN`` — the *synchronous replay reference*: the same jitted
  collect, the same ``ReplayRing`` (same sample seed), the same learner
  step, driven serially by one thread (collect → put → get → update).
  This is the driver the bitwise lockstep pin compares against — the
  pipelined run must reproduce it bit for bit, proving the thread/queue
  machinery adds zero numerics. (The *scan-based* ``ParallelRL`` DQN is a
  different program — per-transition replay, interleaved acting/learning
  RNG — and is the benchmark's throughput baseline, not the bitwise
  reference.)

DQN needs no V-trace: Q-learning's TD target is defined off-policy, so
stale rollouts are corrected by construction. The PAAC/PPO replay path
reuses ``make_learner_step``'s V-trace clips instead (the acting-time
``Transition.logp`` recorded here is the ε-greedy behaviour policy's, so
importance-corrected learners could also consume these rollouts).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.agents.dqn import dqn_loss, dqn_sync_target
from repro.core.framework import (
    MetricsAccumulator,
    RunResult,
    init_rl_common,
)
from repro.core.rollout import Transition
from repro.models import policy_apply
from repro.pipeline.replay_ring import ReplayRing

__all__ = ["make_dqn_collect_fn", "make_dqn_learner_step", "SyncReplayDQN"]


def make_dqn_collect_fn(agent, env, t_max: int) -> Callable:
    """Standalone jittable ε-greedy rollout collector for ``DQNAgent``.

    Returns ``collect(params, env_state, obs, key, rollout_idx) ->
    (env_state, last_obs, key, traj)`` — the acting scan of the synchronous
    DQN train step with the replay writes removed (the pipeline's ring
    stores whole rollouts instead). Per step the key splits
    ``(k_eps, k_act, k_env)`` exactly like the scan body; ε comes from
    ``agent.epsilon(rollout_idx)``. ``Transition.value`` carries the greedy
    Q-value and ``Transition.logp`` the ε-greedy behaviour log-prob
    ``log((1−ε)·1[a = argmax Q] + ε/A)`` so the payload keeps the canonical
    layout (and stays consumable by importance-corrected learners).
    """
    cfg = agent.cfg

    def q_of(params, obs):
        q, _, _ = policy_apply(params, cfg, obs)
        return q

    def collect(params, env_state, obs, key, rollout_idx):
        eps = agent.epsilon(rollout_idx)

        def body(carry, _):
            env_state, obs, key = carry
            key, k_eps, k_act, k_env = jax.random.split(key, 4)
            q = q_of(params, obs)
            greedy = jnp.argmax(q, axis=-1)
            n_actions = q.shape[-1]
            rand = jax.random.randint(k_act, greedy.shape, 0, n_actions)
            explore = jax.random.uniform(k_eps, greedy.shape) < eps
            action = jnp.where(explore, rand, greedy)
            value = jnp.max(q, axis=-1)
            logp = jnp.log(
                jnp.where(action == greedy, 1.0 - eps + eps / n_actions,
                          eps / n_actions)
            )
            env_state, next_obs, reward, done = env.step(
                env_state, action, k_env)
            tr = Transition(obs, action, reward, done, value, logp)
            return (env_state, next_obs, key), tr

        (env_state, obs, key), traj = jax.lax.scan(
            body, (env_state, obs, key), None, length=t_max
        )
        return env_state, obs, key, traj

    return collect


def make_dqn_learner_step(agent, optimizer, lr_schedule,
                          fused_publish: bool = False) -> Callable:
    """Build the replay-fed DQN learner's jittable update step.

    ``fused_publish=False``:
    ``(params, opt_state, target, updates, traj, last_obs, step) ->
    (params, opt_state, target, updates, metrics)``.
    ``fused_publish=True`` appends the donation-ready publish exactly like
    ``make_learner_step`` (extra ``publish_dst`` argument, extra
    ``published`` output); the orchestrator jits it with
    ``donate_argnums=(0, 1, 2, 3, 7)`` — params, opt state, target and the
    updates counter are all learner-private and shape-alias their outputs.

    The sampled rollout is time-major ``(T, E, …)``; successor observations
    are ``obs`` shifted one step with the bootstrap ``last_obs`` closing
    the window, flattened to a ``T·E``-transition double-batched TD update
    (the same ``dqn_loss`` the synchronous scan step evaluates).
    """
    cfg, hp = agent.cfg, agent.hp

    def _update(params, opt_state, target, updates, traj, last_obs, step):
        T, E = traj.action.shape
        next_obs = jnp.concatenate([traj.obs[1:], last_obs[None]], axis=0)

        def flat(x):
            return x.reshape((T * E,) + x.shape[2:])

        batch = {
            "obs": flat(traj.obs),
            "action": flat(traj.action),
            "reward": flat(traj.reward),
            "next_obs": flat(next_obs),
            "done": flat(traj.done),
        }
        (loss, metrics), grads = jax.value_and_grad(
            dqn_loss, has_aux=True)(params, target, batch, cfg, hp.gamma)
        lr = lr_schedule(step)
        params, opt_state = optimizer.update(grads, opt_state, params, lr)
        target, updates = dqn_sync_target(target, params, updates,
                                          hp.target_sync)
        metrics = dict(metrics)
        metrics["loss"] = loss
        # |TD|-mean as the batch priority signal for prioritized replay
        metrics["td_abs"] = jnp.sqrt(loss)
        metrics["reward_sum"] = jnp.sum(traj.reward)
        metrics["episodes"] = jnp.sum(traj.done)
        return params, opt_state, target, updates, metrics

    if not fused_publish:
        return _update

    def learner_step(params, opt_state, target, updates, traj, last_obs,
                     step, publish_dst):
        del publish_dst  # donation target only: its buffers back `published`
        params, opt_state, target, updates, metrics = _update(
            params, opt_state, target, updates, traj, last_obs, step
        )
        published = jax.tree_util.tree_map(lambda a: a.copy(), params)
        return params, opt_state, target, updates, published, metrics

    return learner_step


class SyncReplayDQN:
    """Synchronous replay-DQN reference driver (the bitwise pin's baseline).

    ``ParallelRL``'s API (``run(iterations) -> RunResult``) over exactly
    the components the replay-plane ``PipelinedRL`` schedules
    asynchronously: the jitted ``make_dqn_collect_fn`` collector, a
    ``ReplayRing`` seeded identically, and the jitted
    ``make_dqn_learner_step`` — executed serially on the calling thread,
    one collect → ``put`` → ``get`` (sample) → update per iteration. A
    depth-1 lockstep pipelined run with the same seed and replay shape
    must reproduce this driver's params and metrics *bit for bit* (the
    test-suite pin): the RNG layout (``init_rl_common``), the per-rollout
    ε index, the ring's ``fold_in`` sample stream and the update math are
    all shared, so the only thing the pipeline adds is scheduling.
    """

    def __init__(self, env, agent, *, optimizer: str = "rmsprop",
                 lr_schedule=None, seed: int = 0, replay_capacity: int = 64,
                 replay_batch: int = 1, prioritized: bool = False):
        self.env = env
        self.agent = agent
        (self.optimizer, self.lr_schedule, self.key, k_env, self.params,
         self.opt_state) = init_rl_common(env, agent, optimizer, lr_schedule,
                                          seed)
        self.env_state = env.reset(k_env)
        self.obs = env.observe(self.env_state)
        # learner-private target tree: a copy, so donating params can never
        # delete the target's buffers out from under the next update
        self._target = jax.tree_util.tree_map(lambda a: a.copy(), self.params)
        self._updates = jnp.zeros((), jnp.int32)
        self._seed = seed
        self._capacity = replay_capacity
        self._batch = replay_batch
        self._prioritized = prioritized
        self._collect_jit = jax.jit(
            make_dqn_collect_fn(agent, env, agent.hp.t_max))
        self._update_step = jax.jit(
            make_dqn_learner_step(agent, self.optimizer, self.lr_schedule),
            donate_argnums=(1,),
        )
        self.total_steps = 0
        self._rollouts = 0  # lifetime rollout counter: the ε-schedule index
        self._steps_per_iter = env.n_envs * agent.hp.t_max
        self.ring: ReplayRing | None = None  # per-run; kept for inspection

    def run(self, iterations: int, log_every: int = 0) -> RunResult:
        from repro.pipeline.actor import Rollout

        del log_every
        # a fresh ring per run, exactly like the pipeline's per-run queue:
        # replay residency is a run-scoped resource, and the sample stream
        # is a pure function of (seed, within-run consume index) — what the
        # bitwise pin against the pipelined twin depends on
        self.ring = ReplayRing(
            capacity=self._capacity, batch_size=self._batch, producers=1,
            prioritized=self._prioritized, sample_seed=self._seed,
        )
        acc = MetricsAccumulator()
        step_arr = jnp.asarray(self.total_steps, jnp.int32)
        for _ in range(iterations):
            i = self._rollouts
            self.env_state, self.obs, self.key, traj = self._collect_jit(
                self.params, self.env_state, self.obs, self.key,
                jnp.asarray(i, jnp.int32),
            )
            self._rollouts = i + 1
            self.ring.put(Rollout(traj, self.obs, behavior_version=i,
                                  actor_id=0, seq=i))
            payload = self.ring.get()
            (self.params, self.opt_state, self._target, self._updates,
             metrics) = self._update_step(
                self.params, self.opt_state, self._target, self._updates,
                payload.traj, payload.last_obs, step_arr,
            )
            if self._prioritized:
                self.ring.update_priorities(
                    self.ring.last_sampled,
                    [float(metrics["td_abs"])] * len(self.ring.last_sampled),
                )
            step_arr = step_arr + 1
            self.total_steps += self._steps_per_iter
            acc.update(dict(metrics))
        return acc.result(self.total_steps, self._steps_per_iter)
