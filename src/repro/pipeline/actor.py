"""The pipeline's acting half: rollout collection decoupled from learning.

Two collection paths, mirroring the two environment regimes of
``repro.core.framework``:

* ``make_collect_fn`` (re-exported from ``repro.core.rollout``) — JAX-native
  ``VectorEnv``: one jitted program collects a full ``t_max`` rollout.
* ``collect_host`` — ``HostEnvPool``: jitted batched acting interleaved with
  threaded host env stepping (paper §3's master/worker loop, run on the
  actor thread). While the env workers sleep in C/syscalls the GIL is
  released, so the learner's jitted update runs concurrently — this is the
  overlap that recovers the paper's Fig. 2 "50% env time".

``ParamSlot`` is the double buffer between learner and actor: the learner
publishes fresh params (a reference swap — device arrays are immutable) and
the actor reads the latest snapshot before each rollout. ``Rollout`` is the
queue payload: the trajectory, the bootstrap observation, and the behaviour
params version (staleness = learner_version − behaviour_version).
"""
from __future__ import annotations

import threading
import time
from queue import Full
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.rollout import Transition, make_collect_fn  # noqa: F401
from repro.pipeline.queue import QueueClosed

__all__ = [
    "ParamSlot",
    "Rollout",
    "ActorThread",
    "collect_host",
    "make_collect_fn",
]


class ParamSlot:
    """Versioned single-slot param exchange (learner → actor).

    The learner ``publish``es params after every update; the actor ``read``s
    whatever is newest when it starts a rollout. ``wait_for`` lets a
    lock-stepped actor block until the learner has caught up — synchronous
    semantics through the pipelined code path.
    """

    def __init__(self, params: Any, version: int = 0):
        self._params = params
        self._version = version
        self._cond = threading.Condition()

    def publish(self, params: Any, version: int) -> None:
        with self._cond:
            self._params = params
            self._version = version
            self._cond.notify_all()

    def read(self) -> Tuple[Any, int]:
        with self._cond:
            return self._params, self._version

    def wait_for(self, version: int, timeout: Optional[float] = None) -> bool:
        with self._cond:
            return self._cond.wait_for(
                lambda: self._version >= version, timeout=timeout
            )

    @property
    def version(self) -> int:
        with self._cond:
            return self._version


class Rollout(NamedTuple):
    """Queue payload: one collected rollout plus its provenance.

    ``actor_id``/``seq`` tag which replica produced the rollout and where it
    sits in that replica's stream — the learner uses them to attribute
    staleness and idle time per actor, and the pipeline tests to prove every
    ``(actor_id, seq)`` is learned from exactly once."""

    traj: Transition  # time-major (T, E, ...)
    last_obs: jnp.ndarray  # (E, *obs_shape) — bootstrap observation
    behavior_version: int  # params version the actor acted with
    actor_id: int = 0  # which actor replica collected it
    seq: int = 0  # per-actor rollout sequence number


def make_host_act_step(act_fn: Callable) -> Callable:
    """Fuse one acting step — forward, sample, behaviour logp — into a
    single jitted program so the host loop pays one dispatch per step."""

    @jax.jit
    def act_step(params, obs, key):
        key, k_act = jax.random.split(key)
        logits, value = act_fn(params, obs)
        action = jax.random.categorical(k_act, logits)
        logp = jnp.take_along_axis(
            jax.nn.log_softmax(logits), action[:, None], axis=1
        )[:, 0]
        return action, value, logp, key

    return act_step


def collect_host(act_step: Callable, pool, params, obs, key, t_max: int):
    """Collect ``t_max`` steps from a ``HostEnvPool`` (paper §3 loop).

    ``act_step`` is the jitted fused acting step (``make_host_act_step``);
    env stepping runs on the pool's worker threads. Returns
    ``(next_obs, key, traj, last_obs)`` with ``traj`` a time-major
    ``Transition`` of *host* (numpy) arrays — including the behaviour
    log-prob the learner's importance correction needs — transferred to the
    device only when the learner dispatches its update.
    """
    # accumulate on the host (numpy): the only device traffic per step is the
    # fused act_step — extra device ops here would queue behind the learner's
    # update and stretch the rollout. The trajectory stays host-side; the
    # H2D transfer happens when the learner dispatches its update.
    obs_l, act_l, rew_l, done_l, val_l, logp_l = [], [], [], [], [], []
    obs_np = np.asarray(obs)
    for _ in range(t_max):
        action, value, logp, key = act_step(params, obs_np, key)
        action_np = np.asarray(action)
        next_obs, reward, done = pool.step_host(action_np)
        obs_l.append(obs_np)
        act_l.append(action_np)
        rew_l.append(reward.copy())
        done_l.append(done.copy())
        val_l.append(np.asarray(value))
        logp_l.append(np.asarray(logp))
        obs_np = next_obs.copy()
    traj = Transition(
        obs=np.stack(obs_l),
        action=np.stack(act_l),
        reward=np.stack(rew_l),
        done=np.stack(done_l),
        value=np.stack(val_l),
        logp=np.stack(logp_l),
    )
    return obs_np, key, traj, obs_np  # final obs is the bootstrap observation


class ActorThread(threading.Thread):
    """One actor replica: collects ``iterations`` rollouts and feeds the
    shared trajectory queue.

    ``collect(params, key) -> (key, traj, last_obs)`` encapsulates either
    collection path with env state captured in the closure; the thread owns
    the acting RNG key. In ``lockstep`` mode the actor waits until the
    learner has published version i before collecting rollout i (so data is
    never stale); otherwise it reads the freshest available params and runs
    ahead up to the queue depth (shared across all replicas).

    Shutdown protocol: a replica that finishes its quota (or is ``stop()``ed,
    or finds the queue closed under it) checks out with ``producer_done()``
    — the stream closes only after the *last* replica. A replica that dies
    records the exception and hard-``close()``s the queue so the learner and
    its siblings unwind promptly instead of deadlocking.
    """

    def __init__(self, collect: Callable, queue, slot: ParamSlot, key,
                 iterations: int, lockstep: bool = False, actor_id: int = 0):
        super().__init__(name=f"pipeline-actor-{actor_id}", daemon=True)
        self._collect = collect
        self._queue = queue
        self._slot = slot
        self._key = key
        self._iterations = iterations
        self._lockstep = lockstep
        self.actor_id = actor_id
        self._stop_requested = threading.Event()
        self.wait_s = 0.0  # time blocked waiting for params (lockstep)
        self.put_wait_s = 0.0  # time blocked in queue.put (backpressure)
        self.error: Optional[BaseException] = None

    def stop(self) -> None:
        """Ask the actor to exit at its next blocking point (learner died)."""
        self._stop_requested.set()

    def _put(self, rollout: Rollout) -> bool:
        """Bounded put, interruptible by stop()/close(). Returns False when
        the actor should exit instead of producing more."""
        t0 = time.perf_counter()
        try:
            while True:
                try:
                    self._queue.put(rollout, timeout=0.1)
                    return True
                except Full:
                    if self._stop_requested.is_set():
                        return False
                except QueueClosed:
                    return False  # stream aborted under us — not our error
        finally:
            self.put_wait_s += time.perf_counter() - t0

    def run(self) -> None:
        try:
            for i in range(self._iterations):
                if self._lockstep:
                    t0 = time.perf_counter()
                    while not self._slot.wait_for(i, timeout=0.1):
                        if self._stop_requested.is_set():
                            return
                    self.wait_s += time.perf_counter() - t0
                if self._stop_requested.is_set():
                    return
                params, version = self._slot.read()
                self._key, traj, last_obs = self._collect(params, self._key)
                if not self._put(
                    Rollout(traj, last_obs, version, self.actor_id, i)
                ):
                    return
        except BaseException as e:  # surfaced by the learner loop
            self.error = e
        finally:
            if self.error is not None:
                self._queue.close()  # abort: wake learner + sibling actors
            else:
                self._queue.producer_done()
