"""The pipeline's acting half: rollout collection decoupled from learning.

Two collection paths, mirroring the two environment regimes of
``repro.core.framework``:

* ``make_collect_fn`` (re-exported from ``repro.core.rollout``) — JAX-native
  ``VectorEnv``: one jitted program collects a full ``t_max`` rollout whose
  output feeds the device plane (``DeviceTrajectoryRing``) without ever
  touching host memory.
* ``collect_host`` — ``HostEnvPool``: jitted batched acting interleaved with
  threaded host env stepping (paper §3's master/worker loop, run on the
  actor thread). While the env workers sleep in C/syscalls the GIL is
  released, so the learner's jitted update runs concurrently — this is the
  overlap that recovers the paper's Fig. 2 "50% env time". Trajectories are
  accumulated into reusable ``HostStagingRing`` buffers (one row-write per
  step into a preallocated ``(t_max, E, ...)`` set) instead of fresh numpy
  stacks per rollout.

``ParamSlot`` is the basic learner→actor exchange (a reference swap).
``PingPongParamSlot`` is its donation-safe upgrade: the learner's working
params are *never* handed to actors — each update publishes a bitwise copy
into one of two alternating actor-facing buffers, and actors bracket their
rollouts with ``acquire``/``release`` read leases so the learner can reclaim
(donate) the stale buffer only once nobody reads it. That is what makes
``donate_argnums`` on params *and* opt state safe in the learner step.

``Rollout`` is the queue payload: the trajectory, the bootstrap observation,
the behaviour params version (staleness = learner_version −
behaviour_version), and an optional host-side ``release`` callback the
learner invokes once the payload is fully consumed (returns a staging set to
its ring; ``None`` on the device plane, where XLA's donation chain recycles
the buffers instead).
"""
from __future__ import annotations

import threading
from queue import Full
from typing import Any, Callable, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.rollout import Transition, make_collect_fn  # noqa: F401
from repro.analysis.lockcheck import make_condition, make_lock
from repro.pipeline.queue import QueueClosed
from repro.telemetry.spans import (
    COLLECT,
    LEASE,
    QUEUE_PUT_WAIT,
    SpanEmitter,
)

__all__ = [
    "ParamSlot",
    "PingPongParamSlot",
    "HostStagingRing",
    "StagingSet",
    "Rollout",
    "ActorBase",
    "ActorThread",
    "collect_host",
    "make_collect_fn",
]


class ParamSlot:
    """Versioned single-slot param exchange (learner → actor).

    The learner ``publish``es params after every update; the actor ``read``s
    whatever is newest when it starts a rollout. ``wait_for`` lets a
    lock-stepped actor block until the learner has caught up — synchronous
    semantics through the pipelined code path.

    ``acquire``/``release`` are the lease hooks actors use so the slot's
    donation-safe subclass can track outstanding readers; here they are a
    plain ``read`` and a no-op (reference-swapped params are never reclaimed,
    so holding them needs no protection).
    """

    def __init__(self, params: Any, version: int = 0):
        self._params = params
        self._version = version
        self._cond = make_condition("param_slot.cond")

    def publish(self, params: Any, version: int) -> None:
        with self._cond:
            self._params = params
            self._version = version
            self._cond.notify_all()

    def read(self) -> Tuple[Any, int]:
        with self._cond:
            return self._params, self._version

    def acquire(self, holder: Optional[str] = None) -> Tuple[Any, int]:
        """Take a read lease on the newest params (paired with ``release``).
        ``holder`` labels the leasing party for timeout diagnostics."""
        return self.read()

    def release(self, version: int, holder: Optional[str] = None) -> None:
        """Return the lease taken by ``acquire`` (no-op for the base slot)."""

    def wait_for(self, version: int, timeout: Optional[float] = None) -> bool:
        with self._cond:
            return self._cond.wait_for(
                lambda: self._version >= version, timeout=timeout
            )

    @property
    def version(self) -> int:
        with self._cond:
            return self._version


def _copy_tree(tree):
    return jax.tree_util.tree_map(lambda a: a.copy(), tree)


class PingPongParamSlot(ParamSlot):
    """Two alternating actor-facing param buffers with read leases.

    The donation problem: if the learner jit donates its params, the buffers
    an actor snapshotted via ``read()`` are deleted by the *next* update —
    a use-after-free racing every in-flight rollout. The fix is to never
    share the learner's working params at all: ``publish`` of version ``v``
    lands a bitwise copy in buffer ``v % 2``, actors lease the newest buffer
    for exactly the duration of one rollout, and the learner ``reserve``s a
    buffer for reuse only after its last reader released. The stale buffer is
    handed into the fused learner step as a donation target, so on backends
    that realize input/output aliasing the publish copy writes straight over
    it — classic ping-pong double buffering, one param-copy per update, zero
    steady-state allocation.

    Lease protocol (actor side)::

        params, version = slot.acquire()   # readers[v % 2] += 1
        try:  ... collect with params ...
        finally: slot.release(version)     # readers[v % 2] -= 1

    Publish protocol (learner side, per update ``v``)::

        dst = slot.reserve(v)        # blocks until readers[v % 2] == 0
        ... fused jitted step consumes dst (donated) and returns `published`
        slot.commit(published, v)    # buffer v % 2 <- published, notify

    ``reserve`` can only wait on a reader that is mid-rollout — actors
    release before blocking on the queue — so the wait is bounded by one
    collect and cannot deadlock.
    """

    def __init__(self, params: Any, version: int = 0):
        # actors only ever see copies; the caller keeps the original as the
        # learner's private working params (safe to donate from step one)
        bufs = [_copy_tree(params), _copy_tree(params)]
        super().__init__(bufs[version % 2], version)
        self._bufs = bufs
        self._readers = [0, 0]
        # per-buffer holder labels, parallel to _readers: when a reserve
        # times out, the error can name *who* never released (the stall
        # watchdog's stage-naming idiom applied to leases)
        self._holders: dict = {0: [], 1: []}

    def acquire(self, holder: Optional[str] = None) -> Tuple[Any, int]:
        with self._cond:
            idx = self._version % 2
            self._readers[idx] += 1
            if holder is not None:
                self._holders[idx].append(holder)
            return self._params, self._version

    def release(self, version: int, holder: Optional[str] = None) -> None:
        with self._cond:
            idx = version % 2
            self._readers[idx] -= 1
            assert self._readers[idx] >= 0, "unbalanced release"
            if holder is not None:
                try:
                    self._holders[idx].remove(holder)
                except ValueError:
                    pass  # unlabeled acquire / already revoked
            self._cond.notify_all()

    def holders(self, idx: int) -> List[str]:
        """Labels of the parties currently leasing buffer ``idx``."""
        with self._cond:
            return list(self._holders[idx])

    def revoke(self, holder: str) -> int:
        """Drop every lease ``holder`` still holds (supervisor path: a
        replica that died without releasing). Returns leases cleared."""
        cleared = 0
        with self._cond:
            for idx in (0, 1):
                while holder in self._holders[idx]:
                    self._holders[idx].remove(holder)
                    self._readers[idx] -= 1
                    cleared += 1
            if cleared:
                self._cond.notify_all()
        return cleared

    def reserve(self, version: int, timeout: Optional[float] = None):
        """Claim buffer ``version % 2`` for the upcoming publish.

        Blocks until every reader of the buffer's previous contents has
        released, then returns the stale param tree — the donation target
        for the fused learner step. Returns ``None`` on timeout.
        """
        idx = version % 2
        with self._cond:
            if not self._cond.wait_for(
                lambda: self._readers[idx] == 0, timeout=timeout
            ):
                return None
            return self._bufs[idx]

    def commit(self, params: Any, version: int) -> None:
        """Install the published copy produced against ``reserve``'s target."""
        idx = version % 2
        with self._cond:
            assert self._readers[idx] == 0, "commit while buffer leased"
            self._bufs[idx] = params
            self._params = params
            self._version = version
            self._cond.notify_all()

    def publish(self, params: Any, version: int,
                timeout: Optional[float] = 60.0) -> None:
        """Unfused publish: copy ``params`` into the alternating buffer.

        Convenience path (used when the learner step was not built with
        ``fused_publish``): blocks for the buffer's readers, copies, commits.
        A reserve timeout means a reader never released its lease — raise
        loudly rather than fall through to ``commit`` on a still-leased
        buffer (which would hand actors a tree mutating under them)."""
        dst = self.reserve(version, timeout=timeout)
        if dst is None:
            held = ", ".join(self.holders(version % 2)) or "an unlabeled party"
            raise RuntimeError(
                f"PingPongParamSlot.publish(version={version}): reserve "
                f"timed out after {timeout}s — buffer {version % 2} is "
                f"still leased by {held} (died without release()?)"
            )
        assert dst is self._bufs[version % 2], (
            "reserve() returned a tree that is not the reserved buffer"
        )
        self.commit(_copy_tree(params), version)


class Rollout(NamedTuple):
    """Queue payload: one collected rollout plus its provenance.

    ``actor_id``/``seq`` tag which replica produced the rollout and where it
    sits in that replica's stream — the learner uses them to attribute
    staleness and idle time per actor, and the pipeline tests to prove every
    ``(actor_id, seq)`` is learned from exactly once. ``release`` (host plane
    only) returns the payload's staging buffers to their ring once the
    learner has fully consumed the update."""

    traj: Transition  # time-major (T, E, ...)
    last_obs: jnp.ndarray  # (E, *obs_shape) — bootstrap observation
    behavior_version: int  # params version the actor acted with
    actor_id: int = 0  # which actor replica collected it
    seq: int = 0  # per-actor rollout sequence number
    release: Optional[Callable[[], None]] = None  # staging-set return hook


# ---------------------------------------------------------------------------
# Host staging — reusable pinned buffers for host-plane payloads
# ---------------------------------------------------------------------------


def staging_fields(t_max: int, n_envs: int, obs_shape: Tuple[int, ...],
                   obs_dtype) -> List[Tuple[Tuple[int, ...], np.dtype]]:
    """The canonical staging-payload layout: ``Transition``'s six fields (in
    field order) followed by the bootstrap ``last_obs``. Both staging
    backends build from this one list — ``StagingSet`` as process-private
    numpy arrays, ``repro.pipeline.shm.ShmStagingSet`` as views into one
    shared-memory block — so the layouts cannot drift apart."""
    E = n_envs
    obs_shape = tuple(obs_shape)
    obs_dtype = np.dtype(obs_dtype)
    return [
        ((t_max, E) + obs_shape, obs_dtype),      # Transition.obs
        ((t_max, E), np.dtype(np.int32)),         # Transition.action
        ((t_max, E), np.dtype(np.float32)),       # Transition.reward
        ((t_max, E), np.dtype(bool)),             # Transition.done
        ((t_max, E), np.dtype(np.float32)),       # Transition.value
        ((t_max, E), np.dtype(np.float32)),       # Transition.logp
        ((E,) + obs_shape, obs_dtype),            # last_obs
    ]


class StagingSet:
    """One reusable host payload: a ``(t_max, E, ...)`` trajectory plus the
    bootstrap observation, written in place row by row during collection."""

    __slots__ = ("traj", "last_obs")

    def __init__(self, t_max: int, n_envs: int, obs_shape: Tuple[int, ...],
                 obs_dtype):
        arrays = [np.zeros(shape, dtype) for shape, dtype in
                  staging_fields(t_max, n_envs, obs_shape, obs_dtype)]
        self.traj = Transition(*arrays[:6])
        self.last_obs = arrays[6]


class HostStagingRing:
    """Pool of reusable staging sets for one actor's host-plane rollouts.

    Replaces the per-rollout ``np.stack`` of per-step copies with writes into
    preallocated buffers: ``acquire`` hands out a free set, the payload's
    ``release`` callback (invoked by the learner after it has consumed the
    update, i.e. after the H2D transfer is provably complete) returns it.
    ``n_sets`` must cover every set simultaneously in flight: up to
    ``queue_depth`` enqueued + 1 consumed-but-unreleased + 1 being written,
    so callers size it ``queue_depth + 2``. ``acquire`` never blocks when
    that invariant holds; a blocked acquire is a release-protocol bug, which
    the timeout turns into a loud error instead of a hang.
    """

    def __init__(self, n_sets: int, t_max: int, n_envs: int,
                 obs_shape: Tuple[int, ...], obs_dtype=np.float32):
        if n_sets < 2:
            raise ValueError(f"staging ring needs >= 2 sets, got {n_sets}")
        self._free: List[StagingSet] = [
            StagingSet(t_max, n_envs, obs_shape, obs_dtype)
            for _ in range(n_sets)
        ]
        self.n_sets = n_sets
        self._cond = make_condition("staging_ring.cond")

    def acquire(self, timeout: float = 60.0) -> StagingSet:
        with self._cond:
            if not self._cond.wait_for(lambda: self._free, timeout=timeout):
                raise RuntimeError(
                    "HostStagingRing.acquire timed out — a payload was "
                    "consumed without its release() being called"
                )
            return self._free.pop()

    def release(self, s: StagingSet) -> None:
        with self._cond:
            self._free.append(s)
            self._cond.notify_all()

    def free_sets(self) -> int:
        with self._cond:
            return len(self._free)


def make_host_act_step(act_fn: Callable) -> Callable:
    """Fuse one acting step — forward, sample, behaviour logp — into a
    single jitted program so the host loop pays one dispatch per step."""

    @jax.jit
    def act_step(params, obs, key):
        key, k_act = jax.random.split(key)
        logits, value = act_fn(params, obs)
        action = jax.random.categorical(k_act, logits)
        # behaviour log-prob from the sampled action's logit alone (same
        # gather as core/rollout.step): log π(a|s) = logits[a] − logsumexp.
        # Gathering first keeps the per-step dispatch from materializing the
        # full (E, A) log_softmax matrix when one column per row is read.
        action_logit = jnp.take_along_axis(logits, action[:, None], axis=1)[:, 0]
        logp = action_logit - jax.scipy.special.logsumexp(logits, axis=1)
        return action, value, logp, key

    return act_step


def collect_host(act_step: Callable, pool, params, obs, key, t_max: int,
                 staging: Optional[StagingSet] = None):
    """Collect ``t_max`` steps from a ``HostEnvPool`` (paper §3 loop).

    ``act_step`` is the jitted fused acting step (``make_host_act_step``);
    env stepping runs on the pool's worker threads. Returns
    ``(next_obs, key, traj, last_obs)`` with ``traj`` a time-major
    ``Transition`` of *host* (numpy) arrays — including the behaviour
    log-prob the learner's importance correction needs — transferred to the
    device only when the learner dispatches its update.

    With ``staging`` (a ``HostStagingRing`` set) every step writes its row
    directly into the set's preallocated buffers — zero numpy allocation per
    rollout — and the returned ``traj``/``last_obs`` *are* the staging
    arrays: the caller must not reuse the set until the learner has consumed
    the payload (the pipeline's ``Rollout.release`` protocol). Without
    ``staging`` each call allocates fresh arrays (safe for one-shot callers
    like benchmarks).
    """
    # accumulate on the host (numpy): the only device traffic per step is the
    # fused act_step — extra device ops here would queue behind the learner's
    # update and stretch the rollout. The trajectory stays host-side; the
    # H2D transfer happens when the learner dispatches its update.
    if staging is None:
        staging = StagingSet(t_max, pool.n_envs, pool.obs_shape,
                              np.asarray(obs).dtype)
    traj, last = staging.traj, staging.last_obs
    np.copyto(last, np.asarray(obs))
    for t in range(t_max):
        traj.obs[t] = last
        action, value, logp, key = act_step(params, traj.obs[t], key)
        action_np = np.asarray(action)
        next_obs, reward, done = pool.step_host(action_np)
        traj.action[t] = action_np
        traj.reward[t] = reward
        traj.done[t] = done
        traj.value[t] = np.asarray(value)
        traj.logp[t] = np.asarray(logp)
        np.copyto(last, next_obs)
    return last, key, traj, last  # final obs is the bootstrap observation


class ActorBase(threading.Thread):
    """Shared replica protocol for both actor backends (thread & process).

    The contract every replica honours, independent of *where* its rollouts
    are produced (in this thread, or in a worker subprocess this thread
    drains):

    * **quota** — produce exactly ``iterations`` payloads (possibly zero:
      a replica handed quota 0 by an ``iterations < num_actors`` run goes
      straight to checkout),
    * **never-drop** — every produced payload is ``_put`` into the shared
      stream, which blocks (backpressure) rather than discards,
    * **shutdown** — finishing the quota (or being ``stop()``ed, or finding
      the stream closed underneath) checks out via ``producer_done()``; the
      stream closes only after the *last* replica checks out. A replica
      that dies records its exception and hard-``close()``s the stream so
      the learner and sibling replicas unwind promptly instead of
      deadlocking.

    Subclasses implement ``_produce()`` (the body between start and
    checkout); the base class owns ``_put``, ``stop`` and the
    error-vs-checkout epilogue.
    """

    def __init__(self, queue, actor_id: int = 0, telemetry=None):
        super().__init__(name=f"pipeline-actor-{actor_id}", daemon=True)
        self._queue = queue
        self.actor_id = actor_id
        self._stop_requested = threading.Event()
        # this replica's span track (single-writer: only this thread records).
        # wait_s/put_wait_s are *derived* from its per-category totals — the
        # same float accumulation the old ad-hoc counters performed.
        if telemetry is not None:
            self.span_emitter = telemetry.emitter(f"actor{actor_id}")
        else:
            self.span_emitter = SpanEmitter(f"actor{actor_id}")
        self.error: Optional[BaseException] = None
        # fault-tolerance surface (repro.pipeline.supervisor): the slot this
        # replica occupies (stable across respawns, unlike actor_id), its
        # quota accounting, and the supervisor consulted by the epilogue. A
        # handled fault leaves ``error`` set (diagnostics) but marks
        # ``fault_handled`` so the run doesn't treat it as fatal.
        self.slot_index = actor_id
        self.assigned = 0  # payloads this replica must produce
        self.produced = 0  # payloads successfully put so far
        self.supervisor = None
        self.fault_handled = False

    @property
    def wait_s(self) -> float:
        """Time blocked waiting for params (lockstep) — span-derived."""
        return self.span_emitter.total(LEASE)

    @property
    def put_wait_s(self) -> float:
        """Time blocked in queue.put (backpressure) — span-derived."""
        return self.span_emitter.total(QUEUE_PUT_WAIT)

    def stop(self) -> None:
        """Ask the actor to exit at its next blocking point (learner died)."""
        self._stop_requested.set()

    # hot-path
    def _put(self, rollout: Rollout) -> bool:
        """Bounded put, interruptible by stop()/close(). Returns False when
        the actor should exit instead of producing more."""
        self.span_emitter.begin(QUEUE_PUT_WAIT)
        try:
            while True:
                try:
                    self._queue.put(rollout, timeout=0.1)
                    return True
                except Full:
                    if self._stop_requested.is_set():
                        return False
                except QueueClosed:
                    return False  # stream aborted under us — not our error
        finally:
            self.span_emitter.end()

    def _produce(self) -> None:
        raise NotImplementedError

    def run(self) -> None:
        try:
            self._produce()
        except BaseException as e:  # surfaced by the learner loop
            self.error = e
        finally:
            if self.error is not None:
                # with a supervisor, the dying thread *is* the recovery
                # context: on_actor_error respawns a replacement (which
                # inherits this replica's producer slot) or degrades by
                # orphaning the remaining quota (checking the slot out
                # itself). Only an unhandled death hard-aborts the stream —
                # exactly the pre-supervisor fail-fast path.
                sup = self.supervisor
                if sup is not None and sup.on_actor_error(self):
                    self.fault_handled = True
                else:
                    self._queue.close()  # abort: wake learner + siblings
            else:
                self._queue.producer_done()


class ActorThread(ActorBase):
    """One in-process actor replica: collects ``iterations`` rollouts on its
    own thread and feeds the shared trajectory queue (host plane) or device
    ring (device plane).

    ``collect(params, key) -> (key, traj, last_obs, release)`` encapsulates
    either collection path with env state captured in the closure; the
    thread owns the acting RNG key, and ``release`` (or ``None``) rides the
    payload so the learner can return staging buffers. Params are taken
    under an ``acquire``/``release`` lease for exactly the duration of the
    collect — never while blocked on the queue — which is what lets a
    ping-pong slot reclaim stale buffers without racing this thread. In
    ``lockstep`` mode the actor waits until the learner has published
    version i before collecting rollout i (so data is never stale);
    otherwise it reads the freshest available params and runs ahead up to
    the queue depth (shared across all replicas).

    Quota/shutdown semantics are ``ActorBase``'s; its process-backend twin
    (``repro.pipeline.worker.ProcessActorDrainer``) shares them verbatim.
    """

    def __init__(self, collect: Callable, queue, slot: ParamSlot, key,
                 iterations: int, lockstep: bool = False, actor_id: int = 0,
                 telemetry=None, slot_index: Optional[int] = None,
                 start_seq: int = 0, ledger=None, injector=None,
                 snapshot: Optional[Callable] = None):
        super().__init__(queue, actor_id, telemetry=telemetry)
        self._collect = collect
        self._slot = slot
        self._key = key
        self.assigned = iterations
        self._lockstep = lockstep
        self.slot_index = actor_id if slot_index is None else slot_index
        # seq offset for resumed runs: local rollout index i is tagged
        # ``start_seq + i`` so the (actor_id, seq) stream stays continuous
        # with the pre-checkpoint run
        self._start_seq = start_seq
        # quota ledger (supervisor runs): lets this replica pick up a dead
        # sibling's orphaned quota after finishing its own
        self._ledger = ledger
        # deterministic fault injection (FaultPlan), None outside tests
        self._injector = injector
        # checkpoint support: snapshot(key) -> opaque resume state captured
        # after each collect; the learner calls consume_state(seq) as it
        # consumes the matching payload, so the log holds at most the
        # in-flight window (queue depth + 1) of entries
        self._snapshot = snapshot
        self._state_log: dict = {}
        self._state_lock = make_lock("actor.state")

    def consume_state(self, seq: int):
        """Pop (and prune up to) the resume state recorded after rollout
        ``seq``; ``None`` when snapshotting is off or seq predates it."""
        with self._state_lock:
            st = self._state_log.get(seq)
            for k in [k for k in self._state_log if k <= seq]:
                del self._state_log[k]
            return st

    def _produce(self) -> None:
        i = 0  # local rollout index (lockstep waits on it; seq offsets it)
        while True:
            if i >= self.assigned:
                if self._ledger is None:
                    return
                # quota done — but a sibling may have died with quota
                # outstanding: block for orphaned work instead of checking
                # out, until the ledger proves no work can remain
                got = self._ledger.wait_for_work(
                    stop=self._stop_requested.is_set)
                if got <= 0:
                    return
                self.assigned += got
                continue
            if self._injector is not None:
                self._injector.maybe_kill(self.slot_index, self.produced)
                self._injector.lease_delay(self.slot_index, i)
            if self._lockstep:
                # lease span: the stop-abort path cancels instead of ending
                # (the pre-telemetry counter never accumulated it either)
                self.span_emitter.begin(LEASE)
                while not self._slot.wait_for(i, timeout=0.1):
                    if self._stop_requested.is_set():
                        self.span_emitter.cancel()
                        return
                self.span_emitter.end()
            if self._stop_requested.is_set():
                return
            # lease the params only for the collect: released before the
            # (potentially long) blocking put so the learner's reserve()
            # wait is bounded by one rollout. The instant acquire() itself is
            # deliberately unspanned: wait_s means *blocked on the learner*.
            params, version = self._slot.acquire(holder=self.name)
            self.span_emitter.begin(COLLECT)
            try:
                self._key, traj, last_obs, release = self._collect(
                    params, self._key
                )
            finally:
                self.span_emitter.end()
                self._slot.release(version, holder=self.name)
            seq = self._start_seq + i
            if self._snapshot is not None:
                # capture post-rollout state *before* the put: by the time
                # the learner can consume seq, its resume state exists
                with self._state_lock:
                    self._state_log[seq] = self._snapshot(self._key)
            if not self._put(
                Rollout(traj, last_obs, version, self.actor_id, seq, release)
            ):
                return
            self.produced += 1
            if self._ledger is not None:
                self._ledger.produced()
            i += 1
