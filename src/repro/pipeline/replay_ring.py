"""Device-resident sampled replay ring — the pipeline's off-policy plane.

``DeviceTrajectoryRing`` is a FIFO: every payload is consumed exactly once,
in ticket order, and a full ring *blocks* its producers (backpressure is
the staleness bound for on-policy learners). Off-policy algorithms invert
both halves of that contract: the learner wants to *sample* — uniformly or
by priority — over a window of past rollouts, reusing each many times, and
a slow learner must never throttle acting (experience generation is the
scarce resource; Mnih et al. 2015, Horgan et al. 2018).

``ReplayRing`` is the FIFO ring's sampled twin, keeping everything that
made the device plane safe and changing exactly the two contract points
above:

* **same plane, same policing** — payloads are device arrays end to end
  (numpy leaves raise ``TypeError`` at ``put``), slots are preallocated
  references, and device memory is bounded at ``capacity`` resident
  rollouts.
* **never-drop means never-block** — ``put`` on a full ring *evicts* the
  oldest resident slot (FIFO by ticket) instead of blocking: the ring drops
  the ring's *oldest memory*, never the producer's *stream*. Actors run at
  full speed no matter how slow the learner is. Every accepted put is still
  ticket-stamped (tickets are the eviction order and the freshness
  accounting).
* **sampled get, retained slots** — ``sample(key, batch)`` draws ``batch``
  resident slots (with replacement; uniform, or ∝ priority with
  ``prioritized=True``) and *retains* them: slots are reused across
  updates and retired only by eviction or shutdown. Ownership therefore
  does NOT transfer on sampling — the learner must not donate sampled
  trajectory buffers (the orchestrator's learner jit never donates the
  trajectory argument, so this falls out for free). An evicted slot's
  reference is dropped by the ring; its device memory returns to the
  allocator as soon as no in-flight learner batch still holds it.

The stream surface (``get``/``producer_done``/``close``/``CLOSED``) is kept
so ``ActorThread`` and the ``PipelinedRL`` learner loop drive this plane
unchanged. ``get()`` is **ticket-paced sampling**: it blocks until the ring
holds at least one *unconsumed* ticket (one fresh put per learner update —
the same 1:1 produce/consume pacing as the FIFO planes, which is what
keeps actor quotas, lockstep mode and the bitwise sync-equivalence pin
meaningful), consumes that ticket, then samples ``batch_size`` resident
slots and concatenates them along the env axis into one synthetic
``Rollout`` (``actor_id=-2``, ``seq`` = consume index, ``behavior_version``
= the *minimum* over the sampled slots — staleness reports the oldest
experience in the batch). Eviction never breaks pacing: tickets are
counts, not slot-bound, so a fresh put whose payload is later evicted
still licenses exactly one update.

Sampling RNG: the ring owns a deterministic key stream —
``fold_in(PRNGKey(sample_seed), consume_index)`` — so a run's sample
sequence is a pure function of ``(sample_seed, consume order)``. That is
what lets the synchronous reference driver (``repro.pipeline.offpolicy.
SyncReplayDQN``) reproduce a lockstep pipelined run bit for bit: both
drivers push the same rollouts through a ring with the same seed.

Prioritized sampling is a categorical draw over the per-slot priorities
(``jax.random.choice`` with ``p`` — the device-side Gumbel/categorical
formulation). Slots here are whole rollouts, not transitions, so
``capacity`` is small (tens to low thousands) and the O(capacity) draw
beats a sum-tree's O(log n) with its host-side pointer chasing; a sum-tree
becomes worth it only for per-transition PER at millions of entries. New
slots enter at the current maximum priority (everything is sampled at
least once — Schaul et al. 2016); ``update_priorities`` feeds TD errors
back for the tickets reported by ``last_sampled``.
"""
from __future__ import annotations

import queue as _queue
import threading
import time
from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.lockcheck import make_condition
from repro.analysis import sanitize
from repro.pipeline.queue import CLOSED, QueueClosed
from repro.pipeline.ring import _assert_device_resident
from repro.telemetry.spans import (
    QUEUE_GET_WAIT,
    REPLAY_ADD,
    REPLAY_EVICT,
    REPLAY_SAMPLE,
    SpanEmitter,
)

__all__ = ["ReplayRing"]


class _ReplaySlot:
    """One resident rollout: payload reference, ticket tag, priority."""

    __slots__ = ("payload", "ticket", "full", "priority")

    def __init__(self):
        self.payload: Any = None
        self.ticket: int = -1
        self.full: bool = False
        self.priority: float = 1.0


class ReplayRing:
    """Bounded multi-producer ring of on-device rollout slots, sampled with
    retention instead of consumed FIFO.

    Same stream surface as ``DeviceTrajectoryRing`` (``put`` / ``get`` /
    ``producer_done`` / ``close`` / ``CLOSED`` / idle accounting), so
    actors and the learner loop drive either plane interchangeably — but
    ``put`` never blocks (full ring evicts oldest-by-ticket) and ``get``
    samples ``batch_size`` resident slots per consumed ticket rather than
    popping one. See the module docstring for the full contract.
    """

    def __init__(self, capacity: int = 64, batch_size: int = 1,
                 producers: int = 1, prioritized: bool = False,
                 sample_seed: int = 0, telemetry=None, name: str = "replay"):
        if capacity < 1:
            raise ValueError(f"replay capacity must be >= 1, got {capacity}")
        if batch_size < 1:
            raise ValueError(
                f"replay batch_size must be >= 1, got {batch_size}")
        if producers < 1:
            raise ValueError(f"producers must be >= 1, got {producers}")
        self.capacity = capacity
        self.batch_size = batch_size
        self.prioritized = prioritized
        self._slots: List[_ReplaySlot] = [
            _ReplaySlot() for _ in range(capacity)]
        self._tail = 0  # next ticket to issue (total accepted puts)
        self._evict_head = 0  # oldest resident ticket (evictions advance it)
        self._consumed = 0  # tickets consumed by get() (pacing counter)
        self._cond = make_condition("replay_ring.cond")
        self._producers_left = producers
        self._closed = False
        self._sample_base = jax.random.PRNGKey(sample_seed)
        # tickets drawn by the most recent get(): the learner's handle for
        # update_priorities (single consumer, so a plain attribute is safe)
        self.last_sampled: Tuple[int, ...] = ()
        self.evictions = 0  # total slots retired by full-ring puts
        if telemetry is not None:
            self.span_emitter = telemetry.emitter(name, locked=True)
        else:
            self.span_emitter = SpanEmitter(name, locked=True)

    # -- accounting (same surface as the FIFO planes) ------------------------
    @property
    def put_wait_s(self) -> float:
        """Always 0.0 — replay puts never block — kept for plane parity."""
        return 0.0

    @property
    def get_wait_s(self) -> float:
        """Learner idle (no fresh ticket) — span-derived."""
        return self.span_emitter.total(QUEUE_GET_WAIT)

    @property
    def tickets_issued(self) -> int:
        """Total puts accepted over the ring's lifetime (monotone)."""
        with self._cond:
            return self._tail

    def qsize(self) -> int:
        """Fresh (unconsumed) tickets — the pacing depth, not residency."""
        with self._cond:
            return self._tail - self._consumed

    @property
    def resident(self) -> int:
        """Rollouts currently held (sampleable): ``min(puts, capacity)``."""
        with self._cond:
            return self._tail - self._evict_head

    def resident_tickets(self) -> List[int]:
        """Tickets of the resident slots, oldest first (test/debug surface)."""
        with self._cond:
            return list(range(self._evict_head, self._tail))

    # -- producer side -------------------------------------------------------
    # hot-path
    def put(self, item: Any, timeout: Optional[float] = None) -> None:
        """Deposit a device-resident rollout; never blocks on a full ring.

        A full ring evicts its oldest resident slot (FIFO by ticket,
        ``replay.evict`` span) before inserting — the producer stream is
        never dropped and never throttled. Raises ``QueueClosed`` on a
        closed ring and ``TypeError`` for host-memory payloads. ``timeout``
        is accepted for queue-surface parity but never needed.
        """
        del timeout  # surface parity: a replay put cannot block
        _assert_device_resident(item)
        t0 = time.perf_counter()
        try:
            with self._cond:
                if self._closed:
                    raise QueueClosed("put() on a closed ReplayRing")
                if self._tail - self._evict_head >= self.capacity:
                    te = time.perf_counter()
                    slot = self._slots[self._evict_head % self.capacity]
                    # drop the ring's reference: the evicted rollout's device
                    # memory returns to the allocator once no in-flight
                    # learner batch still reads it
                    slot.payload = None
                    slot.ticket = -1
                    slot.full = False
                    self._evict_head += 1
                    self.evictions += 1
                    self.span_emitter.record(REPLAY_EVICT, te)
                ticket = self._tail
                self._tail = ticket + 1
                slot = self._slots[ticket % self.capacity]
                assert not slot.full, "replay invariant: slot must be free"
                slot.payload = item
                slot.ticket = ticket
                slot.full = True
                # fresh experience enters at the current max priority so it
                # is sampled at least once before TD errors rerank it
                slot.priority = max(
                    (s.priority for s in self._slots if s.full), default=1.0
                )
                self._cond.notify_all()
        finally:
            self.span_emitter.record(REPLAY_ADD, t0)

    # -- sampling ------------------------------------------------------------
    def _draw(self, key, batch_size: int) -> List[_ReplaySlot]:
        """Pick ``batch_size`` resident slots (with replacement). Caller
        holds the lock; at least one slot is resident."""
        residents = [self._slots[t % self.capacity]
                     for t in range(self._evict_head, self._tail)]
        n = len(residents)
        # intended host<->device edges: the draw materializes its indices on
        # host (and, prioritized, ships the priority vector up) by design
        with sanitize.allowed("replay sample draw"):
            if self.prioritized:
                prios = np.asarray(
                    [s.priority for s in residents], np.float64)
                total = prios.sum()
                if total <= 0.0:  # all-zero priorities degrade to uniform
                    prios = np.ones(n)
                    total = float(n)
                idx = np.asarray(jax.random.choice(
                    key, n, (batch_size,), replace=True,
                    p=jnp.asarray(prios / total),
                ))
            else:
                idx = np.asarray(
                    jax.random.randint(key, (batch_size,), 0, n))
        return [residents[int(i)] for i in idx]

    def sample(self, key, batch_size: Optional[int] = None) -> List[Any]:
        """Draw ``batch_size`` resident rollouts (retained, not consumed).

        The direct sampling surface (the stream-paced learner path goes
        through ``get``). Raises ``queue.Empty`` on an empty ring — sampling
        nothing is a caller bug, not a valid batch — and records the
        ``replay.sample`` span. Returns the payloads oldest-draw order as
        sampled; ``last_sampled`` is set to their tickets.
        """
        if batch_size is None:
            batch_size = self.batch_size
        t0 = time.perf_counter()
        try:
            with self._cond:
                if self._tail == self._evict_head:
                    raise _queue.Empty
                slots = self._draw(key, batch_size)
                self.last_sampled = tuple(s.ticket for s in slots)
                return [s.payload for s in slots]
        finally:
            self.span_emitter.record(REPLAY_SAMPLE, t0)

    def update_priorities(self, tickets: Sequence[int],
                          priorities: Sequence[float]) -> None:
        """Feed TD-error priorities back for previously sampled tickets.

        Tickets that were evicted since the sample are silently skipped (the
        experience is gone; its priority is moot). Priorities are clamped to
        a small positive floor so no resident slot starves forever.
        """
        with self._cond:
            for t, p in zip(tickets, priorities):
                if self._evict_head <= t < self._tail:
                    slot = self._slots[t % self.capacity]
                    if slot.ticket == t:
                        slot.priority = max(float(p), 1e-6)

    # -- consumer (stream) side ---------------------------------------------
    def get(self, timeout: Optional[float] = None) -> Any:
        """One sampled batch per fresh ticket: the learner-loop surface.

        Blocks until an unconsumed ticket exists (accumulating learner idle
        time), consumes it, samples ``batch_size`` resident slots with the
        ring's deterministic key stream, and returns them concatenated
        along the env axis as one synthetic ``Rollout``. Returns ``CLOSED``
        once the ring is closed (or all producers checked out) and every
        ticket is consumed; raises stdlib ``queue.Empty`` on timeout.
        """
        from repro.pipeline.actor import Rollout

        t0 = time.perf_counter()
        try:
            with self._cond:
                if not self._cond.wait_for(
                    lambda: self._closed or self._consumed < self._tail,
                    timeout=timeout,
                ):
                    raise _queue.Empty
                if self._consumed >= self._tail:
                    return CLOSED  # closed and ticket-drained
                seq = self._consumed
                self._consumed = seq + 1
                # folding the host-side consume index into the key stream is
                # the sampling path's intended H2D edge (like the draw below)
                with sanitize.allowed("replay sample draw"):
                    key = jax.random.fold_in(self._sample_base, seq)
                ts = time.perf_counter()
                slots = self._draw(key, self.batch_size)
                self.last_sampled = tuple(s.ticket for s in slots)
                parts = [s.payload for s in slots]
                version = min(p.behavior_version for p in parts)
                self._cond.notify_all()
        finally:
            self.span_emitter.record(QUEUE_GET_WAIT, t0)
        # assembly outside the lock: producers must not stall behind a
        # device concat. Single consumer, so the slot references taken
        # above cannot race another get (eviction only drops the ring's
        # reference — `parts` keeps the payloads alive for this batch).
        try:
            if len(parts) == 1:
                traj, last_obs = parts[0].traj, parts[0].last_obs
            else:
                traj = jax.tree_util.tree_map(
                    lambda *ls: jnp.concatenate(ls, axis=1),
                    *[p.traj for p in parts],
                )
                last_obs = jnp.concatenate([p.last_obs for p in parts],
                                           axis=0)
            return Rollout(
                traj=traj,
                last_obs=last_obs,
                behavior_version=version,
                actor_id=-2,  # replay-sampled: no single producing replica
                seq=seq,
                release=None,  # device plane: slots are ring-owned
            )
        finally:
            self.span_emitter.record(REPLAY_SAMPLE, ts)

    # -- shutdown (same protocol as the FIFO planes) -------------------------
    def producer_done(self) -> None:
        """One producer finished its quota; the stream closes when the last
        producer checks out (the consumer drains remaining tickets, then
        sees ``CLOSED``)."""
        with self._cond:
            self._producers_left -= 1
            if self._producers_left <= 0:
                self._closed = True
            self._cond.notify_all()

    def close(self) -> None:
        """Hard abort: wakes producers (``QueueClosed``) and the consumer
        (``CLOSED`` after remaining tickets drain). Idempotent."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()
