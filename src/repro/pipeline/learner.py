"""The pipeline's learning half: V-trace-corrected PAAC update.

The learner consumes rollouts that may be several updates stale (up to
``queue_depth``, from any of the ``num_actors`` replicas). Following IMPALA
(Espeholt et al., 2018), the n-step targets are replaced by full V-trace:

    ρ_t = min(ρ̄, π_learner(a_t|s_t) / π_behaviour(a_t|s_t))
    c_t = min(c̄, π_learner(a_t|s_t) / π_behaviour(a_t|s_t))
    δ_t = ρ_t (r_t + γ_t V(s_{t+1}) − V(s_t))
    v_t = V(s_t) + δ_t + γ_t c_t (v_{t+1} − V(s_{t+1}))

with the behaviour log-prob recorded at acting time (``Transition.logp``),
values recomputed under current params, and the policy gradient driven by
ρ_t (r_t + γ_t v_{t+1} − V(s_t)). The ρ̄ clip bounds each step's correction
(PR-1's per-step clip); the c̄ *product* additionally discounts how far a
correction propagates backwards, which is what keeps queues deeper than 2
unbiased.

ρ̄ = c̄ = ∞ (literally ``float("inf")``) is the synchronous limit: the
correction is compiled out and the step computes the plain PAAC loss on
n-step returns — bit-for-bit the synchronous update, which is how the
lockstep equivalence tests pin the pipeline to ``ParallelRL``.

``make_learner_step`` returns a jittable
``(params, opt_state, traj, last_obs, step) -> (params, opt_state, metrics)``
— the learning half of ``PAACAgent.make_train_step`` with the rollout
replaced by a queue payload. The synchronous ``HostEnvPool`` driver in
``repro.core.framework`` reuses the same step (with infinite clips), so sync
and pipelined backends differ only in overlap, not in math.

With ``fused_publish=True`` the step also produces the actor-facing param
snapshot inside the same program —
``(params, opt_state, traj, last_obs, step, publish_dst) ->
(params, opt_state, published, metrics)`` — so one dispatch per iteration
covers dequeue-consume, update, *and* publish. ``published`` is a bitwise
copy of the new params (the publish copy cannot perturb the lockstep
guarantee), and ``publish_dst`` is the stale ping-pong buffer from
``PingPongParamSlot.reserve``: donated, so backends that realize
input/output aliasing write the snapshot straight over it. This is the
shape that makes full donation safe — the orchestrator jits it with
``donate_argnums`` on params, opt state, and the publish target (each
aliases a shape-identical output, so the update is allocation-free in
steady state), and actors never see a donated buffer because they only
ever lease the published copies.

``make_sharded_learner_step`` is the mesh twin: the same update jitted with
``NamedSharding``s over a 1-axis ``("data",)`` rollout mesh — trajectory and
bootstrap batch sharded along the env axis, params/opt state replicated —
so XLA's SPMD partitioner turns the batch-mean gradients into per-device
partial gradients plus an all-reduce across the data axis (Stooke & Abbeel
2018's synchronous multi-GPU step). The fused-publish donation path is
preserved verbatim: params, opt state and the stale publish buffer are
donated replicated trees whose shards alias the outputs shard-for-shard, so
the sharded update is just as allocation-free as the single-device one. On
a 1-device mesh the partitioner's annotations are no-ops and the step is
bit-identical to the flat ``make_learner_step`` jit (pinned by the mesh=1
lockstep test).
"""
from __future__ import annotations

import math
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.agents.paac import (
    paac_losses,
    trajectory_forward,
    trajectory_logits_values,
)
from repro.core.returns import vtrace_returns


def make_learner_step(agent, optimizer, lr_schedule, rho_bar: float = 1.0,
                      c_bar: float = 1.0,
                      fused_publish: bool = False) -> Callable:
    """Build the pipelined learner's jittable update step for a PAAC agent.

    ``fused_publish=False`` (default): the PR-1/PR-2 signature, shared with
    the synchronous ``HostEnvPool`` driver. ``fused_publish=True``: the
    donation-ready signature described in the module docstring (extra
    ``publish_dst`` argument, extra ``published`` output).
    """
    cfg, hp = agent.cfg, agent.hp
    act = agent.act_fn()
    # the clips are static: the infinite-clip (synchronous) limit is resolved
    # at trace time so it shares the sync path's computation graph exactly
    exact_sync = math.isinf(rho_bar) and math.isinf(c_bar)

    def _rho(logits, actions, behaviour_logp):
        logp_now = jnp.take_along_axis(
            jax.nn.log_softmax(logits), actions[:, None], axis=1
        )[:, 0]
        rho = jnp.exp(
            logp_now - behaviour_logp.reshape(logp_now.shape).astype(jnp.float32)
        )
        return logp_now, jax.lax.stop_gradient(rho)

    def loss_sync(params, traj, bootstrap):
        # ρ̄ = c̄ = ∞: correction disabled — the paper's on-policy loss,
        # identical graph to the synchronous train step (bitwise lockstep)
        logits, values, actions, returns = trajectory_forward(
            params, cfg, hp, traj, bootstrap
        )
        _, rho = _rho(logits, actions, traj.logp)
        total, metrics = paac_losses(
            logits, values, actions, returns, hp.entropy_beta, hp.value_coef
        )
        return total, metrics, rho

    def loss_vtrace(params, traj, bootstrap):
        T, E = traj.action.shape
        logits, values = trajectory_logits_values(params, cfg, traj)
        actions = traj.action.reshape(T * E)
        logp_now, rho = _rho(logits, actions, traj.logp)
        # V-trace runs on (E, T) matrices; the flattened batch is time-major
        vs, pg_adv = vtrace_returns(
            traj.reward.T,
            traj.done.T,
            jax.lax.stop_gradient(values).reshape(T, E).T,
            jax.lax.stop_gradient(bootstrap),
            rho.reshape(T, E).T,
            hp.gamma,
            rho_bar,
            c_bar,
        )
        vs = jax.lax.stop_gradient(vs.T.reshape(T * E))
        pg_adv = jax.lax.stop_gradient(pg_adv.T.reshape(T * E))
        logp_all = jax.nn.log_softmax(logits)
        policy_loss = -jnp.mean(pg_adv * logp_now)
        entropy = -jnp.mean(jnp.sum(jnp.exp(logp_all) * logp_all, axis=-1))
        value_loss = jnp.mean(jnp.square(vs - values))
        total = policy_loss - hp.entropy_beta * entropy \
            + hp.value_coef * value_loss
        return total, {
            "policy_loss": policy_loss,
            "value_loss": value_loss,
            "entropy": entropy,
        }, rho

    def loss_fn(params, traj, bootstrap):
        fn = loss_sync if exact_sync else loss_vtrace
        total, metrics, rho = fn(params, traj, bootstrap)
        metrics["rho_mean"] = jnp.mean(rho)
        metrics["rho_clip_frac"] = jnp.mean((rho > rho_bar).astype(jnp.float32))
        metrics["c_clip_frac"] = jnp.mean((rho > c_bar).astype(jnp.float32))
        return total, metrics

    def _update(params, opt_state, traj, last_obs, step):
        _, bootstrap = act(params, last_obs)  # V(s_{tmax+1}) under learner params
        bootstrap = jax.lax.stop_gradient(bootstrap)
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, traj, bootstrap
        )
        lr = lr_schedule(step)
        params, opt_state = optimizer.update(grads, opt_state, params, lr)
        metrics = dict(metrics)
        metrics["loss"] = loss
        metrics["reward_sum"] = jnp.sum(traj.reward)
        metrics["episodes"] = jnp.sum(traj.done)
        return params, opt_state, metrics

    if not fused_publish:
        return _update

    def learner_step(params, opt_state, traj, last_obs, step, publish_dst):
        del publish_dst  # donation target only: its buffers back `published`
        params, opt_state, metrics = _update(
            params, opt_state, traj, last_obs, step
        )
        # bitwise snapshot for the actors — a copy op, so donating `params`
        # at the jit boundary can never invalidate what actors read
        published = jax.tree_util.tree_map(lambda a: a.copy(), params)
        return params, opt_state, published, metrics

    return learner_step


def make_sharded_learner_step(agent, optimizer, lr_schedule, mesh,
                              rho_bar: float = 1.0, c_bar: float = 1.0,
                              fused_publish: bool = True) -> Callable:
    """The mesh-plane twin of ``make_learner_step``: jitted with shardings.

    ``mesh`` is a 1-axis ``("data",)`` rollout mesh
    (``repro.launch.mesh.make_rollout_mesh``). Inputs arrive pre-sharded —
    the trajectory/bootstrap batch env-axis-partitioned over ``"data"``
    (``MeshTrajectoryRing.get`` assembles exactly that), params/opt
    state/publish buffer replicated — and every output is pinned replicated,
    which is what makes XLA all-reduce the per-device partial gradients
    across the data axis inside the step. Donation semantics are inherited
    unchanged from the flat step: with ``fused_publish`` (the orchestrator's
    configuration) params, opt state and the stale publish target are
    donated and alias their shard-identical outputs.

    Returns the *jitted* callable (unlike ``make_learner_step``, which
    leaves jitting to the caller): the sharding spec is part of the step's
    identity here.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    step = make_learner_step(agent, optimizer, lr_schedule, rho_bar=rho_bar,
                             c_bar=c_bar, fused_publish=fused_publish)
    replicated = NamedSharding(mesh, P())
    # a single sharding broadcasts over the whole output tree: new params,
    # new opt state, published snapshot and the metric scalars are all
    # replicated (the batch means/sums inside the loss already force the
    # cross-device reduction)
    return jax.jit(
        step,
        out_shardings=replicated,
        donate_argnums=(0, 1, 5) if fused_publish else (0, 1),
    )
