"""The pipeline's learning half: importance-corrected PAAC update.

The learner consumes rollouts that may be up to ``queue_depth`` updates
stale. Following GA3C/V-trace, each step is reweighted by the truncated
importance ratio

    ρ_t = min(ρ̄, π_learner(a_t|s_t) / π_behaviour(a_t|s_t))

where the behaviour log-prob was recorded at acting time (``Transition.logp``)
and the learner policy is the recompute under current params. ρ̄ → ∞
disables the correction, recovering the synchronous PAAC loss exactly when
the data is on-policy — the equivalence the pipeline tests pin down.

``make_learner_step`` returns a jittable
``(params, opt_state, traj, last_obs, step) -> (params, opt_state, metrics)``
— the learning half of ``PAACAgent.make_train_step`` with the rollout
replaced by a queue payload. The synchronous ``HostEnvPool`` driver in
``repro.core.framework`` reuses the same step (with ρ̄ huge), so sync and
pipelined backends differ only in overlap, not in math.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.agents.paac import paac_losses, trajectory_forward


def make_learner_step(agent, optimizer, lr_schedule,
                      rho_bar: float = 1.0) -> Callable:
    """Build the pipelined learner's jittable update step for a PAAC agent."""
    cfg, hp = agent.cfg, agent.hp
    act = agent.act_fn()

    def loss_fn(params, traj, bootstrap):
        logits, values, actions, returns = trajectory_forward(
            params, cfg, hp, traj, bootstrap
        )
        logp_now = jnp.take_along_axis(
            jax.nn.log_softmax(logits), actions[:, None], axis=1
        )[:, 0]
        rho = jnp.exp(
            logp_now - traj.logp.reshape(logp_now.shape).astype(jnp.float32)
        )
        rho = jax.lax.stop_gradient(rho)
        weights = jnp.minimum(rho, rho_bar)
        total, metrics = paac_losses(
            logits, values, actions, returns, hp.entropy_beta, hp.value_coef,
            weights=weights,
        )
        metrics["rho_mean"] = jnp.mean(rho)
        metrics["rho_clip_frac"] = jnp.mean((rho > rho_bar).astype(jnp.float32))
        return total, metrics

    def learner_step(params, opt_state, traj, last_obs, step):
        _, bootstrap = act(params, last_obs)  # V(s_{tmax+1}) under learner params
        bootstrap = jax.lax.stop_gradient(bootstrap)
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, traj, bootstrap
        )
        lr = lr_schedule(step)
        params, opt_state = optimizer.update(grads, opt_state, params, lr)
        metrics = dict(metrics)
        metrics["loss"] = loss
        metrics["reward_sum"] = jnp.sum(traj.reward)
        metrics["episodes"] = jnp.sum(traj.done)
        return params, opt_state, metrics

    return learner_step
