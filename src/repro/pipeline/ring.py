"""Device-resident trajectory ring — the pipeline's fast queue plane.

``TrajectoryQueue`` (the *host plane*) carries numpy payloads: correct for
``HostEnvPool``, whose rollouts are born on the host, but a GA3C-style leak
for JAX-native envs — every trajectory would be staged to host memory and
re-uploaded by the learner, which is exactly the host↔device round trip the
paper's single-machine design exists to avoid (Babaeizadeh et al., 2017
measured the staging queues as GA3C's dominant overhead).

``DeviceTrajectoryRing`` is the *device plane*: a preallocated ring of
``depth`` slots whose payloads are device arrays end to end. Producers
(actor threads) deposit their jitted collector's output ``Transition``
directly into a slot; the consumer (learner) takes slots in ticket order
with **sole ownership** — ``get()`` clears the ring's reference, so the
moment the fused learner step has read a slot's arrays their device memory
returns to the allocator for the next collect, instead of lingering behind
a queue reference until some later drain. Nothing crosses the PCIe/host
boundary at any point.

Why slots hold references rather than literally aliased buffers: JAX arrays
are immutable from Python, so "writing into" a preallocated device buffer
cannot be expressed as a pointer write — ownership handoff is the
JAX-native realization. The ring still bounds device memory exactly the way
a mutable slot ring would — at most ``depth`` rollouts live, enforced by
blocking producers — and the reuse chain (collector output → slot →
consumed and retired by the learner step → allocator hands the pages to the
next collect) keeps the steady state allocation-flat. (The learner's
params/opt-state side *does* use literal donation — see
``PingPongParamSlot`` — because there the outputs are shape-identical to
the inputs, which is what XLA input/output aliasing requires.)

Ordering and shutdown semantics are identical to ``TrajectoryQueue`` (same
``put``/``get``/``producer_done``/``close``/idle-accounting surface, same
``CLOSED``/``QueueClosed``/``queue.Full`` signals), so ``ActorThread`` and
the orchestrator drive either plane interchangeably. Every accepted ``put``
is stamped with a monotonically increasing *ticket*; the consumer drains in
ticket order, which is arrival order — multi-producer FIFO, never dropping.

The ring additionally enforces its plane: payloads must be device-resident
(``jax.Array`` leaves). A numpy leaf on the fast path is a bug — it means a
host staging step crept back in — and raises ``TypeError`` immediately
rather than silently re-introducing the round trip.

With a multi-device mesh the ring grows **per-device sub-rings**
(``MeshTrajectoryRing``): one single-producer ``DeviceTrajectoryRing`` per
mesh device, each fed by the actor lane pinned to that device, and a
``get()`` that takes one seq-aligned sub-rollout from *every* lane and
reassembles them into a single globally-sharded ``Rollout`` via
``jax.make_array_from_single_device_arrays`` — the env axis partitioned
over the mesh's ``"data"`` axis with zero host round trips (the global
array is a view of the per-device buffers, not a copy).

"""
from __future__ import annotations

import queue as _queue
import threading
import time
from typing import Any, List, Optional

import jax
import numpy as np

from repro.analysis.lockcheck import make_condition
from repro.pipeline.queue import CLOSED, QueueClosed
from repro.telemetry.spans import (
    MESH_REASSEMBLE,
    QUEUE_GET_WAIT,
    QUEUE_PUT_WAIT,
    SpanEmitter,
)

__all__ = ["DeviceTrajectoryRing", "MeshTrajectoryRing"]


class _Slot:
    """One preallocated ring slot: a payload reference plus its ticket tag."""

    __slots__ = ("payload", "ticket", "full")

    def __init__(self):
        self.payload: Any = None
        self.ticket: int = -1
        self.full: bool = False


def _assert_device_resident(payload) -> None:
    """Reject host-memory (numpy) array leaves. Non-array metadata (ints,
    callables) rides along untouched — only the tensor payload is policed."""
    for leaf in jax.tree_util.tree_leaves(payload):
        if isinstance(leaf, (np.ndarray, np.generic)):
            raise TypeError(
                "DeviceTrajectoryRing payloads must be device arrays; got "
                f"{type(leaf).__name__} — a host staging step crept into the "
                "device plane (use TrajectoryQueue for host payloads)"
            )


class DeviceTrajectoryRing:
    """Bounded multi-producer ring of on-device rollout slots.

    Drop-in for ``TrajectoryQueue`` on the device plane: same blocking
    ``put``/``get`` with idle-time accounting, same multi-producer
    ``producer_done`` refcounted shutdown and hard ``close()`` abort. Depth
    bounds device memory (at most ``depth`` rollouts in flight); every
    accepted put is ticket-stamped and consumed exactly once, in order.
    """

    def __init__(self, depth: int = 2, producers: int = 1, telemetry=None,
                 name: str = "ring"):
        if depth < 1:
            raise ValueError(f"ring depth must be >= 1, got {depth}")
        if producers < 1:
            raise ValueError(f"producers must be >= 1, got {producers}")
        self.depth = depth
        self._slots: List[_Slot] = [_Slot() for _ in range(depth)]
        self._tail = 0  # next ticket to issue (producer side)
        self._head = 0  # next ticket to consume (learner side)
        self._cond = make_condition("ring.cond")
        self._producers_left = producers
        self._closed = False
        # span-derived idle accounting: same contract as TrajectoryQueue —
        # every put/get records its full duration into the ring's aggregate
        # track, and put_wait_s/get_wait_s read the per-category totals
        if telemetry is not None:
            self.span_emitter = telemetry.emitter(name, locked=True)
        else:
            self.span_emitter = SpanEmitter(name, locked=True)

    @property
    def put_wait_s(self) -> float:
        """Producers idle (ring full), all actors merged — span-derived."""
        return self.span_emitter.total(QUEUE_PUT_WAIT)

    @property
    def get_wait_s(self) -> float:
        """Learner idle (ring empty) — span-derived."""
        return self.span_emitter.total(QUEUE_GET_WAIT)

    # -- producer side -------------------------------------------------------
    # hot-path
    def put(self, item: Any, timeout: Optional[float] = None) -> None:
        """Deposit a device-resident payload into the next free slot.

        Blocks while all ``depth`` slots are live (backpressure — the memory
        bound), accumulating producer idle time. Raises ``QueueClosed`` if
        the ring is (or becomes, while blocked) closed, stdlib ``queue.Full``
        on timeout, and ``TypeError`` for host-memory payloads.
        """
        _assert_device_resident(item)
        t0 = time.perf_counter()
        try:
            with self._cond:
                ok = self._cond.wait_for(
                    lambda: self._closed or self._tail - self._head < self.depth,
                    timeout=timeout,
                )
                if self._closed:
                    raise QueueClosed("put() on a closed DeviceTrajectoryRing")
                if not ok:
                    raise _queue.Full
                ticket = self._tail
                self._tail = ticket + 1
                slot = self._slots[ticket % self.depth]
                assert not slot.full, "ring invariant: issued slot must be free"
                slot.payload = item
                slot.ticket = ticket
                slot.full = True
                self._cond.notify_all()
        finally:
            self.span_emitter.record(QUEUE_PUT_WAIT, t0)

    # -- consumer side -------------------------------------------------------
    # hot-path
    def get(self, timeout: Optional[float] = None) -> Any:
        """Take the oldest full slot's payload, transferring ownership.

        The slot's reference is cleared before returning, so the caller is
        the payload's sole owner: once its jitted consumer retires the
        arrays, the slot's device memory goes back to the allocator
        immediately. Returns ``CLOSED`` once closed and drained; raises
        stdlib ``queue.Empty`` on timeout.
        """
        t0 = time.perf_counter()
        try:
            with self._cond:
                if not self._cond.wait_for(
                    lambda: self._slots[self._head % self.depth].full
                    or self._closed,
                    timeout=timeout,
                ):
                    raise _queue.Empty
                slot = self._slots[self._head % self.depth]
                if not slot.full:
                    return CLOSED
                item = slot.payload
                # ownership transfer: drop the ring's reference so the
                # learner's donation is the only live handle to the arrays
                slot.payload = None
                slot.ticket = -1
                slot.full = False
                self._head += 1
                self._cond.notify_all()
                return item
        finally:
            self.span_emitter.record(QUEUE_GET_WAIT, t0)

    # -- shutdown (same protocol as TrajectoryQueue) -------------------------
    def producer_done(self) -> None:
        """One producer finished its quota; the stream closes when the last
        producer checks out (the consumer drains, then sees ``CLOSED``)."""
        with self._cond:
            self._producers_left -= 1
            if self._producers_left <= 0:
                self._closed = True
            self._cond.notify_all()

    def close(self) -> None:
        """Hard abort: wakes blocked producers (``QueueClosed``) and the
        consumer (``CLOSED`` after the remaining slots drain). Idempotent."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()

    def qsize(self) -> int:
        with self._cond:
            return self._tail - self._head

    @property
    def tickets_issued(self) -> int:
        """Total puts accepted over the ring's lifetime (monotone)."""
        with self._cond:
            return self._tail

    @property
    def tickets_consumed(self) -> int:
        """Total gets delivered over the ring's lifetime (monotone);
        ``issued - consumed`` at checkpoint time is the in-flight window a
        resume re-collects."""
        with self._cond:
            return self._head


# ---------------------------------------------------------------------------
# Mesh plane — per-device sub-rings feeding a sharded learner
# ---------------------------------------------------------------------------


class _MeshLane:
    """One actor lane's view of a ``MeshTrajectoryRing``.

    Exposes the producer half of the queue surface (``put`` /
    ``producer_done`` / ``close``) bound to the lane's own sub-ring, so
    ``ActorThread`` drives a mesh lane exactly like any other queue plane.
    ``put`` additionally enforces the lane's *device* contract: every array
    leaf must be a single-device array committed to this lane's mesh device
    — a leaf on the wrong device would silently turn the ``get()``-side
    reassembly into a cross-device copy (or fail deep inside
    ``make_array_from_single_device_arrays``), so it raises here, at the
    boundary, with the lane and device named.
    """

    def __init__(self, ring: "MeshTrajectoryRing", index: int, device):
        self._ring = ring
        self._sub = ring._subs[index]
        self._index = index
        self._device = device
        self._validated: Any = None  # last payload to pass the device check

    # hot-path
    def put(self, item: Any, timeout: Optional[float] = None) -> None:
        # ActorBase._put retries a blocked put with short timeouts; the
        # payload object is unchanged across retries, so validate it once
        if item is not self._validated:
            for leaf in jax.tree_util.tree_leaves(item):
                if (isinstance(leaf, jax.Array)
                        and leaf.devices() != {self._device}):
                    raise TypeError(
                        f"mesh lane {self._index} (device {self._device}) "
                        f"got a payload leaf on "
                        f"{sorted(leaf.devices(), key=str)} — each lane's "
                        "rollouts must be collected on its own mesh device "
                        "(actor state mis-pinned?)"
                    )
            self._validated = item
        self._sub.put(item, timeout=timeout)
        # the cache only needs to survive the Full-retry loop: clearing it
        # on success keeps the ring's ownership contract intact (a lane
        # must not pin a consumed rollout's device memory behind a stale
        # validation reference)
        self._validated = None

    def producer_done(self) -> None:
        self._sub.producer_done()

    def close(self) -> None:
        # a lane abort (actor died) aborts the whole stream: the learner can
        # never assemble another full batch without this lane
        self._ring.close()

    @property
    def put_wait_s(self) -> float:
        return self._sub.put_wait_s


class MeshTrajectoryRing:
    """Per-device sub-rings + sharded reassembly: the mesh queue plane.

    One single-producer ``DeviceTrajectoryRing`` per device of a 1-axis
    ``("data",)`` mesh (``repro.launch.mesh.make_rollout_mesh``). Actor lane
    ``i`` (pinned to ``mesh`` device ``i``) produces into ``lane(i)``;
    ``get()`` takes the oldest payload from *every* sub-ring — one
    seq-aligned sub-rollout per lane — and reassembles a single global
    ``Rollout`` whose array leaves are sharded over the mesh's data axis via
    ``jax.make_array_from_single_device_arrays``: a zero-copy view of the
    per-device buffers, never a host (or cross-device) transfer. Sole-slot
    ownership transfers exactly as in the flat ring — after ``get()`` the
    assembled global array holds the only references, so the buffers return
    to their device allocators the moment the sharded learner step retires
    them.

    Payload contract: items are ``repro.pipeline.actor.Rollout``s with
    time-major ``(T, E, ...)`` trajectory leaves and batch-leading
    ``(E, ...)`` ``last_obs``; every lane must produce identical shapes
    (equal env shards). The assembled rollout spans ``(T, D*E, ...)`` /
    ``(D*E, ...)``, carries ``actor_id=-1`` (mesh-global), the common seq,
    and the *minimum* behaviour version across lanes (staleness reports the
    worst lane). Backpressure is per-lane (each sub-ring blocks its own
    producer at ``depth``); ``close()`` aborts every lane, and the stream
    ends (``CLOSED``) once all lanes' producers checked out and drained.
    """

    def __init__(self, depth: int, mesh, telemetry=None):
        from repro.distributed.sharding import batch_sharding, traj_sharding

        if tuple(mesh.axis_names) != ("data",):
            raise ValueError(
                f"MeshTrajectoryRing needs a 1-axis ('data',) rollout mesh "
                f"(make_rollout_mesh), got axes {tuple(mesh.axis_names)}"
            )
        self.mesh = mesh
        self.devices = list(mesh.devices.flat)
        self.depth = depth
        self._subs = [DeviceTrajectoryRing(depth, producers=1,
                                           telemetry=telemetry,
                                           name=f"mesh.lane{i}")
                      for i in range(len(self.devices))]
        self._lanes = [_MeshLane(self, i, d)
                       for i, d in enumerate(self.devices)]
        self._traj_sharding = lambda ndim: traj_sharding(mesh, ndim)
        self._batch_sharding = lambda ndim: batch_sharding(mesh, ndim)
        # sub-rollouts already popped for a batch whose later lanes timed
        # out: resumed by the next get() (single consumer), so a timeout can
        # never lose a lane's payload or desynchronize the seq streams
        self._pending: List[Any] = []
        # the consumer-side track: the outer get (all-lane wait + assembly)
        # as queue.get_wait spans with the zero-copy reassembly nested as
        # mesh.reassemble. Single consumer => single writer, no lock.
        if telemetry is not None:
            self.span_emitter = telemetry.emitter("mesh")
        else:
            self.span_emitter = SpanEmitter("mesh")

    @property
    def get_wait_s(self) -> float:
        """Learner idle (any lane empty) — span-derived, full outer-get
        duration exactly as the pre-telemetry counter accumulated it."""
        return self.span_emitter.total(QUEUE_GET_WAIT)

    @property
    def n_lanes(self) -> int:
        return len(self._subs)

    def lane(self, i: int) -> _MeshLane:
        """The producer facade actor lane ``i`` drives (device ``i``)."""
        return self._lanes[i]

    @property
    def put_wait_s(self) -> float:
        """Merged producer idle time across all lanes."""
        return sum(s.put_wait_s for s in self._subs)

    def qsize(self) -> int:
        """Complete batches ready to assemble (min over lanes)."""
        return min(s.qsize() for s in self._subs)

    @property
    def tickets_issued(self) -> int:
        """Per-lane accepted put counts (the never-drop audit surface)."""
        return [s.tickets_issued for s in self._subs]

    def _assemble(self, parts: List[Any]):
        """Zero-copy reassembly: D per-device Rollouts -> one sharded one."""
        from repro.pipeline.actor import Rollout

        D = len(parts)
        seqs = [p.seq for p in parts]
        assert len(set(seqs)) == 1, (
            f"mesh lanes desynchronized: per-lane seqs {seqs} — each lane "
            "must contribute exactly one sub-rollout per learner update"
        )

        def leaf(*ls):
            l0 = ls[0]
            gshape = (l0.shape[0], l0.shape[1] * D) + l0.shape[2:]
            return jax.make_array_from_single_device_arrays(
                gshape, self._traj_sharding(l0.ndim), list(ls)
            )

        traj = jax.tree_util.tree_map(leaf, *[p.traj for p in parts])
        l0 = parts[0].last_obs
        last_obs = jax.make_array_from_single_device_arrays(
            (l0.shape[0] * D,) + l0.shape[1:],
            self._batch_sharding(l0.ndim),
            [p.last_obs for p in parts],
        )
        return Rollout(
            traj=traj,
            last_obs=last_obs,
            behavior_version=min(p.behavior_version for p in parts),
            actor_id=-1,  # mesh-global: assembled from every lane
            seq=seqs[0],
            release=None,  # device plane: the learner's consume retires it
        )

    # hot-path
    def get(self, timeout: Optional[float] = None) -> Any:
        """One sharded ``Rollout`` assembled from every lane's oldest slot.

        Blocks until *all* lanes have a payload (the sharded learner step
        needs every shard), accumulating learner idle time. Returns
        ``CLOSED`` once any lane is closed-and-drained — a partial batch can
        never be consumed, so remaining sub-rollouts on other lanes are
        discarded (device arrays; their buffers just return to the
        allocator). Raises stdlib ``queue.Empty`` on timeout.
        """
        self.span_emitter.begin(QUEUE_GET_WAIT)
        t0 = time.perf_counter()
        deadline = None if timeout is None else t0 + timeout
        parts = self._pending
        try:
            for sub in self._subs[len(parts):]:
                remaining = (None if deadline is None
                             else max(deadline - time.perf_counter(), 0.0))
                item = sub.get(timeout=remaining)
                if item is CLOSED:
                    self.close()  # no lane can complete a batch anymore
                    self._pending = []
                    return CLOSED
                parts.append(item)
            self._pending = []
            self.span_emitter.begin(MESH_REASSEMBLE)
            try:
                return self._assemble(parts)
            finally:
                self.span_emitter.end()
        finally:
            self.span_emitter.end()

    def producer_done(self) -> None:
        raise RuntimeError(
            "producer_done() on the mesh ring itself — actors check out "
            "through their lane: ring.lane(i).producer_done()"
        )

    def close(self) -> None:
        """Hard abort: closes every lane's sub-ring. Idempotent."""
        for sub in self._subs:
            sub.close()
