"""Device-resident trajectory ring — the pipeline's fast queue plane.

``TrajectoryQueue`` (the *host plane*) carries numpy payloads: correct for
``HostEnvPool``, whose rollouts are born on the host, but a GA3C-style leak
for JAX-native envs — every trajectory would be staged to host memory and
re-uploaded by the learner, which is exactly the host↔device round trip the
paper's single-machine design exists to avoid (Babaeizadeh et al., 2017
measured the staging queues as GA3C's dominant overhead).

``DeviceTrajectoryRing`` is the *device plane*: a preallocated ring of
``depth`` slots whose payloads are device arrays end to end. Producers
(actor threads) deposit their jitted collector's output ``Transition``
directly into a slot; the consumer (learner) takes slots in ticket order
with **sole ownership** — ``get()`` clears the ring's reference, so the
moment the fused learner step has read a slot's arrays their device memory
returns to the allocator for the next collect, instead of lingering behind
a queue reference until some later drain. Nothing crosses the PCIe/host
boundary at any point.

Why slots hold references rather than literally aliased buffers: JAX arrays
are immutable from Python, so "writing into" a preallocated device buffer
cannot be expressed as a pointer write — ownership handoff is the
JAX-native realization. The ring still bounds device memory exactly the way
a mutable slot ring would — at most ``depth`` rollouts live, enforced by
blocking producers — and the reuse chain (collector output → slot →
consumed and retired by the learner step → allocator hands the pages to the
next collect) keeps the steady state allocation-flat. (The learner's
params/opt-state side *does* use literal donation — see
``PingPongParamSlot`` — because there the outputs are shape-identical to
the inputs, which is what XLA input/output aliasing requires.)

Ordering and shutdown semantics are identical to ``TrajectoryQueue`` (same
``put``/``get``/``producer_done``/``close``/idle-accounting surface, same
``CLOSED``/``QueueClosed``/``queue.Full`` signals), so ``ActorThread`` and
the orchestrator drive either plane interchangeably. Every accepted ``put``
is stamped with a monotonically increasing *ticket*; the consumer drains in
ticket order, which is arrival order — multi-producer FIFO, never dropping.

The ring additionally enforces its plane: payloads must be device-resident
(``jax.Array`` leaves). A numpy leaf on the fast path is a bug — it means a
host staging step crept back in — and raises ``TypeError`` immediately
rather than silently re-introducing the round trip.
"""
from __future__ import annotations

import queue as _queue
import threading
import time
from typing import Any, List, Optional

import jax
import numpy as np

from repro.pipeline.queue import CLOSED, QueueClosed

__all__ = ["DeviceTrajectoryRing"]


class _Slot:
    """One preallocated ring slot: a payload reference plus its ticket tag."""

    __slots__ = ("payload", "ticket", "full")

    def __init__(self):
        self.payload: Any = None
        self.ticket: int = -1
        self.full: bool = False


def _assert_device_resident(payload) -> None:
    """Reject host-memory (numpy) array leaves. Non-array metadata (ints,
    callables) rides along untouched — only the tensor payload is policed."""
    for leaf in jax.tree_util.tree_leaves(payload):
        if isinstance(leaf, (np.ndarray, np.generic)):
            raise TypeError(
                "DeviceTrajectoryRing payloads must be device arrays; got "
                f"{type(leaf).__name__} — a host staging step crept into the "
                "device plane (use TrajectoryQueue for host payloads)"
            )


class DeviceTrajectoryRing:
    """Bounded multi-producer ring of on-device rollout slots.

    Drop-in for ``TrajectoryQueue`` on the device plane: same blocking
    ``put``/``get`` with idle-time accounting, same multi-producer
    ``producer_done`` refcounted shutdown and hard ``close()`` abort. Depth
    bounds device memory (at most ``depth`` rollouts in flight); every
    accepted put is ticket-stamped and consumed exactly once, in order.
    """

    def __init__(self, depth: int = 2, producers: int = 1):
        if depth < 1:
            raise ValueError(f"ring depth must be >= 1, got {depth}")
        if producers < 1:
            raise ValueError(f"producers must be >= 1, got {producers}")
        self.depth = depth
        self._slots: List[_Slot] = [_Slot() for _ in range(depth)]
        self._tail = 0  # next ticket to issue (producer side)
        self._head = 0  # next ticket to consume (learner side)
        self._cond = threading.Condition()
        self._producers_left = producers
        self._closed = False
        self.put_wait_s = 0.0  # producers idle (ring full), all actors merged
        self.get_wait_s = 0.0  # learner idle (ring empty)

    # -- producer side -------------------------------------------------------
    def put(self, item: Any, timeout: Optional[float] = None) -> None:
        """Deposit a device-resident payload into the next free slot.

        Blocks while all ``depth`` slots are live (backpressure — the memory
        bound), accumulating producer idle time. Raises ``QueueClosed`` if
        the ring is (or becomes, while blocked) closed, stdlib ``queue.Full``
        on timeout, and ``TypeError`` for host-memory payloads.
        """
        _assert_device_resident(item)
        t0 = time.perf_counter()
        try:
            with self._cond:
                ok = self._cond.wait_for(
                    lambda: self._closed or self._tail - self._head < self.depth,
                    timeout=timeout,
                )
                if self._closed:
                    raise QueueClosed("put() on a closed DeviceTrajectoryRing")
                if not ok:
                    raise _queue.Full
                ticket = self._tail
                self._tail = ticket + 1
                slot = self._slots[ticket % self.depth]
                assert not slot.full, "ring invariant: issued slot must be free"
                slot.payload = item
                slot.ticket = ticket
                slot.full = True
                self._cond.notify_all()
        finally:
            self.put_wait_s += time.perf_counter() - t0

    # -- consumer side -------------------------------------------------------
    def get(self, timeout: Optional[float] = None) -> Any:
        """Take the oldest full slot's payload, transferring ownership.

        The slot's reference is cleared before returning, so the caller is
        the payload's sole owner: once its jitted consumer retires the
        arrays, the slot's device memory goes back to the allocator
        immediately. Returns ``CLOSED`` once closed and drained; raises
        stdlib ``queue.Empty`` on timeout.
        """
        t0 = time.perf_counter()
        try:
            with self._cond:
                if not self._cond.wait_for(
                    lambda: self._slots[self._head % self.depth].full
                    or self._closed,
                    timeout=timeout,
                ):
                    raise _queue.Empty
                slot = self._slots[self._head % self.depth]
                if not slot.full:
                    return CLOSED
                item = slot.payload
                # ownership transfer: drop the ring's reference so the
                # learner's donation is the only live handle to the arrays
                slot.payload = None
                slot.ticket = -1
                slot.full = False
                self._head += 1
                self._cond.notify_all()
                return item
        finally:
            self.get_wait_s += time.perf_counter() - t0

    # -- shutdown (same protocol as TrajectoryQueue) -------------------------
    def producer_done(self) -> None:
        """One producer finished its quota; the stream closes when the last
        producer checks out (the consumer drains, then sees ``CLOSED``)."""
        with self._cond:
            self._producers_left -= 1
            if self._producers_left <= 0:
                self._closed = True
            self._cond.notify_all()

    def close(self) -> None:
        """Hard abort: wakes blocked producers (``QueueClosed``) and the
        consumer (``CLOSED`` after the remaining slots drain). Idempotent."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()

    def qsize(self) -> int:
        with self._cond:
            return self._tail - self._head

    @property
    def tickets_issued(self) -> int:
        """Total puts accepted over the ring's lifetime (monotone)."""
        with self._cond:
            return self._tail
