"""Shared-memory plumbing for the multi-process actor plane.

The process backend moves rollout collection into worker subprocesses —
the only way to scale *GIL-holding* Python emulators, where the thread
plane's env stepping serializes no matter how many actor replicas run.
Everything that crosses the process boundary in steady state rides
``multiprocessing.shared_memory`` so the per-rollout cost is a memcpy, not
a pickle:

* ``ShmStagingSet`` — the process twin of ``repro.pipeline.actor.
  StagingSet``: one ``(t_max, E, ...)`` trajectory plus the bootstrap
  observation, laid out in a single named shared-memory block. The child
  writes rows in place during collection (``collect_host(staging=...)``)
  and the parent's drainer wraps *views of the same block* into the
  ``Rollout`` it feeds the ``TrajectoryQueue`` — the payload is never
  copied or pickled, only its index is. Sets follow the exact
  ``HostStagingRing`` sizing/lease contract (``queue_depth + 2`` per
  actor: depth enqueued + 1 consumed-but-unreleased + 1 being written);
  the free-list itself lives in an ``mp.Queue`` of set indices (see
  ``repro.pipeline.worker``), since the lease must hop processes.

* ``ShmParamSlot`` — ``PingPongParamSlot``'s reserve/commit protocol over
  shared memory: two alternating param buffers, per-buffer cross-process
  reader counts, and a monotone version, all guarded by one
  ``mp.Condition``. The learner ``reserve``s buffer ``v % 2`` (blocks
  until its readers drain), ``commit``s the new params into it (one
  device→host copy per update) and bumps the version; each worker
  ``acquire``s a read lease only long enough to copy the newest buffer
  onto its own device, so the learner's reserve wait is bounded by one
  param copy — strictly shorter than the thread plane's one-collect bound.
  ``ShmParamSlot.handle()`` is the picklable half a spawned child rebuilds
  its ``ShmParamView`` from.

Ownership: the parent creates every segment and is the only process that
``unlink``s (``ShmStagingSet.unlink`` / ``ShmParamSlot.unlink``); children
attach by name and only ever ``close`` their mappings. Attach-side
mappings are untracked (``_attach``) so a child's exit cannot tear down
segments the parent still serves from.
"""
from __future__ import annotations

import math
import os
from typing import Any, List, Optional, Tuple

import numpy as np
from multiprocessing import shared_memory

import jax

from repro.analysis.lockcheck import make_condition
from repro.core.rollout import Transition
from repro.pipeline.actor import staging_fields

__all__ = ["ShmStagingSet", "ShmParamSlot", "ShmParamView"]

_ALIGN = 64  # leaf/field alignment inside a block (cache line)


def _attach(name: str) -> shared_memory.SharedMemory:
    """Attach to an existing segment, untracked where the runtime allows.

    Python 3.13's ``track=False`` skips resource-tracker registration for
    attachments. On 3.10–3.12 the attach *is* registered (bpo-39959), but
    workers spawned by ``multiprocessing`` share the parent's tracker
    process, so the duplicate registration collapses into the parent's own
    (``cache`` is a set) and teardown stays balanced: do NOT "fix" this by
    unregistering after attach — that removes the parent's entry from the
    shared tracker and double-frees at unlink."""
    try:
        return shared_memory.SharedMemory(name=name, track=False)
    except TypeError:  # pragma: no cover - cpython < 3.13
        return shared_memory.SharedMemory(name=name)


def _quiet_close(shm: shared_memory.SharedMemory) -> None:
    """``shm.close()`` that survives live views. If a numpy view (a carried
    bootstrap obs, an unconsumed payload riding a reference cycle) still
    pins the mapping, ``mmap.close`` raises BufferError — and would keep
    raising from ``SharedMemory.__del__`` at GC time. Detach the
    bookkeeping instead: drop the handle's mmap/fd so no retry ever fires;
    the mapping itself is freed when the last view dies (the views hold the
    mmap object alive until then)."""
    try:
        shm.close()
    except BufferError:
        shm._mmap = None
        if getattr(shm, "_fd", -1) >= 0:
            os.close(shm._fd)
            shm._fd = -1


def _layout(fields: List[Tuple[Tuple[int, ...], np.dtype]]):
    """(offset per field, total bytes) for one aligned shared block."""
    offsets, off = [], 0
    for shape, dtype in fields:
        off = _ALIGN * math.ceil(off / _ALIGN)
        offsets.append(off)
        off += int(np.prod(shape, dtype=np.int64)) * np.dtype(dtype).itemsize
    return offsets, max(off, 1)


def _views(shm: shared_memory.SharedMemory, fields, offsets) -> List[np.ndarray]:
    out = []
    for (shape, dtype), off in zip(fields, offsets):
        n = int(np.prod(shape, dtype=np.int64))
        out.append(
            np.frombuffer(shm.buf, dtype=dtype, count=n, offset=off)
            .reshape(shape)
        )
    return out


class ShmStagingSet:
    """One reusable cross-process rollout payload in a named shm block.

    Same field set and write-in-place discipline as ``StagingSet`` (the
    arrays satisfy ``collect_host``'s ``staging=`` contract), but the
    parent and any child that knows ``self.name`` see the *same* memory.
    Construct with ``create=True`` (parent, allocates + zero-fills) or
    ``create=False`` with the creator's ``name`` (child, attaches).
    """

    def __init__(self, t_max: int, n_envs: int, obs_shape: Tuple[int, ...],
                 obs_dtype, name: Optional[str] = None, create: bool = True):
        # the one layout shared with the thread plane's StagingSet
        fields = staging_fields(t_max, n_envs, obs_shape, obs_dtype)
        offsets, nbytes = _layout(fields)
        if create:
            # POSIX shm is zero-filled on allocation — no memset needed
            self.shm = shared_memory.SharedMemory(create=True, size=nbytes)
        else:
            if name is None:
                raise ValueError("attaching (create=False) requires a name")
            self.shm = _attach(name)
        self.name = self.shm.name
        self._created = create
        views = _views(self.shm, fields, offsets)
        self.traj = Transition(*views[:6])
        self.last_obs = views[6]

    def close(self) -> None:
        """Drop this process's mapping. Tolerates live views (the carried
        bootstrap obs, an unconsumed payload): the mmap then stays pinned
        until those die with the process, which is exactly the semantics a
        teardown wants — never a crash in a ``finally``."""
        self.traj = None
        self.last_obs = None
        _quiet_close(self.shm)

    def unlink(self) -> None:
        """Destroy the segment (creator only, after every mapping closed)."""
        if self._created:
            self.shm.unlink()


class ShmParamSlot:
    """Parent half of the cross-process ping-pong param broadcast.

    Mirrors ``PingPongParamSlot``'s learner-side protocol::

        ok = slot.reserve(v)      # blocks until shm readers[v % 2] == 0
        slot.commit(tree, v)      # shm buffer v%2 <- tree, version = v

    with reader leases taken by worker-side ``ShmParamView.acquire`` /
    ``release``. ``reserve`` returns ``False`` on timeout (mirroring the
    thread slot's ``None``), never silently proceeds. The flattened leaf
    layout (shapes/dtypes/offsets) is fixed at construction from a
    template tree; ``handle()`` packages it, the two segment names, and
    the shared synchronization primitives for a spawned child.
    """

    def __init__(self, template_tree: Any, ctx, version: int = 0,
                 max_readers: int = 16):
        # force real host copies for seeding: np.asarray of a CPU jax array
        # can alias the device buffer, and the learner donates its initial
        # params on the very first update
        flat, treedef = jax.tree_util.tree_flatten(template_tree)
        leaves = [np.array(l) for l in flat]
        fields = [(l.shape, l.dtype) for l in leaves]
        self._fields = fields
        # what children rebuild the tree from: shape/dtype placeholders with
        # the original structure — bytes to pickle, not a param-sized copy
        self._spec_tree = jax.tree_util.tree_unflatten(
            treedef, [_LeafSpec(s, d) for s, d in fields]
        )
        self._offsets, nbytes = _layout(fields)
        self._shms = [shared_memory.SharedMemory(create=True, size=nbytes)
                      for _ in range(2)]
        self._bufs = [_views(s, fields, self._offsets) for s in self._shms]
        self._cond = make_condition("shm.param_slot", inner=ctx.Condition())
        self._version = ctx.Value("q", version, lock=False)
        self._readers = [ctx.Value("i", 0, lock=False) for _ in range(2)]
        # per-reader lease counts, parallel to _readers: lease slot j is
        # worker j's outstanding acquires on that buffer. Lets a reserve
        # timeout name the holder, and lets the supervisor revoke() a dead
        # worker's leaked lease instead of deadlocking the learner.
        self._leases = [ctx.Array("i", max_readers, lock=False)
                        for _ in range(2)]
        for buf in self._bufs:  # version 0 is readable before any commit
            for dst, src in zip(buf, leaves):
                np.copyto(dst, src)

    # -- learner side --------------------------------------------------------
    def reserve(self, version: int, timeout: Optional[float] = None) -> bool:
        """Claim shm buffer ``version % 2``: wait out its readers."""
        idx = version % 2
        with self._cond:
            return self._cond.wait_for(
                lambda: self._readers[idx].value == 0, timeout=timeout
            )

    def commit(self, tree: Any, version: int) -> None:
        """Install ``tree`` (device or host) into the reserved buffer and
        publish ``version`` — one D2H copy per leaf, then notify waiters."""
        idx = version % 2
        leaves = [np.asarray(l) for l in jax.tree_util.tree_leaves(tree)]
        for dst, src in zip(self._bufs[idx], leaves):
            np.copyto(dst, src)
        with self._cond:
            assert self._readers[idx].value == 0, "commit while buffer leased"
            self._version.value = version
            self._cond.notify_all()

    def publish(self, tree: Any, version: int,
                timeout: Optional[float] = 60.0) -> None:
        """reserve + commit, loud on lease starvation (also the run-start
        reset path: workers are idle between runs, so rewinding the version
        to 0 cannot race a reader)."""
        if not self.reserve(version, timeout=timeout):
            held = ", ".join(self.holders(version % 2)) or "an unlabeled party"
            raise RuntimeError(
                f"ShmParamSlot.publish(version={version}): reserve timed "
                f"out after {timeout}s — buffer {version % 2} is still "
                f"leased by {held} (died holding its lease?)"
            )
        self.commit(tree, version)

    def holders(self, idx: int) -> List[str]:
        """Labels of the workers currently leasing shm buffer ``idx``."""
        with self._cond:
            return [f"worker {j}" for j in range(len(self._leases[idx]))
                    if self._leases[idx][j] > 0]

    def revoke(self, reader_id: int) -> int:
        """Clear every lease ``reader_id`` still holds (supervisor path: a
        worker that died mid-acquire). Returns leases cleared."""
        cleared = 0
        with self._cond:
            for idx in (0, 1):
                n = self._leases[idx][reader_id]
                if n > 0:
                    self._leases[idx][reader_id] = 0
                    self._readers[idx].value -= n
                    cleared += n
            if cleared:
                self._cond.notify_all()
        return cleared

    def handle(self) -> "ShmParamHandle":
        return ShmParamHandle(
            names=tuple(s.name for s in self._shms),
            template=self._spec_tree,
            cond=self._cond,
            version=self._version,
            readers=tuple(self._readers),
            leases=tuple(self._leases),
        )

    def close(self) -> None:
        self._bufs = None
        for s in self._shms:
            _quiet_close(s)

    def unlink(self) -> None:
        for s in self._shms:
            s.unlink()


class _LeafSpec:
    """Shape/dtype placeholder leaf: lets the param tree's *structure*
    cross the process boundary without shipping (or pinning) a full host
    copy of the params — the values already live in the shm buffers."""

    __slots__ = ("shape", "dtype")

    def __init__(self, shape, dtype):
        self.shape = tuple(shape)
        self.dtype = np.dtype(dtype)

    def __getstate__(self):
        return self.shape, self.dtype.str

    def __setstate__(self, state):
        self.shape = state[0]
        self.dtype = np.dtype(state[1])


class ShmParamHandle:
    """Picklable ingredients for a worker-side ``ShmParamView``.

    ``template`` is the param tree with every leaf replaced by a
    ``_LeafSpec`` — structure and layout only, no values."""

    def __init__(self, names, template, cond, version, readers, leases=None):
        self.names = names
        self.template = template
        self.cond = cond
        self.version = version
        self.readers = readers
        self.leases = leases  # per-reader lease arrays (None: untracked)


class ShmParamView:
    """Worker half: lease-bracketed reads of the newest published params.

    ``acquire`` takes the read lease (``readers[v % 2] += 1``) and returns
    host views of the leased buffer plus its version; the caller copies
    them out (e.g. onto its device) and ``release``s. ``read_params``
    packages that into one call returning a fresh jnp tree, holding the
    lease only for the copy. ``wait_for`` is the lockstep gate.
    """

    def __init__(self, handle: ShmParamHandle, reader_id: Optional[int] = None):
        specs, self._treedef = jax.tree_util.tree_flatten(handle.template)
        fields = [(s.shape, s.dtype) for s in specs]
        offsets, _ = _layout(fields)
        self._shms = [_attach(n) for n in handle.names]
        self._bufs = [_views(s, fields, offsets) for s in self._shms]
        self._cond = handle.cond
        self._version = handle.version
        self._readers = handle.readers
        # which lease slot this reader marks on acquire (None, or a handle
        # without lease arrays, skips the tracking — pre-supervisor protocol)
        self._leases = getattr(handle, "leases", None)
        self._reader_id = reader_id

    def acquire(self) -> Tuple[List[np.ndarray], int]:
        with self._cond:
            v = int(self._version.value)
            self._readers[v % 2].value += 1
            if self._leases is not None and self._reader_id is not None:
                self._leases[v % 2][self._reader_id] += 1
            return self._bufs[v % 2], v

    def release(self, version: int) -> None:
        with self._cond:
            idx = version % 2
            if self._leases is not None and self._reader_id is not None:
                if self._leases[idx][self._reader_id] <= 0:
                    return  # revoked under us — the slot already balanced
                self._leases[idx][self._reader_id] -= 1
            self._readers[idx].value -= 1
            assert self._readers[idx].value >= 0, "unbalanced release"
            self._cond.notify_all()

    def read_params(self) -> Tuple[Any, int]:
        """Newest params as a device tree + their version (lease-bracketed:
        the copy is the entire critical section)."""
        import jax.numpy as jnp

        views, version = self.acquire()
        try:
            leaves = [jnp.array(v) for v in views]
        finally:
            self.release(version)
        return jax.tree_util.tree_unflatten(self._treedef, leaves), version

    def wait_for(self, version: int, timeout: Optional[float] = None) -> bool:
        with self._cond:
            return self._cond.wait_for(
                lambda: self._version.value >= version, timeout=timeout
            )

    def close(self) -> None:
        self._bufs = None
        for s in self._shms:
            _quiet_close(s)
