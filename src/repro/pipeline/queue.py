"""Bounded trajectory queue between the actor and the learner.

A thin wrapper over ``queue.Queue`` with the two properties the pipeline
needs beyond the stdlib:

* **backpressure accounting** — the cumulative time the producer (actor)
  spent blocked on a full queue and the consumer (learner) spent blocked on
  an empty one. These are exactly the paper-Fig.2 style "who is on the
  critical path" numbers the ``fig2_time_split`` benchmark reports for the
  pipelined backend.
* **never drops** — depth bounds memory (at most ``depth`` rollouts in
  flight) by blocking the actor, not by discarding trajectories; every
  collected rollout is learned from exactly once.

``close()`` wakes a blocked consumer with a ``Closed`` sentinel so the
learner can drain remaining items and exit cleanly.
"""
from __future__ import annotations

import queue as _queue
import time
from typing import Any, Optional


class Closed:
    """Sentinel delivered to a consumer after ``close()`` drains."""


CLOSED = Closed()


class TrajectoryQueue:
    """Bounded FIFO of rollout payloads with idle-time accounting."""

    def __init__(self, depth: int = 2):
        if depth < 1:
            raise ValueError(f"queue depth must be >= 1, got {depth}")
        self.depth = depth
        self._q: _queue.Queue = _queue.Queue(maxsize=depth)
        self._closed = False
        self.put_wait_s = 0.0  # actor idle (queue full)
        self.get_wait_s = 0.0  # learner idle (queue empty)

    def put(self, item: Any, timeout: Optional[float] = None) -> None:
        """Blocking put; accumulates the time spent waiting on a full queue.
        Raises stdlib ``queue.Full`` when ``timeout`` elapses."""
        if self._closed:
            raise RuntimeError("put() on a closed TrajectoryQueue")
        t0 = time.perf_counter()
        try:
            self._q.put(item, timeout=timeout)
        finally:
            self.put_wait_s += time.perf_counter() - t0

    def get(self, timeout: Optional[float] = None) -> Any:
        """Blocking get; returns ``CLOSED`` once closed and drained.
        Raises stdlib ``queue.Empty`` when ``timeout`` elapses first."""
        t0 = time.perf_counter()
        deadline = None if timeout is None else t0 + timeout
        try:
            while True:
                # poll in small slices: ``close()`` never blocks, so the
                # sentinel may be the flag alone rather than a queued item
                try:
                    return self._q.get(timeout=0.05)
                except _queue.Empty:
                    if self._closed:
                        return CLOSED
                    if deadline is not None and time.perf_counter() >= deadline:
                        raise
        finally:
            self.get_wait_s += time.perf_counter() - t0

    def close(self) -> None:
        """Mark the stream finished; the consumer sees ``CLOSED`` after the
        remaining items. Never blocks (the flag covers a full queue).
        Idempotent."""
        if not self._closed:
            self._closed = True
            try:
                self._q.put_nowait(CLOSED)
            except _queue.Full:
                pass  # consumer drains, then sees the flag

    def qsize(self) -> int:
        return self._q.qsize()
