"""Bounded trajectory queue between N actor replicas and the learner.

This is the pipeline's *host queue plane*: payloads are host (numpy) arrays
— rollouts born on the host (``HostEnvPool``) in reusable staging sets, or
JAX rollouts deliberately staged down for the GA3C-style baseline. Its
device-plane twin, ``repro.pipeline.ring.DeviceTrajectoryRing``, shares
this class's exact put/get/shutdown surface so the orchestrator and
``ActorThread`` drive either interchangeably.

A condition-variable FIFO with the properties the pipeline needs beyond the
stdlib ``queue.Queue``:

* **backpressure accounting** — the cumulative time producers (actors) spent
  blocked on a full queue (merged across all of them) and the consumer
  (learner) spent blocked on an empty one: the paper-Fig.2 style "who is on
  the critical path" numbers, observable on the bare queue. Since PR 6 the
  numbers are *derived from telemetry spans*: every ``put``/``get`` records
  a ``queue.put_wait``/``queue.get_wait`` span into the queue's
  ``repro.telemetry.SpanEmitter`` (its merged aggregate track), and
  ``put_wait_s``/``get_wait_s`` read the emitter's per-category totals —
  the identical float accumulation the old ad-hoc counters performed, so
  the semantics (full call duration, accumulated per call in call order)
  are unchanged. The pipeline's per-actor attribution
  (``RunResult.per_actor_idle_s``) is accounted by each ``ActorThread``
  around its own puts; ``get_wait_s`` here is the learner-idle figure the
  benchmarks report.
* **never drops** — depth bounds memory (at most ``depth`` rollouts in
  flight) by blocking producers, not by discarding trajectories; every
  collected rollout is learned from exactly once.
* **multi-producer shutdown** — with ``producers=N``, each actor calls
  ``producer_done()`` when it finishes its quota; the stream closes only
  after the last one, so one actor finishing early never cuts off the
  others. ``close()`` is the hard abort (an actor crashed, or the learner is
  bailing out): it wakes *everyone* immediately — a producer blocked in
  ``put()`` raises ``QueueClosed`` promptly instead of hanging until its
  timeout, and the consumer sees ``CLOSED`` after draining.
"""
from __future__ import annotations

import queue as _queue
import threading
import time
from collections import deque
from typing import Any, Optional

from repro.analysis.lockcheck import make_condition
from repro.telemetry.spans import QUEUE_GET_WAIT, QUEUE_PUT_WAIT, SpanEmitter


def _assert_host_payload(item: Any) -> None:
    """Reject mesh-sharded (multi-device) array leaves on the host plane.

    Single-device jax arrays pass (the forced-host baseline stages them down
    explicitly, and ``np.asarray`` on one device is the intended D2H copy);
    a leaf spanning several devices means a ``MeshTrajectoryRing`` payload
    leaked onto the ``TrajectoryQueue`` — raise with the routing fix named.
    """
    import jax

    for leaf in jax.tree_util.tree_leaves(item):
        if isinstance(leaf, jax.Array) and len(leaf.devices()) > 1:
            raise TypeError(
                "TrajectoryQueue (host plane) got an array leaf sharded "
                f"over {len(leaf.devices())} devices — a mesh-plane rollout "
                "leaked to the host queue. Mesh rollouts must stay on the "
                "MeshTrajectoryRing (rollout_plane='mesh'); the host plane "
                "carries numpy/single-device payloads only."
            )


class Closed:
    """Sentinel delivered to a consumer after the stream closes and drains."""


CLOSED = Closed()


class QueueClosed(RuntimeError):
    """Raised by ``put()`` on a closed queue — including a put that was
    already blocked when ``close()`` landed."""


class TrajectoryQueue:
    """Bounded FIFO of rollout payloads with idle-time accounting."""

    def __init__(self, depth: int = 2, producers: int = 1, telemetry=None,
                 name: str = "queue"):
        if depth < 1:
            raise ValueError(f"queue depth must be >= 1, got {depth}")
        if producers < 1:
            raise ValueError(f"producers must be >= 1, got {producers}")
        self.depth = depth
        self._items: deque = deque()
        self._cond = make_condition("queue.cond")
        self._producers_left = producers
        self._closed = False
        # the queue's aggregate span track: put spans land here from every
        # producer thread (hence locked), get spans from the consumer.
        # `telemetry` (a repro.telemetry.Telemetry hub) registers the track
        # for trace export; a bare queue gets a private unregistered emitter
        # so put_wait_s/get_wait_s work standalone.
        if telemetry is not None:
            self.span_emitter = telemetry.emitter(name, locked=True)
        else:
            self.span_emitter = SpanEmitter(name, locked=True)
        self._validated: Any = None  # last payload to pass the plane check
        # lifetime ticket counters (monotone; survive close): checkpoint
        # metadata records them so a resume can audit how many in-flight
        # payloads the interruption dropped
        self._tickets_issued = 0  # payloads ever accepted by put()
        self._tickets_consumed = 0  # payloads ever handed out by get()

    @property
    def put_wait_s(self) -> float:
        """Producers idle (queue full), all actors merged — span-derived."""
        return self.span_emitter.total(QUEUE_PUT_WAIT)

    @property
    def get_wait_s(self) -> float:
        """Learner idle (queue empty) — span-derived."""
        return self.span_emitter.total(QUEUE_GET_WAIT)

    # hot-path
    def put(self, item: Any, timeout: Optional[float] = None) -> None:
        """Blocking put; accumulates the time spent waiting on a full queue.

        Raises ``QueueClosed`` if the queue is (or becomes, while blocked)
        closed, and stdlib ``queue.Full`` when ``timeout`` elapses first.
        Raises ``TypeError`` for payloads carrying *multi-device* (sharded)
        array leaves: a mesh-plane rollout on the host queue is always a
        plumbing bug — consuming it would force a cross-device gather plus
        the host round trip both device planes exist to avoid — so it is
        rejected loudly at the boundary (the ``validate_picklable`` idiom)
        instead of surfacing as a slow, mysterious ``np.asarray`` deep in
        the learner.
        """
        # actors retry a blocked put with short timeouts; the payload is
        # unchanged across retries, so don't re-walk its tree every 0.1 s
        if item is not self._validated:
            _assert_host_payload(item)
            self._validated = item
        t0 = time.perf_counter()
        try:
            with self._cond:
                ok = self._cond.wait_for(
                    lambda: self._closed or len(self._items) < self.depth,
                    timeout=timeout,
                )
                if self._closed:
                    raise QueueClosed("put() on a closed TrajectoryQueue")
                if not ok:
                    raise _queue.Full
                self._items.append(item)
                self._tickets_issued += 1
                self._cond.notify_all()
            # cache only spans the Full-retry loop — don't retain a
            # reference to a payload the consumer may since have released
            self._validated = None
        finally:
            self.span_emitter.record(QUEUE_PUT_WAIT, t0)

    # hot-path
    def get(self, timeout: Optional[float] = None) -> Any:
        """Blocking get; returns ``CLOSED`` once closed and drained.
        Raises stdlib ``queue.Empty`` when ``timeout`` elapses first."""
        t0 = time.perf_counter()
        try:
            with self._cond:
                if not self._cond.wait_for(
                    lambda: self._items or self._closed, timeout=timeout
                ):
                    raise _queue.Empty
                if self._items:
                    item = self._items.popleft()
                    self._tickets_consumed += 1
                    self._cond.notify_all()
                    return item
                return CLOSED
        finally:
            self.span_emitter.record(QUEUE_GET_WAIT, t0)

    def producer_done(self) -> None:
        """One producer finished its quota; closes the stream when the last
        producer checks out (the consumer drains, then sees ``CLOSED``)."""
        with self._cond:
            self._producers_left -= 1
            if self._producers_left <= 0:
                self._closed = True
            self._cond.notify_all()

    def close(self) -> None:
        """Hard abort: mark the stream finished *now*, regardless of how many
        producers remain. Wakes blocked producers (``QueueClosed``) and the
        consumer (``CLOSED`` after the remaining items). Never blocks;
        idempotent."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()

    def qsize(self) -> int:
        with self._cond:
            return len(self._items)

    @property
    def tickets_issued(self) -> int:
        """Payloads ever accepted (monotone — the device ring's idiom)."""
        with self._cond:
            return self._tickets_issued

    @property
    def tickets_consumed(self) -> int:
        """Payloads ever delivered to the consumer (monotone)."""
        with self._cond:
            return self._tickets_consumed
