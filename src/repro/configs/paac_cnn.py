"""The paper's own policy/value networks (§5.1).

``paac_nips``   — A3C-FF network (Mnih et al. 2013 adapted to actor-critic).
``paac_nature`` — Mnih et al. 2015 (Nature DQN) adaptation.
Both consume (84, 84, 4) stacked frames and emit softmax policy + value.
``paac_vector`` — tiny MLP trunk for vector-observation envs (tests/examples).
"""
from repro.configs.base import ArchConfig, register


@register("paac_nips")
def paac_nips() -> ArchConfig:
    return ArchConfig(
        name="paac_nips",
        family="cnn",
        source="paper §5.1 (Mnih et al. 2013 arch, actor-critic heads)",
        cnn_spec=((16, 8, 4), (32, 4, 2)),
        cnn_dense=256,
        d_model=256,
        obs_shape=(84, 84, 4),
        num_actions=6,
        num_layers=2,
        param_dtype="float32",
        compute_dtype="float32",
        remat="none",
    )


@register("paac_nature")
def paac_nature() -> ArchConfig:
    return ArchConfig(
        name="paac_nature",
        family="cnn",
        source="paper §5.1 (Mnih et al. 2015 arch, actor-critic heads)",
        cnn_spec=((32, 8, 4), (64, 4, 2), (64, 3, 1)),
        cnn_dense=512,
        d_model=512,
        obs_shape=(84, 84, 4),
        num_actions=6,
        num_layers=3,
        param_dtype="float32",
        compute_dtype="float32",
        remat="none",
    )


@register("paac_vector")
def paac_vector() -> ArchConfig:
    return ArchConfig(
        name="paac_vector",
        family="cnn",
        source="framework-native MLP policy for vector envs",
        cnn_spec=(),
        cnn_dense=128,
        d_model=128,
        obs_shape=(8,),
        num_actions=4,
        num_layers=1,
        param_dtype="float32",
        compute_dtype="float32",
        remat="none",
    )
