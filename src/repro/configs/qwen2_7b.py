"""Qwen2-7B — dense decoder, GQA kv=4, QKV bias.

[arXiv:2407.10671] 28L, d_model 3584, 28 heads, d_ff 18944, vocab 152064.
"""
from repro.configs.base import ArchConfig, register


@register("qwen2-7b")
def qwen2_7b() -> ArchConfig:
    return ArchConfig(
        name="qwen2-7b",
        family="dense",
        source="arXiv:2407.10671",
        num_layers=28,
        d_model=3584,
        vocab_size=152064,
        attention="gqa",
        num_heads=28,
        num_kv_heads=4,
        head_dim=128,
        qkv_bias=True,
        d_ff=18944,
        supports_long_context=True,
        remat="full",
    )
