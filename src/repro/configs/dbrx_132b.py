"""DBRX-132B — fine-grained MoE: 16 experts, top-4.

[hf:databricks/dbrx-base] 40L, d_model 6144, 48 heads (GQA kv=8),
per-expert d_ff 10752, vocab 100352.
"""
from repro.configs.base import ArchConfig, register


@register("dbrx-132b")
def dbrx_132b() -> ArchConfig:
    return ArchConfig(
        name="dbrx-132b",
        family="moe",
        source="hf:databricks/dbrx-base",
        num_layers=40,
        d_model=6144,
        vocab_size=100352,
        attention="gqa",
        num_heads=48,
        num_kv_heads=8,
        head_dim=128,
        d_ff=10752,
        num_experts=16,
        num_experts_per_tok=4,
        moe_d_ff=10752,
        supports_long_context=True,
        remat="full",
    )
