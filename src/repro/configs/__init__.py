"""Architecture registry — importing this package registers all configs."""
from repro.configs.base import (
    INPUT_SHAPES,
    ArchConfig,
    InputShape,
    PipelineConfig,
    get_config,
    list_archs,
)

# registration side-effects
from repro.configs import (  # noqa: F401
    dbrx_132b,
    deepseek_coder_33b,
    deepseek_v2_236b,
    glm4_9b,
    mamba2_370m,
    minicpm3_4b,
    paac_cnn,
    pixtral_12b,
    qwen2_7b,
    seamless_m4t_large_v2,
    zamba2_7b,
)

ASSIGNED_ARCHS = [
    "minicpm3-4b",
    "glm4-9b",
    "deepseek-v2-236b",
    "seamless-m4t-large-v2",
    "deepseek-coder-33b",
    "dbrx-132b",
    "qwen2-7b",
    "zamba2-7b",
    "pixtral-12b",
    "mamba2-370m",
]

__all__ = [
    "ArchConfig",
    "InputShape",
    "PipelineConfig",
    "INPUT_SHAPES",
    "get_config",
    "list_archs",
    "ASSIGNED_ARCHS",
]
