"""Mamba2-370M — attention-free SSM via State-Space Duality.

[arXiv:2405.21060] 48L, d_model 1024, ssm_state 128, vocab 50280, no MLP
(d_ff 0). Natively O(1) decode state: runs long_500k.
"""
from repro.configs.base import ArchConfig, register


@register("mamba2-370m")
def mamba2_370m() -> ArchConfig:
    return ArchConfig(
        name="mamba2-370m",
        family="ssm",
        source="arXiv:2405.21060",
        num_layers=48,
        d_model=1024,
        vocab_size=50280,
        attention="none",
        num_heads=0,
        num_kv_heads=0,
        head_dim=0,
        d_ff=0,
        mlp="none",
        ssm_state=128,
        ssm_conv=4,
        ssm_head_dim=64,
        ssm_expand=2,
        ssm_chunk=128,
        supports_long_context=True,
        remat="full",
    )
