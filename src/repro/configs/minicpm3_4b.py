"""MiniCPM3-4B — dense decoder with Multi-head Latent Attention.

[hf:openbmb/MiniCPM3-4B] 62L, d_model 2560, 40 heads (kv=40 via MLA),
d_ff 6400, vocab 73448. MLA ranks: q_lora 768, kv_lora 256,
qk_nope 64, qk_rope 32, v_head 64.
"""
from repro.configs.base import ArchConfig, register


@register("minicpm3-4b")
def minicpm3_4b() -> ArchConfig:
    return ArchConfig(
        name="minicpm3-4b",
        family="dense",
        source="hf:openbmb/MiniCPM3-4B",
        num_layers=62,
        d_model=2560,
        vocab_size=73448,
        attention="mla",
        num_heads=40,
        num_kv_heads=40,
        head_dim=64,
        q_lora_rank=768,
        kv_lora_rank=256,
        qk_nope_dim=64,
        qk_rope_dim=32,
        v_head_dim=64,
        d_ff=6400,
        supports_long_context=True,  # via sliding-window variant (long_500k)
        remat="full",
    )
