"""DeepSeek-Coder-33B — dense llama-architecture decoder.

[arXiv:2401.14196] 62L, d_model 7168, 56 heads (GQA kv=8), d_ff 19200,
vocab 32256.
"""
from repro.configs.base import ArchConfig, register


@register("deepseek-coder-33b")
def deepseek_coder_33b() -> ArchConfig:
    return ArchConfig(
        name="deepseek-coder-33b",
        family="dense",
        source="arXiv:2401.14196",
        num_layers=62,
        d_model=7168,
        vocab_size=32256,
        attention="gqa",
        num_heads=56,
        num_kv_heads=8,
        head_dim=128,
        d_ff=19200,
        supports_long_context=True,
        remat="full",
    )
