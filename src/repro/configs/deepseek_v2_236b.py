"""DeepSeek-V2 236B — MoE (160 routed experts top-6, 2 shared) with MLA.

[arXiv:2405.04434] 60L, d_model 5120, 128 heads, vocab 102400.
MLA: kv_lora 512, q_lora 1536, qk_nope 128, qk_rope 64, v_head 128.
MoE: per-expert d_ff 1536, first layer dense (d_ff 12288).
"""
from repro.configs.base import ArchConfig, register


@register("deepseek-v2-236b")
def deepseek_v2_236b() -> ArchConfig:
    return ArchConfig(
        name="deepseek-v2-236b",
        family="moe",
        source="arXiv:2405.04434",
        num_layers=60,
        d_model=5120,
        vocab_size=102400,
        attention="mla",
        num_heads=128,
        num_kv_heads=128,
        head_dim=128,
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_dim=128,
        qk_rope_dim=64,
        v_head_dim=128,
        d_ff=1536,
        num_experts=160,
        num_experts_per_tok=6,
        num_shared_experts=2,
        moe_d_ff=1536,
        first_dense_layers=1,
        dense_d_ff=12288,
        supports_long_context=True,
        remat="full",
    )
