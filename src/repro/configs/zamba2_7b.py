"""Zamba2-7B — hybrid: Mamba2 trunk + one shared attention+MLP block.

[arXiv:2411.15242] 81 Mamba2 layers, d_model 3584, ssm_state 64; a single
shared attention block (32 heads) + MLP (d_ff 14336) applied every 6 Mamba2
layers (one weight copy, per-application KV cache). vocab 32000.

Natively sub-quadratic: runs long_500k (O(1) SSM state; the shared
attention applications use the sliding-window cache there).
"""
from repro.configs.base import ArchConfig, register


@register("zamba2-7b")
def zamba2_7b() -> ArchConfig:
    return ArchConfig(
        name="zamba2-7b",
        family="hybrid",
        source="arXiv:2411.15242",
        num_layers=81,
        d_model=3584,
        vocab_size=32000,
        attention="gqa",
        num_heads=32,
        num_kv_heads=32,
        head_dim=112,
        d_ff=14336,
        ssm_state=64,
        ssm_conv=4,
        ssm_head_dim=64,
        ssm_expand=2,
        ssm_chunk=128,
        shared_attn_every=6,
        supports_long_context=True,
        remat="full",
    )
