"""SeamlessM4T-large v2 — encoder-decoder speech/text transformer backbone.

[arXiv:2308.11596] 24L enc + 24L dec, d_model 1024, 16 heads, d_ff 8192,
vocab 256206. The mel-spectrogram + conformer speech front-end is a STUB:
``input_specs()`` provides precomputed frame embeddings (B, S_enc, 1024)
consumed through a linear adapter (the carve-out allowed by the spec).

long_500k: SKIPPED — an encoder-decoder speech model has no 500k-token
autoregressive decode; see DESIGN.md §4.
"""
from repro.configs.base import ArchConfig, register


@register("seamless-m4t-large-v2")
def seamless_m4t_large_v2() -> ArchConfig:
    return ArchConfig(
        name="seamless-m4t-large-v2",
        family="audio",
        source="arXiv:2308.11596",
        num_layers=24,
        d_model=1024,
        vocab_size=256206,
        attention="gqa",
        num_heads=16,
        num_kv_heads=16,
        head_dim=64,
        d_ff=8192,
        mlp="gelu",
        is_encoder_decoder=True,
        encoder_layers=24,
        encoder_seq_len=1024,
        modality="audio",
        frontend_dim=1024,
        supports_long_context=False,
        remat="full",
    )
