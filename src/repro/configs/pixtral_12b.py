"""Pixtral-12B — VLM: Pixtral-ViT front-end (STUB) + Mistral-NeMo decoder.

[hf:mistralai/Pixtral-12B-2409] 40L, d_model 5120, 32 heads (GQA kv=8),
d_ff 14336, vocab 131072. The ViT is a stub: ``input_specs()`` provides
precomputed patch embeddings (B, 1024, 1024) fed through the multimodal
projector; the language decoder is implemented in full.
"""
from repro.configs.base import ArchConfig, register


@register("pixtral-12b")
def pixtral_12b() -> ArchConfig:
    return ArchConfig(
        name="pixtral-12b",
        family="vlm",
        source="hf:mistralai/Pixtral-12B-2409",
        num_layers=40,
        d_model=5120,
        vocab_size=131072,
        attention="gqa",
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        modality="vision",
        prefix_len=1024,
        frontend_dim=1024,
        supports_long_context=True,
        remat="full",
    )
