"""Architecture configuration system.

Every selectable architecture (``--arch <id>``) is an ``ArchConfig``. The ten
assigned architectures live in one file each under ``repro/configs``; the
paper's own CNN policy networks are ``paac_nips`` / ``paac_nature``.

Each config also exposes a ``reduced()`` variant (<=2 layers, d_model<=512,
<=4 experts) used by the per-arch CPU smoke tests, and the full variant is
exercised only through the multi-pod dry-run (ShapeDtypeStruct lowering, no
allocation).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Tuple

# ---------------------------------------------------------------------------
# Input shapes (assigned, global — see system spec)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Pipeline (asynchronous actor/learner) config — repro.pipeline
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PipelineConfig:
    """Knobs for the asynchronous actor/learner pipeline (``repro.pipeline``).

    ``num_actors`` is the number of actor replicas feeding the learner
    (GA3C's n_actors sweep): a single env handed to ``PipelinedRL`` is split
    along the env axis into ``num_actors`` equal shards, or a list of envs
    gives each replica its own pool. ``queue_depth`` bounds the shared
    trajectory queue: depth d lets the actors collectively run at most d
    rollouts ahead (depth 1 is classic double buffering — rollout i+1 is
    collected while rollout i is consumed). ``rho_bar`` and ``c_bar`` are the
    V-trace clips (Espeholt et al. 2018) on the importance ratio
    ρ_t = π_learner(a|s)/π_behaviour(a|s): ρ̄ bounds each step's TD-error
    correction, c̄ bounds the product that propagates corrections backwards
    through the n-step targets — what keeps queues deeper than 2 unbiased.
    ``float("inf")`` for both disables the correction exactly (the
    synchronous PAAC update, bit-for-bit). ``lockstep`` forces the (single)
    actor to wait for the learner's latest params before each rollout —
    synchronous semantics through the pipelined code path (used by
    equivalence tests); it requires ``num_actors == 1``.

    ``rollout_plane`` selects the queue plane carrying trajectories from the
    actors to the learner:

    * ``"device"`` — ``DeviceTrajectoryRing``: payloads stay on the
      accelerator end to end and the learner step donates them (the fast
      path; JAX-native envs only),
    * ``"host"`` — ``TrajectoryQueue``: payloads are host numpy arrays in
      reusable staging buffers, uploaded when the learner dispatches (the
      only option for ``HostEnvPool``, whose rollouts are born on the host;
      for JAX-native envs it is the GA3C-style baseline the benchmarks
      compare against),
    * ``"mesh"`` — ``MeshTrajectoryRing``: the device plane scaled across a
      1-axis ``("data",)`` device mesh (see ``mesh_shape`` below); with
      ``mesh_shape=1`` it is the device ring routed through the mesh
      machinery on one device — the configuration the bitwise mesh=1
      lockstep test pins against the flat device plane,
    * ``"auto"`` (default) — mesh ring when ``mesh_shape > 1``, else device
      ring for JAX-native envs, host queue for ``HostEnvPool``.

    ``actor_backend`` selects where the actor replicas *execute*:

    * ``"thread"`` (default) — replicas are threads in this process. Right
      whenever env stepping releases the GIL (JAX-native envs, C/C++
      emulators behind thin bindings) — collection overlaps the learner's
      jitted update for free.
    * ``"process"`` — each replica is a worker subprocess owning a private
      env pool rebuilt from a picklable ``repro.envs.HostEnvSpec`` (live
      pools cannot cross the boundary). Rollouts ride
      ``multiprocessing.shared_memory`` staging sets into the parent's
      ``TrajectoryQueue`` and params broadcast back through a shared-memory
      ping-pong slot. This is the only backend that scales *GIL-holding*
      Python emulators (ALE-style wrappers, pure-Python simulators), whose
      env stepping serializes the thread plane no matter how many replicas
      run; it implies the host rollout plane.

    ``mesh_shape`` scales the device plane across accelerators:
    ``mesh_shape=D > 1`` builds a 1-axis ``("data",)`` ``jax.sharding.Mesh``
    over the first ``D`` devices and partitions the env/batch axis of every
    rollout over it — one actor lane per device feeds a per-device sub-ring
    (``MeshTrajectoryRing``), the learner consumes a globally-sharded batch
    (one sub-rollout from *every* lane per update) and its gradients
    all-reduce across the data axis (Stooke & Abbeel 2018's multi-GPU
    synchronous regime). CPU CI exercises it via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.

    **Valid combinations** (the plane matrix — everything else raises
    ``ValueError`` here or in ``PipelinedRL``):

    ====================  ===============  ==========================
    actor_backend         rollout_plane    mesh_shape
    ====================  ===============  ==========================
    thread, JAX env       auto/mesh        1 or D (mesh plane)
    thread, JAX env       auto/device      1 only (flat device ring)
    thread, JAX env       host             1 only (GA3C baseline)
    thread, HostEnvPool   auto/host        1 only (host plane)
    process, HostEnvSpec  auto/host        1 only (host plane; a
                                           device plane would require
                                           rollouts born on-device)
    ====================  ===============  ==========================

    In particular: the process backend *forces* the host plane (its
    rollouts are born in worker shared memory), so a device/mesh plane or
    ``mesh_shape > 1`` with ``actor_backend="process"`` is a contradiction
    and raises immediately; ``mesh_shape > 1`` likewise rejects
    ``rollout_plane="host"`` (mesh payloads are device-resident by
    construction — a sharded rollout on the host queue would force a
    cross-device gather) and ``rollout_plane="device"`` (the flat
    single-device ring cannot carry more than one lane — say ``"mesh"`` or
    ``"auto"``). ``lockstep`` requires a single actor *stream*:
    ``num_actors == 1``, or the mesh plane (whose lanes are consumed in
    lockstep sets anyway — one sub-rollout per lane per update).

    **Replay plane** (``replay_plane=True``): the trajectory stream becomes a
    sampled ``ReplayRing`` instead of a FIFO ring — actors *never block* on
    the learner (a full ring evicts its oldest rollout), sampled slots are
    *retained* for reuse, and each learner update draws ``replay_batch``
    resident rollouts (uniformly, or TD-error-weighted with
    ``prioritized=True``). This is the off-policy plane: it drives
    ``DQNAgent`` (whose TD target needs no staleness correction) and
    off-policy PAAC/PPO (V-trace clips correct the sampled rollouts'
    staleness ≫ 1). Replay payloads are device-resident whole rollouts, so
    the plane requires JAX-native envs with ``actor_backend="thread"``,
    ``rollout_plane`` of ``"auto"``/``"device"`` and ``mesh_shape == 1``;
    ``prioritized``/``replay_capacity``/``replay_batch`` in turn require
    ``replay_plane=True`` (they have no FIFO meaning). ``replay_capacity``
    counts resident *rollouts* (each ``t_max × shard_envs`` transitions),
    ``replay_batch`` is rollouts sampled per update.

    **Fault tolerance** (``repro.pipeline.supervisor``; see
    ``docs/fault_tolerance.md``): ``elastic=True`` arms the
    ``ActorSupervisor`` — a dying actor replica no longer hard-aborts the
    stream. Under ``restart_budget`` respawns per actor *slot* (exponential
    backoff from ``restart_backoff_s``) the dead replica is respawned with
    a fresh ``(actor_id, seq)`` epoch and re-leased the current params;
    past the budget (or with ``restart_budget=0``) its remaining quota is
    reassigned to the surviving replicas and the run degrades to fewer
    actors instead of aborting. ``elastic=False`` (default) is the
    pre-supervisor fail-fast path, bit-for-bit: any replica death closes
    the stream and ``run()`` raises. The mesh plane stays fail-fast
    regardless (a lane's death leaves the sharded batch unassemblable), so
    ``elastic`` with the mesh plane is rejected here. ``lease_timeout_s``
    bounds how long the learner waits to reserve a ping-pong buffer before
    failing loudly — the error names the party still holding the lease.
    ``fault_plan`` (a ``repro.pipeline.faults.FaultPlan``) deterministically
    injects faults for tests/CI; ``checkpoint_dir``/``checkpoint_every``
    snapshot full pipeline state (params, opt state, RNG keys, per-actor
    seq/quota counters, ring tickets) every N learner iterations for
    ``PipelinedRL.restore()`` kill-and-resume.
    """

    queue_depth: int = 2
    rho_bar: float = 1.0
    c_bar: float = 1.0
    num_actors: int = 1
    lockstep: bool = False
    rollout_plane: str = "auto"  # "auto" | "device" | "host" | "mesh"
    actor_backend: str = "thread"  # "thread" | "process"
    mesh_shape: int = 1  # devices on the ("data",) rollout mesh
    # off-policy replay plane (sampled ReplayRing instead of the FIFO ring)
    replay_plane: bool = False
    replay_capacity: int = 64  # resident rollouts before FIFO eviction
    replay_batch: int = 1  # rollouts sampled per learner update
    prioritized: bool = False  # TD-error-weighted sampling (else uniform)
    # observability (repro.telemetry; see docs/observability.md). Span
    # recording itself is always on — it *is* the RunResult idle accounting;
    # these knobs control the exports and the observer threads:
    trace_path: str = ""  # "" -> no Chrome trace written at run end
    metrics_jsonl: str = ""  # "" -> no JSONL heartbeat stream
    heartbeat_s: float = 1.0  # heartbeat tick interval
    stall_timeout_s: float = 0.0  # 0 -> stall watchdog off
    # fault tolerance (repro.pipeline.supervisor; docs/fault_tolerance.md)
    elastic: bool = False  # False -> pre-supervisor fail-fast, bit-for-bit
    restart_budget: int = 1  # respawns per actor slot before degrading
    restart_backoff_s: float = 0.05  # base of the exponential respawn backoff
    lease_timeout_s: float = 60.0  # param-slot reserve/publish deadline
    # a repro.pipeline.faults.FaultPlan (typed loosely: configs must stay
    # importable without pulling the pipeline package in — and FaultPlan
    # imports nothing back, so the runtime isinstance check lives in
    # PipelinedRL, not here)
    fault_plan: Optional[object] = None
    checkpoint_dir: str = ""  # "" -> periodic checkpointing off
    checkpoint_every: int = 0  # learner iterations between snapshots (0=off)

    def __post_init__(self):
        if self.mesh_shape < 1:
            raise ValueError(f"mesh_shape must be >= 1, got {self.mesh_shape}")
        if self.heartbeat_s <= 0:
            raise ValueError(
                f"heartbeat_s must be > 0, got {self.heartbeat_s}")
        if self.stall_timeout_s < 0:
            raise ValueError(
                f"stall_timeout_s must be >= 0 (0 = off), got "
                f"{self.stall_timeout_s}")
        if self.mesh_shape > 1:
            if self.actor_backend == "process":
                raise ValueError(
                    "mesh_shape > 1 requires actor_backend='thread': process"
                    " rollouts are born in host shared memory and cannot ride"
                    " the device-resident mesh plane"
                )
            if self.rollout_plane in ("host", "device"):
                raise ValueError(
                    f"mesh_shape={self.mesh_shape} requires rollout_plane="
                    "'auto' or 'mesh': the host TrajectoryQueue cannot carry"
                    " a sharded rollout, and the flat single-device ring"
                    " cannot carry more than one lane"
                )
            if self.num_actors not in (1, self.mesh_shape):
                raise ValueError(
                    "the mesh plane runs exactly one actor lane per mesh"
                    f" device: num_actors must be 1 (auto) or mesh_shape"
                    f"={self.mesh_shape}, got {self.num_actors}"
                )
        if self.actor_backend == "process" and self.rollout_plane in (
                "device", "mesh"):
            raise ValueError(
                "actor_backend='process' forces the host rollout plane"
                " (worker rollouts are born in shared memory); rollout_plane"
                f"={self.rollout_plane!r} is a contradiction"
            )
        if self.replay_capacity < 1:
            raise ValueError(
                f"replay_capacity must be >= 1, got {self.replay_capacity}")
        if self.replay_batch < 1:
            raise ValueError(
                f"replay_batch must be >= 1, got {self.replay_batch}")
        if self.replay_plane:
            if self.actor_backend == "process":
                raise ValueError(
                    "replay_plane requires actor_backend='thread': replay"
                    " payloads are device-resident whole rollouts and cannot"
                    " ride the process backend's shared-memory staging"
                )
            if self.mesh_shape > 1 or self.rollout_plane == "mesh":
                raise ValueError(
                    "replay_plane does not compose with the mesh plane yet:"
                    " a sampled batch would have to draw one sub-rollout per"
                    " lane coherently; use mesh_shape=1"
                )
            if self.rollout_plane == "host":
                raise ValueError(
                    "replay_plane requires the device plane (rollout_plane"
                    " 'auto' or 'device'): the ReplayRing retains sampled"
                    " slots on the accelerator, which the host TrajectoryQueue"
                    " staging buffers cannot do"
                )
        elif self.prioritized:
            raise ValueError(
                "prioritized=True requires replay_plane=True: FIFO rings"
                " consume each rollout exactly once, so sampling priorities"
                " have no meaning there"
            )
        if self.restart_budget < 0:
            raise ValueError(
                f"restart_budget must be >= 0, got {self.restart_budget}")
        if self.restart_backoff_s < 0:
            raise ValueError(
                f"restart_backoff_s must be >= 0, got "
                f"{self.restart_backoff_s}")
        if self.lease_timeout_s <= 0:
            raise ValueError(
                f"lease_timeout_s must be > 0, got {self.lease_timeout_s}")
        if self.checkpoint_every < 0:
            raise ValueError(
                f"checkpoint_every must be >= 0 (0 = off), got "
                f"{self.checkpoint_every}")
        if self.checkpoint_every > 0 and not self.checkpoint_dir:
            raise ValueError(
                "checkpoint_every > 0 requires checkpoint_dir: periodic"
                " snapshots need somewhere to land")
        if self.elastic and (self.mesh_shape > 1
                             or self.rollout_plane == "mesh"):
            raise ValueError(
                "elastic=True does not compose with the mesh plane: a dead"
                " lane leaves every subsequent sharded batch unassemblable,"
                " so the mesh plane stays fail-fast (see"
                " docs/fault_tolerance.md)"
            )


# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ArchConfig:
    """Configuration for one policy/value backbone.

    The PAAC framework is model agnostic (paper §3): every architecture gets
    the two-headed output of paper §4 — a softmax policy head and a linear
    value head — attached by ``repro.models.heads``.
    """

    name: str = "unnamed"
    family: str = "dense"  # dense | moe | ssm | hybrid | vlm | audio | cnn
    source: str = ""  # citation (hf:... / arXiv:...)

    # trunk
    num_layers: int = 2
    d_model: int = 256
    vocab_size: int = 1024

    # attention
    attention: str = "gqa"  # "gqa" | "mla" | "none"
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 64
    qkv_bias: bool = False
    rope_theta: float = 10_000.0

    # MLA (DeepSeek-V2 / MiniCPM3 style multi-head latent attention)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    mla_absorb: bool = False  # matmul-absorption decode path (perf variant)

    # feed-forward
    d_ff: int = 1024
    mlp: str = "swiglu"  # "swiglu" | "gelu" | "none"

    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0  # per-expert hidden dim (0 -> use d_ff)
    first_dense_layers: int = 0  # leading layers that use a dense FFN
    dense_d_ff: int = 0  # hidden dim of those dense layers
    router_aux_coef: float = 0.01
    moe_capacity_factor: float = 1.25
    moe_group_size: int = 4096  # routing group (sequence chunk) length

    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256

    # hybrid (Zamba2-style: shared attention block applied periodically)
    shared_attn_every: int = 0  # 0 -> no shared attention

    # encoder-decoder (Seamless-style)
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq_len: int = 1024  # stub front-end frames/patches

    # modality front-end stub
    modality: str = "text"  # text | audio | vision
    prefix_len: int = 0  # patch/frame embedding prefix length (vlm)
    frontend_dim: int = 0  # raw front-end embedding dim (0 -> d_model, no proj)

    # long-context variant
    sliding_window: int = 0  # 0 -> full causal attention
    supports_long_context: bool = False  # may run long_500k

    # numerics
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    cache_dtype: str = ""  # "" -> compute_dtype
    norm_eps: float = 1e-5

    # heads / RL
    num_actions: int = 0  # 0 -> action space == vocab (token actions)
    tie_policy_head: bool = False

    # cnn (paper's arch_nips / arch_nature)
    cnn_spec: Tuple[Tuple[int, int, int], ...] = ()  # (features, kernel, stride)
    cnn_dense: int = 0
    obs_shape: Tuple[int, ...] = ()

    # remat policy for the scanned trunk: "none"|"full"|"dots"
    remat: str = "dots"
    # sequence-shard attention over "model" when heads don't divide the axis
    # ("auto"), or never ("off" — the pre-optimization baseline)
    attn_seq_shard: str = "auto"

    def actions(self) -> int:
        return self.num_actions if self.num_actions > 0 else self.vocab_size

    def expert_ff(self) -> int:
        return self.moe_d_ff if self.moe_d_ff else self.d_ff

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # -- reduced variant for CPU smoke tests ---------------------------------
    def reduced(self) -> "ArchConfig":
        """Same family, tiny: <=2 layers, d_model<=512, <=4 experts."""
        kw = dict(
            num_layers=min(self.num_layers, 2),
            d_model=min(self.d_model, 256),
            vocab_size=min(self.vocab_size, 512),
            num_heads=min(self.num_heads, 4),
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads else 0,
            head_dim=min(self.head_dim, 64) if self.head_dim else 0,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            param_dtype="float32",
            compute_dtype="float32",
            remat="none",
        )
        if self.attention == "mla":
            kw.update(
                q_lora_rank=min(self.q_lora_rank, 64) if self.q_lora_rank else 0,
                kv_lora_rank=min(self.kv_lora_rank, 32),
                qk_nope_dim=min(self.qk_nope_dim, 32),
                qk_rope_dim=min(self.qk_rope_dim, 16),
                v_head_dim=min(self.v_head_dim, 32),
            )
        if self.num_experts:
            kw.update(
                num_experts=min(self.num_experts, 4),
                num_experts_per_tok=min(self.num_experts_per_tok, 2),
                num_shared_experts=min(self.num_shared_experts, 1),
                moe_d_ff=min(self.expert_ff(), 128),
                first_dense_layers=min(self.first_dense_layers, 1),
                dense_d_ff=min(self.dense_d_ff, 256) if self.dense_d_ff else 0,
            )
        if self.ssm_state:
            kw.update(ssm_state=min(self.ssm_state, 16), ssm_chunk=32)
        if self.shared_attn_every:
            kw.update(shared_attn_every=2, num_layers=2)
        if self.is_encoder_decoder:
            kw.update(encoder_layers=min(self.encoder_layers, 2), encoder_seq_len=16)
        if self.prefix_len:
            kw.update(prefix_len=8)
        if self.frontend_dim:
            kw.update(frontend_dim=min(self.frontend_dim, 64))
        if self.sliding_window:
            kw.update(sliding_window=64)
        if self.family == "cnn":
            dense = min(self.cnn_dense, 64)
            kw.update(cnn_spec=self.cnn_spec[:2], cnn_dense=dense, d_model=dense)
        return self.replace(**kw)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[[], ArchConfig]] = {}


def register(name: str):
    def deco(fn: Callable[[], ArchConfig]):
        _REGISTRY[name] = fn
        return fn

    return deco


def get_config(name: str) -> ArchConfig:
    # import side-effect registration
    import repro.configs  # noqa: F401

    if name not in _REGISTRY:
        raise KeyError(f"unknown arch '{name}'; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs():
    import repro.configs  # noqa: F401

    return sorted(_REGISTRY)
